//! Bench: hot-path microbenchmarks (the §Perf targets).
//!
//! * engine throughput per filter, scalar vs lane-batched (Mpixels/s
//!   through the functional netlist evaluator — the end-to-end bound of
//!   every hardware-model bench);
//! * window-generator overhead in isolation (scalar and lane traversal);
//! * coordinator scaling with worker count (inter-frame round-robin);
//! * intra-frame tiling: one 1080p frame sharded into row bands.
//!
//! Writes the machine-readable results to `BENCH_hotpath.json` at the
//! repository root (per-filter scalar/batched Mpix/s + tiled scaling),
//! so the perf trajectory is tracked across PRs.
//!
//! `cargo bench --bench hotpath`

use std::time::Duration;

use fpspatial::bench::timeit;
use fpspatial::coordinator::{
    run_frame_tiled, run_pipeline, synth_sequence, PipelineConfig, TileConfig,
};
use fpspatial::filters::{FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::util::json::{num, obj, s as jstr, Json};
use fpspatial::util::LANES;
use fpspatial::video::{Frame, WindowGenerator};

const FMT: FloatFormat = FloatFormat::new(10, 5);

/// The canonical DSL program suite (examples/dsl/) — benched through the
/// same engines as the built-ins they mirror.
const DSL_SUITE: [(&str, &str); 5] = [
    ("dsl:conv3x3", include_str!("../../examples/dsl/conv3x3.dsl")),
    ("dsl:conv5x5", include_str!("../../examples/dsl/conv5x5.dsl")),
    ("dsl:median", include_str!("../../examples/dsl/median.dsl")),
    ("dsl:nlfilter", include_str!("../../examples/dsl/nlfilter.dsl")),
    ("dsl:sobel", include_str!("../../examples/dsl/sobel.dsl")),
];

/// Measure one filter's scalar vs batched whole-frame throughput; returns
/// `(scalar_mpix, batched_mpix)`.
fn measure_engine(hw: &HwFilter, frame: &Frame, px: f64) -> (f64, f64) {
    let scalar = timeit(
        || {
            std::hint::black_box(hw.run_frame(frame, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    let batched = timeit(
        || {
            std::hint::black_box(hw.run_frame_batched(frame, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    (
        px / scalar.mean.as_secs_f64() / 1e6,
        px / batched.mean.as_secs_f64() / 1e6,
    )
}

fn main() {
    let frame = Frame::test_card(640, 480);
    let px = (frame.width * frame.height) as f64;

    println!("=== engine throughput (640x480 frame, exact mode, lanes = {LANES}) ===");
    let mut engine_json: Vec<(&str, Json)> = Vec::new();
    let mut two_x_count = 0;
    for kind in FilterKind::NETLIST {
        let hw = HwFilter::new(kind, FMT).unwrap();
        let (s_mpix, b_mpix) = measure_engine(&hw, &frame, px);
        let speedup = b_mpix / s_mpix;
        if speedup >= 2.0 {
            two_x_count += 1;
        }
        println!(
            "  {:<10} scalar {s_mpix:>7.2} Mpx/s | batched {b_mpix:>7.2} Mpx/s | {speedup:>5.2}x  ({} ops/pixel)",
            kind.name(),
            hw.netlist.nodes.len()
        );
        engine_json.push((
            kind.name(),
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("speedup", num(speedup)),
            ]),
        ));
    }
    println!(
        "  ({two_x_count}/{} filters at >= 2x batched speedup)",
        FilterKind::NETLIST.len()
    );

    // DSL-compiled programs through the identical hot path: rates should
    // track the built-in rows (same netlists, different front end).
    println!("\n=== DSL-compiled filters (HwFilter::from_dsl, same hot path) ===");
    for (name, src) in DSL_SUITE {
        let hw = HwFilter::from_dsl(src, name, None).unwrap();
        let (s_mpix, b_mpix) = measure_engine(&hw, &frame, px);
        println!(
            "  {name:<12} scalar {s_mpix:>7.2} Mpx/s | batched {b_mpix:>7.2} Mpx/s | {:>5.2}x  (lat {} cycles)",
            b_mpix / s_mpix,
            hw.latency()
        );
        engine_json.push((
            name,
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("speedup", num(b_mpix / s_mpix)),
            ]),
        ));
    }

    println!("\n=== window generator alone ===");
    let mut gen = WindowGenerator::new(3, frame.width);
    let scalar_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame(&frame, |_, _, w| acc += w[4]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    let lane_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame_lanes(&frame, |_, _, n, taps| acc += taps[4][n - 1]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    println!(
        "  3x3 scalar stream: {:>8.2} ms/frame  {:>7.2} Mpx/s",
        scalar_gen.mean.as_secs_f64() * 1e3,
        px / scalar_gen.mean.as_secs_f64() / 1e6
    );
    println!(
        "  3x3 lane stream  : {:>8.2} ms/frame  {:>7.2} Mpx/s",
        lane_gen.mean.as_secs_f64() * 1e3,
        px / lane_gen.mean.as_secs_f64() / 1e6
    );

    println!("\n=== coordinator scaling (median, 16 frames @ 320x240) ===");
    let frames = synth_sequence(320, 240, 16);
    let hw = HwFilter::new(FilterKind::Median, FMT).unwrap();
    for batched in [false, true] {
        for workers in [1usize, 2, 4, 8] {
            let cfg = PipelineConfig { workers, batched, ..Default::default() };
            let (_, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
            println!(
                "  {} {workers} worker(s): {:>7.2} FPS  ({:>6.1} Mpx/s)  p99 {:.2?}",
                if batched { "batched" } else { "scalar " },
                m.fps(),
                m.pixel_rate(320, 240) / 1e6,
                m.p99_latency
            );
        }
    }

    println!("\n=== intra-frame tiling (single 1080p frame, median) ===");
    let frame1080 = Frame::test_card(1920, 1080);
    let px1080 = (1920 * 1080) as f64;
    let mut tiled_json: Vec<(&str, Json)> = vec![("filter", jstr("median"))];
    let mut per_mode: Vec<(bool, Vec<(usize, f64)>)> = Vec::new();
    for batched in [false, true] {
        let mut curve = Vec::new();
        for workers in [1usize, 2, 4, 8] {
            let cfg = TileConfig { workers, mode: OpMode::Exact, batched };
            let s = timeit(
                || {
                    std::hint::black_box(run_frame_tiled(&hw, &frame1080, &cfg));
                },
                Duration::from_millis(200),
                5,
            );
            let mpix = px1080 / s.mean.as_secs_f64() / 1e6;
            println!(
                "  {} {workers} worker(s): {:>8.2} ms/frame  {:>7.2} Mpx/s",
                if batched { "batched" } else { "scalar " },
                s.mean.as_secs_f64() * 1e3,
                mpix
            );
            curve.push((workers, mpix));
        }
        let w1 = curve[0].1;
        let w4 = curve.iter().find(|&&(w, _)| w == 4).map(|&(_, m)| m).unwrap_or(w1);
        println!(
            "    4-worker scaling vs 1: {:.2}x ({})",
            w4 / w1,
            if batched { "batched" } else { "scalar" }
        );
        per_mode.push((batched, curve));
    }
    for (batched, curve) in &per_mode {
        let key = if *batched { "batched_mpix_s" } else { "scalar_mpix_s" };
        let entries: Vec<(String, Json)> = curve
            .iter()
            .map(|&(w, m)| (format!("workers_{w}"), num(m)))
            .collect();
        tiled_json.push((
            key,
            Json::Obj(entries.into_iter().collect()),
        ));
    }

    let report = obj(vec![
        ("bench", jstr("hotpath")),
        ("lanes", num(LANES as f64)),
        (
            "frame",
            obj(vec![("width", num(640.0)), ("height", num(480.0))]),
        ),
        ("engine", obj(engine_json)),
        ("tiled_1080p", obj(tiled_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
