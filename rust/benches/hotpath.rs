//! Bench: hot-path microbenchmarks (the §Perf targets), driven entirely
//! through the unified `Pipeline` → `CompiledPipeline` → `Session` API.
//!
//! * engine throughput per filter: scalar sessions vs the interpreted
//!   lane-batched `BatchEngine` vs the fused direct-threaded
//!   `CompiledKernel` (Mpixels/s; batched/tiled/streaming sessions run
//!   the kernel, so `BatchEngine` here is the pre-compiler baseline);
//! * session amortization: one long-lived session vs rebuilding the
//!   plan + session for every frame (what the `Session` layer buys);
//! * window-generator overhead in isolation (scalar and lane traversal);
//! * streaming scaling with worker count (inter-frame pipeline);
//! * intra-frame tiling: one 1080p frame sharded into row bands.
//!
//! Writes the machine-readable results to `BENCH_hotpath.json` at the
//! repository root (per-filter scalar/batched/kernel Mpix/s + session
//! amortization + tiled scaling), so the perf trajectory is tracked
//! across PRs.  Exits nonzero if the compiled kernel is slower than the
//! interpreted `BatchEngine` on any of relu / maxpool2x2 / conv3x3 —
//! the compiler must never lose to the interpreter it replaced.
//!
//! `cargo bench --bench hotpath`

use std::time::Duration;

use fpspatial::bench::timeit;
use fpspatial::coordinator::synth_sequence;
use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, ExecPlan, Pipeline};
use fpspatial::util::json::{num, obj, s as jstr, Json};
use fpspatial::filters::{eval_band_batched, eval_band_kernel};
use fpspatial::sim::{BatchEngine, KernelExec};
use fpspatial::util::LANES;
use fpspatial::video::{Frame, WindowGenerator};

const FMT: FloatFormat = FloatFormat::new(10, 5);

/// `HOTPATH_SMALL=1` shrinks every frame (CI smoke: compile-and-run the
/// whole bench in seconds and still refresh `BENCH_hotpath.json`); the
/// full-size run remains the recorded perf baseline.
fn small_mode() -> bool {
    std::env::var("HOTPATH_SMALL").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// The canonical DSL program suite (examples/dsl/) — benched through the
/// same engines as the built-ins they mirror.
const DSL_SUITE: [(&str, &str); 5] = [
    ("dsl:conv3x3", include_str!("../../examples/dsl/conv3x3.dsl")),
    ("dsl:conv5x5", include_str!("../../examples/dsl/conv5x5.dsl")),
    ("dsl:median", include_str!("../../examples/dsl/median.dsl")),
    ("dsl:nlfilter", include_str!("../../examples/dsl/nlfilter.dsl")),
    ("dsl:sobel", include_str!("../../examples/dsl/sobel.dsl")),
];

fn builtin_plan(kind: FilterKind) -> CompiledPipeline {
    Pipeline::new().builtin(kind).format(FMT).compile(OpMode::Exact).unwrap()
}

/// Measure one single-stage plan three ways: a scalar session (tape
/// interpreter, one pixel at a time), the interpreted lane-batched
/// `BatchEngine` (the pre-compiler hot path, driven directly through
/// `eval_band_batched`), and the fused direct-threaded `CompiledKernel`
/// (what batched/tiled/streaming sessions now run).  Returns
/// `(scalar_mpix, batched_mpix, kernel_mpix)`.
fn measure_engine(plan: &CompiledPipeline, frame: &Frame, px: f64) -> (f64, f64, f64) {
    assert_eq!(plan.len(), 1, "engine rows bench single-stage plans");
    let hw = &plan.stages()[0];
    let (ow, oh) = hw.output_dims(frame.width, frame.height);
    let mut out = Frame::new(ow, oh);

    let mut scalar_s = plan.session(ExecPlan::Scalar).unwrap();
    let scalar = timeit(
        || {
            scalar_s.process_into(frame, &mut out).unwrap();
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );

    let mut beng = BatchEngine::new(&hw.netlist, OpMode::Exact);
    let mut bgen = WindowGenerator::with_geometry(hw.geom, frame.width).unwrap();
    let batched = timeit(
        || {
            eval_band_batched(&mut beng, &mut bgen, frame, 0, oh, &mut out.data);
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );

    let mut keng = KernelExec::for_netlist(&hw.netlist, OpMode::Exact);
    let mut kgen = WindowGenerator::with_geometry(hw.geom, frame.width).unwrap();
    let kernel = timeit(
        || {
            eval_band_kernel(&mut keng, &mut kgen, frame, 0, oh, &mut out.data);
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );

    (
        px / scalar.mean.as_secs_f64() / 1e6,
        px / batched.mean.as_secs_f64() / 1e6,
        px / kernel.mean.as_secs_f64() / 1e6,
    )
}

fn main() {
    let small = small_mode();
    let (fw, fh) = if small { (160, 120) } else { (640, 480) };
    let frame = Frame::test_card(fw, fh);
    let px = (frame.width * frame.height) as f64;

    println!("=== engine throughput ({fw}x{fh} frame, exact mode, lanes = {LANES}) ===");
    let mut engine_json: Vec<(&str, Json)> = Vec::new();
    // kernel-vs-BatchEngine regression gate: the compiled kernel must not
    // lose to the interpreter it replaced on any of these rows
    let mut gate: Vec<(String, f64, f64)> = Vec::new();
    let mut two_x_count = 0;
    for kind in FilterKind::NETLIST {
        let plan = builtin_plan(kind);
        let (s_mpix, b_mpix, k_mpix) = measure_engine(&plan, &frame, px);
        let speedup = k_mpix / b_mpix;
        if speedup >= 2.0 {
            two_x_count += 1;
        }
        println!(
            "  {:<10} scalar {s_mpix:>7.2} | batched {b_mpix:>7.2} | kernel {k_mpix:>8.2} Mpx/s | {speedup:>5.2}x vs batched  ({} ops/pixel)",
            kind.name(),
            plan.stages()[0].netlist.nodes.len()
        );
        gate.push((kind.name().to_string(), b_mpix, k_mpix));
        engine_json.push((
            kind.name(),
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("kernel_mpix_s", num(k_mpix)),
                ("speedup", num(b_mpix / s_mpix)),
                ("kernel_speedup", num(speedup)),
            ]),
        ));
    }
    println!(
        "  ({two_x_count}/{} filters with kernel >= 2x the interpreted BatchEngine)",
        FilterKind::NETLIST.len()
    );

    // DSL-compiled programs through the identical hot path: rates should
    // track the built-in rows (same netlists, different front end).
    println!("\n=== DSL-compiled filters (Pipeline::dsl, same hot path) ===");
    for (name, src) in DSL_SUITE {
        let plan = Pipeline::new().dsl_named(src, name).compile(OpMode::Exact).unwrap();
        let (s_mpix, b_mpix, k_mpix) = measure_engine(&plan, &frame, px);
        println!(
            "  {name:<12} scalar {s_mpix:>7.2} | batched {b_mpix:>7.2} | kernel {k_mpix:>8.2} Mpx/s | {:>5.2}x vs batched  (lat {} cycles)",
            k_mpix / b_mpix,
            plan.datapath_latency()
        );
        engine_json.push((
            name,
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("kernel_mpix_s", num(k_mpix)),
                ("speedup", num(b_mpix / s_mpix)),
                ("kernel_speedup", num(k_mpix / b_mpix)),
            ]),
        ));
    }

    // CNN-shaped stages: a strided conv (emits one output per 2×2 input
    // block), a pointwise relu and the classic 2×2 max-pool.  Rates are
    // *input* Mpix/s so the rows compare against the full-rate filters
    // above (strided stages write fewer output pixels per input pixel).
    println!("\n=== CNN-shaped stages (stride / relu / pool, input Mpx/s) ===");
    let cnn_rows: [(&str, CompiledPipeline); 3] = [
        (
            "conv3x3_s2",
            Pipeline::new()
                .builtin(FilterKind::Conv3x3)
                .format(FMT)
                .stride(2)
                .compile(OpMode::Exact)
                .unwrap(),
        ),
        ("relu", Pipeline::new().relu().format(FMT).compile(OpMode::Exact).unwrap()),
        (
            "maxpool2x2",
            Pipeline::new().max_pool(2, 2).format(FMT).compile(OpMode::Exact).unwrap(),
        ),
    ];
    for (name, plan) in &cnn_rows {
        let (s_mpix, b_mpix, k_mpix) = measure_engine(plan, &frame, px);
        let (ow, oh) = plan.output_dims(frame.width, frame.height);
        println!(
            "  {name:<12} scalar {s_mpix:>7.2} | batched {b_mpix:>7.2} | kernel {k_mpix:>8.2} Mpx/s | {:>5.2}x vs batched  (out {ow}x{oh})",
            k_mpix / b_mpix
        );
        gate.push((name.to_string(), b_mpix, k_mpix));
        engine_json.push((
            *name,
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("kernel_mpix_s", num(k_mpix)),
                ("speedup", num(b_mpix / s_mpix)),
                ("kernel_speedup", num(k_mpix / b_mpix)),
            ]),
        ));
    }

    // Session amortization: one long-lived session (engines, window
    // generators and scratch stay warm) vs rebuilding plan + session for
    // every frame — the steady-state-allocation cost the Session layer
    // removes from streaming workloads.
    println!("\n=== session reuse vs per-frame construction (median, batched) ===");
    let plan = builtin_plan(FilterKind::Median);
    let mut warm = plan.session(ExecPlan::Batched).unwrap();
    let mut out = Frame::new(frame.width, frame.height);
    let reused = timeit(
        || {
            warm.process_into(&frame, &mut out).unwrap();
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );
    let cold = timeit(
        || {
            let plan = builtin_plan(FilterKind::Median);
            let mut s = plan.session(ExecPlan::Batched).unwrap();
            std::hint::black_box(s.process(&frame).unwrap());
        },
        Duration::from_millis(400),
        50,
    );
    let reused_mpix = px / reused.mean.as_secs_f64() / 1e6;
    let cold_mpix = px / cold.mean.as_secs_f64() / 1e6;
    println!(
        "  reused     {reused_mpix:>7.2} Mpx/s | per-frame {cold_mpix:>7.2} Mpx/s | {:>5.2}x",
        reused_mpix / cold_mpix
    );
    engine_json.push((
        "session:median",
        obj(vec![
            ("reused_mpix_s", num(reused_mpix)),
            ("cold_mpix_s", num(cold_mpix)),
            ("amortization", num(reused_mpix / cold_mpix)),
        ]),
    ));

    // Fused chain vs sequential full-frame application: the chain holds
    // O(N·ksize) line buffers instead of materialising an intermediate
    // frame per stage, so the fused walk touches far less memory.
    println!("\n=== fused chain (median -> fp_sobel, batched) ===");
    let chain_plan = Pipeline::new()
        .builtin(FilterKind::Median)
        .format(FMT)
        .builtin(FilterKind::FpSobel)
        .format(FMT)
        .compile(OpMode::Exact)
        .unwrap();
    let mut fused_s = chain_plan.session(ExecPlan::Batched).unwrap();
    let fused = timeit(
        || {
            fused_s.process_into(&frame, &mut out).unwrap();
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );
    let median_plan = builtin_plan(FilterKind::Median);
    let mut stage0 = median_plan.session(ExecPlan::Batched).unwrap();
    // the sobel session sees the median output, same geometry
    let sobel_plan = builtin_plan(FilterKind::FpSobel);
    let mut stage1 = sobel_plan.session(ExecPlan::Batched).unwrap();
    let sequential = timeit(
        || {
            let mid = stage0.process(&frame).unwrap();
            std::hint::black_box(stage1.process(&mid).unwrap());
        },
        Duration::from_millis(400),
        50,
    );
    let fused_mpix = px / fused.mean.as_secs_f64() / 1e6;
    let seq_mpix = px / sequential.mean.as_secs_f64() / 1e6;
    println!(
        "  fused      {fused_mpix:>7.2} Mpx/s | sequential {seq_mpix:>7.2} Mpx/s | {:>5.2}x",
        fused_mpix / seq_mpix
    );
    engine_json.push((
        "chain:median->fp_sobel",
        obj(vec![
            ("fused_mpix_s", num(fused_mpix)),
            ("sequential_mpix_s", num(seq_mpix)),
            ("speedup", num(fused_mpix / seq_mpix)),
        ]),
    ));

    // Conv fusion (the PR 10 optimizer): one composed 5x5 stage vs the
    // two-stage 3x3∘3x3 cascade it replaces — one window generator and
    // one datapath pass instead of two, at a measured numeric drift the
    // FusionReport carries.
    println!("\n=== conv fusion (conv3x3∘conv3x3 -> conv5x5, batched) ===");
    let cascade = Pipeline::new()
        .builtin(FilterKind::Conv3x3)
        .format(FMT)
        .builtin(FilterKind::Conv3x3)
        .format(FMT)
        .compile(OpMode::Exact)
        .unwrap();
    let (fused_plan, fusion_report) = cascade.fused().expect("3x3∘3x3 fuses");
    let mut unfused_s = cascade.session(ExecPlan::Batched).unwrap();
    let unfused = timeit(
        || {
            unfused_s.process_into(&frame, &mut out).unwrap();
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );
    let mut fused_conv_s = fused_plan.session(ExecPlan::Batched).unwrap();
    let fused_conv = timeit(
        || {
            fused_conv_s.process_into(&frame, &mut out).unwrap();
            std::hint::black_box(&out);
        },
        Duration::from_millis(400),
        50,
    );
    let unfused_mpix = px / unfused.mean.as_secs_f64() / 1e6;
    let fused_conv_mpix = px / fused_conv.mean.as_secs_f64() / 1e6;
    println!(
        "  fused      {fused_conv_mpix:>7.2} Mpx/s | unfused {unfused_mpix:>7.2} Mpx/s | {:>5.2}x  (latency {} -> {} cycles, drift {:.1} ulp)",
        fused_conv_mpix / unfused_mpix,
        fusion_report.latency_before,
        fusion_report.latency_after,
        fusion_report.accuracy.max_ulp
    );
    engine_json.push((
        "fusion:conv3x3∘conv3x3",
        obj(vec![
            ("fused_mpix_s", num(fused_conv_mpix)),
            ("unfused_mpix_s", num(unfused_mpix)),
            ("speedup", num(fused_conv_mpix / unfused_mpix)),
            ("latency_before", num(fusion_report.latency_before as f64)),
            ("latency_after", num(fusion_report.latency_after as f64)),
            ("drift_max_ulp", num(fusion_report.accuracy.max_ulp)),
        ]),
    ));

    println!("\n=== window generator alone ===");
    let mut gen = WindowGenerator::new(3, frame.width).unwrap();
    let scalar_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame(&frame, |_, _, w| acc += w[4]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    let lane_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame_lanes(&frame, |_, _, n, taps| acc += taps[4][n - 1]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    println!(
        "  3x3 scalar stream: {:>8.2} ms/frame  {:>7.2} Mpx/s",
        scalar_gen.mean.as_secs_f64() * 1e3,
        px / scalar_gen.mean.as_secs_f64() / 1e6
    );
    println!(
        "  3x3 lane stream  : {:>8.2} ms/frame  {:>7.2} Mpx/s",
        lane_gen.mean.as_secs_f64() * 1e3,
        px / lane_gen.mean.as_secs_f64() / 1e6
    );

    let (pw, ph, pn) = if small { (160, 120, 6) } else { (320, 240, 16) };
    println!("\n=== streaming scaling (median, {pn} frames @ {pw}x{ph}) ===");
    let frames = synth_sequence(pw, ph, pn);
    let plan = builtin_plan(FilterKind::Median);
    for workers in [1usize, 2, 4, 8] {
        let mut sess = plan.session(ExecPlan::streaming(workers)).unwrap();
        let m = sess.process_sequence(frames.clone(), |_, _| {}).unwrap();
        println!(
            "  {workers} worker(s): {:>7.2} FPS  ({:>6.1} Mpx/s)  p99 {:.2?}",
            m.fps(),
            m.pixel_rate(pw, ph) / 1e6,
            m.p99_latency
        );
    }

    let (tw, th) = if small { (640, 360) } else { (1920, 1080) };
    println!("\n=== intra-frame tiling (single {tw}x{th} frame, median) ===");
    let frame1080 = Frame::test_card(tw, th);
    let px1080 = (tw * th) as f64;
    // Record the tiled frame size: HOTPATH_SMALL runs measure 640x360, so
    // consumers must not compare across differently-sized artifacts.
    let mut tiled_json: Vec<(&str, Json)> = vec![
        ("filter", jstr("median")),
        ("width", num(tw as f64)),
        ("height", num(th as f64)),
    ];
    let mut out1080 = Frame::new(tw, th);
    let mut curve = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut sess = plan.session(ExecPlan::Tiled { workers }).unwrap();
        let s = timeit(
            || {
                sess.process_into(&frame1080, &mut out1080).unwrap();
                std::hint::black_box(&out1080);
            },
            Duration::from_millis(200),
            5,
        );
        let mpix = px1080 / s.mean.as_secs_f64() / 1e6;
        println!(
            "  {workers} worker(s): {:>8.2} ms/frame  {:>7.2} Mpx/s",
            s.mean.as_secs_f64() * 1e3,
            mpix
        );
        curve.push((workers, mpix));
    }
    let w1 = curve[0].1;
    let w4 = curve.iter().find(|&&(w, _)| w == 4).map(|&(_, m)| m).unwrap_or(w1);
    println!("    4-worker scaling vs 1: {:.2}x", w4 / w1);
    // tiled sessions always run the lane-batched engines; the key keeps
    // its historical name so the artifact series stays comparable
    let entries: Vec<(String, Json)> =
        curve.iter().map(|&(w, m)| (format!("workers_{w}"), num(m))).collect();
    tiled_json.push(("batched_mpix_s", Json::Obj(entries.into_iter().collect())));

    let report = obj(vec![
        ("bench", jstr("hotpath")),
        ("lanes", num(LANES as f64)),
        ("small", num(if small { 1.0 } else { 0.0 })),
        (
            "frame",
            obj(vec![("width", num(fw as f64)), ("height", num(fh as f64))]),
        ),
        ("engine", obj(engine_json)),
        ("tiled", obj(tiled_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // Regression gate: on the rows the kernel was built to win, losing to
    // the interpreted BatchEngine is a bug, not noise.
    let mut failed = false;
    for want in ["relu", "maxpool2x2", "conv3x3"] {
        if let Some((name, b, k)) = gate.iter().find(|(n, _, _)| n == want) {
            if k < b {
                eprintln!(
                    "FAIL: {name}: compiled kernel ({k:.2} Mpx/s) slower than \
                     interpreted BatchEngine ({b:.2} Mpx/s)"
                );
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
