//! Bench: hot-path microbenchmarks (the §Perf targets).
//!
//! * engine throughput per filter (Mpixels/s through the functional
//!   netlist evaluator — the end-to-end bound of every hardware-model
//!   bench);
//! * window-generator overhead in isolation;
//! * coordinator scaling with worker count.
//!
//! `cargo bench --bench hotpath`

use std::time::Duration;

use fpspatial::bench::timeit;
use fpspatial::coordinator::{run_pipeline, synth_sequence, PipelineConfig};
use fpspatial::filters::{FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::video::{Frame, WindowGenerator};

const FMT: FloatFormat = FloatFormat::new(10, 5);

fn main() {
    let frame = Frame::test_card(640, 480);
    let px = (frame.width * frame.height) as f64;

    println!("=== engine throughput (640x480 frame, exact mode) ===");
    for kind in [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
    ] {
        let hw = HwFilter::new(kind, FMT);
        let s = timeit(
            || {
                std::hint::black_box(hw.run_frame(&frame, OpMode::Exact));
            },
            Duration::from_millis(400),
            50,
        );
        println!(
            "  {:<10} {:>8.2} ms/frame  {:>7.2} Mpx/s  ({} ops/pixel)",
            kind.name(),
            s.mean.as_secs_f64() * 1e3,
            px / s.mean.as_secs_f64() / 1e6,
            hw.netlist.nodes.len()
        );
    }

    println!("\n=== window generator alone ===");
    let mut gen = WindowGenerator::new(3, frame.width);
    let s = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame(&frame, |_, _, w| acc += w[4]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    println!(
        "  3x3 window stream: {:>8.2} ms/frame  {:>7.2} Mpx/s",
        s.mean.as_secs_f64() * 1e3,
        px / s.mean.as_secs_f64() / 1e6
    );

    println!("\n=== coordinator scaling (median, 16 frames @ 320x240) ===");
    let frames = synth_sequence(320, 240, 16);
    let hw = HwFilter::new(FilterKind::Median, FMT);
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };
        let (_, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
        println!(
            "  {workers} worker(s): {:>7.2} FPS  ({:>6.1} Mpx/s)",
            m.fps(),
            m.pixel_rate(320, 240) / 1e6
        );
    }
}
