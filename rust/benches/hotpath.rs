//! Bench: hot-path microbenchmarks (the §Perf targets).
//!
//! * engine throughput per filter, scalar vs lane-batched (Mpixels/s
//!   through the functional netlist evaluator — the end-to-end bound of
//!   every hardware-model bench);
//! * window-generator overhead in isolation (scalar and lane traversal);
//! * coordinator scaling with worker count (inter-frame round-robin);
//! * intra-frame tiling: one 1080p frame sharded into row bands.
//!
//! Writes the machine-readable results to `BENCH_hotpath.json` at the
//! repository root (per-filter scalar/batched Mpix/s + tiled scaling),
//! so the perf trajectory is tracked across PRs.
//!
//! `cargo bench --bench hotpath`

use std::time::Duration;

use fpspatial::bench::timeit;
use fpspatial::coordinator::{
    run_frame_tiled, run_pipeline, synth_sequence, PipelineConfig, TileConfig,
};
use fpspatial::filters::{FilterChain, FilterKind, HwFilter};
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::util::json::{num, obj, s as jstr, Json};
use fpspatial::util::LANES;
use fpspatial::video::{Frame, WindowGenerator};

const FMT: FloatFormat = FloatFormat::new(10, 5);

/// `HOTPATH_SMALL=1` shrinks every frame (CI smoke: compile-and-run the
/// whole bench in seconds and still refresh `BENCH_hotpath.json`); the
/// full-size run remains the recorded perf baseline.
fn small_mode() -> bool {
    std::env::var("HOTPATH_SMALL").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// The canonical DSL program suite (examples/dsl/) — benched through the
/// same engines as the built-ins they mirror.
const DSL_SUITE: [(&str, &str); 5] = [
    ("dsl:conv3x3", include_str!("../../examples/dsl/conv3x3.dsl")),
    ("dsl:conv5x5", include_str!("../../examples/dsl/conv5x5.dsl")),
    ("dsl:median", include_str!("../../examples/dsl/median.dsl")),
    ("dsl:nlfilter", include_str!("../../examples/dsl/nlfilter.dsl")),
    ("dsl:sobel", include_str!("../../examples/dsl/sobel.dsl")),
];

/// Measure one filter's scalar vs batched whole-frame throughput; returns
/// `(scalar_mpix, batched_mpix)`.
fn measure_engine(hw: &HwFilter, frame: &Frame, px: f64) -> (f64, f64) {
    let scalar = timeit(
        || {
            std::hint::black_box(hw.run_frame(frame, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    let batched = timeit(
        || {
            std::hint::black_box(hw.run_frame_batched(frame, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    (
        px / scalar.mean.as_secs_f64() / 1e6,
        px / batched.mean.as_secs_f64() / 1e6,
    )
}

fn main() {
    let small = small_mode();
    let (fw, fh) = if small { (160, 120) } else { (640, 480) };
    let frame = Frame::test_card(fw, fh);
    let px = (frame.width * frame.height) as f64;

    println!("=== engine throughput ({fw}x{fh} frame, exact mode, lanes = {LANES}) ===");
    let mut engine_json: Vec<(&str, Json)> = Vec::new();
    let mut two_x_count = 0;
    for kind in FilterKind::NETLIST {
        let hw = HwFilter::new(kind, FMT).unwrap();
        let (s_mpix, b_mpix) = measure_engine(&hw, &frame, px);
        let speedup = b_mpix / s_mpix;
        if speedup >= 2.0 {
            two_x_count += 1;
        }
        println!(
            "  {:<10} scalar {s_mpix:>7.2} Mpx/s | batched {b_mpix:>7.2} Mpx/s | {speedup:>5.2}x  ({} ops/pixel)",
            kind.name(),
            hw.netlist.nodes.len()
        );
        engine_json.push((
            kind.name(),
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("speedup", num(speedup)),
            ]),
        ));
    }
    println!(
        "  ({two_x_count}/{} filters at >= 2x batched speedup)",
        FilterKind::NETLIST.len()
    );

    // DSL-compiled programs through the identical hot path: rates should
    // track the built-in rows (same netlists, different front end).
    println!("\n=== DSL-compiled filters (HwFilter::from_dsl, same hot path) ===");
    for (name, src) in DSL_SUITE {
        let hw = HwFilter::from_dsl(src, name, None).unwrap();
        let (s_mpix, b_mpix) = measure_engine(&hw, &frame, px);
        println!(
            "  {name:<12} scalar {s_mpix:>7.2} Mpx/s | batched {b_mpix:>7.2} Mpx/s | {:>5.2}x  (lat {} cycles)",
            b_mpix / s_mpix,
            hw.latency()
        );
        engine_json.push((
            name,
            obj(vec![
                ("scalar_mpix_s", num(s_mpix)),
                ("batched_mpix_s", num(b_mpix)),
                ("speedup", num(b_mpix / s_mpix)),
            ]),
        ));
    }

    // Fused chain vs sequential full-frame application: the chain holds
    // O(N·ksize) line buffers instead of materialising an intermediate
    // frame per stage, so the fused walk touches far less memory.
    println!("\n=== fused chain (median -> fp_sobel, batched) ===");
    let chain = FilterChain::new(vec![
        HwFilter::new(FilterKind::Median, FMT).unwrap(),
        HwFilter::new(FilterKind::FpSobel, FMT).unwrap(),
    ])
    .unwrap();
    let fused = timeit(
        || {
            std::hint::black_box(chain.run_frame_batched(&frame, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    let sequential = timeit(
        || {
            let mid = chain.stages()[0].run_frame_batched(&frame, OpMode::Exact);
            std::hint::black_box(chain.stages()[1].run_frame_batched(&mid, OpMode::Exact));
        },
        Duration::from_millis(400),
        50,
    );
    let fused_mpix = px / fused.mean.as_secs_f64() / 1e6;
    let seq_mpix = px / sequential.mean.as_secs_f64() / 1e6;
    println!(
        "  fused      {fused_mpix:>7.2} Mpx/s | sequential {seq_mpix:>7.2} Mpx/s | {:>5.2}x",
        fused_mpix / seq_mpix
    );
    engine_json.push((
        "chain:median->fp_sobel",
        obj(vec![
            ("fused_mpix_s", num(fused_mpix)),
            ("sequential_mpix_s", num(seq_mpix)),
            ("speedup", num(fused_mpix / seq_mpix)),
        ]),
    ));

    println!("\n=== window generator alone ===");
    let mut gen = WindowGenerator::new(3, frame.width).unwrap();
    let scalar_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame(&frame, |_, _, w| acc += w[4]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    let lane_gen = timeit(
        || {
            let mut acc = 0.0;
            gen.process_frame_lanes(&frame, |_, _, n, taps| acc += taps[4][n - 1]);
            std::hint::black_box(acc);
        },
        Duration::from_millis(300),
        50,
    );
    println!(
        "  3x3 scalar stream: {:>8.2} ms/frame  {:>7.2} Mpx/s",
        scalar_gen.mean.as_secs_f64() * 1e3,
        px / scalar_gen.mean.as_secs_f64() / 1e6
    );
    println!(
        "  3x3 lane stream  : {:>8.2} ms/frame  {:>7.2} Mpx/s",
        lane_gen.mean.as_secs_f64() * 1e3,
        px / lane_gen.mean.as_secs_f64() / 1e6
    );

    let (pw, ph, pn) = if small { (160, 120, 6) } else { (320, 240, 16) };
    println!("\n=== coordinator scaling (median, {pn} frames @ {pw}x{ph}) ===");
    let frames = synth_sequence(pw, ph, pn);
    let hw = HwFilter::new(FilterKind::Median, FMT).unwrap();
    for batched in [false, true] {
        for workers in [1usize, 2, 4, 8] {
            let cfg = PipelineConfig { workers, batched, ..Default::default() };
            let (_, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
            println!(
                "  {} {workers} worker(s): {:>7.2} FPS  ({:>6.1} Mpx/s)  p99 {:.2?}",
                if batched { "batched" } else { "scalar " },
                m.fps(),
                m.pixel_rate(pw, ph) / 1e6,
                m.p99_latency
            );
        }
    }

    let (tw, th) = if small { (640, 360) } else { (1920, 1080) };
    println!("\n=== intra-frame tiling (single {tw}x{th} frame, median) ===");
    let frame1080 = Frame::test_card(tw, th);
    let px1080 = (tw * th) as f64;
    // Record the tiled frame size: HOTPATH_SMALL runs measure 640x360, so
    // consumers must not compare across differently-sized artifacts.
    let mut tiled_json: Vec<(&str, Json)> = vec![
        ("filter", jstr("median")),
        ("width", num(tw as f64)),
        ("height", num(th as f64)),
    ];
    let mut per_mode: Vec<(bool, Vec<(usize, f64)>)> = Vec::new();
    for batched in [false, true] {
        let mut curve = Vec::new();
        for workers in [1usize, 2, 4, 8] {
            let cfg = TileConfig { workers, mode: OpMode::Exact, batched };
            let s = timeit(
                || {
                    std::hint::black_box(run_frame_tiled(&hw, &frame1080, &cfg));
                },
                Duration::from_millis(200),
                5,
            );
            let mpix = px1080 / s.mean.as_secs_f64() / 1e6;
            println!(
                "  {} {workers} worker(s): {:>8.2} ms/frame  {:>7.2} Mpx/s",
                if batched { "batched" } else { "scalar " },
                s.mean.as_secs_f64() * 1e3,
                mpix
            );
            curve.push((workers, mpix));
        }
        let w1 = curve[0].1;
        let w4 = curve.iter().find(|&&(w, _)| w == 4).map(|&(_, m)| m).unwrap_or(w1);
        println!(
            "    4-worker scaling vs 1: {:.2}x ({})",
            w4 / w1,
            if batched { "batched" } else { "scalar" }
        );
        per_mode.push((batched, curve));
    }
    for (batched, curve) in &per_mode {
        let key = if *batched { "batched_mpix_s" } else { "scalar_mpix_s" };
        let entries: Vec<(String, Json)> = curve
            .iter()
            .map(|&(w, m)| (format!("workers_{w}"), num(m)))
            .collect();
        tiled_json.push((
            key,
            Json::Obj(entries.into_iter().collect()),
        ));
    }

    let report = obj(vec![
        ("bench", jstr("hotpath")),
        ("lanes", num(LANES as f64)),
        ("small", num(if small { 1.0 } else { 0.0 })),
        (
            "frame",
            obj(vec![("width", num(fw as f64)), ("height", num(fh as f64))]),
        ),
        ("engine", obj(engine_json)),
        // renamed from "tiled_1080p": the section records its own
        // width/height now that HOTPATH_SMALL can shrink the frame
        ("tiled", obj(tiled_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
