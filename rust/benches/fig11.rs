//! Bench: regenerate Figure 11 (FPGA resource usage vs float type for the
//! six filters, against the Zybo Z7-20 budget), then measure the software
//! model's single-frame 1080p throughput through the tiled coordinator in
//! both engine modes (scalar and lane-batched) — the worker-scaling curve
//! that complements the figure's resource story.
//!
//! `cargo bench --bench fig11`

use std::time::Duration;

use fpspatial::bench::{fig11, timeit};
use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{ExecPlan, Pipeline};
use fpspatial::resources::ZYBO_Z7_20;
use fpspatial::video::Frame;

fn main() {
    let pts = fig11::run();
    println!("=== Figure 11: FPGA implementation results (Zybo Z7-20) ===\n");
    println!("{}", fig11::render(&pts));

    // the paper's qualitative claims
    let get = |f: &str, fmt: &str| pts.iter().find(|p| p.filter == f && p.format == fmt).unwrap();
    assert!(!get("conv5x5", "f64").fits, "conv5x5 float64 must fail (paper: 206.20% LUTs)");
    assert!(!get("fp_sobel", "f64").fits, "fp_sobel float64 must fail (paper: 135.08% LUTs)");
    let lut_pct = get("conv5x5", "f64").usage.utilization(ZYBO_Z7_20)[0];
    println!("conv5x5 float64 LUT utilization: {lut_pct:.1}% (paper: 206.20%) -> implementation fails");
    let hls = pts.iter().find(|p| p.filter == "hls_sobel").unwrap();
    for fmt in ["f16", "f24"] {
        assert!(
            get("fp_sobel", fmt).usage.luts < hls.usage.luts,
            "fp_sobel {fmt} must beat hls_sobel on LUTs"
        );
    }
    println!("shape checks passed: f64 failures, median 0 DSPs, fp_sobel<=24b beats hls_sobel");

    // Software-model throughput at the figure's 1080p line width: one
    // frame tiled into row bands through reusable tiled sessions.
    println!("\n=== 1080p single-frame throughput (conv3x3 f16, tiled sessions) ===");
    let plan = Pipeline::new()
        .builtin(FilterKind::Conv3x3)
        .format(FloatFormat::new(10, 5))
        .compile(OpMode::Exact)
        .unwrap();
    let frame = Frame::test_card(1920, 1080);
    let px = (1920 * 1080) as f64;
    let mut out = Frame::new(1920, 1080);
    for workers in [1usize, 2, 4, 8] {
        let mut sess = plan.session(ExecPlan::Tiled { workers }).unwrap();
        let s = timeit(
            || {
                sess.process_into(&frame, &mut out).unwrap();
                std::hint::black_box(&out);
            },
            Duration::from_millis(200),
            5,
        );
        println!(
            "  {workers} worker(s): {:>8.2} ms/frame  {:>7.2} Mpx/s",
            s.mean.as_secs_f64() * 1e3,
            px / s.mean.as_secs_f64() / 1e6
        );
    }
}
