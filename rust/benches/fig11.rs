//! Bench: regenerate Figure 11 (FPGA resource usage vs float type for the
//! six filters, against the Zybo Z7-20 budget).
//!
//! `cargo bench --bench fig11`

use fpspatial::bench::fig11;
use fpspatial::resources::ZYBO_Z7_20;

fn main() {
    let pts = fig11::run();
    println!("=== Figure 11: FPGA implementation results (Zybo Z7-20) ===\n");
    println!("{}", fig11::render(&pts));

    // the paper's qualitative claims
    let get = |f: &str, fmt: &str| pts.iter().find(|p| p.filter == f && p.format == fmt).unwrap();
    assert!(!get("conv5x5", "f64").fits, "conv5x5 float64 must fail (paper: 206.20% LUTs)");
    assert!(!get("fp_sobel", "f64").fits, "fp_sobel float64 must fail (paper: 135.08% LUTs)");
    let lut_pct = get("conv5x5", "f64").usage.utilization(ZYBO_Z7_20)[0];
    println!("conv5x5 float64 LUT utilization: {lut_pct:.1}% (paper: 206.20%) -> implementation fails");
    let hls = pts.iter().find(|p| p.filter == "hls_sobel").unwrap();
    for fmt in ["f16", "f24"] {
        assert!(
            get("fp_sobel", fmt).usage.luts < hls.usage.luts,
            "fp_sobel {fmt} must beat hls_sobel on LUTs"
        );
    }
    println!("shape checks passed: f64 failures, median 0 DSPs, fp_sobel<=24b beats hls_sobel");
}
