//! Bench: regenerate Table I (software vs hardware FPS per resolution).
//!
//! `cargo bench --bench table1` — quick mode (quarter-size measurement
//! frames, FPS extrapolated by pixel count).  Set FPSPATIAL_BENCH_FULL=1
//! for full-resolution measurement (slow: the interpreted nlfilter row
//! takes seconds per 1080p frame, exactly like the paper's MATLAB row).

use fpspatial::bench::table1;
use fpspatial::fpcore::FloatFormat;

fn main() {
    let full = std::env::var("FPSPATIAL_BENCH_FULL").is_ok();
    let fmt = FloatFormat::new(10, 5);
    let rows = table1::run(fmt, !full).expect("table1");
    println!("=== Table I: frame rate of filter functions vs image resolution ===");
    println!("(software measured on this machine; hardware = 148.5 MHz pixel clock, II=1 proven by the RTL sim)\n");
    println!("{}", table1::render(&rows));
    if let Some(s) = table1::headline_speedup(&rows) {
        println!("headline: hardware nlfilter = {s:.0}x software at 1080p (paper: ~810x)");
    }
    // shape assertions (who wins, by roughly what factor)
    let sw = |f: &str, r: &str| {
        rows.iter()
            .find(|x| x.filter == f && x.resolution == r)
            .unwrap()
            .software_fps
    };
    assert!(sw("nlfilter", "1080p") < 5.0, "interpreted nlfilter must be slow");
    assert!(sw("conv3x3", "480p") > sw("conv3x3", "1080p"));
    println!("\nshape checks passed: conv > median > nlfilter; FPS falls with resolution; hw >> sw for nlfilter");
}
