//! Bench: the frame-server layer — N independent streams multiplexed
//! over ONE shared worker pool ([`fpspatial::pipeline::FrameServer`]).
//!
//! Sweeps the stream count (1 / 8 / 64) at 480p and 1080p with a shared
//! conv3x3 float16 plan and reports, per cell, the *aggregate* pixel
//! rate across every stream plus the aggregate p99 submit→delivery
//! latency.  The driving loop is deterministic (round-robin submission
//! from one thread, one reused input frame per resolution), so the
//! numbers measure scheduling + evaluation, not producer jitter.
//!
//! Writes the machine-readable results to `BENCH_server.json` at the
//! repository root and **exits nonzero if any cell reports a worker
//! restart** — this healthy run doubles as the CI supervision smoke.
//!
//! `cargo bench --bench server` (`SERVER_SMALL=1` shrinks frames and
//! stream counts for CI).

use std::time::Instant;

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::{FloatFormat, OpMode};
use fpspatial::pipeline::{CompiledPipeline, FrameServer, Pipeline, ServerEvent, SessionConfig};
use fpspatial::util::json::{num, obj, s as jstr, Json};
use fpspatial::video::Frame;

/// `SERVER_SMALL=1`: CI smoke sizing (seconds, not minutes) that still
/// refreshes `BENCH_server.json`.
fn small_mode() -> bool {
    std::env::var("SERVER_SMALL").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

struct Cell {
    streams: usize,
    width: usize,
    height: usize,
    aggregate_mpix_s: f64,
    p99_ms: f64,
    restarts: u64,
}

/// One sweep cell: `streams` sessions of the shared plan, `frames`
/// frames each, pushed round-robin through the shared pool.
fn run_cell(
    plan: &CompiledPipeline,
    workers: usize,
    streams: usize,
    width: usize,
    height: usize,
    frames: usize,
) -> Cell {
    let mut builder = FrameServer::builder(workers);
    for _ in 0..streams {
        builder = builder.stream(plan, SessionConfig::new());
    }
    let mut server = builder.build().expect("server spawn");
    let input = Frame::noise(width, height, 0xF1D0);
    let mut delivered = 0u64;
    let started = Instant::now();
    for _ in 0..frames {
        for s in 0..streams {
            server.submit(s, &input).expect("healthy submit");
        }
        for ev in server.pump().expect("healthy pump") {
            if let ServerEvent::Frame { frame, .. } = ev {
                delivered += 1;
                server.recycle(frame);
            }
        }
    }
    for ev in server.drain().expect("healthy drain") {
        if let ServerEvent::Frame { frame, .. } = ev {
            delivered += 1;
            server.recycle(frame);
        }
    }
    let elapsed = started.elapsed();
    assert_eq!(delivered, (streams * frames) as u64, "healthy run delivers every frame");
    let a = server.aggregate();
    let mpix_s = delivered as f64 * (width * height) as f64 / elapsed.as_secs_f64() / 1e6;
    Cell {
        streams,
        width,
        height,
        aggregate_mpix_s: mpix_s,
        p99_ms: a.p99_latency.as_secs_f64() * 1e3,
        restarts: a.worker_restarts,
    }
}

fn main() {
    let small = small_mode();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let plan = Pipeline::new()
        .builtin(FilterKind::Conv3x3)
        .format(FloatFormat::new(10, 5))
        .compile(OpMode::Exact)
        .unwrap();

    // (width, height, frames per stream): 480p and 1080p, fewer frames
    // at the larger size so the full sweep stays in bench-smoke budget
    let sizes: &[(usize, usize, usize)] =
        if small { &[(160, 120, 6), (320, 240, 4)] } else { &[(640, 480, 16), (1920, 1080, 4)] };
    let stream_counts: &[usize] = if small { &[1, 4, 8] } else { &[1, 8, 64] };

    println!("=== frame server: aggregate rate, {workers} shared workers (conv3x3 f16) ===");
    let mut cells: Vec<Json> = Vec::new();
    let mut unhealthy = false;
    for &(w, h, frames) in sizes {
        for &streams in stream_counts {
            let cell = run_cell(&plan, workers, streams, w, h, frames);
            println!(
                "  {streams:>3} stream(s) @ {w}x{h}: {:>8.2} Mpx/s aggregate, p99 {:>7.2} ms, {} restarts",
                cell.aggregate_mpix_s, cell.p99_ms, cell.restarts
            );
            unhealthy |= cell.restarts > 0;
            cells.push(obj(vec![
                ("streams", num(cell.streams as f64)),
                ("width", num(cell.width as f64)),
                ("height", num(cell.height as f64)),
                ("aggregate_mpix_s", num(cell.aggregate_mpix_s)),
                ("p99_ms", num(cell.p99_ms)),
                ("restarts", num(cell.restarts as f64)),
            ]));
        }
    }

    let report = obj(vec![
        ("bench", jstr("server")),
        ("small", num(if small { 1.0 } else { 0.0 })),
        ("workers", num(workers as f64)),
        ("filter", jstr("conv3x3")),
        ("cells", Json::Arr(cells)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_server.json");
    match std::fs::write(path, report.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
    if unhealthy {
        eprintln!("worker restarts observed on a healthy run — failing the bench");
        std::process::exit(1);
    }
}
