//! Bench: design-choice ablations called out in DESIGN.md.
//!
//! A1 — polynomial segments/degree vs accuracy vs DSP cost (the paper
//!      fixes 4 segments, deg 2/3; this sweep shows why that point works
//!      for float16 and what wider formats would need).
//! A2 — 2×SORT5 vs one SORT9 (paper footnote 5: fewer CAS).
//! A3 — exact-op vs poly-approx filter outputs (PSNR per format).
//!
//! `cargo bench --bench ablation`

use fpspatial::filters::FilterKind;
use fpspatial::fpcore::format::FORMATS;
use fpspatial::fpcore::poly::{PiecewisePoly, PolyConfig};
use fpspatial::fpcore::OpMode;
use fpspatial::pipeline::Pipeline;
use fpspatial::video::Frame;

fn main() {
    // --- A1: poly accuracy sweep -------------------------------------------
    println!("=== A1: piecewise-polynomial accuracy vs segments/degree ===\n");
    println!(
        "{:<8} {:<10} {:>10} {:>14} {:>14}",
        "op", "config", "DSP mults", "max rel err", "f16 ulp (2^-11)"
    );
    let fns: [(&str, fn(f64) -> f64, f64, f64); 3] = [
        ("recip", |x| 1.0 / x, 1.0, 2.0),
        ("sqrt", f64::sqrt, 1.0, 4.0),
        ("log2", f64::log2, 1.0, 2.0),
    ];
    for (name, f, lo, hi) in fns {
        for segments in [2u32, 4, 8, 16] {
            for degree in [1u32, 2, 3] {
                let cfg = PolyConfig::new(segments, degree);
                let p = PiecewisePoly::fit(f, lo, hi, cfg);
                let err = p.max_rel_error(f, 8192);
                println!(
                    "{:<8} {:<10} {:>10} {:>14.3e} {:>14}",
                    name,
                    format!("{segments}seg/deg{degree}"),
                    degree,
                    err,
                    if err < 2.0_f64.powi(-11) { "ok" } else { "too coarse" }
                );
            }
        }
        println!();
    }
    // the paper's operating points
    let recip = PiecewisePoly::fit(|x| 1.0 / x, 1.0, 2.0, PolyConfig::new(4, 3));
    let sqrt = PiecewisePoly::fit(f64::sqrt, 1.0, 4.0, PolyConfig::new(4, 2));
    println!(
        "paper points: div 4seg/deg3 err {:.2e}, sqrt 4seg/deg2 err {:.2e} (f16 ulp 4.9e-4)\n",
        recip.max_rel_error(|x| 1.0 / x, 8192),
        sqrt.max_rel_error(f64::sqrt, 8192)
    );

    // --- A2: sorting network sizes ------------------------------------------
    println!("=== A2: 2xSORT5 vs SORT9 (footnote 5) ===");
    // Bose-Nelson SORT9 needs 25 CAS; two SORT5 networks need 2x9 = 18.
    let cas_sort9 = 25;
    let cas_2xsort5 = 2 * 9;
    println!("  SORT9 (Bose-Nelson)  : {cas_sort9} CMP_and_SWAP");
    println!("  2 x SORT5 (paper)    : {cas_2xsort5} CMP_and_SWAP  ({}% fewer)\n",
        100 * (cas_sort9 - cas_2xsort5) / cas_sort9);
    assert!(cas_2xsort5 < cas_sort9);

    // --- A3: exact vs poly datapaths per format ------------------------------
    println!("=== A3: exact-op vs poly-approx datapaths (PSNR, higher = closer) ===\n");
    println!("{:<14} {:>12} {:>12}", "format", "nlfilter dB", "fp_sobel dB");
    let frame = Frame::test_card(160, 120);
    for (key, fmt) in FORMATS {
        // one plan per (filter, mode): the plan fixes the numeric model
        let run = |kind: FilterKind, mode: OpMode| {
            Pipeline::new()
                .builtin(kind)
                .format(fmt)
                .compile(mode)
                .unwrap()
                .run_frame_sequential(&frame)
        };
        let nl_db = run(FilterKind::Nlfilter, OpMode::Poly)
            .psnr(&run(FilterKind::Nlfilter, OpMode::Exact));
        let so_db = run(FilterKind::FpSobel, OpMode::Poly)
            .psnr(&run(FilterKind::FpSobel, OpMode::Exact));
        println!("{:<14} {:>12.1} {:>12.1}", format!("{fmt} ({key})"), nl_db, so_db);
    }
    println!("\nnarrow formats absorb the poly error (quantization dominates); wide formats expose it —");
    println!("the hardware would need more segments, i.e. more coefficient ROM + DSPs (the A1 sweep).");
}
