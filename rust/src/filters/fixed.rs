//! hls_sobel — the paper's fixed-point comparison baseline (§IV-B).
//!
//! The paper implements a Sobel in C++ with Vivado HLS using 24-bit
//! fixed-point pixels and the Xilinx line-buffer/window libraries.  We
//! model the same datapath: integer taps, integer accumulation, and an
//! integer square root, in Q16.8 (24-bit) arithmetic.  Used functionally
//! (as an accuracy baseline) and structurally (fig. 11 resource
//! comparison — see `resources::hls_sobel_usage`).

use crate::video::{map_windows, Frame};

/// Q16.8 fixed point inside a 24-bit word.
pub const FRAC_BITS: u32 = 8;
pub const WORD_BITS: u32 = 24;

/// Convert a pixel (0..255) into Q16.8.
#[inline]
pub fn to_fixed(v: f64) -> i64 {
    (v * (1 << FRAC_BITS) as f64).round() as i64
}

/// Convert Q16.8 back to a double.
#[inline]
pub fn from_fixed(v: i64) -> f64 {
    v as f64 / (1 << FRAC_BITS) as f64
}

/// Saturate into the signed 24-bit range.
#[inline]
fn sat24(v: i64) -> i64 {
    let max = (1i64 << (WORD_BITS - 1)) - 1;
    v.clamp(-max - 1, max)
}

/// Integer square root (binary restoring — what HLS synthesizes).
pub fn isqrt(v: u64) -> u64 {
    if v == 0 {
        return 0;
    }
    let mut x = v;
    let mut res = 0u64;
    let mut bit = 1u64 << (63 - v.leading_zeros()) / 2 * 2; // highest even bit
    while bit > v {
        bit >>= 2;
    }
    while bit != 0 {
        if x >= res + bit {
            x -= res + bit;
            res = (res >> 1) + bit;
        } else {
            res >>= 1;
        }
        bit >>= 2;
    }
    res
}

/// Fixed-point Sobel over one window (raster 3×3, Q16.8 internally).
pub fn sobel_fixed_window(w: &[f64]) -> f64 {
    let px: Vec<i64> = w.iter().map(|&v| to_fixed(v)).collect();
    // Kx = [1 0 -1; 2 0 -2; 1 0 -1], Ky = transpose-ish (eq. 3)
    let gx = sat24(px[0] - px[2] + 2 * (px[3] - px[5]) + px[6] - px[8]);
    let gy = sat24(px[0] + 2 * px[1] + px[2] - px[6] - 2 * px[7] - px[8]);
    // |g| = isqrt(gx² + gy²) — products are Q32.16; take the root back
    // to Q16.8 (isqrt halves the fraction bits: sqrt(Q16) = Q8 → still Q8
    // after the even-bit alignment below).
    let mag2 = (gx * gx + gy * gy) as u64;
    from_fixed(sat24(isqrt(mag2) as i64))
}

/// Run the fixed-point Sobel over a frame (line-buffered window stream).
pub fn sobel_fixed_frame(frame: &Frame) -> Frame {
    map_windows(frame, 3, sobel_fixed_window)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_exact_squares() {
        for v in [0u64, 1, 4, 9, 16, 144, 1 << 20, 999 * 999] {
            assert_eq!(isqrt(v), (v as f64).sqrt() as u64, "{v}");
        }
    }

    #[test]
    fn isqrt_floor_behaviour() {
        assert_eq!(isqrt(2), 1);
        assert_eq!(isqrt(15), 3);
        assert_eq!(isqrt(17), 4);
        for v in (0..5000u64).step_by(37) {
            let r = isqrt(v);
            assert!(r * r <= v && (r + 1) * (r + 1) > v, "{v}");
        }
    }

    #[test]
    fn fixed_round_trip() {
        for v in [0.0, 1.0, 127.5, 255.0] {
            assert!((from_fixed(to_fixed(v)) - v).abs() < 1.0 / 256.0);
        }
    }

    #[test]
    fn flat_window_zero() {
        assert_eq!(sobel_fixed_window(&[42.0; 9]), 0.0);
    }

    #[test]
    fn close_to_float_sobel() {
        use crate::fpcore::{FloatFormat, OpMode};
        use crate::sim::Engine;
        let nl = crate::filters::sobel::sobel_netlist(FloatFormat::new(23, 8));
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..200 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0).floor()).collect();
            let fx = sobel_fixed_window(&w);
            let fp = eng.eval(&w)[0];
            // Q16.8 vs float32(23,8): agree to within a fraction of a grey level
            assert!((fx - fp).abs() < 1.0, "{w:?}: {fx} vs {fp}");
        }
    }
}
