//! Software baselines for Table I.
//!
//! Two tiers, matching how the paper's software column was produced:
//!
//! * **Vectorized** (`conv_sw`, `median_sw`, `sobel_sw`) — tight compiled
//!   loops, the scipy `convolve2d` / `medfilt` equivalents.  (The PJRT
//!   runtime provides a second, independently-compiled vectorized baseline
//!   from the JAX artifacts.)
//! * **Generic per-pixel** (`nlfilter_sw`) — MATLAB `nlfilter` semantics:
//!   an arbitrary user *function* is invoked per window through a dynamic
//!   callback, the reason the paper measures 0.074 FPS at 1080p.  The
//!   function value is identical to the hardware path; only the execution
//!   model differs.

use crate::video::Frame;

/// Vectorized direct convolution (replicate borders), native f64.
pub fn conv_sw(frame: &Frame, k: &[f64], ksize: usize) -> Frame {
    assert_eq!(k.len(), ksize * ksize);
    let p = (ksize / 2) as isize;
    let mut out = Frame::new(frame.width, frame.height);
    for y in 0..frame.height as isize {
        for x in 0..frame.width as isize {
            let mut acc = 0.0;
            let mut idx = 0;
            for dy in -p..=p {
                for dx in -p..=p {
                    acc += frame.get_clamped(x + dx, y + dy) * k[idx];
                    idx += 1;
                }
            }
            out.set(x as usize, y as usize, acc);
        }
    }
    out
}

/// Vectorized 3×3 median (replicate borders), native f64 full sort.
pub fn median_sw(frame: &Frame) -> Frame {
    let mut out = Frame::new(frame.width, frame.height);
    let mut buf = [0.0f64; 9];
    for y in 0..frame.height as isize {
        for x in 0..frame.width as isize {
            let mut idx = 0;
            for dy in -1..=1 {
                for dx in -1..=1 {
                    buf[idx] = frame.get_clamped(x + dx, y + dy);
                    idx += 1;
                }
            }
            buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out.set(x as usize, y as usize, buf[4]);
        }
    }
    out
}

/// Vectorized Sobel magnitude, native f64.
pub fn sobel_sw(frame: &Frame) -> Frame {
    let mut out = Frame::new(frame.width, frame.height);
    for y in 0..frame.height as isize {
        for x in 0..frame.width as isize {
            let g = |dx: isize, dy: isize| frame.get_clamped(x + dx, y + dy);
            let gx = g(-1, -1) - g(1, -1) + 2.0 * (g(-1, 0) - g(1, 0)) + g(-1, 1) - g(1, 1);
            let gy = g(-1, -1) + 2.0 * g(0, -1) + g(1, -1)
                - g(-1, 1)
                - 2.0 * g(0, 1)
                - g(1, 1);
            out.set(x as usize, y as usize, (gx * gx + gy * gy).sqrt());
        }
    }
    out
}

/// MATLAB-`nlfilter`-style generic filter: `f` is an arbitrary window →
/// pixel function invoked through dynamic dispatch per pixel (this is the
/// software execution model whose 0.074 FPS at 1080p motivates the paper).
pub fn nlfilter_sw(frame: &Frame, ksize: usize, f: &dyn Fn(&[f64]) -> f64) -> Frame {
    let p = (ksize / 2) as isize;
    let mut out = Frame::new(frame.width, frame.height);
    let mut window = vec![0.0f64; ksize * ksize];
    for y in 0..frame.height as isize {
        for x in 0..frame.width as isize {
            let mut idx = 0;
            for dy in -p..=p {
                for dx in -p..=p {
                    window[idx] = frame.get_clamped(x + dx, y + dy);
                    idx += 1;
                }
            }
            out.set(x as usize, y as usize, f(&window));
        }
    }
    out
}

/// The eq. 2 function as a plain closure (native f64) — the body MATLAB
/// would evaluate per pixel.
pub fn eq2_native(w: &[f64]) -> f64 {
    let wp: Vec<f64> = w.iter().map(|&v| v.max(1.0)).collect();
    let f_alpha = 0.5 * ((wp[0] * wp[2]).sqrt() + (wp[6] * wp[8]).sqrt());
    let f_beta = 8.0 * ((wp[1] * wp[7]).log2() + (wp[3] * wp[5]).log2());
    let f_delta = (0.0313 * wp[4]).exp2();
    let (g1, g2) = if f_beta > f_delta {
        (f_delta, f_beta)
    } else {
        (f_beta, f_delta)
    };
    f_alpha * (g1 / g2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::conv::{box_kernel, gaussian3x3};

    #[test]
    fn conv_identity() {
        let f = Frame::test_card(12, 10);
        let mut k = vec![0.0; 9];
        k[4] = 1.0;
        let out = conv_sw(&f, &k, 3);
        assert_eq!(out.data, f.data);
    }

    #[test]
    fn conv_box_preserves_mean_dc() {
        let f = Frame::from_fn(8, 8, |_, _| 40.0);
        let out = conv_sw(&f, &box_kernel(3), 3);
        for &v in &out.data {
            assert!((v - 40.0).abs() < 1e-9);
        }
    }

    #[test]
    fn gaussian_smooths_noise() {
        let f = Frame::noise(32, 32, 9);
        let out = conv_sw(&f, &gaussian3x3(), 3);
        let var = |fr: &Frame| {
            let m = fr.data.iter().sum::<f64>() / fr.data.len() as f64;
            fr.data.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / fr.data.len() as f64
        };
        assert!(var(&out) < var(&f) / 2.0);
    }

    #[test]
    fn median_removes_salt_pepper() {
        let clean = Frame::gradient(32, 32);
        let noisy = Frame::salt_pepper(32, 32, 0.05, 4);
        let denoised = median_sw(&noisy);
        assert!(denoised.psnr(&clean) > noisy.psnr(&clean) + 5.0);
    }

    #[test]
    fn sobel_flat_zero() {
        let f = Frame::from_fn(8, 8, |_, _| 9.0);
        let out = sobel_sw(&f);
        assert!(out.data.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn nlfilter_sw_matches_direct_eq2() {
        let f = Frame::test_card(16, 12);
        let out = nlfilter_sw(&f, 3, &eq2_native);
        // interior spot check
        let x = 7isize;
        let y = 5isize;
        let mut w = Vec::new();
        for dy in -1..=1 {
            for dx in -1..=1 {
                w.push(f.get_clamped(x + dx, y + dy));
            }
        }
        assert_eq!(out.get(7, 5), eq2_native(&w));
    }
}
