//! fp_sobel (§IV-B, eq. 3): gradient magnitude from two 3×3 convolutions,
//! `Φ_o = √(conv(Kx)² + conv(Ky)²)`.

use crate::fpcore::FloatFormat;
use crate::sim::netlist::{Builder, Netlist, SignalId};

/// Sobel horizontal kernel Kx (eq. 3), raster order.
pub const KX: [f64; 9] = [1.0, 0.0, -1.0, 2.0, 0.0, -2.0, 1.0, 0.0, -1.0];
/// Sobel vertical kernel Ky (eq. 3), raster order.
pub const KY: [f64; 9] = [1.0, 2.0, 1.0, 0.0, 0.0, 0.0, -1.0, -2.0, -1.0];

fn conv_into(b: &mut Builder, wins: &[SignalId], k: &[f64; 9]) -> SignalId {
    let prods: Vec<_> = wins.iter().zip(k).map(|(&w, &c)| b.mul_const(w, c)).collect();
    b.adder_tree(&prods)
}

/// Build the fp_sobel datapath.
pub fn sobel_netlist(fmt: FloatFormat) -> Netlist {
    let mut b = Builder::new(fmt);
    let wins: Vec<_> = (0..9)
        .map(|i| b.input(&format!("w{}{}", i / 3, i % 3)))
        .collect();
    let gx = conv_into(&mut b, &wins, &KX);
    let gy = conv_into(&mut b, &wins, &KY);
    b.rename(gx, "gx");
    b.rename(gy, "gy");
    let gx2 = b.mul(gx, gx);
    let gy2 = b.mul(gy, gy);
    let sum = b.add(gx2, gy2);
    let mag = b.sqrt(sum);
    b.rename(mag, "pix_mag");
    b.output("pix_o", mag);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::sim::Engine;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn structure() {
        let nl = sobel_netlist(F16);
        // two 9-tap convolutions + 2 squares = 20 multipliers, 16+2... :
        // each conv: 9 mult_const + 8 adders; plus gx², gy² (mult), one add,
        // one sqrt
        assert_eq!(nl.op_count("mult_const"), 18);
        assert_eq!(nl.op_count("adder"), 17);
        assert_eq!(nl.op_count("mult"), 2);
        assert_eq!(nl.op_count("sqrt"), 1);
        // λ = conv(26) + mul(2) + add(6) + sqrt(5) = 39
        assert_eq!(nl.total_latency(), 39);
    }

    #[test]
    fn flat_window_zero_gradient() {
        let nl = sobel_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[100.0; 9])[0], 0.0);
    }

    #[test]
    fn vertical_edge_response() {
        let nl = sobel_netlist(FloatFormat::new(23, 8));
        let mut eng = Engine::new(&nl, OpMode::Exact);
        // left column 0, right column 255: |gx| = 4·255, gy = 0
        let w = [0.0, 0.0, 255.0, 0.0, 0.0, 255.0, 0.0, 0.0, 255.0];
        let out = eng.eval(&w)[0];
        assert!((out - 4.0 * 255.0).abs() < 1.0, "{out}");
    }

    #[test]
    fn gradient_symmetry() {
        let nl = sobel_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let horiz = [0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 9.0, 9.0, 9.0];
        let vert = [0.0, 0.0, 9.0, 0.0, 0.0, 9.0, 0.0, 0.0, 9.0];
        assert_eq!(eng.eval(&horiz)[0], eng.eval(&vert)[0]);
    }
}
