//! Linear convolution datapaths (§III-B): per-tap multipliers feeding the
//! paper's recursive adder tree.

use crate::fpcore::FloatFormat;
use crate::sim::netlist::{Builder, Netlist};

/// Build the `conv_{k×k}` datapath for kernel coefficients `k` (raster
/// order, length `ksize²`).  Coefficients are quantized into the format at
/// build time (the DSL's hex-literal constants); in the FPGA they live in
/// reconfigurable coefficient registers feeding DSP multipliers.
pub fn conv_netlist(fmt: FloatFormat, ksize: usize, k: &[f64]) -> Netlist {
    conv_netlist_rect(fmt, ksize, ksize, k)
}

/// Rectangular-window convolution: `win_h × win_w` taps in raster order
/// (input `w{r}{c}` = window row `r`, column `c`).
pub fn conv_netlist_rect(fmt: FloatFormat, win_h: usize, win_w: usize, k: &[f64]) -> Netlist {
    assert_eq!(k.len(), win_h * win_w);
    let mut b = Builder::new(fmt);
    let wins: Vec<_> = (0..win_h * win_w)
        .map(|i| b.input(&format!("w{}{}", i / win_w, i % win_w)))
        .collect();
    let prods: Vec<_> = wins
        .iter()
        .zip(k)
        .map(|(&w, &c)| b.mul_const(w, c))
        .collect();
    let sum = b.adder_tree(&prods);
    b.output("pix_o", sum);
    b.build()
}

/// The normalized box (mean) kernel.
pub fn box_kernel(ksize: usize) -> Vec<f64> {
    vec![1.0 / (ksize * ksize) as f64; ksize * ksize]
}

/// 3×3 Gaussian (1/16 · [1 2 1; 2 4 2; 1 2 1]).
pub fn gaussian3x3() -> Vec<f64> {
    [1.0, 2.0, 1.0, 2.0, 4.0, 2.0, 1.0, 2.0, 1.0]
        .iter()
        .map(|v| v / 16.0)
        .collect()
}

/// 5×5 Gaussian (binomial, /256).
pub fn gaussian5x5() -> Vec<f64> {
    let b = [1.0, 4.0, 6.0, 4.0, 1.0];
    let mut k = Vec::with_capacity(25);
    for &r in &b {
        for &c in &b {
            k.push(r * c / 256.0);
        }
    }
    k
}

/// 3×3 Laplacian (edge enhance).
pub fn laplacian3x3() -> Vec<f64> {
    vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::sim::Engine;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn conv3x3_structure() {
        let nl = conv_netlist(F16, 3, &gaussian3x3());
        assert_eq!(nl.inputs.len(), 9);
        assert_eq!(nl.op_count("mult_const"), 9);
        assert_eq!(nl.op_count("adder"), 8);
        // λ = mul(2) + AdderTree(9) 4·6 = 26
        assert_eq!(nl.total_latency(), 26);
    }

    #[test]
    fn conv5x5_structure() {
        let nl = conv_netlist(F16, 5, &gaussian5x5());
        assert_eq!(nl.inputs.len(), 25);
        assert_eq!(nl.op_count("mult_const"), 25);
        assert_eq!(nl.op_count("adder"), 24);
        // λ = mul(2) + AdderTree(25) 5·6 = 32
        assert_eq!(nl.total_latency(), 32);
    }

    #[test]
    fn rect_conv_structure() {
        // 3x5 row-major taps: 15 inputs named by window row/column
        let nl = conv_netlist_rect(F16, 3, 5, &[1.0 / 15.0; 15]);
        assert_eq!(nl.inputs.len(), 15);
        assert_eq!(nl.op_count("mult_const"), 15);
        assert_eq!(nl.op_count("adder"), 14);
        assert!(nl.inputs.iter().any(|i| i == "w04"));
        assert!(nl.inputs.iter().any(|i| i == "w24"));
        assert!(!nl.inputs.iter().any(|i| i == "w40"));
    }

    #[test]
    fn box_filter_averages() {
        let nl = conv_netlist(FloatFormat::new(23, 8), 3, &box_kernel(3));
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let out = eng.eval(&[9.0; 9]);
        assert!((out[0] - 9.0).abs() < 1e-2);
    }

    #[test]
    fn identity_kernel_passes_center() {
        let mut k = vec![0.0; 9];
        k[4] = 1.0;
        let nl = conv_netlist(F16, 3, &k);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let mut w = [0.0; 9];
        for (i, v) in w.iter_mut().enumerate() {
            *v = i as f64;
        }
        assert_eq!(eng.eval(&w)[0], 4.0);
    }
}
