//! The spatial-filter library (§III): hardware datapaths as scheduled
//! netlists, software baselines, and the fixed-point HLS comparator.

pub mod cnn;
pub mod conv;
pub mod fixed;
pub mod median;
pub mod nlfilter;
pub mod sobel;
pub mod software;

use std::borrow::Cow;

use anyhow::{bail, Context, Result};

use crate::fpcore::{FloatFormat, FmtConvert, OpMode};
use crate::sim::{BatchEngine, Engine, KernelExec, Netlist, LANES};
use crate::video::{Frame, StageGeometry, WindowGenerator};

/// The six filters of the paper's evaluation (fig. 11 x-categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterKind {
    Conv3x3,
    Conv5x5,
    Median,
    Nlfilter,
    FpSobel,
    /// Fixed-point HLS baseline — not a custom-float netlist.
    HlsSobel,
}

impl FilterKind {
    pub const ALL: [FilterKind; 6] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
        FilterKind::HlsSobel,
    ];

    /// The four Table-I filters.
    pub const TABLE1: [FilterKind; 4] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
    ];

    /// Every custom-float netlist filter (TABLE1 + Sobel): the population
    /// the engine benches and parity tests sweep.
    pub const NETLIST: [FilterKind; 5] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FilterKind::Conv3x3 => "conv3x3",
            FilterKind::Conv5x5 => "conv5x5",
            FilterKind::Median => "median",
            FilterKind::Nlfilter => "nlfilter",
            FilterKind::FpSobel => "fp_sobel",
            FilterKind::HlsSobel => "hls_sobel",
        }
    }

    pub fn by_name(name: &str) -> Option<FilterKind> {
        Self::ALL.iter().copied().find(|f| f.name() == name)
    }

    pub fn ksize(&self) -> usize {
        match self {
            FilterKind::Conv5x5 => 5,
            _ => 3,
        }
    }
}

/// A filter's identity: one of the paper's built-in datapaths, a window
/// program compiled from DSL source, or a CNN stage (ReLU / max-pool).
/// The runtime treats all of them uniformly — a [`HwFilter`] is a
/// scheduled netlist plus a window geometry, however it was produced —
/// so every variant streams through the same scalar/batched/tiled hot
/// paths.
///
/// Equality is *display identity* only: two `Dsl` specs with the same
/// name compare equal even if they were compiled from different sources.
/// Compare [`HwFilter::netlist`] when program contents matter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FilterSpec {
    Builtin(FilterKind),
    /// A compiled DSL program (name = module/display name).  Also used
    /// for ad-hoc rectangular convolutions ([`HwFilter::conv_rect`]).
    Dsl { name: String },
    /// Pointwise `max(x, 0)` over a 1×1 window.
    Relu,
    /// `k×k` max-pool with its own stride (name precomputed — this is
    /// hit in per-frame metrics/logging paths).
    Pool { name: String, k: usize, stride: usize },
}

impl FilterSpec {
    pub fn name(&self) -> &str {
        match self {
            FilterSpec::Builtin(k) => k.name(),
            FilterSpec::Dsl { name } => name,
            FilterSpec::Relu => "relu",
            FilterSpec::Pool { name, .. } => name,
        }
    }

    /// The built-in kind, when this is not a DSL program or CNN stage.
    pub fn kind(&self) -> Option<FilterKind> {
        match self {
            FilterSpec::Builtin(k) => Some(*k),
            _ => None,
        }
    }
}

/// A hardware filter: a scheduled custom-float datapath fed by the
/// window generator, plus the window geometry ([`StageGeometry`]) that
/// decides how the generator feeds it — window shape, stride, channel
/// planes.
///
/// This is plain data.  Execution state (compiled engines, window
/// generators, row buffers) lives in the executors — [`eval_band`] /
/// [`eval_band_batched`] for a single filter, [`ChainRunner`] for fused
/// chains — so workers never contend on shared caches.
#[derive(Clone)]
pub struct HwFilter {
    pub spec: FilterSpec,
    pub fmt: FloatFormat,
    pub geom: StageGeometry,
    pub netlist: Netlist,
}

impl HwFilter {
    fn from_parts(
        spec: FilterSpec,
        fmt: FloatFormat,
        geom: StageGeometry,
        netlist: Netlist,
    ) -> Self {
        Self { spec, fmt, geom, netlist }
    }

    /// Build a built-in filter datapath.  Conv kernels default to Gaussian
    /// blur (reconfigurable coefficients in the FPGA — see `with_kernel`).
    ///
    /// Errors on [`FilterKind::HlsSobel`]: the fixed-point HLS baseline
    /// has no custom-float netlist and cannot stream through the engine
    /// paths — run it via [`fixed::sobel_fixed_frame`] instead.
    pub fn new(kind: FilterKind, fmt: FloatFormat) -> Result<Self> {
        WindowGenerator::validate_ksize(kind.ksize())
            .with_context(|| format!("building {}", kind.name()))?;
        let g3 = StageGeometry::square(3);
        Ok(match kind {
            FilterKind::Conv3x3 => Self::with_kernel(kind, fmt, &conv::gaussian3x3()),
            FilterKind::Conv5x5 => Self::with_kernel(kind, fmt, &conv::gaussian5x5()),
            FilterKind::Median => {
                Self::from_parts(FilterSpec::Builtin(kind), fmt, g3, median::median_netlist(fmt))
            }
            FilterKind::Nlfilter => Self::from_parts(
                FilterSpec::Builtin(kind),
                fmt,
                g3,
                nlfilter::nlfilter_netlist(fmt),
            ),
            FilterKind::FpSobel => {
                Self::from_parts(FilterSpec::Builtin(kind), fmt, g3, sobel::sobel_netlist(fmt))
            }
            FilterKind::HlsSobel => bail!(
                "hls_sobel is the fixed-point HLS baseline (no custom-float netlist); \
                 run it with `fpspatial run hls_sobel` / filters::fixed::sobel_fixed_frame"
            ),
        })
    }

    /// A convolution with caller-supplied coefficients.
    pub fn with_kernel(kind: FilterKind, fmt: FloatFormat, k: &[f64]) -> Self {
        let ksize = kind.ksize();
        assert!(matches!(kind, FilterKind::Conv3x3 | FilterKind::Conv5x5));
        Self::from_parts(
            FilterSpec::Builtin(kind),
            fmt,
            StageGeometry::square(ksize),
            conv::conv_netlist(fmt, ksize, k),
        )
    }

    /// A rectangular convolution (`win_h × win_w` taps in raster order).
    /// Both axes must be odd, 3..=16 — the same contract square filter
    /// windows obey, applied per axis.
    pub fn conv_rect(fmt: FloatFormat, win_h: usize, win_w: usize, k: &[f64]) -> Result<Self> {
        WindowGenerator::validate_filter_window(win_h, win_w)
            .with_context(|| format!("building conv{win_h}x{win_w}"))?;
        if k.len() != win_h * win_w {
            bail!(
                "conv{win_h}x{win_w} needs {} coefficients (got {})",
                win_h * win_w,
                k.len()
            );
        }
        Ok(Self::from_parts(
            FilterSpec::Dsl { name: format!("conv{win_h}x{win_w}") },
            fmt,
            StageGeometry::rect(win_h, win_w),
            conv::conv_netlist_rect(fmt, win_h, win_w, k),
        ))
    }

    /// The ReLU stage: `max(x, 0)` over a 1×1 window (stride 1).
    pub fn relu(fmt: FloatFormat) -> Self {
        Self::from_parts(FilterSpec::Relu, fmt, StageGeometry::square(1), cnn::relu_netlist(fmt))
    }

    /// A `k×k` max-pool stage with output stride `stride` (the common
    /// CNN pool is `max_pool(fmt, 2, 2)`).  `k` may be even — pooling
    /// windows are top-left aligned, not centred.
    pub fn max_pool(fmt: FloatFormat, k: usize, stride: usize) -> Result<Self> {
        let geom = StageGeometry::square(k).with_stride(stride);
        geom.validate().with_context(|| format!("building maxpool{k}x{k}"))?;
        let name = if stride == k {
            format!("maxpool{k}x{k}")
        } else {
            format!("maxpool{k}x{k}s{stride}")
        };
        Ok(Self::from_parts(
            FilterSpec::Pool { name, k, stride },
            fmt,
            geom,
            cnn::pool_netlist(fmt, k),
        ))
    }

    /// Same filter, subsampling its output on an `stride × stride` grid
    /// (strided convolution — the output frame shrinks to
    /// `ceil(dim / stride)` per axis).
    pub fn with_stride(mut self, stride: usize) -> Self {
        self.geom.stride = stride;
        self
    }

    /// Same filter applied depthwise over `channels` independent planes
    /// stacked vertically in the frame (`frame.height = channels · plane
    /// height`).
    pub fn with_channels(mut self, channels: usize) -> Self {
        self.geom.channels = channels;
        self
    }

    /// Compile a DSL window program (`sliding_window` based) into a
    /// first-class runtime filter: the compiled netlist streams through
    /// the session hot paths, the tiled coordinator and the frame
    /// pipeline exactly like a built-in.  Rectangular windows are
    /// supported; each axis must be odd, 3..=16.
    ///
    /// The program's own `use float(m, e);` directive applies unless
    /// `fmt` overrides it.  Scalar programs (no `sliding_window`) are
    /// rejected — compile those to SystemVerilog with `fpspatial compile`.
    pub fn from_dsl(src: &str, name: &str, fmt: Option<FloatFormat>) -> Result<Self> {
        let c = crate::dsl::compile_with_format(src, name, fmt)?;
        let win = c.window.with_context(|| {
            format!(
                "DSL program `{name}` has no sliding_window — scalar programs \
                 are not spatial filters"
            )
        })?;
        WindowGenerator::validate_filter_window(win.height, win.width)
            .with_context(|| format!("DSL program `{name}` window"))?;
        if c.netlist.outputs.len() != 1 {
            bail!(
                "DSL program `{name}` has {} outputs; spatial filters stream \
                 exactly one pixel per window",
                c.netlist.outputs.len()
            );
        }
        let taps = win.height * win.width;
        if c.netlist.inputs.len() != taps {
            bail!(
                "DSL program `{name}` mixes scalar inputs with the window \
                 ({} input ports, expected the {taps} window taps)",
                c.netlist.inputs.len()
            );
        }
        Ok(Self::from_parts(
            FilterSpec::Dsl { name: c.name },
            c.fmt,
            StageGeometry::rect(win.height, win.width),
            c.netlist,
        ))
    }

    /// Display name (built-in kind name, DSL program name, or CNN stage).
    pub fn name(&self) -> &str {
        self.spec.name()
    }

    /// Output frame dimensions for a `width × height` input (striding
    /// shrinks each axis to `ceil(dim / stride)`; channel planes shrink
    /// independently).
    pub fn output_dims(&self, width: usize, height: usize) -> (usize, usize) {
        self.geom.out_dims(width, height)
    }

    /// Can this filter stream `frame`?  Errors (usable, not a panic) when
    /// the frame is narrower than the window, empty, or not divisible
    /// into the configured channel planes — the check the CLI runs before
    /// processing, which itself panics on a frame that was never checked.
    pub fn check_frame(&self, frame: &Frame) -> Result<()> {
        if frame.height == 0 {
            bail!("`{}` cannot filter an empty frame (height 0)", self.name());
        }
        if frame.height % self.geom.channels != 0 {
            bail!(
                "frame height {} does not divide into the {} channel planes of `{}`",
                frame.height,
                self.geom.channels,
                self.name()
            );
        }
        if frame.width < self.geom.win_w {
            bail!(
                "{}x{} frame is narrower than the {}x{} window of `{}`",
                frame.width,
                frame.height,
                self.geom.win_h,
                self.geom.win_w,
                self.name()
            );
        }
        Ok(())
    }

    /// Datapath pipeline latency in cycles (excludes the window
    /// generator's structural latency `p_bot·W + p_right`).
    pub fn latency(&self) -> u32 {
        self.netlist.total_latency()
    }
}

/// Evaluate output rows `[y0, y1)` of `frame` with a caller-owned scalar
/// engine, writing the band's pixels into `out_rows` (row-major,
/// `(y1 − y0) · out_width` values — band coordinates are *output* rows,
/// which differ from input rows when the stage strides or stacks channel
/// planes).  Band outputs are bit-identical to the same rows of a
/// whole-frame pass, which is what makes intra-frame tiling safe
/// (`ExecPlan::Tiled`).
pub fn eval_band(
    eng: &mut Engine,
    gen: &mut WindowGenerator,
    frame: &Frame,
    y0: usize,
    y1: usize,
    out_rows: &mut [f64],
) {
    assert_eq!(eng.n_outputs(), 1, "spatial filters have one output port");
    let ow = gen.geom().out_width(frame.width);
    assert_eq!(out_rows.len(), (y1 - y0) * ow);
    let mut buf = [0.0f64; 1];
    gen.process_band(frame, y0, y1, |x, y, win| {
        eng.eval_into(win, &mut buf);
        out_rows[(y - y0) * ow + x] = buf[0];
    });
}

/// Lane-batched [`eval_band`]: evaluates up to [`LANES`] windows per tape
/// dispatch and stores each chunk's outputs with one contiguous copy.
pub fn eval_band_batched(
    eng: &mut BatchEngine,
    gen: &mut WindowGenerator,
    frame: &Frame,
    y0: usize,
    y1: usize,
    out_rows: &mut [f64],
) {
    assert_eq!(eng.n_outputs(), 1, "spatial filters have one output port");
    let ow = gen.geom().out_width(frame.width);
    assert_eq!(out_rows.len(), (y1 - y0) * ow);
    let mut olanes = [[0.0f64; LANES]; 1];
    gen.process_band_lanes(frame, y0, y1, |x0, y, n, taps| {
        eng.eval_lanes(taps, &mut olanes);
        let row = (y - y0) * ow;
        out_rows[row + x0..row + x0 + n].copy_from_slice(&olanes[0][..n]);
    });
}

/// [`eval_band_batched`] on the compiled fused kernel — the production
/// hot path (`Session`, pool workers, server streams).  Bit-identical to
/// the batched interpreter: the kernel passes only fuse dispatch, never
/// arithmetic (see `sim::kernel`).
pub fn eval_band_kernel(
    eng: &mut KernelExec,
    gen: &mut WindowGenerator,
    frame: &Frame,
    y0: usize,
    y1: usize,
    out_rows: &mut [f64],
) {
    assert_eq!(eng.n_outputs(), 1, "spatial filters have one output port");
    let ow = gen.geom().out_width(frame.width);
    assert_eq!(out_rows.len(), (y1 - y0) * ow);
    let mut olanes = [[0.0f64; LANES]; 1];
    gen.process_band_lanes(frame, y0, y1, |x0, y, n, taps| {
        eng.eval_lanes(taps, &mut olanes);
        let row = (y - y0) * ow;
        out_rows[row + x0..row + x0 + n].copy_from_slice(&olanes[0][..n]);
    });
}

/// A multi-stage streaming chain: N compiled stages (builtin, DSL, ReLU,
/// pool — mixed) executed in **one** streaming pass.  Stage `i+1`'s
/// window generator is fed row by row from stage `i`'s output instead of
/// a materialised frame, so the whole chain holds only O(Σ win_h) line
/// buffers — no intermediate frames, exactly like cascading window
/// generators in the FPGA fabric (Al-Dujaili & Fahmy, arXiv:1710.05154).
///
/// **Geometry semantics:** stages may use rectangular windows, stride,
/// and depthwise channel planes ([`StageGeometry`]); every stage must
/// agree on the channel count.  A striding stage shrinks the frame, so
/// stage `i+1` consumes stage `i`'s *output* geometry — the fold
/// [`FilterChain::output_dims`] reports where a frame ends up.
///
/// **Border semantics:** every stage applies the same replicate
/// (clamped-edge) border policy a single filter applies at the real frame
/// borders, to *its own input stream*.  The fused chain is bit-identical
/// to sequentially applying each stage to full materialised frames
/// ([`crate::pipeline::CompiledPipeline::run_frame_sequential`]) —
/// asserted by `tests/chain_parity.rs` across the scalar, lane-batched,
/// tiled and streaming execution paths in both numeric modes.
///
/// **Format semantics:** stages may use different [`FloatFormat`]s.  At
/// every boundary where the producing and consuming stages disagree, the
/// chain inserts an explicit converter ([`FmtConvert`], i.e.
/// [`crate::fpcore::convert`]): the producer's output row is re-rounded
/// into the consumer's format — RNE, flush, saturate — before it enters
/// the consumer's window generator, exactly like the `fmt_converter`
/// block between the cascaded modules in fabric
/// ([`FilterChain::emit_sv`]).  Same-format boundaries are plain wires.
pub struct FilterChain {
    stages: Vec<HwFilter>,
    /// Joined display name, computed once — [`FilterChain::name`] is hit
    /// in per-frame metrics/logging paths.
    name: String,
}

impl FilterChain {
    /// Build a chain from compiled stages (at least one; every stage must
    /// be a streaming netlist filter with a single output port, and all
    /// stages must agree on the channel-plane count).
    pub fn new(stages: Vec<HwFilter>) -> Result<Self> {
        if stages.is_empty() {
            bail!("a filter chain needs at least one stage");
        }
        for hw in &stages {
            hw.geom
                .validate()
                .with_context(|| format!("chain stage `{}`", hw.name()))?;
            if hw.netlist.outputs.len() != 1 {
                bail!(
                    "chain stage `{}` has {} output ports; chained filters stream \
                     exactly one pixel per window",
                    hw.name(),
                    hw.netlist.outputs.len()
                );
            }
            if hw.geom.channels != stages[0].geom.channels {
                bail!(
                    "chain stage `{}` runs {} channel planes but `{}` runs {}; \
                     every stage of a chain sees the same plane stack",
                    hw.name(),
                    hw.geom.channels,
                    stages[0].name(),
                    stages[0].geom.channels
                );
            }
        }
        let names: Vec<&str> = stages.iter().map(|hw| hw.name()).collect();
        let name = names.join("->");
        Ok(Self { stages, name })
    }

    pub fn stages(&self) -> &[HwFilter] {
        &self.stages
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Display name: stage names joined in flow order.  Cached at
    /// construction — no per-call allocation (this is called from
    /// per-frame metrics/logging paths).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The depthwise channel-plane count shared by every stage.
    pub fn channels(&self) -> usize {
        self.stages[0].geom.channels
    }

    /// Largest stage window axis.
    pub fn max_ksize(&self) -> usize {
        self.stages.iter().map(|hw| hw.geom.win_h.max(hw.geom.win_w)).max().unwrap_or(0)
    }

    /// Where a `width × height` input frame ends up after every stage's
    /// striding: per stage, each axis shrinks to `ceil(dim / stride)`
    /// (channel planes shrink independently).
    pub fn output_dims(&self, width: usize, height: usize) -> (usize, usize) {
        let c = self.channels();
        let mut w = width;
        let mut ph = height / c;
        for hw in &self.stages {
            w = hw.geom.out_width(w);
            ph = ph.div_ceil(hw.geom.stride);
        }
        (w, c * ph)
    }

    /// Source context rows a final-stage output row needs above (or
    /// below) its own position: the stride-aware fold of per-stage halo
    /// radii, back to front (`h ← h·stride + max(p_top, p_bot)`).  For
    /// stride-1 odd-window chains this reduces to the classic `Σ kᵢ/2`.
    /// Reporting only — banded execution plans exact per-stage row
    /// ranges instead ([`ChainRunner::run_band`]).
    pub fn total_halo(&self) -> usize {
        self.stages
            .iter()
            .rev()
            .fold(0, |h, hw| h * hw.geom.stride + hw.geom.p_top().max(hw.geom.p_bot()))
    }

    /// The explicit converter at each of the `len() − 1` stage
    /// boundaries — `None` where the neighbouring stages share a format
    /// and the boundary is a plain wire.
    pub fn converters(&self) -> Vec<Option<FmtConvert>> {
        self.stages
            .windows(2)
            .map(|p| (p[0].fmt != p[1].fmt).then(|| FmtConvert::new(p[0].fmt, p[1].fmt)))
            .collect()
    }

    /// Does any boundary need a format converter?
    pub fn is_mixed_format(&self) -> bool {
        self.converters().iter().any(Option::is_some)
    }

    /// Stage `i`'s **execution netlist**: the stage datapath with the
    /// boundary converter to stage `i + 1`'s format folded in as a final
    /// `Convert` node — what the chain executors actually compile.  The
    /// kernel compiler then absorbs that node into the producing
    /// instruction's output write (`sim::passes::absorb_converts`), so a
    /// mixed-format boundary costs zero extra tape steps instead of a
    /// full re-walk of every completed row.  Same-format boundaries (and
    /// the last stage) borrow the stage netlist untouched.
    ///
    /// Reporting (`resource_usage`, `emit_sv`, `netlist_json`) stays on
    /// the *hardware* netlists + explicit [`FmtConvert`]s: in fabric the
    /// converter is its own block between the stage modules.
    pub fn exec_netlist(&self, i: usize) -> Cow<'_, Netlist> {
        let hw = &self.stages[i];
        match self.stages.get(i + 1) {
            Some(next) if next.fmt != hw.fmt => {
                Cow::Owned(hw.netlist.with_output_convert(next.fmt))
            }
            _ => Cow::Borrowed(&hw.netlist),
        }
    }

    /// Summed converter pipeline latency (cycles) over the boundaries
    /// that actually convert.
    fn converter_latency(&self) -> u32 {
        self.converters().iter().flatten().map(|c| c.latency()).sum()
    }

    /// Combined datapath latency: the sum of stage netlist latencies plus
    /// the inter-stage converters (cycles) — windows between stages add
    /// the structural part, see [`FilterChain::pipeline_latency_cycles`].
    pub fn datapath_latency(&self) -> u32 {
        self.stages.iter().map(|hw| hw.latency()).sum::<u32>() + self.converter_latency()
    }

    /// End-to-end latency in cycles for `width`-pixel lines: each stage
    /// contributes its window generator's structural latency
    /// (`p_bot` lines + `p_right` pixels *of its own input width* — a
    /// striding stage shrinks the line every stage downstream sees) plus
    /// its datapath pipeline depth, and each mixed-format boundary its
    /// converter's depth.
    pub fn pipeline_latency_cycles(&self, width: usize) -> u64 {
        let mut w = width;
        let mut total = 0u64;
        for hw in &self.stages {
            total += hw.geom.p_bot() as u64 * w as u64
                + hw.geom.p_right() as u64
                + hw.latency() as u64;
            w = hw.geom.out_width(w);
        }
        total + self.converter_latency() as u64
    }

    /// Total line-buffer storage across stages for `width`-pixel input
    /// lines — the O(Σ win_h) memory the fused pass holds instead of
    /// N − 1 intermediate frames.  Each stage stores `win_h − 1` lines of
    /// its own (stride-shrunk) input width per channel plane, at its own
    /// format width.
    pub fn line_buffer_bits(&self, width: usize) -> u64 {
        let c = self.channels() as u64;
        let mut w = width;
        let mut total = 0u64;
        for hw in &self.stages {
            total += (hw.geom.win_h as u64 - 1) * w as u64 * c * hw.fmt.width() as u64;
            w = hw.geom.out_width(w);
        }
        total
    }

    /// Chain-wide FPGA resource estimate (datapaths + line buffers of
    /// every stage, summed) for `line_width`-pixel input lines.
    pub fn resource_usage(&self, line_width: usize) -> crate::resources::Usage {
        crate::resources::estimate_chain(
            self.stages.iter().map(|hw| (&hw.netlist, hw.geom)),
            line_width,
        )
    }

    /// Can this chain stream `frame`?  (Usable error instead of the panic
    /// the run methods raise on unchecked frames.)  Threads the
    /// stride-shrunk dimensions stage to stage, so a later stage whose
    /// window no longer fits the shrunken frame is reported by name.
    pub fn check_frame(&self, frame: &Frame) -> Result<()> {
        let c = self.channels();
        if frame.height == 0 {
            bail!("`{}` cannot filter an empty frame (height 0)", self.name());
        }
        if frame.height % c != 0 {
            bail!(
                "frame height {} does not divide into the {} channel planes of `{}`",
                frame.height,
                c,
                self.name()
            );
        }
        let mut w = frame.width;
        let mut ph = frame.height / c;
        for (i, hw) in self.stages.iter().enumerate() {
            if w < hw.geom.win_w {
                let after = if i == 0 { "" } else { " (after upstream striding)" };
                bail!(
                    "{}x{} frame is narrower than the {}x{} window of `{}`{}",
                    w,
                    ph * c,
                    hw.geom.win_h,
                    hw.geom.win_w,
                    hw.name(),
                    after
                );
            }
            w = hw.geom.out_width(w);
            ph = ph.div_ceil(hw.geom.stride);
        }
        Ok(())
    }

    /// Emit ONE SystemVerilog top module instantiating every stage's
    /// compiled module, the `fmt_converter` blocks between mixed-format
    /// stages, and per-stage `generateWindow` line buffers sized by that
    /// stage's format width (see [`crate::dsl::sverilog::emit_chain`]).
    pub fn emit_sv(&self, top: &str, resolution: (u32, u32)) -> String {
        let stages: Vec<crate::dsl::sverilog::SvStage<'_>> = self
            .stages
            .iter()
            .map(|hw| crate::dsl::sverilog::SvStage {
                name: hw.name(),
                netlist: &hw.netlist,
                geom: hw.geom,
            })
            .collect();
        crate::dsl::sverilog::emit_chain(top, &stages, resolution)
    }

    /// JSON dump of the whole cascade (`compile --emit netlist` for
    /// chains): every stage's scheduled netlist, its window geometry,
    /// plus the inter-stage converters.
    pub fn netlist_json(&self, top: &str) -> crate::util::json::Json {
        use crate::util::json::{num, obj, s, Json};
        let stages = self
            .stages
            .iter()
            .map(|hw| {
                obj(vec![
                    ("name", s(hw.name())),
                    ("win_h", num(hw.geom.win_h as f64)),
                    ("win_w", num(hw.geom.win_w as f64)),
                    ("stride", num(hw.geom.stride as f64)),
                    ("channels", num(hw.geom.channels as f64)),
                    ("netlist", hw.netlist.to_json()),
                ])
            })
            .collect();
        let converters = self
            .converters()
            .into_iter()
            .enumerate()
            .filter_map(|(i, c)| c.map(|c| (i, c)))
            .map(|(i, c)| {
                obj(vec![
                    ("after_stage", num(i as f64)),
                    ("src", crate::sim::netlist::format_to_json(c.src)),
                    ("dst", crate::sim::netlist::format_to_json(c.dst)),
                    ("latency", num(c.latency() as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("top", s(top)),
            ("stages", Json::Arr(stages)),
            ("converters", Json::Arr(converters)),
            ("datapath_latency", num(self.datapath_latency() as f64)),
        ])
    }
}

/// A worker's compiled stage engine — scalar interpreter or fused
/// direct-threaded kernel (shared through the process-wide cache).
enum StageEngine {
    Scalar(Engine),
    Kernel(KernelExec),
}

/// One stage of a fused chain execution: its window generator (the only
/// inter-stage storage), compiled engine, and the output row under
/// construction.  Mixed-format boundaries need no per-row converter pass
/// here: the stage engine is compiled from [`FilterChain::exec_netlist`],
/// which already re-rounds the output into the next stage's format.  The
/// `out_*` fields are the per-plane band plan [`ChainRunner::run_band`]
/// installs before streaming.
struct ChainStage {
    geom: StageGeometry,
    gen: Option<WindowGenerator>,
    eng: StageEngine,
    row_buf: Vec<f64>,
    /// First output row (plane-local) the plan wants from this stage;
    /// earlier emissions (top-border clamping when the planned input
    /// start saturated at row 0) are dropped before they cascade.
    out_start: usize,
    /// One past the last wanted output row; later emissions (bottom
    /// border replay past the band) are dropped likewise.
    out_end: usize,
    /// Does the plan reach this stage's plane bottom (run the
    /// border-replicating `push_finish`)?
    finish: bool,
    /// Output row width (`= ceil(input width / stride)`).
    out_w: usize,
}

/// Per-thread fused executor for a [`FilterChain`]: owns each stage's
/// engine + generator, so pipeline workers can run chains without shared
/// state.
pub struct ChainRunner {
    stages: Vec<ChainStage>,
    channels: usize,
}

impl ChainRunner {
    pub fn new(chain: &FilterChain, mode: OpMode, batched: bool) -> Self {
        let stages: Vec<ChainStage> = chain
            .stages
            .iter()
            .enumerate()
            .map(|(i, hw)| ChainStage {
                geom: hw.geom,
                gen: None,
                // the execution netlist folds the boundary converter (if
                // any) into this stage's datapath
                eng: {
                    let nl = chain.exec_netlist(i);
                    if batched {
                        StageEngine::Kernel(KernelExec::for_netlist(nl.as_ref(), mode))
                    } else {
                        StageEngine::Scalar(Engine::new(nl.as_ref(), mode))
                    }
                },
                row_buf: Vec::new(),
                out_start: 0,
                out_end: 0,
                finish: true,
                out_w: 0,
            })
            .collect();
        Self { stages, channels: chain.channels() }
    }

    /// Where a `width × height` input frame ends up (same fold as
    /// [`FilterChain::output_dims`]).
    pub fn output_dims(&self, width: usize, height: usize) -> (usize, usize) {
        let c = self.channels;
        let mut w = width;
        let mut ph = height / c;
        for st in &self.stages {
            w = st.geom.out_width(w);
            ph = ph.div_ceil(st.geom.stride);
        }
        (w, c * ph)
    }

    /// Fused whole-frame evaluation into a fresh output-geometry frame.
    pub fn run_frame(&mut self, frame: &Frame) -> Frame {
        let (ow, oh) = self.output_dims(frame.width, frame.height);
        let mut out = Frame::new(ow, oh);
        if frame.height > 0 {
            self.run_band(frame, 0, oh, &mut out.data);
        }
        out
    }

    /// Fused evaluation of final-stage **output** rows `[b0, b1)` into
    /// `out_rows` (row-major, `(b1 − b0) · out_width` values),
    /// bit-identical to the same rows of a sequential full-frame
    /// application.
    ///
    /// Banding is planned *exactly*, back to front: for each channel
    /// plane, the wanted output rows `[lo, hi)` of stage `i` require
    /// input rows `[(lo·s − p_top)⁺, min(h, (hi−1)·s + p_bot + 1))` of
    /// stage `i − 1`, recursively down to the source frame — the
    /// stride-aware generalisation of the classic `[y0 − P, y1 + P)`
    /// halo.  Where a stage's planned input start saturated at its plane
    /// top, the generator re-emits clamped top rows the band does not
    /// want; those are drop-filtered before they cascade, so interior
    /// bands stitch seamlessly (`ExecPlan::Tiled`).
    pub fn run_band(&mut self, frame: &Frame, b0: usize, b1: usize, out_rows: &mut [f64]) {
        let n = self.stages.len();
        let c = self.channels;
        let w0 = frame.width;
        assert_eq!(
            frame.height % c,
            0,
            "frame height {} not divisible into {c} planes",
            frame.height
        );
        let ph0 = frame.height / c;
        // Per-stage input widths / plane heights (index i = stage i's
        // input; index n = final output).
        let mut ws = Vec::with_capacity(n + 1);
        let mut phs = Vec::with_capacity(n + 1);
        ws.push(w0);
        phs.push(ph0);
        for st in &self.stages {
            ws.push(st.geom.out_width(*ws.last().unwrap()));
            phs.push(phs.last().unwrap().div_ceil(st.geom.stride));
        }
        let (out_w, oph) = (ws[n], phs[n]);
        assert!(b0 < b1 && b1 <= c * oph, "bad band [{b0}, {b1})");
        assert_eq!(out_rows.len(), (b1 - b0) * out_w);
        for ci in 0..c {
            let base = ci * oph;
            let lo = b0.max(base);
            let hi = b1.min(base + oph);
            if lo >= hi {
                continue;
            }
            let (lo, hi) = (lo - base, hi - base);
            // Backward plan: [los[i], his[i]) = stage i's required input
            // rows; [los[n], his[n]) = the wanted final output rows.
            let mut los = vec![0usize; n + 1];
            let mut his = vec![0usize; n + 1];
            los[n] = lo;
            his[n] = hi;
            for i in (0..n).rev() {
                let g = self.stages[i].geom;
                los[i] = (los[i + 1] * g.stride).saturating_sub(g.p_top());
                his[i] = ((his[i + 1] - 1) * g.stride + g.p_bot() + 1).min(phs[i]);
            }
            for (i, st) in self.stages.iter_mut().enumerate() {
                let gen = WindowGenerator::reuse(&mut st.gen, st.geom, ws[i])
                    .unwrap_or_else(|e| panic!("chain stage: {e} (see FilterChain::check_frame)"));
                gen.begin_push_at(los[i]);
                st.out_start = los[i + 1];
                st.out_end = his[i + 1];
                st.finish = his[i] == phs[i];
                st.out_w = ws[i + 1];
                st.row_buf.clear();
                st.row_buf.resize(ws[i + 1], 0.0);
            }
            let mut emitted = 0usize;
            let mut emit = |oy: usize, row: &[f64]| {
                let o = (base + oy - b0) * out_w;
                out_rows[o..o + out_w].copy_from_slice(row);
                emitted += 1;
            };
            let plane0 = ci * ph0;
            for ay in los[0]..his[0] {
                let row = &frame.data[(plane0 + ay) * w0..(plane0 + ay + 1) * w0];
                push_row_chain(&mut self.stages, row, ay, &mut emit);
            }
            finish_chain(&mut self.stages, &mut emit);
            debug_assert_eq!(emitted, hi - lo, "chain dropped rows");
        }
    }
}

/// Push one input row into the first stage; every output row a stage
/// completes (inside its planned band — see [`ChainStage::out_start`])
/// cascades into the next stage immediately (row granularity — nothing
/// is materialised beyond one row per stage).  Mixed-format boundaries
/// are already re-rounded *inside* the stage engine (the execution
/// netlist's folded `Convert` — no per-row converter pass).  Rows that
/// fall out of the last stage go to `emit` with their plane-local output
/// row index, in order.
fn push_row_chain(
    stages: &mut [ChainStage],
    row: &[f64],
    oy: usize,
    emit: &mut dyn FnMut(usize, &[f64]),
) {
    let Some((first, rest)) = stages.split_first_mut() else {
        emit(oy, row);
        return;
    };
    let gen = first.gen.as_mut().expect("run_band prepares the generators");
    let buf = &mut first.row_buf;
    let (lo, hi, w) = (first.out_start, first.out_end, first.out_w);
    match &mut first.eng {
        StageEngine::Scalar(eng) => {
            let mut out1 = [0.0f64; 1];
            gen.push_row(row, |x, y, win| {
                if y < lo || y >= hi {
                    return;
                }
                eng.eval_into(win, &mut out1);
                buf[x] = out1[0];
                if x + 1 == w {
                    push_row_chain(rest, &buf[..], y, emit);
                }
            });
        }
        StageEngine::Kernel(eng) => {
            let mut olanes = [[0.0f64; LANES]; 1];
            gen.push_row_lanes(row, |x0, y, n, taps| {
                if y < lo || y >= hi {
                    return;
                }
                eng.eval_lanes(taps, &mut olanes);
                buf[x0..x0 + n].copy_from_slice(&olanes[0][..n]);
                if x0 + n == w {
                    push_row_chain(rest, &buf[..], y, emit);
                }
            });
        }
    }
}

/// Flush the chain front to back: finishing stage `i` (bottom-border
/// replication — only where the band plan reaches the plane bottom)
/// emits its last rows, which cascade through stages `i+1..` *before*
/// those stages are finished in turn.
fn finish_chain(stages: &mut [ChainStage], emit: &mut dyn FnMut(usize, &[f64])) {
    let Some((first, rest)) = stages.split_first_mut() else {
        return;
    };
    if first.finish {
        let gen = first.gen.as_mut().expect("run_band prepares the generators");
        let buf = &mut first.row_buf;
        let (lo, hi, w) = (first.out_start, first.out_end, first.out_w);
        match &mut first.eng {
            StageEngine::Scalar(eng) => {
                let mut out1 = [0.0f64; 1];
                gen.push_finish(|x, y, win| {
                    if y < lo || y >= hi {
                        return;
                    }
                    eng.eval_into(win, &mut out1);
                    buf[x] = out1[0];
                    if x + 1 == w {
                        push_row_chain(rest, &buf[..], y, emit);
                    }
                });
            }
            StageEngine::Kernel(eng) => {
                let mut olanes = [[0.0f64; LANES]; 1];
                gen.push_finish_lanes(|x0, y, n, taps| {
                    if y < lo || y >= hi {
                        return;
                    }
                    eng.eval_lanes(taps, &mut olanes);
                    buf[x0..x0 + n].copy_from_slice(&olanes[0][..n]);
                    if x0 + n == w {
                        push_row_chain(rest, &buf[..], y, emit);
                    }
                });
            }
        }
    }
    finish_chain(rest, emit);
}

#[cfg(test)]
mod tests {
    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    const MEDIAN_DSL: &str = include_str!("../../../examples/dsl/median.dsl");
    const FIG12_DSL: &str = include_str!("../../../examples/dsl/fig12.dsl");

    /// Single-filter reference run: caller-owned engine + generator via
    /// `eval_band` over the full output range.
    fn run_hw(hw: &HwFilter, f: &Frame, mode: OpMode) -> Frame {
        let (ow, oh) = hw.output_dims(f.width, f.height);
        let mut out = Frame::new(ow, oh);
        let mut eng = Engine::new(&hw.netlist, mode);
        let mut gen = WindowGenerator::with_geometry(hw.geom, f.width).unwrap();
        eval_band(&mut eng, &mut gen, f, 0, oh, &mut out.data);
        out
    }

    fn run_hw_batched(hw: &HwFilter, f: &Frame, mode: OpMode) -> Frame {
        let (ow, oh) = hw.output_dims(f.width, f.height);
        let mut out = Frame::new(ow, oh);
        let mut eng = BatchEngine::new(&hw.netlist, mode);
        let mut gen = WindowGenerator::with_geometry(hw.geom, f.width).unwrap();
        eval_band_batched(&mut eng, &mut gen, f, 0, oh, &mut out.data);
        out
    }

    /// Sequential chain reference: materialise every intermediate frame,
    /// converting at mixed-format boundaries.
    fn run_seq(chain: &FilterChain, f: &Frame, mode: OpMode) -> Frame {
        let converters = chain.converters();
        let mut cur = run_hw(&chain.stages()[0], f, mode);
        for (i, hw) in chain.stages().iter().enumerate().skip(1) {
            if let Some(cvt) = converters[i - 1] {
                cvt.apply_row(&mut cur.data);
            }
            cur = run_hw(hw, &cur, mode);
        }
        cur
    }

    #[test]
    fn all_filters_build_and_run() {
        let f = Frame::test_card(24, 16);
        for kind in FilterKind::TABLE1 {
            let hw = HwFilter::new(kind, F16).unwrap();
            let out = run_hw(&hw, &f, OpMode::Exact);
            assert_eq!(out.width, 24);
            assert!(out.data.iter().all(|v| v.is_finite()), "{}", kind.name());
        }
        let sob = HwFilter::new(FilterKind::FpSobel, F16).unwrap();
        let out = run_hw(&sob, &f, OpMode::Exact);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn paper_latencies_by_filter() {
        let lat = |k| HwFilter::new(k, F16).unwrap().latency();
        assert_eq!(lat(FilterKind::Conv3x3), 26);
        assert_eq!(lat(FilterKind::Conv5x5), 32);
        assert_eq!(lat(FilterKind::Median), 19);
        assert_eq!(lat(FilterKind::Nlfilter), 26);
        assert_eq!(lat(FilterKind::FpSobel), 39);
    }

    #[test]
    fn hls_sobel_is_a_usable_error_not_a_panic() {
        let err = HwFilter::new(FilterKind::HlsSobel, F16).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("hls_sobel"), "{msg}");
        assert!(msg.contains("sobel_fixed_frame"), "{msg}");
    }

    #[test]
    fn from_dsl_is_a_first_class_filter() {
        let hw = HwFilter::from_dsl(MEDIAN_DSL, "median_dsl", None).unwrap();
        assert_eq!(hw.spec, FilterSpec::Dsl { name: "median_dsl".to_string() });
        assert_eq!(hw.name(), "median_dsl");
        assert_eq!(hw.spec.kind(), None);
        assert_eq!(hw.fmt, F16);
        assert_eq!(hw.geom, StageGeometry::square(3));
        assert_eq!(hw.latency(), 19);
        // streams through the same engine paths as a built-in
        let f = Frame::test_card(25, 14);
        let want = run_hw(&HwFilter::new(FilterKind::Median, F16).unwrap(), &f, OpMode::Exact);
        assert_eq!(run_hw(&hw, &f, OpMode::Exact).data, want.data);
        assert_eq!(run_hw_batched(&hw, &f, OpMode::Exact).data, want.data);
    }

    #[test]
    fn from_dsl_format_override() {
        let hw =
            HwFilter::from_dsl(MEDIAN_DSL, "median_wide", Some(FloatFormat::new(23, 8))).unwrap();
        assert_eq!(hw.fmt, FloatFormat::new(23, 8));
        let f = Frame::salt_pepper(20, 12, 0.1, 3);
        let want = run_hw(
            &HwFilter::new(FilterKind::Median, FloatFormat::new(23, 8)).unwrap(),
            &f,
            OpMode::Exact,
        );
        assert_eq!(run_hw(&hw, &f, OpMode::Exact).data, want.data);
    }

    #[test]
    fn from_dsl_rejects_scalar_programs() {
        let err = HwFilter::from_dsl(FIG12_DSL, "fig12", None).unwrap_err();
        assert!(format!("{err:#}").contains("sliding_window"), "{err:#}");
    }

    #[test]
    fn hw_median_matches_software_median_on_noise() {
        // With a wide format the quantized hardware median equals the
        // software median of the two-footprint design... they differ by
        // design (2×SORT5 vs full SORT9), so compare against the same
        // footprint algorithm instead.
        let f = Frame::salt_pepper(20, 14, 0.1, 8);
        let hw = HwFilter::new(FilterKind::Median, FloatFormat::new(39, 8)).unwrap();
        let out = run_hw(&hw, &f, OpMode::Exact);
        // mean of two footprint medians, computed directly
        let want = crate::video::map_windows(&f, 3, |w| {
            let med5 = |idx: [usize; 5]| {
                let mut v = idx.map(|i| w[i]);
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[2]
            };
            (med5(median::FOOTPRINT_A) + med5(median::FOOTPRINT_B)) / 2.0
        });
        assert!(out.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn batched_matches_scalar_on_ragged_width() {
        // 37 = 2·16 + 5: exercises the ragged right-edge lanes
        let f = Frame::test_card(37, 12);
        for kind in FilterKind::TABLE1 {
            let hw = HwFilter::new(kind, F16).unwrap();
            let scalar = run_hw(&hw, &f, OpMode::Exact);
            let batched = run_hw_batched(&hw, &f, OpMode::Exact);
            assert_eq!(scalar.data, batched.data, "{}", kind.name());
        }
    }

    #[test]
    fn relu_and_pool_stages_build() {
        let relu = HwFilter::relu(F16);
        assert_eq!(relu.name(), "relu");
        assert_eq!(relu.spec.kind(), None);
        assert_eq!(relu.geom, StageGeometry::square(1));
        assert_eq!(relu.latency(), 1);
        let f = Frame::test_card(13, 9);
        let out = run_hw(&relu, &f, OpMode::Exact);
        assert_eq!((out.width, out.height), (13, 9));
        for (got, src) in out.data.iter().zip(&f.data) {
            assert_eq!(got.to_bits(), src.max(0.0).to_bits());
        }

        let pool = HwFilter::max_pool(F16, 2, 2).unwrap();
        assert_eq!(pool.name(), "maxpool2x2");
        assert_eq!(pool.geom, StageGeometry::square(2).with_stride(2));
        assert_eq!(pool.latency(), 3);
        assert_eq!(HwFilter::max_pool(F16, 3, 1).unwrap().name(), "maxpool3x3s1");
        assert!(HwFilter::max_pool(F16, 0, 1).is_err());
        assert!(HwFilter::max_pool(F16, 2, 0).is_err());
        assert!(HwFilter::max_pool(F16, 17, 17).is_err());
    }

    #[test]
    fn pool_matches_naive_reference() {
        // 7×5 input, 2×2/s2 pool → 4×3 ceil-mode output (top-left
        // aligned, right/bottom edges replicate-clamped)
        let f = Frame::test_card(7, 5);
        let pool = HwFilter::max_pool(FloatFormat::new(23, 8), 2, 2).unwrap();
        let out = run_hw(&pool, &f, OpMode::Exact);
        assert_eq!((out.width, out.height), (4, 3));
        let at = |x: usize, y: usize| f.data[y.min(4) * 7 + x.min(6)];
        for oy in 0..3 {
            for ox in 0..4 {
                let (x, y) = (ox * 2, oy * 2);
                let want = at(x, y).max(at(x + 1, y)).max(at(x, y + 1)).max(at(x + 1, y + 1));
                assert_eq!(out.data[oy * 4 + ox].to_bits(), want.to_bits(), "({ox},{oy})");
            }
        }
    }

    #[test]
    fn strided_conv_shrinks_output() {
        let f = Frame::test_card(23, 11);
        let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap();
        let full = run_hw(&hw, &f, OpMode::Exact);
        let strided = hw.clone().with_stride(2);
        let out = run_hw(&strided, &f, OpMode::Exact);
        assert_eq!((out.width, out.height), (12, 6));
        // strided output = full output subsampled on the stride grid
        for oy in 0..6 {
            for ox in 0..12 {
                assert_eq!(
                    out.data[oy * 12 + ox].to_bits(),
                    full.data[(oy * 2) * 23 + ox * 2].to_bits(),
                    "({ox},{oy})"
                );
            }
        }
    }

    #[test]
    fn conv_rect_builds_and_validates() {
        let hw = HwFilter::conv_rect(F16, 3, 5, &[1.0 / 15.0; 15]).unwrap();
        assert_eq!(hw.name(), "conv3x5");
        assert_eq!(hw.geom, StageGeometry::rect(3, 5));
        let f = Frame::test_card(21, 9);
        let out = run_hw(&hw, &f, OpMode::Exact);
        assert_eq!((out.width, out.height), (21, 9));
        // even/oversized axes and wrong tap counts are usable errors
        assert!(HwFilter::conv_rect(F16, 2, 3, &[0.0; 6]).is_err());
        assert!(HwFilter::conv_rect(F16, 3, 17, &[0.0; 51]).is_err());
        let err = HwFilter::conv_rect(F16, 3, 5, &[0.0; 9]).unwrap_err();
        assert!(format!("{err:#}").contains("15 coefficients"), "{err:#}");
    }

    #[test]
    fn eval_band_covers_frame_in_pieces() {
        let f = Frame::test_card(20, 15);
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let want = run_hw(&hw, &f, OpMode::Exact);
        let mut eng = crate::sim::Engine::new(&hw.netlist, OpMode::Exact);
        let mut gen = WindowGenerator::with_geometry(hw.geom, f.width).unwrap();
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 5usize), (5, 11), (11, 15)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            eval_band(&mut eng, &mut gen, &f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn conv_by_name_round_trip() {
        for kind in FilterKind::ALL {
            assert_eq!(FilterKind::by_name(kind.name()), Some(kind));
        }
    }

    #[test]
    fn check_frame_reports_usable_errors() {
        let hw = HwFilter::new(FilterKind::Conv5x5, F16).unwrap();
        assert!(hw.check_frame(&Frame::test_card(24, 16)).is_ok());
        let err = hw.check_frame(&Frame::test_card(4, 16)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        assert!(err.to_string().contains("conv5x5"), "{err}");
        let err = hw.check_frame(&Frame::new(24, 0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        // channel planes must divide the frame height
        let hw3 = hw.with_channels(3);
        let err = hw3.check_frame(&Frame::test_card(24, 16)).unwrap_err();
        assert!(err.to_string().contains("channel planes"), "{err}");
        assert!(hw3.check_frame(&Frame::test_card(24, 15)).is_ok());
    }

    fn two_stage_chain() -> FilterChain {
        FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn chain_construction_and_reporting() {
        let chain = two_stage_chain();
        assert_eq!(chain.len(), 2);
        assert!(!chain.is_empty());
        assert_eq!(chain.name(), "median->fp_sobel");
        assert_eq!(chain.max_ksize(), 3);
        assert_eq!(chain.channels(), 1);
        assert_eq!(chain.total_halo(), 2);
        assert_eq!(chain.output_dims(100, 60), (100, 60));
        assert_eq!(chain.datapath_latency(), 19 + 39);
        // per stage: p·W + p + datapath = 1·100 + 1 + lat
        assert_eq!(chain.pipeline_latency_cycles(100), (100 + 1 + 19) + (100 + 1 + 39));
        // two 3x3 stages at f16: 2 line buffers x width x 16 bits each
        assert_eq!(chain.line_buffer_bits(100), 2 * (2 * 100 * 16));
        assert!(FilterChain::new(vec![]).is_err());
    }

    #[test]
    fn chain_rejects_channel_mismatch() {
        let err = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap().with_channels(3),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("channel planes"), "{err}");
    }

    #[test]
    fn chain_fused_matches_sequential() {
        let chain = two_stage_chain();
        let f = Frame::test_card(37, 15); // ragged width
        for mode in [OpMode::Exact, OpMode::Poly] {
            let want = run_seq(&chain, &f, mode);
            let fused = ChainRunner::new(&chain, mode, false).run_frame(&f);
            let batched = ChainRunner::new(&chain, mode, true).run_frame(&f);
            for (i, (w, g)) in want.data.iter().zip(&fused.data).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} scalar pixel {i}");
            }
            for (i, (w, g)) in want.data.iter().zip(&batched.data).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} batched pixel {i}");
            }
        }
    }

    #[test]
    fn chain_runner_band_matches_whole_frame() {
        let chain = two_stage_chain();
        let f = Frame::salt_pepper(29, 17, 0.1, 3);
        let want = run_seq(&chain, &f, OpMode::Exact);
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, true);
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 5usize), (5, 11), (11, 17)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            runner.run_band(&f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn single_stage_chain_matches_filter() {
        let hw = HwFilter::new(FilterKind::Nlfilter, F16).unwrap();
        let chain =
            FilterChain::new(vec![HwFilter::new(FilterKind::Nlfilter, F16).unwrap()]).unwrap();
        let f = Frame::test_card(21, 12);
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, false);
        assert_eq!(runner.run_frame(&f).data, run_hw(&hw, &f, OpMode::Exact).data);
    }

    #[test]
    fn chain_mixes_dsl_and_builtin_stages() {
        let chain = FilterChain::new(vec![
            HwFilter::from_dsl(MEDIAN_DSL, "median_dsl", None).unwrap(),
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap(),
        ])
        .unwrap();
        assert_eq!(chain.name(), "median_dsl->conv3x3");
        let f = Frame::test_card(20, 13);
        let want = run_seq(&chain, &f, OpMode::Exact);
        assert_eq!(ChainRunner::new(&chain, OpMode::Exact, true).run_frame(&f).data, want.data);
    }

    #[test]
    fn cnn_shaped_chain_matches_sequential() {
        // conv→relu→pool with a stride-2 conv: every stage reshapes the
        // frame, mixed per-layer formats convert at both boundaries
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, FloatFormat::new(16, 7)).unwrap().with_stride(2),
            HwFilter::relu(F16),
            HwFilter::max_pool(F16, 2, 2).unwrap(),
        ])
        .unwrap();
        assert_eq!(chain.name(), "conv3x3->relu->maxpool2x2");
        let f = Frame::test_card(37, 21);
        // 37×21 → conv/s2 → 19×11 → relu → 19×11 → pool/s2 → 10×6
        assert_eq!(chain.output_dims(37, 21), (10, 6));
        for mode in [OpMode::Exact, OpMode::Poly] {
            let want = run_seq(&chain, &f, mode);
            assert_eq!((want.width, want.height), (10, 6));
            for batched in [false, true] {
                let got = ChainRunner::new(&chain, mode, batched).run_frame(&f);
                assert_eq!((got.width, got.height), (10, 6));
                for (i, (w, g)) in want.data.iter().zip(&got.data).enumerate() {
                    assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} batched={batched} pixel {i}");
                }
            }
        }
    }

    #[test]
    fn strided_chain_bands_match_whole_frame() {
        // band boundaries land between the stride grids of both stages
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2),
            HwFilter::max_pool(F16, 2, 2).unwrap(),
        ])
        .unwrap();
        let f = Frame::salt_pepper(33, 29, 0.1, 7);
        let (ow, oh) = chain.output_dims(33, 29);
        assert_eq!((ow, oh), (9, 8));
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, true);
        let want = runner.run_frame(&f);
        assert_eq!(want.data, run_seq(&chain, &f, OpMode::Exact).data);
        let mut got = Frame::new(ow, oh);
        for (b0, b1) in [(0usize, 3usize), (3, 4), (4, 8)] {
            let band = &mut got.data[b0 * ow..b1 * ow];
            runner.run_band(&f, b0, b1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn channel_plane_chain_matches_per_plane_runs() {
        // a 2-plane chain equals running each plane through a 1-plane chain
        let mk = |c: usize| {
            FilterChain::new(vec![
                HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_channels(c),
                HwFilter::max_pool(F16, 2, 2).unwrap().with_channels(c),
            ])
            .unwrap()
        };
        let top = Frame::test_card(19, 7);
        let bot = Frame::salt_pepper(19, 7, 0.1, 5);
        let mut stacked = Frame::new(19, 14);
        stacked.data[..19 * 7].copy_from_slice(&top.data);
        stacked.data[19 * 7..].copy_from_slice(&bot.data);
        let out = ChainRunner::new(&mk(2), OpMode::Exact, true).run_frame(&stacked);
        assert_eq!((out.width, out.height), (10, 8));
        let mut single = ChainRunner::new(&mk(1), OpMode::Exact, true);
        let want_top = single.run_frame(&top);
        let want_bot = single.run_frame(&bot);
        assert_eq!(&out.data[..10 * 4], &want_top.data[..]);
        assert_eq!(&out.data[10 * 4..], &want_bot.data[..]);
    }

    #[test]
    fn chain_check_frame_names_the_offending_stage() {
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::Conv5x5, F16).unwrap(),
        ])
        .unwrap();
        let err = chain.check_frame(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        assert!(err.to_string().contains("conv5x5"), "{err}");
        // a stride-shrunk intermediate frame that no longer fits the next
        // window names the downstream stage
        let strided = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2),
            HwFilter::new(FilterKind::Conv5x5, F16).unwrap(),
        ])
        .unwrap();
        assert!(strided.check_frame(&Frame::test_card(10, 8)).is_ok());
        let err = strided.check_frame(&Frame::test_card(8, 8)).unwrap_err();
        assert!(err.to_string().contains("conv5x5"), "{err}");
        assert!(err.to_string().contains("striding"), "{err}");
    }

    #[test]
    fn from_dsl_rejects_oversized_windows_upfront() {
        assert!(WindowGenerator::validate_ksize(17).is_err());
        assert!(WindowGenerator::validate_ksize(2).is_err());
        assert!(WindowGenerator::validate_ksize(5).is_ok());
    }

    #[test]
    fn strided_total_halo_is_stride_aware() {
        // 3x3/s2 then 3x3: halo = (1·2 + 1) = 3 source rows, not 1+1
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap().with_stride(2),
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap(),
        ])
        .unwrap();
        assert_eq!(chain.total_halo(), 3);
        // pool stages count their bottom pad (2x2: p_top 0, p_bot 1)
        let pooled = FilterChain::new(vec![HwFilter::max_pool(F16, 2, 2).unwrap()]).unwrap();
        assert_eq!(pooled.total_halo(), 1);
    }

    const F24: FloatFormat = FloatFormat::new(16, 7);
    const F14: FloatFormat = FloatFormat::new(7, 6);

    fn mixed_chain() -> FilterChain {
        FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F24).unwrap(),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn uniform_chain_has_no_converters() {
        let chain = two_stage_chain();
        assert_eq!(chain.converters(), vec![None]);
        assert!(!chain.is_mixed_format());
        // latency identical to the plain stage sum (no converter cycles)
        assert_eq!(chain.datapath_latency(), 19 + 39);
    }

    #[test]
    fn mixed_chain_reports_its_boundary_converter() {
        let chain = mixed_chain();
        assert_eq!(chain.converters(), vec![Some(FmtConvert::new(F24, F16))]);
        assert!(chain.is_mixed_format());
        // converter cycles are part of the cascade latency
        assert_eq!(chain.datapath_latency(), 19 + 39 + 2);
        assert_eq!(chain.pipeline_latency_cycles(100), (100 + 1 + 19) + 2 + (100 + 1 + 39));
        // line buffers stay per-stage width: one 24-bit + one 16-bit stage
        assert_eq!(chain.line_buffer_bits(100), 2 * 100 * 24 + 2 * 100 * 16);
    }

    #[test]
    fn mixed_chain_fused_matches_sequential_quantized() {
        let chain = mixed_chain();
        let f = Frame::test_card(37, 15); // ragged width
        for mode in [OpMode::Exact, OpMode::Poly] {
            // independent reference: materialise, quantize into the next
            // stage's format by hand, run the next stage
            let s0 = HwFilter::new(FilterKind::Median, F24).unwrap();
            let s1 = HwFilter::new(FilterKind::FpSobel, F16).unwrap();
            let mut mid = run_hw(&s0, &f, mode);
            for v in &mut mid.data {
                *v = crate::fpcore::quantize(*v, F16);
            }
            let want = run_hw(&s1, &mid, mode);
            for (label, got) in [
                ("sequential", run_seq(&chain, &f, mode)),
                ("fused scalar", ChainRunner::new(&chain, mode, false).run_frame(&f)),
                ("fused batched", ChainRunner::new(&chain, mode, true).run_frame(&f)),
            ] {
                for (i, (w, g)) in want.data.iter().zip(&got.data).enumerate() {
                    assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} {label} pixel {i}");
                }
            }
        }
    }

    #[test]
    fn mixed_chain_narrow_stage_output_is_on_its_grid() {
        // after a wide->narrow boundary the narrow stage only ever sees
        // narrow-format values, so its selection-only ops (median) can
        // no longer leak wide values through
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, F24).unwrap(),
            HwFilter::new(FilterKind::Median, F14).unwrap(),
        ])
        .unwrap();
        let f = Frame::salt_pepper(23, 13, 0.1, 5);
        let out = ChainRunner::new(&chain, OpMode::Exact, true).run_frame(&f);
        for (i, &v) in out.data.iter().enumerate() {
            assert_eq!(
                crate::fpcore::quantize(v, F14).to_bits(),
                v.to_bits(),
                "pixel {i} = {v} not a float14(7,6) value"
            );
        }
    }

    #[test]
    fn mixed_chain_band_runner_matches_whole_frame() {
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv5x5, F24).unwrap(),
            HwFilter::new(FilterKind::Median, F16).unwrap(),
        ])
        .unwrap();
        let f = Frame::salt_pepper(29, 17, 0.1, 11);
        let want = run_seq(&chain, &f, OpMode::Exact);
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, true);
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 4usize), (4, 12), (12, 17)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            runner.run_band(&f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn chain_netlist_json_lists_stages_and_converters() {
        let chain = mixed_chain();
        let txt = chain.netlist_json("cascade").to_string();
        let v = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(v.get("top").unwrap().as_str(), Some("cascade"));
        let stages = v.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].get("win_h").unwrap().as_usize(), Some(3));
        assert_eq!(stages[0].get("stride").unwrap().as_usize(), Some(1));
        let cvts = v.get("converters").unwrap().as_arr().unwrap();
        assert_eq!(cvts.len(), 1);
        assert_eq!(cvts[0].get("after_stage").unwrap().as_usize(), Some(0));
        assert_eq!(cvts[0].get("src").unwrap().get("mantissa").unwrap().as_usize(), Some(16));
        assert_eq!(cvts[0].get("dst").unwrap().get("mantissa").unwrap().as_usize(), Some(10));
        // uniform chains serialize an empty converter list
        let uni = two_stage_chain();
        let v = crate::util::json::Json::parse(&uni.netlist_json("c").to_string()).unwrap();
        assert!(v.get("converters").unwrap().as_arr().unwrap().is_empty());
    }
}
