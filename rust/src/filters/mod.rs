//! The spatial-filter library (§III): hardware datapaths as scheduled
//! netlists, software baselines, and the fixed-point HLS comparator.

pub mod conv;
pub mod fixed;
pub mod median;
pub mod nlfilter;
pub mod sobel;
pub mod software;

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::fpcore::{FloatFormat, FmtConvert, OpMode};
use crate::sim::{BatchEngine, Engine, Netlist, LANES};
use crate::video::{Frame, WindowGenerator};

/// The six filters of the paper's evaluation (fig. 11 x-categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterKind {
    Conv3x3,
    Conv5x5,
    Median,
    Nlfilter,
    FpSobel,
    /// Fixed-point HLS baseline — not a custom-float netlist.
    HlsSobel,
}

impl FilterKind {
    pub const ALL: [FilterKind; 6] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
        FilterKind::HlsSobel,
    ];

    /// The four Table-I filters.
    pub const TABLE1: [FilterKind; 4] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
    ];

    /// Every custom-float netlist filter (TABLE1 + Sobel): the population
    /// the engine benches and parity tests sweep.
    pub const NETLIST: [FilterKind; 5] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FilterKind::Conv3x3 => "conv3x3",
            FilterKind::Conv5x5 => "conv5x5",
            FilterKind::Median => "median",
            FilterKind::Nlfilter => "nlfilter",
            FilterKind::FpSobel => "fp_sobel",
            FilterKind::HlsSobel => "hls_sobel",
        }
    }

    pub fn by_name(name: &str) -> Option<FilterKind> {
        Self::ALL.iter().copied().find(|f| f.name() == name)
    }

    pub fn ksize(&self) -> usize {
        match self {
            FilterKind::Conv5x5 => 5,
            _ => 3,
        }
    }
}

/// The cached engines/generator are rebuilt-on-demand and never left
/// half-updated, so a panic while a cache lock is held (e.g. a bad-band
/// assert in a caller-supplied frame) must not poison the filter for
/// subsequent calls.
#[inline]
fn unpoison<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

/// Index into the per-mode engine caches.
#[inline]
fn mode_idx(mode: OpMode) -> usize {
    match mode {
        OpMode::Exact => 0,
        OpMode::Poly => 1,
    }
}

/// Index into the per-(mode, batched) chain-runner cache.
#[inline]
fn runner_idx(mode: OpMode, batched: bool) -> usize {
    mode_idx(mode) * 2 + batched as usize
}

/// A filter's identity: one of the paper's built-in datapaths, or a
/// window program compiled from DSL source.  The runtime treats both
/// uniformly — a [`HwFilter`] is a scheduled netlist plus a window size,
/// however it was produced — so DSL programs stream through the same
/// scalar/batched/tiled hot paths as the built-ins.
///
/// Equality is *display identity* only: two `Dsl` specs with the same
/// name compare equal even if they were compiled from different sources.
/// Compare [`HwFilter::netlist`] when program contents matter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FilterSpec {
    Builtin(FilterKind),
    /// A compiled DSL program (name = module/display name).
    Dsl { name: String },
}

impl FilterSpec {
    pub fn name(&self) -> &str {
        match self {
            FilterSpec::Builtin(k) => k.name(),
            FilterSpec::Dsl { name } => name,
        }
    }

    /// The built-in kind, when this is not a DSL program.
    pub fn kind(&self) -> Option<FilterKind> {
        match self {
            FilterSpec::Builtin(k) => Some(*k),
            FilterSpec::Dsl { .. } => None,
        }
    }
}

/// A hardware filter: a scheduled custom-float datapath fed by the
/// window generator.
///
/// Compiled engines (scalar and lane-batched, one per [`OpMode`]) and the
/// window generator are cached behind mutexes, so repeated
/// [`HwFilter::run_frame`] / [`HwFilter::run_frame_batched`] calls pay
/// the netlist→tape compilation and scratch allocation once.  Concurrent
/// calls on the *same* `HwFilter` serialize on those caches; parallel
/// workers (the coordinator) build their own engines from
/// [`HwFilter::netlist`] instead and use [`eval_band`] /
/// [`eval_band_batched`] directly.
pub struct HwFilter {
    pub spec: FilterSpec,
    pub fmt: FloatFormat,
    pub ksize: usize,
    pub netlist: Netlist,
    /// Cached scalar engines, indexed by [`mode_idx`].
    scalar_cache: [Mutex<Option<Engine>>; 2],
    /// Cached lane-batched engines, indexed by [`mode_idx`].
    batch_cache: [Mutex<Option<BatchEngine>>; 2],
    /// Cached window generator (rebuilt when the frame width changes).
    gen_cache: Mutex<Option<WindowGenerator>>,
}

impl HwFilter {
    fn from_parts(spec: FilterSpec, fmt: FloatFormat, ksize: usize, netlist: Netlist) -> Self {
        Self {
            spec,
            fmt,
            ksize,
            netlist,
            scalar_cache: Default::default(),
            batch_cache: Default::default(),
            gen_cache: Mutex::new(None),
        }
    }

    /// Build a built-in filter datapath.  Conv kernels default to Gaussian
    /// blur (reconfigurable coefficients in the FPGA — see `with_kernel`).
    ///
    /// Errors on [`FilterKind::HlsSobel`]: the fixed-point HLS baseline
    /// has no custom-float netlist and cannot stream through the engine
    /// paths — run it via [`fixed::sobel_fixed_frame`] instead.
    pub fn new(kind: FilterKind, fmt: FloatFormat) -> Result<Self> {
        WindowGenerator::validate_ksize(kind.ksize())
            .with_context(|| format!("building {}", kind.name()))?;
        Ok(match kind {
            FilterKind::Conv3x3 => Self::with_kernel(kind, fmt, &conv::gaussian3x3()),
            FilterKind::Conv5x5 => Self::with_kernel(kind, fmt, &conv::gaussian5x5()),
            FilterKind::Median => {
                Self::from_parts(FilterSpec::Builtin(kind), fmt, 3, median::median_netlist(fmt))
            }
            FilterKind::Nlfilter => Self::from_parts(
                FilterSpec::Builtin(kind),
                fmt,
                3,
                nlfilter::nlfilter_netlist(fmt),
            ),
            FilterKind::FpSobel => {
                Self::from_parts(FilterSpec::Builtin(kind), fmt, 3, sobel::sobel_netlist(fmt))
            }
            FilterKind::HlsSobel => bail!(
                "hls_sobel is the fixed-point HLS baseline (no custom-float netlist); \
                 run it with `fpspatial run hls_sobel` / filters::fixed::sobel_fixed_frame"
            ),
        })
    }

    /// A convolution with caller-supplied coefficients.
    pub fn with_kernel(kind: FilterKind, fmt: FloatFormat, k: &[f64]) -> Self {
        let ksize = kind.ksize();
        assert!(matches!(kind, FilterKind::Conv3x3 | FilterKind::Conv5x5));
        Self::from_parts(
            FilterSpec::Builtin(kind),
            fmt,
            ksize,
            conv::conv_netlist(fmt, ksize, k),
        )
    }

    /// Compile a DSL window program (`sliding_window` based) into a
    /// first-class runtime filter: the compiled netlist streams through
    /// [`HwFilter::run_frame`], [`HwFilter::run_frame_batched`], the
    /// tiled coordinator and the frame pipeline exactly like a built-in.
    ///
    /// The program's own `use float(m, e);` directive applies unless
    /// `fmt` overrides it.  Scalar programs (no `sliding_window`) are
    /// rejected — compile those to SystemVerilog with `fpspatial compile`.
    pub fn from_dsl(src: &str, name: &str, fmt: Option<FloatFormat>) -> Result<Self> {
        let c = crate::dsl::compile_with_format(src, name, fmt)?;
        let win = c.window.with_context(|| {
            format!(
                "DSL program `{name}` has no sliding_window — scalar programs \
                 are not spatial filters"
            )
        })?;
        if win.height != win.width {
            bail!(
                "DSL program `{name}` uses a {}x{} window; the streaming runtime \
                 supports square windows only",
                win.height,
                win.width
            );
        }
        WindowGenerator::validate_ksize(win.height)
            .with_context(|| format!("DSL program `{name}` window"))?;
        if c.netlist.outputs.len() != 1 {
            bail!(
                "DSL program `{name}` has {} outputs; spatial filters stream \
                 exactly one pixel per window",
                c.netlist.outputs.len()
            );
        }
        let taps = win.height * win.width;
        if c.netlist.inputs.len() != taps {
            bail!(
                "DSL program `{name}` mixes scalar inputs with the window \
                 ({} input ports, expected the {taps} window taps)",
                c.netlist.inputs.len()
            );
        }
        Ok(Self::from_parts(
            FilterSpec::Dsl { name: c.name },
            c.fmt,
            win.height,
            c.netlist,
        ))
    }

    /// Display name (built-in kind name or the DSL program name).
    pub fn name(&self) -> &str {
        self.spec.name()
    }

    /// Can this filter stream `frame`?  Errors (usable, not a panic) when
    /// the frame is narrower than the window or empty — the check the CLI
    /// runs before `run_frame`-style calls, which themselves panic on a
    /// frame that was never checked.
    pub fn check_frame(&self, frame: &Frame) -> Result<()> {
        if frame.height == 0 {
            bail!("`{}` cannot filter an empty frame (height 0)", self.name());
        }
        if frame.width < self.ksize {
            bail!(
                "{}x{} frame is narrower than the {}x{} window of `{}`",
                frame.width,
                frame.height,
                self.ksize,
                self.ksize,
                self.name()
            );
        }
        Ok(())
    }

    /// Run `f` with the cached window generator for `width` (rebuilding it
    /// if the width changed since the last call).
    fn with_gen<R>(&self, width: usize, f: impl FnOnce(&mut WindowGenerator) -> R) -> R {
        let mut slot = unpoison(self.gen_cache.lock());
        let gen = WindowGenerator::reuse(&mut slot, self.ksize, width)
            .unwrap_or_else(|e| panic!("{}: {e} (see HwFilter::check_frame)", self.name()));
        f(gen)
    }

    /// Stream a frame through the window generator + datapath (functional
    /// evaluation; `sim::RtlSim` proves the timing separately).  Uses the
    /// cached scalar [`Engine`] — no per-call compilation or allocation
    /// beyond the output frame.
    #[deprecated(
        note = "build a pipeline::Pipeline (a filter is a chain of one) and process frames \
                through a Session with ExecPlan::Scalar"
    )]
    pub fn run_frame(&self, frame: &Frame, mode: OpMode) -> Frame {
        let mut out = Frame::new(frame.width, frame.height);
        let mut slot = unpoison(self.scalar_cache[mode_idx(mode)].lock());
        let eng = slot.get_or_insert_with(|| Engine::new(&self.netlist, mode));
        self.with_gen(frame.width, |gen| {
            eval_band(eng, gen, frame, 0, frame.height, &mut out.data);
        });
        out
    }

    /// Lane-batched variant of [`HwFilter::run_frame`]: same output,
    /// bit-identical, but evaluates [`LANES`] windows per tape dispatch
    /// through the cached [`BatchEngine`].  This is the fast path for
    /// whole-frame throughput.
    #[deprecated(
        note = "build a pipeline::Pipeline (a filter is a chain of one) and process frames \
                through a Session with ExecPlan::Batched"
    )]
    pub fn run_frame_batched(&self, frame: &Frame, mode: OpMode) -> Frame {
        let mut out = Frame::new(frame.width, frame.height);
        let mut slot = unpoison(self.batch_cache[mode_idx(mode)].lock());
        let eng = slot.get_or_insert_with(|| BatchEngine::new(&self.netlist, mode));
        self.with_gen(frame.width, |gen| {
            eval_band_batched(eng, gen, frame, 0, frame.height, &mut out.data);
        });
        out
    }

    /// Datapath pipeline latency in cycles (excludes the window
    /// generator's p·W + p structural latency).
    pub fn latency(&self) -> u32 {
        self.netlist.total_latency()
    }
}

/// Cloning duplicates the filter's *identity* (spec, format, netlist);
/// the engine/generator caches start cold — each clone warms its own.
impl Clone for HwFilter {
    fn clone(&self) -> Self {
        Self::from_parts(self.spec.clone(), self.fmt, self.ksize, self.netlist.clone())
    }
}

/// Evaluate output rows `[y0, y1)` of `frame` with a caller-owned scalar
/// engine, writing the band's pixels into `out_rows` (row-major,
/// `(y1 − y0) · width` values).  Band outputs are bit-identical to the
/// same rows of a whole-frame pass, which is what makes intra-frame
/// tiling safe (`coordinator::run_frame_tiled`).
pub fn eval_band(
    eng: &mut Engine,
    gen: &mut WindowGenerator,
    frame: &Frame,
    y0: usize,
    y1: usize,
    out_rows: &mut [f64],
) {
    assert_eq!(eng.n_outputs(), 1, "spatial filters have one output port");
    assert_eq!(out_rows.len(), (y1 - y0) * frame.width);
    let w = frame.width;
    let mut buf = [0.0f64; 1];
    gen.process_band(frame, y0, y1, |x, y, win| {
        eng.eval_into(win, &mut buf);
        out_rows[(y - y0) * w + x] = buf[0];
    });
}

/// Lane-batched [`eval_band`]: evaluates up to [`LANES`] windows per tape
/// dispatch and stores each chunk's outputs with one contiguous copy.
pub fn eval_band_batched(
    eng: &mut BatchEngine,
    gen: &mut WindowGenerator,
    frame: &Frame,
    y0: usize,
    y1: usize,
    out_rows: &mut [f64],
) {
    assert_eq!(eng.n_outputs(), 1, "spatial filters have one output port");
    assert_eq!(out_rows.len(), (y1 - y0) * frame.width);
    let w = frame.width;
    let mut olanes = [[0.0f64; LANES]; 1];
    gen.process_band_lanes(frame, y0, y1, |x0, y, n, taps| {
        eng.eval_lanes(taps, &mut olanes);
        let row = (y - y0) * w;
        out_rows[row + x0..row + x0 + n].copy_from_slice(&olanes[0][..n]);
    });
}

/// A multi-filter streaming chain: N compiled filters (builtin or DSL,
/// mixed) executed in **one** streaming pass.  Stage `i+1`'s window
/// generator is fed row by row from stage `i`'s output instead of a
/// materialised frame, so the whole chain holds only O(N · ksize) line
/// buffers — no intermediate frames, exactly like cascading window
/// generators in the FPGA fabric (Al-Dujaili & Fahmy, arXiv:1710.05154).
///
/// **Border semantics:** every stage applies the same replicate
/// (clamped-edge) border policy a single filter applies at the real frame
/// borders, to *its own input stream*.  Because each stage emits exactly
/// one output row per input row, the fused chain is bit-identical to
/// sequentially applying each filter to full materialised frames
/// (`FilterChain::run_frame_sequential`) — asserted by
/// `tests/chain_parity.rs` across the scalar, lane-batched and tiled
/// execution paths in both numeric modes.
///
/// **Format semantics:** stages may use different window sizes *and*
/// different [`FloatFormat`]s.  At every boundary where the producing
/// and consuming stages disagree, the chain inserts an explicit
/// converter ([`FmtConvert`], i.e. [`crate::fpcore::convert`]): the
/// producer's output row is re-rounded into the consumer's format —
/// RNE, flush, saturate — before it enters the consumer's window
/// generator, exactly like the `fmt_converter` block between the
/// cascaded modules in fabric ([`FilterChain::emit_sv`]).  Same-format
/// boundaries are plain wires (no conversion — the uniform-format
/// behaviour is unchanged).  The sequential reference
/// ([`FilterChain::run_frame_sequential`]) applies the same conversion
/// to the materialised frame, so fused and sequential stay bit-identical
/// in mixed-precision chains too (`tests/chain_parity.rs`).
pub struct FilterChain {
    stages: Vec<HwFilter>,
    /// Joined display name, computed once — [`FilterChain::name`] is hit
    /// in per-frame metrics/logging paths.
    name: String,
    /// Cached fused runners, indexed by [`runner_idx`].
    runners: [Mutex<Option<ChainRunner>>; 4],
}

impl FilterChain {
    /// Build a chain from compiled stages (at least one; every stage must
    /// be a streaming netlist filter with a single output port).
    pub fn new(stages: Vec<HwFilter>) -> Result<Self> {
        if stages.is_empty() {
            bail!("a filter chain needs at least one stage");
        }
        for hw in &stages {
            if hw.netlist.outputs.len() != 1 {
                bail!(
                    "chain stage `{}` has {} output ports; chained filters stream \
                     exactly one pixel per window",
                    hw.name(),
                    hw.netlist.outputs.len()
                );
            }
        }
        let names: Vec<&str> = stages.iter().map(|hw| hw.name()).collect();
        let name = names.join("->");
        Ok(Self { stages, name, runners: Default::default() })
    }

    pub fn stages(&self) -> &[HwFilter] {
        &self.stages
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Display name: stage names joined in flow order.  Cached at
    /// construction — no per-call allocation (this is called from
    /// per-frame metrics/logging paths).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Largest stage window (the chain's total vertical halo is the *sum*
    /// of per-stage halos — see [`ChainRunner::run_band`]).
    pub fn max_ksize(&self) -> usize {
        self.stages.iter().map(|hw| hw.ksize).max().unwrap_or(0)
    }

    /// The explicit converter at each of the `len() − 1` stage
    /// boundaries — `None` where the neighbouring stages share a format
    /// and the boundary is a plain wire.
    pub fn converters(&self) -> Vec<Option<FmtConvert>> {
        self.stages
            .windows(2)
            .map(|p| (p[0].fmt != p[1].fmt).then(|| FmtConvert::new(p[0].fmt, p[1].fmt)))
            .collect()
    }

    /// Does any boundary need a format converter?
    pub fn is_mixed_format(&self) -> bool {
        self.converters().iter().any(Option::is_some)
    }

    /// Summed converter pipeline latency (cycles) over the boundaries
    /// that actually convert.
    fn converter_latency(&self) -> u32 {
        self.converters().iter().flatten().map(|c| c.latency()).sum()
    }

    /// Combined datapath latency: the sum of stage netlist latencies plus
    /// the inter-stage converters (cycles) — windows between stages add
    /// the structural part, see [`FilterChain::pipeline_latency_cycles`].
    pub fn datapath_latency(&self) -> u32 {
        self.stages.iter().map(|hw| hw.latency()).sum::<u32>() + self.converter_latency()
    }

    /// End-to-end latency in cycles for `width`-pixel lines: each stage
    /// contributes its window generator's structural latency (`p` lines +
    /// `p` pixels) plus its datapath pipeline depth, and each mixed-format
    /// boundary its converter's depth.
    pub fn pipeline_latency_cycles(&self, width: usize) -> u64 {
        self.stages
            .iter()
            .map(|hw| {
                let p = (hw.ksize / 2) as u64;
                p * width as u64 + p + hw.latency() as u64
            })
            .sum::<u64>()
            + self.converter_latency() as u64
    }

    /// Total line-buffer storage across stages for `width`-pixel lines —
    /// the O(N · ksize) memory the fused pass holds instead of N − 1
    /// intermediate frames.
    pub fn line_buffer_bits(&self, width: usize) -> u64 {
        self.stages
            .iter()
            .map(|hw| (hw.ksize as u64 - 1) * width as u64 * hw.fmt.width() as u64)
            .sum()
    }

    /// Chain-wide FPGA resource estimate (datapaths + line buffers of
    /// every stage, summed) for `line_width`-pixel lines.
    pub fn resource_usage(&self, line_width: usize) -> crate::resources::Usage {
        crate::resources::estimate_chain(
            self.stages.iter().map(|hw| (&hw.netlist, hw.ksize)),
            line_width,
        )
    }

    /// Can this chain stream `frame`?  (Usable error instead of the panic
    /// the run methods raise on unchecked frames.)
    pub fn check_frame(&self, frame: &Frame) -> Result<()> {
        for hw in &self.stages {
            hw.check_frame(frame)?;
        }
        Ok(())
    }

    /// Reference semantics: apply each stage to a full materialised
    /// frame, sequentially, converting the frame into the next stage's
    /// format at every mixed-format boundary (per-stage *quantized*
    /// application).  The fused paths must be bit-identical to this.
    #[deprecated(
        note = "the sequential oracle lives on the plan now: \
                pipeline::CompiledPipeline::run_frame_sequential"
    )]
    #[allow(deprecated)]
    pub fn run_frame_sequential(&self, frame: &Frame, mode: OpMode) -> Frame {
        let converters = self.converters();
        let mut cur = self.stages[0].run_frame(frame, mode);
        for (i, hw) in self.stages.iter().enumerate().skip(1) {
            if let Some(cvt) = converters[i - 1] {
                cvt.apply_row(&mut cur.data);
            }
            cur = hw.run_frame(&cur, mode);
        }
        cur
    }

    /// Emit ONE SystemVerilog top module instantiating every stage's
    /// compiled module, the `fmt_converter` blocks between mixed-format
    /// stages, and per-stage `generateWindow` line buffers sized by that
    /// stage's format width (see [`crate::dsl::sverilog::emit_chain`]).
    pub fn emit_sv(&self, top: &str, resolution: (u32, u32)) -> String {
        let stages: Vec<crate::dsl::sverilog::SvStage<'_>> = self
            .stages
            .iter()
            .map(|hw| crate::dsl::sverilog::SvStage {
                name: hw.name(),
                netlist: &hw.netlist,
                ksize: hw.ksize,
            })
            .collect();
        crate::dsl::sverilog::emit_chain(top, &stages, resolution)
    }

    /// JSON dump of the whole cascade (`compile --emit netlist` for
    /// chains): every stage's scheduled netlist plus the inter-stage
    /// converters.
    pub fn netlist_json(&self, top: &str) -> crate::util::json::Json {
        use crate::util::json::{num, obj, s, Json};
        let stages = self
            .stages
            .iter()
            .map(|hw| {
                obj(vec![
                    ("name", s(hw.name())),
                    ("ksize", num(hw.ksize as f64)),
                    ("netlist", hw.netlist.to_json()),
                ])
            })
            .collect();
        let converters = self
            .converters()
            .into_iter()
            .enumerate()
            .filter_map(|(i, c)| c.map(|c| (i, c)))
            .map(|(i, c)| {
                obj(vec![
                    ("after_stage", num(i as f64)),
                    ("src", crate::sim::netlist::format_to_json(c.src)),
                    ("dst", crate::sim::netlist::format_to_json(c.dst)),
                    ("latency", num(c.latency() as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("top", s(top)),
            ("stages", Json::Arr(stages)),
            ("converters", Json::Arr(converters)),
            ("datapath_latency", num(self.datapath_latency() as f64)),
        ])
    }

    fn with_runner<R>(
        &self,
        mode: OpMode,
        batched: bool,
        f: impl FnOnce(&mut ChainRunner) -> R,
    ) -> R {
        let mut slot = unpoison(self.runners[runner_idx(mode, batched)].lock());
        let runner = slot.get_or_insert_with(|| ChainRunner::new(self, mode, batched));
        f(runner)
    }

    /// Fused single-pass evaluation with scalar engines.  Uses the cached
    /// per-(mode, batched) [`ChainRunner`]; concurrent calls serialize —
    /// parallel workers build their own runners ([`ChainRunner::new`]).
    #[deprecated(
        note = "compile the stages into a pipeline::CompiledPipeline and process frames \
                through a Session with ExecPlan::Scalar"
    )]
    pub fn run_frame(&self, frame: &Frame, mode: OpMode) -> Frame {
        self.with_runner(mode, false, |r| r.run_frame(frame))
    }

    /// Fused single-pass evaluation with lane-batched engines
    /// (bit-identical, faster).
    #[deprecated(
        note = "compile the stages into a pipeline::CompiledPipeline and process frames \
                through a Session with ExecPlan::Batched"
    )]
    pub fn run_frame_batched(&self, frame: &Frame, mode: OpMode) -> Frame {
        self.with_runner(mode, true, |r| r.run_frame(frame))
    }
}

/// A worker's compiled stage engine — scalar or lane-batched.
enum StageEngine {
    Scalar(Engine),
    Batched(BatchEngine),
}

/// One stage of a fused chain execution: its window generator (the only
/// inter-stage storage), compiled engine, the output row under
/// construction, and — when the next stage uses a different format —
/// the explicit converter applied to every completed output row before
/// it crosses the boundary.
struct ChainStage {
    ksize: usize,
    gen: Option<WindowGenerator>,
    eng: StageEngine,
    row_buf: Vec<f64>,
    /// `Some` iff the next stage's format differs (last stage: `None`).
    out_convert: Option<FmtConvert>,
}

/// Per-thread fused executor for a [`FilterChain`]: owns each stage's
/// engine + generator, so coordinator workers can run chains without
/// touching the chain's shared caches.
pub struct ChainRunner {
    stages: Vec<ChainStage>,
    /// Sum of per-stage halo radii: how many source context rows a band
    /// evaluation needs above/below the output band.
    total_halo: usize,
}

impl ChainRunner {
    pub fn new(chain: &FilterChain, mode: OpMode, batched: bool) -> Self {
        let mut converters = chain.converters().into_iter();
        let stages: Vec<ChainStage> = chain
            .stages
            .iter()
            .map(|hw| ChainStage {
                ksize: hw.ksize,
                gen: None,
                eng: if batched {
                    StageEngine::Batched(BatchEngine::new(&hw.netlist, mode))
                } else {
                    StageEngine::Scalar(Engine::new(&hw.netlist, mode))
                },
                row_buf: Vec::new(),
                // boundary i sits *after* stage i; the last stage has none
                out_convert: converters.next().flatten(),
            })
            .collect();
        let total_halo = stages.iter().map(|s| s.ksize / 2).sum();
        Self { stages, total_halo }
    }

    /// Fused whole-frame evaluation.
    pub fn run_frame(&mut self, frame: &Frame) -> Frame {
        let mut out = Frame::new(frame.width, frame.height);
        if frame.height > 0 {
            self.run_band(frame, 0, frame.height, &mut out.data);
        }
        out
    }

    /// Fused evaluation of final-stage output rows `[y0, y1)` into
    /// `out_rows` (row-major, `(y1 − y0) · width` values), bit-identical
    /// to the same rows of a sequential full-frame application.
    ///
    /// The band is computed by streaming the source rows `[y0 − P, y1 + P)`
    /// (`P` = the summed per-stage halo radii, clamped at the real frame
    /// borders) through the fused pipeline and keeping only the requested
    /// output rows.  Rows that close enough to the crop borders would be
    /// polluted by the generators' replicate clamping are exactly the rows
    /// the halo discards, so interior bands stitch seamlessly
    /// (`coordinator::run_frame_chain_tiled`).
    pub fn run_band(&mut self, frame: &Frame, y0: usize, y1: usize, out_rows: &mut [f64]) {
        let w = frame.width;
        let h = frame.height;
        assert!(y0 < y1 && y1 <= h, "bad band [{y0}, {y1})");
        assert_eq!(out_rows.len(), (y1 - y0) * w);
        let a = y0.saturating_sub(self.total_halo);
        let b = (y1 + self.total_halo).min(h);
        for st in &mut self.stages {
            let gen = WindowGenerator::reuse(&mut st.gen, st.ksize, w)
                .unwrap_or_else(|e| panic!("chain stage: {e} (see FilterChain::check_frame)"));
            gen.begin_push();
            st.row_buf.clear();
            st.row_buf.resize(w, 0.0);
        }
        let mut crop_cy = 0usize;
        let mut emit = |row: &[f64]| {
            let orig = a + crop_cy;
            if orig >= y0 && orig < y1 {
                let o = (orig - y0) * w;
                out_rows[o..o + w].copy_from_slice(row);
            }
            crop_cy += 1;
        };
        for ay in a..b {
            push_row_chain(&mut self.stages, &frame.data[ay * w..(ay + 1) * w], &mut emit);
        }
        finish_chain(&mut self.stages, &mut emit);
        debug_assert_eq!(crop_cy, b - a, "chain dropped rows");
    }
}

/// Push one input row into the first stage; every output row a stage
/// completes is re-rounded into the next stage's format where the
/// boundary converts ([`ChainStage::out_convert`]) and then cascades
/// into the next stage immediately (row granularity — nothing is
/// materialised beyond one row per stage).  Rows that fall out of the
/// last stage go to `emit`, in order.
fn push_row_chain(stages: &mut [ChainStage], row: &[f64], emit: &mut dyn FnMut(&[f64])) {
    let Some((first, rest)) = stages.split_first_mut() else {
        emit(row);
        return;
    };
    let gen = first.gen.as_mut().expect("run_band prepares the generators");
    let buf = &mut first.row_buf;
    let cvt = first.out_convert;
    let w = buf.len();
    match &mut first.eng {
        StageEngine::Scalar(eng) => {
            let mut out1 = [0.0f64; 1];
            gen.push_row(row, |x, _y, win| {
                eng.eval_into(win, &mut out1);
                buf[x] = out1[0];
                if x + 1 == w {
                    if let Some(c) = cvt {
                        c.apply_row(buf);
                    }
                    push_row_chain(rest, &buf[..], emit);
                }
            });
        }
        StageEngine::Batched(eng) => {
            let mut olanes = [[0.0f64; LANES]; 1];
            gen.push_row_lanes(row, |x0, _y, n, taps| {
                eng.eval_lanes(taps, &mut olanes);
                buf[x0..x0 + n].copy_from_slice(&olanes[0][..n]);
                if x0 + n == w {
                    if let Some(c) = cvt {
                        c.apply_row(buf);
                    }
                    push_row_chain(rest, &buf[..], emit);
                }
            });
        }
    }
}

/// Flush the chain front to back: finishing stage `i` (bottom-border
/// replication) emits its last rows, which cascade through stages `i+1..`
/// *before* those stages are finished in turn.
fn finish_chain(stages: &mut [ChainStage], emit: &mut dyn FnMut(&[f64])) {
    let Some((first, rest)) = stages.split_first_mut() else {
        return;
    };
    let gen = first.gen.as_mut().expect("run_band prepares the generators");
    let buf = &mut first.row_buf;
    let cvt = first.out_convert;
    let w = buf.len();
    match &mut first.eng {
        StageEngine::Scalar(eng) => {
            let mut out1 = [0.0f64; 1];
            gen.push_finish(|x, _y, win| {
                eng.eval_into(win, &mut out1);
                buf[x] = out1[0];
                if x + 1 == w {
                    if let Some(c) = cvt {
                        c.apply_row(buf);
                    }
                    push_row_chain(rest, &buf[..], emit);
                }
            });
        }
        StageEngine::Batched(eng) => {
            let mut olanes = [[0.0f64; LANES]; 1];
            gen.push_finish_lanes(|x0, _y, n, taps| {
                eng.eval_lanes(taps, &mut olanes);
                buf[x0..x0 + n].copy_from_slice(&olanes[0][..n]);
                if x0 + n == w {
                    if let Some(c) = cvt {
                        c.apply_row(buf);
                    }
                    push_row_chain(rest, &buf[..], emit);
                }
            });
        }
    }
    finish_chain(rest, emit);
}

#[cfg(test)]
mod tests {
    // The deprecated run paths are kept as compatibility shims; these unit
    // tests pin their behaviour (the new-API equivalents live in
    // tests/session_reuse.rs and the parity suites).
    #![allow(deprecated)]

    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    const MEDIAN_DSL: &str = include_str!("../../../examples/dsl/median.dsl");
    const FIG12_DSL: &str = include_str!("../../../examples/dsl/fig12.dsl");

    #[test]
    fn all_filters_build_and_run() {
        let f = Frame::test_card(24, 16);
        for kind in FilterKind::TABLE1 {
            let hw = HwFilter::new(kind, F16).unwrap();
            let out = hw.run_frame(&f, OpMode::Exact);
            assert_eq!(out.width, 24);
            assert!(out.data.iter().all(|v| v.is_finite()), "{}", kind.name());
        }
        let sob = HwFilter::new(FilterKind::FpSobel, F16).unwrap();
        let out = sob.run_frame(&f, OpMode::Exact);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn paper_latencies_by_filter() {
        let lat = |k| HwFilter::new(k, F16).unwrap().latency();
        assert_eq!(lat(FilterKind::Conv3x3), 26);
        assert_eq!(lat(FilterKind::Conv5x5), 32);
        assert_eq!(lat(FilterKind::Median), 19);
        assert_eq!(lat(FilterKind::Nlfilter), 26);
        assert_eq!(lat(FilterKind::FpSobel), 39);
    }

    #[test]
    fn hls_sobel_is_a_usable_error_not_a_panic() {
        let err = HwFilter::new(FilterKind::HlsSobel, F16).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("hls_sobel"), "{msg}");
        assert!(msg.contains("sobel_fixed_frame"), "{msg}");
    }

    #[test]
    fn from_dsl_is_a_first_class_filter() {
        let hw = HwFilter::from_dsl(MEDIAN_DSL, "median_dsl", None).unwrap();
        assert_eq!(hw.spec, FilterSpec::Dsl { name: "median_dsl".to_string() });
        assert_eq!(hw.name(), "median_dsl");
        assert_eq!(hw.spec.kind(), None);
        assert_eq!(hw.fmt, F16);
        assert_eq!(hw.ksize, 3);
        assert_eq!(hw.latency(), 19);
        // runs through the same cached scalar/batched paths as a built-in
        let f = Frame::test_card(25, 14);
        let want = HwFilter::new(FilterKind::Median, F16).unwrap().run_frame(&f, OpMode::Exact);
        assert_eq!(hw.run_frame(&f, OpMode::Exact).data, want.data);
        assert_eq!(hw.run_frame_batched(&f, OpMode::Exact).data, want.data);
    }

    #[test]
    fn from_dsl_format_override() {
        let hw = HwFilter::from_dsl(MEDIAN_DSL, "median_wide", Some(FloatFormat::new(23, 8)))
            .unwrap();
        assert_eq!(hw.fmt, FloatFormat::new(23, 8));
        let f = Frame::salt_pepper(20, 12, 0.1, 3);
        let want = HwFilter::new(FilterKind::Median, FloatFormat::new(23, 8))
            .unwrap()
            .run_frame(&f, OpMode::Exact);
        assert_eq!(hw.run_frame(&f, OpMode::Exact).data, want.data);
    }

    #[test]
    fn from_dsl_rejects_scalar_programs() {
        let err = HwFilter::from_dsl(FIG12_DSL, "fig12", None).unwrap_err();
        assert!(format!("{err:#}").contains("sliding_window"), "{err:#}");
    }

    #[test]
    fn hw_median_matches_software_median_on_noise() {
        // With a wide format the quantized hardware median equals the
        // software median of the two-footprint design... they differ by
        // design (2×SORT5 vs full SORT9), so compare against the same
        // footprint algorithm instead.
        let f = Frame::salt_pepper(20, 14, 0.1, 8);
        let hw = HwFilter::new(FilterKind::Median, FloatFormat::new(39, 8)).unwrap();
        let out = hw.run_frame(&f, OpMode::Exact);
        // mean of two footprint medians, computed directly
        let want = crate::video::map_windows(&f, 3, |w| {
            let med5 = |idx: [usize; 5]| {
                let mut v = idx.map(|i| w[i]);
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[2]
            };
            (med5(median::FOOTPRINT_A) + med5(median::FOOTPRINT_B)) / 2.0
        });
        assert!(out.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn batched_matches_scalar_on_ragged_width() {
        // 37 = 2·16 + 5: exercises the ragged right-edge lanes
        let f = Frame::test_card(37, 12);
        for kind in FilterKind::TABLE1 {
            let hw = HwFilter::new(kind, F16).unwrap();
            let scalar = hw.run_frame(&f, OpMode::Exact);
            let batched = hw.run_frame_batched(&f, OpMode::Exact);
            assert_eq!(scalar.data, batched.data, "{}", kind.name());
        }
    }

    #[test]
    fn cached_engine_survives_width_changes() {
        let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap();
        let a = Frame::test_card(24, 10);
        let b = Frame::test_card(16, 8);
        let out_a1 = hw.run_frame(&a, OpMode::Exact);
        let out_b = hw.run_frame(&b, OpMode::Exact); // forces gen rebuild
        let out_a2 = hw.run_frame(&a, OpMode::Exact); // and back
        assert_eq!(out_a1.data, out_a2.data);
        assert_eq!(out_b.width, 16);
        // batched path shares the same generator cache
        let out_b2 = hw.run_frame_batched(&b, OpMode::Exact);
        assert_eq!(out_b.data, out_b2.data);
    }

    #[test]
    fn eval_band_covers_frame_in_pieces() {
        let f = Frame::test_card(20, 15);
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let want = hw.run_frame(&f, OpMode::Exact);
        let mut eng = crate::sim::Engine::new(&hw.netlist, OpMode::Exact);
        let mut gen = WindowGenerator::new(hw.ksize, f.width).unwrap();
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 5usize), (5, 11), (11, 15)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            eval_band(&mut eng, &mut gen, &f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn conv_by_name_round_trip() {
        for kind in FilterKind::ALL {
            assert_eq!(FilterKind::by_name(kind.name()), Some(kind));
        }
    }

    #[test]
    fn check_frame_reports_usable_errors() {
        let hw = HwFilter::new(FilterKind::Conv5x5, F16).unwrap();
        assert!(hw.check_frame(&Frame::test_card(24, 16)).is_ok());
        let err = hw.check_frame(&Frame::test_card(4, 16)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        assert!(err.to_string().contains("conv5x5"), "{err}");
        let err = hw.check_frame(&Frame::new(24, 0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    fn two_stage_chain() -> FilterChain {
        FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn chain_construction_and_reporting() {
        let chain = two_stage_chain();
        assert_eq!(chain.len(), 2);
        assert!(!chain.is_empty());
        assert_eq!(chain.name(), "median->fp_sobel");
        assert_eq!(chain.max_ksize(), 3);
        assert_eq!(chain.datapath_latency(), 19 + 39);
        // per stage: p·W + p + datapath = 1·100 + 1 + lat
        assert_eq!(chain.pipeline_latency_cycles(100), (100 + 1 + 19) + (100 + 1 + 39));
        // two 3x3 stages at f16: 2 line buffers x width x 16 bits each
        assert_eq!(chain.line_buffer_bits(100), 2 * (2 * 100 * 16));
        assert!(FilterChain::new(vec![]).is_err());
    }

    #[test]
    fn chain_fused_matches_sequential() {
        let chain = two_stage_chain();
        let f = Frame::test_card(37, 15); // ragged width
        for mode in [OpMode::Exact, OpMode::Poly] {
            let want = chain.run_frame_sequential(&f, mode);
            let fused = chain.run_frame(&f, mode);
            let batched = chain.run_frame_batched(&f, mode);
            for (i, (w, g)) in want.data.iter().zip(&fused.data).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} scalar pixel {i}");
            }
            for (i, (w, g)) in want.data.iter().zip(&batched.data).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} batched pixel {i}");
            }
        }
    }

    #[test]
    fn chain_runner_band_matches_whole_frame() {
        let chain = two_stage_chain();
        let f = Frame::salt_pepper(29, 17, 0.1, 3);
        let want = chain.run_frame_sequential(&f, OpMode::Exact);
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, true);
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 5usize), (5, 11), (11, 17)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            runner.run_band(&f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn single_stage_chain_matches_filter() {
        let hw = HwFilter::new(FilterKind::Nlfilter, F16).unwrap();
        let chain =
            FilterChain::new(vec![HwFilter::new(FilterKind::Nlfilter, F16).unwrap()]).unwrap();
        let f = Frame::test_card(21, 12);
        assert_eq!(chain.run_frame(&f, OpMode::Exact).data, hw.run_frame(&f, OpMode::Exact).data);
    }

    #[test]
    fn chain_mixes_dsl_and_builtin_stages() {
        let chain = FilterChain::new(vec![
            HwFilter::from_dsl(MEDIAN_DSL, "median_dsl", None).unwrap(),
            HwFilter::new(FilterKind::Conv3x3, F16).unwrap(),
        ])
        .unwrap();
        assert_eq!(chain.name(), "median_dsl->conv3x3");
        let f = Frame::test_card(20, 13);
        let want = chain.run_frame_sequential(&f, OpMode::Exact);
        assert_eq!(chain.run_frame_batched(&f, OpMode::Exact).data, want.data);
    }

    #[test]
    fn chain_check_frame_names_the_offending_stage() {
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::Conv5x5, F16).unwrap(),
        ])
        .unwrap();
        let err = chain.check_frame(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("conv5x5"), "{err}");
    }

    #[test]
    fn from_dsl_rejects_oversized_windows_upfront() {
        assert!(WindowGenerator::validate_ksize(17).is_err());
        assert!(WindowGenerator::validate_ksize(2).is_err());
        assert!(WindowGenerator::validate_ksize(5).is_ok());
    }

    const F24: FloatFormat = FloatFormat::new(16, 7);
    const F14: FloatFormat = FloatFormat::new(7, 6);

    fn mixed_chain() -> FilterChain {
        FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F24).unwrap(),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn uniform_chain_has_no_converters() {
        let chain = two_stage_chain();
        assert_eq!(chain.converters(), vec![None]);
        assert!(!chain.is_mixed_format());
        // latency identical to the plain stage sum (no converter cycles)
        assert_eq!(chain.datapath_latency(), 19 + 39);
    }

    #[test]
    fn mixed_chain_reports_its_boundary_converter() {
        let chain = mixed_chain();
        assert_eq!(chain.converters(), vec![Some(FmtConvert::new(F24, F16))]);
        assert!(chain.is_mixed_format());
        // converter cycles are part of the cascade latency
        assert_eq!(chain.datapath_latency(), 19 + 39 + 2);
        assert_eq!(chain.pipeline_latency_cycles(100), (100 + 1 + 19) + 2 + (100 + 1 + 39));
        // line buffers stay per-stage width: one 24-bit + one 16-bit stage
        assert_eq!(chain.line_buffer_bits(100), 2 * 100 * 24 + 2 * 100 * 16);
    }

    #[test]
    fn mixed_chain_fused_matches_sequential_quantized() {
        let chain = mixed_chain();
        let f = Frame::test_card(37, 15); // ragged width
        for mode in [OpMode::Exact, OpMode::Poly] {
            // independent reference: materialise, quantize into the next
            // stage's format by hand, run the next stage
            let s0 = HwFilter::new(FilterKind::Median, F24).unwrap();
            let s1 = HwFilter::new(FilterKind::FpSobel, F16).unwrap();
            let mut mid = s0.run_frame(&f, mode);
            for v in &mut mid.data {
                *v = crate::fpcore::quantize(*v, F16);
            }
            let want = s1.run_frame(&mid, mode);
            for (label, got) in [
                ("sequential", chain.run_frame_sequential(&f, mode)),
                ("fused scalar", chain.run_frame(&f, mode)),
                ("fused batched", chain.run_frame_batched(&f, mode)),
            ] {
                for (i, (w, g)) in want.data.iter().zip(&got.data).enumerate() {
                    assert_eq!(w.to_bits(), g.to_bits(), "{mode:?} {label} pixel {i}");
                }
            }
        }
    }

    #[test]
    fn mixed_chain_narrow_stage_output_is_on_its_grid() {
        // after a wide->narrow boundary the narrow stage only ever sees
        // narrow-format values, so its selection-only ops (median) can
        // no longer leak wide values through
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv3x3, F24).unwrap(),
            HwFilter::new(FilterKind::Median, F14).unwrap(),
        ])
        .unwrap();
        let f = Frame::salt_pepper(23, 13, 0.1, 5);
        let out = chain.run_frame_batched(&f, OpMode::Exact);
        for (i, &v) in out.data.iter().enumerate() {
            assert_eq!(
                crate::fpcore::quantize(v, F14).to_bits(),
                v.to_bits(),
                "pixel {i} = {v} not a float14(7,6) value"
            );
        }
    }

    #[test]
    fn mixed_chain_band_runner_matches_whole_frame() {
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Conv5x5, F24).unwrap(),
            HwFilter::new(FilterKind::Median, F16).unwrap(),
        ])
        .unwrap();
        let f = Frame::salt_pepper(29, 17, 0.1, 11);
        let want = chain.run_frame_sequential(&f, OpMode::Exact);
        let mut runner = ChainRunner::new(&chain, OpMode::Exact, true);
        let mut got = Frame::new(f.width, f.height);
        for (y0, y1) in [(0usize, 4usize), (4, 12), (12, 17)] {
            let band = &mut got.data[y0 * f.width..y1 * f.width];
            runner.run_band(&f, y0, y1, band);
        }
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn chain_netlist_json_lists_stages_and_converters() {
        let chain = mixed_chain();
        let txt = chain.netlist_json("cascade").to_string();
        let v = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(v.get("top").unwrap().as_str(), Some("cascade"));
        assert_eq!(v.get("stages").unwrap().as_arr().unwrap().len(), 2);
        let cvts = v.get("converters").unwrap().as_arr().unwrap();
        assert_eq!(cvts.len(), 1);
        assert_eq!(cvts[0].get("after_stage").unwrap().as_usize(), Some(0));
        assert_eq!(cvts[0].get("src").unwrap().get("mantissa").unwrap().as_usize(), Some(16));
        assert_eq!(cvts[0].get("dst").unwrap().get("mantissa").unwrap().as_usize(), Some(10));
        // uniform chains serialize an empty converter list
        let uni = two_stage_chain();
        let v = crate::util::json::Json::parse(&uni.netlist_json("c").to_string()).unwrap();
        assert!(v.get("converters").unwrap().as_arr().unwrap().is_empty());
    }
}
