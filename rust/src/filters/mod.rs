//! The spatial-filter library (§III): hardware datapaths as scheduled
//! netlists, software baselines, and the fixed-point HLS comparator.

pub mod conv;
pub mod fixed;
pub mod median;
pub mod nlfilter;
pub mod sobel;
pub mod software;

use crate::fpcore::{FloatFormat, OpMode};
use crate::sim::{Engine, Netlist};
use crate::video::{Frame, WindowGenerator};

/// The six filters of the paper's evaluation (fig. 11 x-categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterKind {
    Conv3x3,
    Conv5x5,
    Median,
    Nlfilter,
    FpSobel,
    /// Fixed-point HLS baseline — not a custom-float netlist.
    HlsSobel,
}

impl FilterKind {
    pub const ALL: [FilterKind; 6] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
        FilterKind::FpSobel,
        FilterKind::HlsSobel,
    ];

    /// The four Table-I filters.
    pub const TABLE1: [FilterKind; 4] = [
        FilterKind::Conv3x3,
        FilterKind::Conv5x5,
        FilterKind::Median,
        FilterKind::Nlfilter,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FilterKind::Conv3x3 => "conv3x3",
            FilterKind::Conv5x5 => "conv5x5",
            FilterKind::Median => "median",
            FilterKind::Nlfilter => "nlfilter",
            FilterKind::FpSobel => "fp_sobel",
            FilterKind::HlsSobel => "hls_sobel",
        }
    }

    pub fn by_name(name: &str) -> Option<FilterKind> {
        Self::ALL.iter().copied().find(|f| f.name() == name)
    }

    pub fn ksize(&self) -> usize {
        match self {
            FilterKind::Conv5x5 => 5,
            _ => 3,
        }
    }
}

/// A hardware filter: a scheduled custom-float datapath fed by the
/// window generator.
pub struct HwFilter {
    pub kind: FilterKind,
    pub fmt: FloatFormat,
    pub ksize: usize,
    pub netlist: Netlist,
}

impl HwFilter {
    /// Build a filter datapath.  Conv kernels default to Gaussian blur
    /// (reconfigurable coefficients in the FPGA — see `with_kernel`).
    pub fn new(kind: FilterKind, fmt: FloatFormat) -> Self {
        match kind {
            FilterKind::Conv3x3 => Self::with_kernel(kind, fmt, &conv::gaussian3x3()),
            FilterKind::Conv5x5 => Self::with_kernel(kind, fmt, &conv::gaussian5x5()),
            FilterKind::Median => Self {
                kind,
                fmt,
                ksize: 3,
                netlist: median::median_netlist(fmt),
            },
            FilterKind::Nlfilter => Self {
                kind,
                fmt,
                ksize: 3,
                netlist: nlfilter::nlfilter_netlist(fmt),
            },
            FilterKind::FpSobel => Self {
                kind,
                fmt,
                ksize: 3,
                netlist: sobel::sobel_netlist(fmt),
            },
            FilterKind::HlsSobel => panic!("hls_sobel is fixed-point; use fixed::sobel_fixed_frame"),
        }
    }

    /// A convolution with caller-supplied coefficients.
    pub fn with_kernel(kind: FilterKind, fmt: FloatFormat, k: &[f64]) -> Self {
        let ksize = kind.ksize();
        assert!(matches!(kind, FilterKind::Conv3x3 | FilterKind::Conv5x5));
        Self {
            kind,
            fmt,
            ksize,
            netlist: conv::conv_netlist(fmt, ksize, k),
        }
    }

    /// Stream a frame through the window generator + datapath (functional
    /// evaluation; `sim::RtlSim` proves the timing separately).
    pub fn run_frame(&self, frame: &Frame, mode: OpMode) -> Frame {
        let mut eng = Engine::new(&self.netlist, mode);
        let mut out = Frame::new(frame.width, frame.height);
        let mut gen = WindowGenerator::new(self.ksize, frame.width);
        let mut buf = [0.0f64; 1];
        gen.process_frame(frame, |x, y, w| {
            eng.eval_into(w, &mut buf);
            out.set(x, y, buf[0]);
        });
        out
    }

    /// Datapath pipeline latency in cycles (excludes the window
    /// generator's p·W + p structural latency).
    pub fn latency(&self) -> u32 {
        self.netlist.total_latency()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn all_filters_build_and_run() {
        let f = Frame::test_card(24, 16);
        for kind in FilterKind::TABLE1 {
            let hw = HwFilter::new(kind, F16);
            let out = hw.run_frame(&f, OpMode::Exact);
            assert_eq!(out.width, 24);
            assert!(out.data.iter().all(|v| v.is_finite()), "{}", kind.name());
        }
        let sob = HwFilter::new(FilterKind::FpSobel, F16);
        let out = sob.run_frame(&f, OpMode::Exact);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn paper_latencies_by_filter() {
        assert_eq!(HwFilter::new(FilterKind::Conv3x3, F16).latency(), 26);
        assert_eq!(HwFilter::new(FilterKind::Conv5x5, F16).latency(), 32);
        assert_eq!(HwFilter::new(FilterKind::Median, F16).latency(), 19);
        assert_eq!(HwFilter::new(FilterKind::Nlfilter, F16).latency(), 26);
        assert_eq!(HwFilter::new(FilterKind::FpSobel, F16).latency(), 39);
    }

    #[test]
    fn hw_median_matches_software_median_on_noise() {
        // With a wide format the quantized hardware median equals the
        // software median of the two-footprint design... they differ by
        // design (2×SORT5 vs full SORT9), so compare against the same
        // footprint algorithm instead.
        let f = Frame::salt_pepper(20, 14, 0.1, 8);
        let hw = HwFilter::new(FilterKind::Median, FloatFormat::new(39, 8));
        let out = hw.run_frame(&f, OpMode::Exact);
        // mean of two footprint medians, computed directly
        let want = crate::video::map_windows(&f, 3, |w| {
            let med5 = |idx: [usize; 5]| {
                let mut v = idx.map(|i| w[i]);
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[2]
            };
            (med5(median::FOOTPRINT_A) + med5(median::FOOTPRINT_B)) / 2.0
        });
        assert!(out.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn conv_by_name_round_trip() {
        for kind in FilterKind::ALL {
            assert_eq!(FilterKind::by_name(kind.name()), Some(kind));
        }
    }
}
