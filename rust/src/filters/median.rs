//! Median filter (§III-C, fig. 8): two Bose–Nelson SORT5 networks over the
//! diagonal+centre and cross footprints; output = mean of the two medians
//! (add + floating-point right shift).

use crate::fpcore::FloatFormat;
use crate::sim::netlist::{Builder, Netlist};

/// Footprint of the left SORT5 (footnote 3): w00 w02 w11 w20 w22.
pub const FOOTPRINT_A: [usize; 5] = [0, 2, 4, 6, 8];
/// Footprint of the right SORT5 (§III-C): w01 w10 w11 w12 w21.
pub const FOOTPRINT_B: [usize; 5] = [1, 3, 4, 5, 7];

/// Build the fig. 8 median datapath.
pub fn median_netlist(fmt: FloatFormat) -> Netlist {
    let mut b = Builder::new(fmt);
    let wins: Vec<_> = (0..9)
        .map(|i| b.input(&format!("w{}{}", i / 3, i % 3)))
        .collect();
    let sa = b.sort5([
        wins[FOOTPRINT_A[0]],
        wins[FOOTPRINT_A[1]],
        wins[FOOTPRINT_A[2]],
        wins[FOOTPRINT_A[3]],
        wins[FOOTPRINT_A[4]],
    ]);
    let sb = b.sort5([
        wins[FOOTPRINT_B[0]],
        wins[FOOTPRINT_B[1]],
        wins[FOOTPRINT_B[2]],
        wins[FOOTPRINT_B[3]],
        wins[FOOTPRINT_B[4]],
    ]);
    // median of each network is the middle element; mean of the two
    let sum = b.add(sa[2], sb[2]);
    let out = b.rsh(sum, 1); // ÷2: exponent decrement (footnote 4)
    b.output("pix_o", out);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::sim::Engine;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn structure_matches_paper() {
        let nl = median_netlist(F16);
        // two SORT5 × 9 CAS = 18 CAS; no multipliers at all (fig. 11:
        // the median uses zero DSP blocks)
        assert_eq!(nl.op_count("cmp_and_swap"), 18);
        assert_eq!(nl.op_count("mult"), 0);
        assert_eq!(nl.op_count("mult_const"), 0);
        assert_eq!(nl.op_count("div"), 0);
        // λ = SORT5(12) + add(6) + rsh(1) = 19
        assert_eq!(nl.total_latency(), 19);
    }

    #[test]
    fn constant_window_passes_through() {
        let nl = median_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[7.0; 9])[0], 7.0);
    }

    #[test]
    fn rejects_impulse() {
        let nl = median_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let mut w = [10.0; 9];
        w[4] = 255.0; // hot centre pixel
        let out = eng.eval(&w)[0];
        assert_eq!(out, 10.0);
    }

    #[test]
    fn mean_of_two_medians() {
        let nl = median_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        // diag footprint {w00,w02,w11,w20,w22} = {1,2,3,4,5} -> 3
        // cross footprint {w01,w10,w11,w12,w21} = {10,20,3,40,50} -> 20
        // output = (3+20)/2 = 11.5
        let w = [1.0, 10.0, 2.0, 20.0, 3.0, 40.0, 4.0, 50.0, 5.0];
        assert_eq!(eng.eval(&w)[0], 11.5);
    }
}
