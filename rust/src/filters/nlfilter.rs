//! The generic non-linear spatial filter of eq. 2 / figs. 9, 10, 16.
//!
//! `f^ζ = f^α · min(f^β, f^δ) / max(f^β, f^δ)` with
//!
//! ```text
//! f^α = 0.5 · (√(w00'·w02') + √(w20'·w22'))      (right shift by 1)
//! f^β = 8 · (log2(w01'·w21') + log2(w10'·w12'))  (left shift by 3)
//! f^δ = 2^(0.0313 · w11')                        (fig. 16 line 40)
//! w'  = max(w, 1)                                 (guards log/div)
//! ```
//!
//! The CAS between f^β and f^δ and the λ bookkeeping reproduce the §III-D
//! walk-through: λ(f^β) = 15, λ(f^δ) = 9 → Δ = 6; λ(f^φ) = 24; f^α is
//! delayed 9 cycles before the final multiply.

use crate::fpcore::FloatFormat;
use crate::sim::netlist::{Builder, Netlist};

/// The eq. 2 constant multiplying the centre pixel.
pub const DELTA_COEFF: f64 = 0.0313;

/// Build the generic-filter datapath.
pub fn nlfilter_netlist(fmt: FloatFormat) -> Netlist {
    let mut b = Builder::new(fmt);
    let w: Vec<_> = (0..9)
        .map(|i| b.input(&format!("w{}{}", i / 3, i % 3)))
        .collect();
    // w' = max(w, 1) for every tap (fig. 16 lines 10–18)
    let wp: Vec<_> = w.iter().map(|&s| b.max_const(s, 1.0)).collect();
    let (w00, w01, w02) = (wp[0], wp[1], wp[2]);
    let (w10, w11, w12) = (wp[3], wp[4], wp[5]);
    let (w20, w21, w22) = (wp[6], wp[7], wp[8]);

    // f^α — diagonal geometric means
    let m0 = b.mul(w00, w02);
    let m1 = b.mul(w20, w22);
    let s0 = b.sqrt(m0);
    let s1 = b.sqrt(m1);
    let a0 = b.add(s0, s1);
    let f_alpha = b.rsh(a0, 1); // × 0.5
    b.rename(f_alpha, "f_alpha");

    // f^β — cross log-energies
    let m2 = b.mul(w01, w21);
    let m3 = b.mul(w10, w12);
    let l0 = b.log2(m2);
    let l1 = b.log2(m3);
    let a1 = b.add(l0, l1);
    let f_beta = b.lsh(a1, 3); // × 8
    b.rename(f_beta, "f_beta");

    // f^δ — centre exponential
    let m4 = b.mul_const(w11, DELTA_COEFF);
    let f_delta = b.exp2(m4);
    b.rename(f_delta, "f_delta");

    // f^φ = min/max ratio via CMP_and_SWAP + divide
    let (g1, g2) = b.cas(f_beta, f_delta);
    let g = b.div(g1, g2);
    b.rename(g, "f_phi");

    let out = b.mul(f_alpha, g);
    b.rename(out, "f_zeta");
    b.output("pix_o", out);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::sim::Engine;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    /// §III-D latency walk-through, exactly as printed in the paper.
    #[test]
    fn paper_latency_algebra() {
        let nl = nlfilter_netlist(F16);
        let f_alpha = nl.signal_by_name("f_alpha").unwrap();
        let f_beta = nl.signal_by_name("f_beta").unwrap();
        let f_delta = nl.signal_by_name("f_delta").unwrap();
        let f_phi = nl.signal_by_name("f_phi").unwrap();
        let f_zeta = nl.signal_by_name("f_zeta").unwrap();

        assert_eq!(nl.signals[f_alpha].latency, 15);
        assert_eq!(nl.signals[f_beta].latency, 15);
        assert_eq!(nl.signals[f_delta].latency, 9);
        // CAS node: f^δ delayed by Δ = 6 to meet f^β
        let cas = nl.nodes.iter().find(|n| n.op.name() == "cmp_and_swap").unwrap();
        assert_eq!(cas.in_delays, vec![0, 6]);
        assert_eq!(nl.signals[f_phi].latency, 24);
        // final multiply: f^α delayed 9 cycles; total = 26
        let last = nl.nodes.last().unwrap();
        assert_eq!(last.in_delays, vec![9, 0]);
        assert_eq!(nl.signals[f_zeta].latency, 26);
        assert_eq!(nl.total_latency(), 26);
    }

    #[test]
    fn numerics_match_eq2_scalar() {
        // compare against a plain-double transcription with per-op rounding
        // disabled errors bounded by the format
        let nl = nlfilter_netlist(FloatFormat::new(39, 8)); // near-double
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let w: [f64; 9] = [12.0, 30.0, 7.0, 100.0, 50.0, 3.0, 9.0, 60.0, 25.0];
        let got = eng.eval(&w)[0];

        let wp: Vec<f64> = w.iter().map(|&v| v.max(1.0)).collect();
        let f_alpha =
            0.5 * ((wp[0] * wp[2]).sqrt() + (wp[6] * wp[8]).sqrt());
        let f_beta = 8.0 * ((wp[1] * wp[7]).log2() + (wp[3] * wp[5]).log2());
        let f_delta = (0.0313 * wp[4]).exp2();
        let (g1, g2) = if f_beta > f_delta { (f_delta, f_beta) } else { (f_beta, f_delta) };
        let want = f_alpha * (g1 / g2);
        assert!(
            (got - want).abs() <= want.abs() * 1e-6,
            "{got} vs {want}"
        );
    }

    #[test]
    fn guard_prevents_log_of_zero() {
        let nl = nlfilter_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let out = eng.eval(&[0.0; 9])[0];
        assert!(out.is_finite(), "{out}");
        assert!(out >= 0.0);
    }

    #[test]
    fn output_finite_across_range() {
        let nl = nlfilter_netlist(F16);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..500 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            let out = eng.eval(&w)[0];
            assert!(out.is_finite() && out >= 0.0, "{w:?} -> {out}");
        }
    }

    #[test]
    fn structure_counts() {
        let nl = nlfilter_netlist(F16);
        assert_eq!(nl.op_count("max_const"), 9);
        assert_eq!(nl.op_count("sqrt"), 2);
        assert_eq!(nl.op_count("log2"), 2);
        assert_eq!(nl.op_count("exp2"), 1);
        assert_eq!(nl.op_count("div"), 1);
        assert_eq!(nl.op_count("cmp_and_swap"), 1);
    }
}
