//! CNN stage datapaths: ReLU and max-pool as ordinary netlists.
//!
//! Both are pure *selection* datapaths — built entirely from the paper's
//! `max` operator family, which compares and selects without ever
//! rounding ([`crate::fpcore::FpOps::max`] is mode-independent and
//! exact).  That means a ReLU or pool stage passes its input values
//! through bit-unchanged regardless of the stage's `FloatFormat`, and
//! the software engines, RTL sim, resource model and SystemVerilog
//! emitter all handle them through the existing `OpKind` machinery with
//! no new evaluation code.

use crate::fpcore::{FloatFormat, OpKind};
use crate::sim::netlist::{Builder, Netlist};

/// ReLU datapath: `max(x, 0)` over a 1×1 window (one `max_const` node,
/// latency 1 cycle).
pub fn relu_netlist(fmt: FloatFormat) -> Netlist {
    let mut b = Builder::new(fmt);
    let x = b.input("w00");
    let y = b.max_const(x, 0.0);
    b.output("pix_o", y);
    b.build()
}

/// Max-pool datapath over a `k×k` window: a left-fold chain of `max`
/// nodes in window raster order (`k²−1` comparators, latency `k²−1`
/// cycles).  The fold order matches a naive raster-order `f64::max`
/// reduction operator for operator, so the hardware datapath is
/// bit-identical to the software reference even for `±0.0` ties.
pub fn pool_netlist(fmt: FloatFormat, k: usize) -> Netlist {
    assert!(k >= 1, "pool window must be at least 1x1");
    let mut b = Builder::new(fmt);
    let wins: Vec<_> =
        (0..k * k).map(|i| b.input(&format!("w{}{}", i / k, i % k))).collect();
    let mut acc = wins[0];
    for &w in &wins[1..] {
        acc = b.op2(OpKind::Max, acc, w);
    }
    b.output("pix_o", acc);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::OpMode;
    use crate::sim::Engine;

    const F16: FloatFormat = FloatFormat::new(10, 5);
    const F8: FloatFormat = FloatFormat::new(4, 3);

    #[test]
    fn relu_structure_and_eval() {
        let nl = relu_netlist(F16);
        assert_eq!(nl.inputs.len(), 1);
        assert_eq!(nl.op_count("max_const"), 1);
        assert_eq!(nl.total_latency(), 1);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[-3.5])[0], 0.0);
        assert_eq!(eng.eval(&[2.25])[0], 2.25);
        assert_eq!(eng.eval(&[0.0])[0], 0.0);
    }

    #[test]
    fn pool_structure() {
        let nl = pool_netlist(F16, 2);
        assert_eq!(nl.inputs.len(), 4);
        assert_eq!(nl.op_count("max"), 3);
        assert_eq!(nl.total_latency(), 3);
        let nl3 = pool_netlist(F16, 3);
        assert_eq!(nl3.inputs.len(), 9);
        assert_eq!(nl3.op_count("max"), 8);
        assert_eq!(nl3.total_latency(), 8);
    }

    #[test]
    fn pool_matches_raster_fold_even_in_narrow_formats() {
        // selection never rounds: values outside F8's grid still come
        // out bit-identical to the f64 fold
        let nl = pool_netlist(F8, 2);
        for mode in [OpMode::Exact, OpMode::Poly] {
            let mut eng = Engine::new(&nl, mode);
            let w = [0.3, -7.123456, 0.2999999, 5.0000001];
            let want = w.iter().copied().fold(w[0], f64::max);
            assert_eq!(eng.eval(&w)[0], want, "{mode:?}");
        }
    }

    #[test]
    fn pool_tie_break_matches_fold_order() {
        // ±0.0 ties: f64::max(-0.0, 0.0) and the netlist fold must agree
        let nl = pool_netlist(F16, 2);
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let w = [-0.0, 0.0, -0.0, -0.0];
        let want = w[1..].iter().copied().fold(w[0], f64::max);
        assert_eq!(eng.eval(&w)[0].to_bits(), want.to_bits());
    }
}
