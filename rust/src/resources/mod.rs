//! FPGA resource model — regenerates fig. 11.
//!
//! Structural cost estimation for scheduled netlists on the paper's board
//! (Zybo Z7-20, XC7Z020: 53 200 LUTs, 106 400 flip-flops, 140 BRAM36,
//! 220 DSP48E1 — §IV-B footnote 19).  The model counts the same objects a
//! synthesizer maps:
//!
//! * **DSPs** — mantissa multipliers (`ceil(m+1 / 17) · ceil(m+1 / 24)`
//!   DSP48 tiles per multiply) for `mult`/`mult_const` and the Horner
//!   multiplies inside the polynomial datapaths (div = 3 + 1, sqrt/log2 =
//!   2, exp2 = 2 — footnotes 9/13).
//! * **LUTs** — alignment/normalization barrel shifters (`≈ 2·m·log2 m`
//!   per adder), exponent/control logic (`≈ k·(m+e)`), comparators,
//!   segment-select + coefficient ROMs of the poly ops, and — crucially —
//!   *fabric fallback multipliers* when the DSP budget is exhausted, which
//!   reproduces the paper's conv5x5/fp_sobel float64 failures (206 % /
//!   135 % LUTs with the DSP count dropping).
//! * **FFs** — one format-width register per pipeline stage per operator,
//!   plus the Δ delay-matching registers the scheduler inserted, plus the
//!   window registers + border-handling registers of §III-A.
//! * **BRAM36** — line buffers: `H−1` buffers of `line_width` pixels; each
//!   maps to `ceil(width / bits_per_column(depth))` RAMB36 (Xilinx aspect
//!   ratios: 512×72, 1024×36, 2048×18, 4096×9).
//!
//! Absolute counts are estimates (the real board and Vivado are not in
//! this environment — DESIGN.md §Substitutions); orderings, scaling with
//! format width, and the over-budget failures are the reproduced claims.

use crate::fpcore::{FloatFormat, OpKind};
use crate::sim::netlist::Netlist;
use crate::video::StageGeometry;

/// Zybo Z7-20 (XC7Z020-1CLG400C) budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Budget {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
}

pub const ZYBO_Z7_20: Budget = Budget {
    luts: 53_200,
    ffs: 106_400,
    bram36: 140.0,
    dsps: 220,
};

/// Estimated resource usage.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Usage {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
}

impl Usage {
    pub fn add(&mut self, o: Usage) {
        self.luts += o.luts;
        self.ffs += o.ffs;
        self.bram36 += o.bram36;
        self.dsps += o.dsps;
    }

    /// Percent utilization against a budget (LUT, FF, BRAM, DSP).
    pub fn utilization(&self, b: Budget) -> [f64; 4] {
        [
            100.0 * self.luts as f64 / b.luts as f64,
            100.0 * self.ffs as f64 / b.ffs as f64,
            100.0 * self.bram36 / b.bram36,
            100.0 * self.dsps as f64 / b.dsps as f64,
        ]
    }

    /// Does the design fit the device?  (The paper's float64 conv5x5 and
    /// fp_sobel implementations fail at 206.20 % and 135.08 % LUTs.)
    pub fn fits(&self, b: Budget) -> bool {
        self.utilization(b).iter().all(|&u| u <= 100.0)
    }
}

/// DSP48 tiles for an (m+1)×(m+1) mantissa multiplier.
pub fn dsps_per_multiply(fmt: FloatFormat) -> u64 {
    let bits = (fmt.mantissa + 1) as u64;
    bits.div_ceil(17) * bits.div_ceil(24)
}

/// LUTs for a fabric (non-DSP) multiplier of the same width.
fn fabric_mult_luts(fmt: FloatFormat) -> u64 {
    let bits = (fmt.mantissa + 1) as u64;
    // carry-save array multiplier ≈ 1.1 LUT per partial-product bit
    (bits * bits * 11) / 10
}

/// `log2`-ish for shifter sizing.
fn log2u(v: u64) -> u64 {
    64 - v.leading_zeros() as u64
}

/// Per-operator LUT/FF/DSP cost (BRAM is only used by line buffers).
pub fn op_cost(op: &OpKind, fmt: FloatFormat) -> Usage {
    let m = fmt.mantissa as u64;
    let e = fmt.exponent as u64;
    let w = fmt.width() as u64;
    let lat = op.latency() as u64;
    // pipeline registers: one word per stage (+20% control)
    let pipe_ff = lat * w * 12 / 10;
    let (luts, dsps) = match op {
        OpKind::Add | OpKind::Sub => {
            // align + normalize barrel shifters + mantissa adder + exp logic
            (2 * m * log2u(m) + m + 8 * (m + e) / 2 + 4 * e, 0)
        }
        OpKind::Mul | OpKind::MulConst(_) => {
            // mantissa product in DSPs; exponent add + normalize in LUTs
            (3 * (m + e), dsps_per_multiply(fmt))
        }
        OpKind::Div => {
            // reciprocal: deg-3 Horner (3 mults) + final multiply (1),
            // segment select + coefficient ROM + normalize
            (4 * (m + e) + coeff_rom_luts(4, 4, w), 4 * dsps_per_multiply(fmt))
        }
        OpKind::Sqrt | OpKind::Log2 => {
            // deg-2 Horner (2 mults) + range reduction
            (4 * (m + e) + coeff_rom_luts(4, 3, w), 2 * dsps_per_multiply(fmt))
        }
        OpKind::Exp2 => (4 * (m + e) + coeff_rom_luts(4, 3, w), 2 * dsps_per_multiply(fmt)),
        OpKind::Max | OpKind::Min | OpKind::MaxConst(_) => {
            // comparator + mux
            (3 * w / 2, 0)
        }
        OpKind::Rsh(_) | OpKind::Lsh(_) => {
            // exponent ± constant with saturation
            (2 * e + 4, 0)
        }
        OpKind::Cas => {
            // comparator + two muxes, two output pipes
            (5 * w / 2, 0)
        }
        OpKind::Convert(dst) => {
            // inter-format converter: exponent re-bias adder + range
            // compare, RNE increment at the destination width, and the
            // saturate/flush output muxes — no multipliers
            let md = dst.mantissa as u64;
            let ed = dst.exponent as u64;
            (2 * md + 3 * (e + ed) + 8, 0)
        }
        OpKind::Reg => (0, 0),
    };
    let ff = match op {
        OpKind::Cas => 2 * pipe_ff,
        // converter pipeline registers hold destination-width words
        OpKind::Convert(dst) => lat * dst.width() as u64 * 12 / 10,
        _ => pipe_ff,
    };
    Usage { luts, ffs: ff, bram36: 0.0, dsps }
}

/// Coefficient ROM in fabric: segments × terms × word bits, 64 bits/LUT(M).
fn coeff_rom_luts(segments: u64, terms: u64, word: u64) -> u64 {
    (segments * terms * word).div_ceil(64) + 8
}

/// RAMB36 blocks for one line buffer of `depth` pixels × `width` bits
/// (Xilinx 7-series aspect ratios).
pub fn bram36_per_line(depth: u64, width: u64) -> f64 {
    let bits_per_col = match depth {
        0..=512 => 72,
        513..=1024 => 36,
        1025..=2048 => 18,
        _ => 9,
    };
    let cols = width.div_ceil(bits_per_col);
    // a half BRAM (RAMB18) suffices for narrow final columns
    let rem = width % bits_per_col;
    if rem != 0 && rem <= bits_per_col / 2 && cols > 0 {
        cols as f64 - 0.5
    } else {
        cols as f64
    }
}

/// Estimate a complete filter: datapath netlist + (optional) window
/// generator with the stage's geometry (window shape, stride, channel
/// planes) over `line_width`-pixel lines.  Line-buffer BRAM scales with
/// `(win_h − 1) · channels` buffers; the window/border register file and
/// mux tree scale with the rectangular window dimensions.
pub fn estimate(nl: &Netlist, window: Option<(StageGeometry, usize)>) -> Usage {
    let fmt = nl.fmt;
    let w = fmt.width() as u64;
    let mut total = Usage::default();
    let mut dsp_mult_count = 0u64; // fabric-fallback bookkeeping

    for node in &nl.nodes {
        let mut c = op_cost(&node.op, fmt);
        // Δ delay registers on operand edges
        let delay_ff: u64 = node.in_delays.iter().map(|&d| d as u64 * w).sum();
        c.ffs += delay_ff;
        if matches!(node.op, OpKind::Mul | OpKind::MulConst(_)) {
            dsp_mult_count += c.dsps;
        }
        total.add(c);
    }

    if let Some((geom, line_width)) = window {
        let wh = geom.win_h as u64;
        let ww = geom.win_w as u64;
        let ch = geom.channels as u64;
        // window shift registers + border-handling registers (§III-A:
        // H·(W−1)/2 extra registers and H·(W+1)−1 muxes)
        let win_ff = wh * ww * w + wh * (ww - 1) / 2 * w;
        let mux_luts = (wh * (ww + 1) - 1) * w;
        // temporal controllers: two counters + compare
        let ctl_luts = 2 * 24 + 32;
        total.ffs += win_ff + 48;
        total.luts += mux_luts + ctl_luts;
        // (win_h − 1) line buffers per channel plane
        total.bram36 += ((wh - 1) * ch) as f64 * bram36_per_line(line_width as u64, w);
    }

    // DSP exhaustion → Vivado falls back to fabric multipliers for the
    // datapath multiplies (reproduces the fig. 11 float64 failures: DSP
    // count drops, LUTs explode past 100 %).
    if total.dsps > ZYBO_Z7_20.dsps && dsp_mult_count > 0 {
        let per_mult = dsps_per_multiply(fmt);
        let n_mults = dsp_mult_count / per_mult;
        total.dsps -= dsp_mult_count;
        total.luts += n_mults * fabric_mult_luts(fmt);
    }

    total
}

/// Estimate a multi-filter streaming chain: each stage's datapath netlist
/// plus its own window generator (line buffers sized by that stage's
/// *own* format width AND its own line width — a strided upstream stage
/// shrinks every downstream line buffer by `ceil(w / stride)`), summed —
/// the fused chain lays every stage down in fabric simultaneously, so
/// resources add.  Boundaries where consecutive stages use different
/// formats are priced as explicit `fmt_converter` blocks ([`op_cost`] on
/// [`OpKind::Convert`]); same-format boundaries are plain wires.  The
/// DSP-exhaustion fabric fallback is applied per stage ([`estimate`]),
/// which is conservative: a chain whose *combined* multiplier demand
/// exceeds the budget can still report DSP counts per-stage-feasible
/// stages kept in DSPs.
pub fn estimate_chain<'a>(
    stages: impl IntoIterator<Item = (&'a Netlist, StageGeometry)>,
    line_width: usize,
) -> Usage {
    let stages: Vec<(&Netlist, StageGeometry)> = stages.into_iter().collect();
    let mut total = Usage::default();
    let mut lw = line_width;
    for &(nl, geom) in &stages {
        total.add(estimate(nl, Some((geom, lw))));
        lw = geom.out_width(lw);
    }
    for pair in stages.windows(2) {
        let (src, dst) = (pair[0].0.fmt, pair[1].0.fmt);
        if src != dst {
            total.add(op_cost(&OpKind::Convert(dst), src));
        }
    }
    total
}

/// Structural estimate of the Vivado-HLS 24-bit fixed-point Sobel
/// (§IV-B hls_sobel): xf::LineBuffer (2 lines, padded to a power-of-two
/// depth) + xf::Window + integer datapath + HLS control overhead.
pub fn hls_sobel_usage(line_width: usize) -> Usage {
    // xf::LineBuffer pads the line depth to the next power of two, and
    // the Xilinx video libraries buffer RGB lines (3 channels × 2 line
    // buffers of 24-bit pixels) — at 1920 that infers the paper's
    // measured 9.0 BRAMs, and it scales with the line width like
    // `estimate` does for the custom-float line buffers.
    let depth = (line_width.max(1) as u64).next_power_of_two();
    Usage {
        // integer adds are cheap but HLS control/dataflow logic is not
        luts: 7_600,
        ffs: 9_000,
        bram36: 3.0 * 2.0 * bram36_per_line(depth, 24),
        dsps: 4, // gx/gy constant shifts-adds + mag² products
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::{FilterKind, HwFilter};
    use crate::fpcore::format::{FORMATS, FORMAT_KEYS};

    fn fmt(key: &str) -> FloatFormat {
        FORMATS.iter().find(|(k, _)| *k == key).unwrap().1
    }

    fn usage(kind: FilterKind, key: &str) -> Usage {
        let f = fmt(key);
        let hw = HwFilter::new(kind, f).unwrap();
        estimate(&hw.netlist, Some((hw.geom, 1920)))
    }

    #[test]
    fn dsp_per_multiply_widths() {
        assert_eq!(dsps_per_multiply(fmt("f16")), 1); // 11-bit
        assert_eq!(dsps_per_multiply(fmt("f24")), 1); // 17-bit
        assert_eq!(dsps_per_multiply(fmt("f32")), 2); // 24-bit
        assert_eq!(dsps_per_multiply(fmt("f48")), 6); // 40-bit
        assert_eq!(dsps_per_multiply(fmt("f64")), 12); // 54-bit
    }

    #[test]
    fn bram_counts_match_paper_band() {
        // paper: 3×3 filters 2.0–4.0 BRAM over 16–64 bit; 5×5: 4.0–10.0
        let b16 = 2.0 * bram36_per_line(1920, 16);
        assert_eq!(b16, 2.0);
        let b64_3x3 = 2.0 * bram36_per_line(1920, 64);
        assert!(b64_3x3 >= 4.0, "{b64_3x3}");
        let b16_5x5 = 4.0 * bram36_per_line(1920, 16);
        assert_eq!(b16_5x5, 4.0);
    }

    #[test]
    fn monotone_in_width() {
        // every resource grows (weakly) with the float width
        for kind in [FilterKind::Conv3x3, FilterKind::Median, FilterKind::FpSobel] {
            let mut prev = Usage::default();
            for key in FORMAT_KEYS {
                let u = usage(kind, key);
                assert!(u.luts >= prev.luts, "{} {key}", kind.name());
                assert!(u.ffs >= prev.ffs);
                assert!(u.bram36 >= prev.bram36);
                prev = u;
            }
        }
    }

    #[test]
    fn median_uses_no_dsps() {
        for key in FORMAT_KEYS {
            assert_eq!(usage(FilterKind::Median, key).dsps, 0, "{key}");
        }
    }

    #[test]
    fn conv5x5_float64_fails_like_paper() {
        // fig. 11: conv5x5 float64(53,10) → DSP drops, 206 % LUTs, fails
        let u = usage(FilterKind::Conv5x5, "f64");
        assert!(!u.fits(ZYBO_Z7_20));
        let lut_pct = u.utilization(ZYBO_Z7_20)[0];
        assert!(lut_pct > 100.0, "{lut_pct}%");
        // DSPs fell back to fabric: below the raw 25×12 = 300 demand
        assert!(u.dsps < 300);
    }

    #[test]
    fn fp_sobel_float64_fails_like_paper() {
        let u = usage(FilterKind::FpSobel, "f64");
        assert!(!u.fits(ZYBO_Z7_20), "{:?}", u.utilization(ZYBO_Z7_20));
    }

    #[test]
    fn small_formats_fit() {
        for kind in [
            FilterKind::Conv3x3,
            FilterKind::Conv5x5,
            FilterKind::Median,
            FilterKind::Nlfilter,
            FilterKind::FpSobel,
        ] {
            for key in ["f16", "f24", "f32"] {
                let u = usage(kind, key);
                assert!(u.fits(ZYBO_Z7_20), "{} {key}: {:?}", kind.name(), u.utilization(ZYBO_Z7_20));
            }
        }
    }

    #[test]
    fn fp_sobel_beats_hls_at_narrow_widths() {
        // paper: "the floating-point Sobel used less hardware than its HLS
        // version for custom floating-point widths of up to 24 bits"
        let hls = hls_sobel_usage(1920);
        for key in ["f16", "f24"] {
            let u = usage(FilterKind::FpSobel, key);
            assert!(u.luts < hls.luts, "{key}: {} vs {}", u.luts, hls.luts);
        }
        // and loses at 48+ bits
        let u48 = usage(FilterKind::FpSobel, "f48");
        assert!(u48.luts > hls.luts);
    }

    #[test]
    fn hls_sobel_nine_brams() {
        assert_eq!(hls_sobel_usage(1920).bram36, 9.0);
    }

    #[test]
    fn hls_sobel_bram_scales_with_line_width() {
        // the line-buffer BRAM must track the width (the old model pinned
        // it at the 1920 figure regardless of the argument)
        let narrow = hls_sobel_usage(256).bram36;
        let mid = hls_sobel_usage(640).bram36;
        let wide = hls_sobel_usage(1920).bram36;
        assert!(narrow < wide, "{narrow} !< {wide}");
        assert!(narrow <= mid && mid <= wide, "{narrow} {mid} {wide}");
        // depth padding: 1025..2048 share one power-of-two depth
        assert_eq!(hls_sobel_usage(1100).bram36, hls_sobel_usage(1920).bram36);
        // non-BRAM resources are the HLS control overhead, width-free
        assert_eq!(hls_sobel_usage(256).luts, hls_sobel_usage(1920).luts);
    }

    #[test]
    fn converter_cost_is_small_and_multiplier_free() {
        let f16 = fmt("f16");
        let f24 = fmt("f24");
        let c = op_cost(&OpKind::Convert(f16), f24);
        assert_eq!(c.dsps, 0);
        assert_eq!(c.bram36, 0.0);
        assert!(c.luts > 0 && c.ffs > 0);
        // far cheaper than any arithmetic block of either format
        assert!(c.luts < op_cost(&OpKind::Add, f24).luts);
        // pipeline registers are destination-width words: a narrowing
        // converter holds fewer FFs than the widening one
        let widen = op_cost(&OpKind::Convert(f24), f16);
        assert!(c.ffs < widen.ffs, "{} !< {}", c.ffs, widen.ffs);
    }

    #[test]
    fn mixed_format_chain_prices_the_boundary_converter() {
        let med = HwFilter::new(FilterKind::Median, fmt("f24")).unwrap();
        let sob = HwFilter::new(FilterKind::FpSobel, fmt("f16")).unwrap();
        let a = estimate(&med.netlist, Some((med.geom, 1920)));
        let b = estimate(&sob.netlist, Some((sob.geom, 1920)));
        let cvt = op_cost(&OpKind::Convert(fmt("f16")), fmt("f24"));
        let chain = estimate_chain(
            [(&med.netlist, med.geom), (&sob.netlist, sob.geom)],
            1920,
        );
        assert_eq!(chain.luts, a.luts + b.luts + cvt.luts);
        assert_eq!(chain.ffs, a.ffs + b.ffs + cvt.ffs);
        assert_eq!(chain.dsps, a.dsps + b.dsps);
        // line buffers stay per-stage format width: 2×24 bit + 2×16 bit
        assert_eq!(chain.bram36, a.bram36 + b.bram36);
        // the same chain at a uniform format has no converter
        let med16 = HwFilter::new(FilterKind::Median, fmt("f16")).unwrap();
        let uniform = estimate_chain(
            [(&med16.netlist, med16.geom), (&sob.netlist, sob.geom)],
            1920,
        );
        let a16 = estimate(&med16.netlist, Some((med16.geom, 1920)));
        assert_eq!(uniform.luts, a16.luts + b.luts);
    }

    #[test]
    fn chain_estimate_is_the_sum_of_stage_estimates() {
        let med = HwFilter::new(FilterKind::Median, fmt("f16")).unwrap();
        let sob = HwFilter::new(FilterKind::FpSobel, fmt("f16")).unwrap();
        let a = estimate(&med.netlist, Some((med.geom, 1920)));
        let b = estimate(&sob.netlist, Some((sob.geom, 1920)));
        let chain = estimate_chain(
            [(&med.netlist, med.geom), (&sob.netlist, sob.geom)],
            1920,
        );
        assert_eq!(chain.luts, a.luts + b.luts);
        assert_eq!(chain.ffs, a.ffs + b.ffs);
        assert_eq!(chain.bram36, a.bram36 + b.bram36);
        assert_eq!(chain.dsps, a.dsps + b.dsps);
        // a 2-stage f16 chain still fits the paper's board
        assert!(chain.fits(ZYBO_Z7_20));
    }

    #[test]
    fn filter_chain_resource_usage_reports_chain_totals() {
        use crate::filters::FilterChain;
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, fmt("f16")).unwrap(),
            HwFilter::new(FilterKind::FpSobel, fmt("f16")).unwrap(),
        ])
        .unwrap();
        let u = chain.resource_usage(1920);
        let direct = estimate_chain(
            chain.stages().iter().map(|hw| (&hw.netlist, hw.geom)),
            1920,
        );
        assert_eq!(u, direct);
        // two 3x3 window generators => 4 line buffers at 16 bits => 4 BRAM
        assert_eq!(u.bram36, 4.0);
    }

    #[test]
    fn nlfilter_uses_more_dsps_than_median() {
        for key in FORMAT_KEYS {
            let nl = usage(FilterKind::Nlfilter, key);
            let med = usage(FilterKind::Median, key);
            assert!(nl.dsps > med.dsps, "{key}");
        }
    }
}
