//! Conv fusion: compose adjacent stride-1, same-format linear-convolution
//! stages into one wider convolution (3×3∘3×3 → 5×5), the way
//! high-throughput 2-D filter generators cascade kernels in fabric.
//!
//! Fusion is a *plan rewrite*, not an execution strategy: the fused plan
//! has fewer stages (one window generator, one datapath, one software
//! pass where there were two) and a shorter pipeline, at the price of a
//! measured numeric drift — composing the taps re-rounds them and
//! replaces two small adder trees with one big reassociated one, so the
//! fused plan is NOT bit-identical to the unfused cascade.  The
//! [`FusionReport`] carries both sides of that trade: signed per-pair
//! resource/latency deltas from the cost model (a 3×3∘3×3 fusion *grows*
//! the datapath — 24 adders and 25 multipliers against 16 and 18 — while
//! shaving latency and a full per-row pass) and the measured max-ulp /
//! PSNR drift against the unfused sequential oracle.

use anyhow::{anyhow, bail, Context, Result};

use super::accuracy::{self, Accuracy};
use crate::filters::{FilterChain, HwFilter};
use crate::fpcore::OpKind;
use crate::pipeline::CompiledPipeline;
use crate::resources::Usage;
use crate::sim::netlist::SignalSrc;
use crate::sim::Netlist;
use crate::video::Frame;

/// Default line width the report prices line buffers / resources at.
pub const REPORT_LINE_WIDTH: usize = 1920;

/// Resource and latency movement of one fusion, `fused − unfused`
/// (negative = the fused stage is cheaper on that axis).  Priced by the
/// same `estimate_chain` cost model the rest of the crate reports, at the
/// pair's own position in the cascade (upstream striding shrinks the line
/// the pair buffers).
#[derive(Debug, Clone)]
pub struct PairReport {
    pub upstream: String,
    pub downstream: String,
    pub fused: String,
    /// Datapath latency delta in cycles (always negative: one composed
    /// adder tree is shallower than two chained ones).
    pub latency_delta: i64,
    /// Line-buffer storage delta in bits at the report line width.
    pub line_buffer_delta: i64,
    pub lut_delta: i64,
    pub ff_delta: i64,
    pub dsp_delta: i64,
    pub bram36_delta: f64,
}

/// What [`fuse_plan`] did and what it cost: which boundaries fused, the
/// whole-chain before/after resource picture, and the measured numeric
/// drift of the fused plan against the unfused sequential oracle.
#[derive(Debug, Clone)]
pub struct FusionReport {
    pub pairs: Vec<PairReport>,
    pub stages_before: usize,
    pub stages_after: usize,
    pub usage_before: Usage,
    pub usage_after: Usage,
    pub latency_before: u32,
    pub latency_after: u32,
    pub line_buffer_bits_before: u64,
    pub line_buffer_bits_after: u64,
    /// Input line width the line-buffer/resource numbers were priced at.
    pub line_width: usize,
    /// Measured fused-vs-unfused drift on the reference frames, in the
    /// final stage's output format.
    pub accuracy: Accuracy,
}

impl FusionReport {
    /// One-paragraph human summary (the CLI prints this).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for p in &self.pairs {
            s.push_str(&format!(
                "fused `{}` ∘ `{}` -> `{}`  (latency {:+}, line-buffer {:+} bits, \
                 LUT {:+}, FF {:+}, DSP {:+})\n",
                p.upstream,
                p.downstream,
                p.fused,
                p.latency_delta,
                p.line_buffer_delta,
                p.lut_delta,
                p.ff_delta,
                p.dsp_delta,
            ));
        }
        s.push_str(&format!(
            "stages {} -> {}, datapath latency {} -> {} cycles, \
             line buffers {} -> {} bits @ {}px lines\n",
            self.stages_before,
            self.stages_after,
            self.latency_before,
            self.latency_after,
            self.line_buffer_bits_before,
            self.line_buffer_bits_after,
            self.line_width,
        ));
        s.push_str(&format!(
            "drift vs unfused oracle: psnr {:.2} dB, max {:.1} ulp\n",
            self.accuracy.psnr, self.accuracy.max_ulp
        ));
        s
    }
}

/// Extract the linear-map coefficients of a single-output netlist over
/// its input ports: succeeds exactly when the datapath computes
/// `out = Σ cᵢ·inᵢ` (a pure convolution — no bias, no comparison, no
/// data-dependent product), and returns the `cᵢ` in input-port order.
///
/// The walk is symbolic over the *unrounded* dataflow — add/sub combine,
/// constant multiplies/divides and exponent shifts scale, `Reg`/`Convert`
/// pass through — so the coefficients it recovers are the taps as the
/// netlist quantized them at build time.
pub fn linear_taps(nl: &Netlist) -> Result<Vec<f64>> {
    if nl.outputs.len() != 1 {
        bail!(
            "{}-output netlist; linear convolutions stream exactly one pixel",
            nl.outputs.len()
        );
    }
    let n = nl.inputs.len();

    #[derive(Clone)]
    struct Lin {
        c: Vec<f64>,
        k: f64,
    }
    impl Lin {
        fn is_const(&self) -> bool {
            self.c.iter().all(|&v| v == 0.0)
        }
        fn scale(&self, s: f64) -> Lin {
            Lin { c: self.c.iter().map(|v| v * s).collect(), k: self.k * s }
        }
    }
    fn zip(a: &Lin, b: &Lin, f: impl Fn(f64, f64) -> f64) -> Lin {
        Lin {
            c: a.c.iter().zip(&b.c).map(|(&x, &y)| f(x, y)).collect(),
            k: f(a.k, b.k),
        }
    }

    let mut lin: Vec<Option<Lin>> = vec![None; nl.signals.len()];
    for (i, sig) in nl.signals.iter().enumerate() {
        match sig.src {
            SignalSrc::Input(p) => {
                let mut c = vec![0.0; n];
                c[p] = 1.0;
                lin[i] = Some(Lin { c, k: 0.0 });
            }
            SignalSrc::Const(v) => lin[i] = Some(Lin { c: vec![0.0; n], k: v }),
            SignalSrc::Node { .. } => {}
        }
    }
    for node in &nl.nodes {
        let ins: Vec<Lin> = node
            .ins
            .iter()
            .map(|&s| {
                lin[s]
                    .clone()
                    .ok_or_else(|| anyhow!("netlist not in topological order"))
            })
            .collect::<Result<_>>()?;
        let out = match node.op {
            OpKind::Add => zip(&ins[0], &ins[1], |x, y| x + y),
            OpKind::Sub => zip(&ins[0], &ins[1], |x, y| x - y),
            OpKind::MulConst(c) => ins[0].scale(c),
            OpKind::Mul => {
                if ins[0].is_const() {
                    ins[1].scale(ins[0].k)
                } else if ins[1].is_const() {
                    ins[0].scale(ins[1].k)
                } else {
                    bail!("contains a non-linear `multiplier` of two data-dependent signals");
                }
            }
            OpKind::Div => {
                if ins[1].is_const() && ins[1].k != 0.0 {
                    ins[0].scale(1.0 / ins[1].k)
                } else {
                    bail!("contains a non-linear `divider`");
                }
            }
            OpKind::Rsh(s) => ins[0].scale(2.0f64.powi(-(s as i32))),
            OpKind::Lsh(s) => ins[0].scale(2.0f64.powi(s as i32)),
            OpKind::Reg => ins[0].clone(),
            OpKind::Convert(_) => ins[0].clone(),
            op => bail!("contains the non-linear `{}` operator", op.name()),
        };
        lin[node.outs[0]] = Some(out);
    }
    let out = lin[nl.outputs[0].1]
        .clone()
        .ok_or_else(|| anyhow!("output signal is unscheduled"))?;
    if out.k != 0.0 {
        bail!(
            "carries an affine bias {} on its output; fusion composes pure convolutions",
            out.k
        );
    }
    Ok(out.c)
}

/// Full 2-D composition of two correlation kernels (raster order):
/// `C[y][x] = Σ A[i][j]·B[y−i][x−j]`, dims `(ha+hb−1, wa+wb−1)` — applying
/// `A` then `B` to a stream equals applying `C` once (away from clamped
/// borders, where the fused window sees source pixels the cascade's
/// re-clamping hides; the measured drift covers both effects).
pub fn compose_kernels(
    a: &[f64],
    (ha, wa): (usize, usize),
    b: &[f64],
    (hb, wb): (usize, usize),
) -> Vec<f64> {
    assert_eq!(a.len(), ha * wa);
    assert_eq!(b.len(), hb * wb);
    let (hc, wc) = (ha + hb - 1, wa + wb - 1);
    let mut c = vec![0.0; hc * wc];
    for ia in 0..ha {
        for ja in 0..wa {
            let av = a[ia * wa + ja];
            for ib in 0..hb {
                for jb in 0..wb {
                    c[(ia + ib) * wc + (ja + jb)] += av * b[ib * wb + jb];
                }
            }
        }
    }
    c
}

/// Try to fuse one adjacent pair into a single convolution stage.
/// Refuses — with the reason — mixed-format boundaries, strided stages,
/// non-linear datapaths, and compositions outside the window generator's
/// 3..=16 limits.
fn try_fuse_pair(a: &HwFilter, b: &HwFilter) -> Result<HwFilter> {
    if a.fmt != b.fmt {
        bail!(
            "mixed-format boundary ({} -> {}); fusion composes taps in one format — \
             restage the pair (e.g. via --auto-fmt) first",
            a.fmt.name(),
            b.fmt.name()
        );
    }
    if a.geom.stride != 1 || b.geom.stride != 1 {
        bail!(
            "strided stage (stride {} -> {}); fusing across a stride would change \
             the sampling grid",
            a.geom.stride,
            b.geom.stride
        );
    }
    let ta = linear_taps(&a.netlist)
        .with_context(|| format!("`{}` is not a linear convolution", a.name()))?;
    let tb = linear_taps(&b.netlist)
        .with_context(|| format!("`{}` is not a linear convolution", b.name()))?;
    let (ha, wa) = (a.geom.win_h, a.geom.win_w);
    let (hb, wb) = (b.geom.win_h, b.geom.win_w);
    if ta.len() != ha * wa || tb.len() != hb * wb {
        bail!("stage input ports do not cover the window taps");
    }
    let (hc, wc) = (ha + hb - 1, wa + wb - 1);
    if hc < 3 || wc < 3 {
        bail!("composed {hc}x{wc} window is below the 3-pixel window generator minimum");
    }
    if hc > 16 || wc > 16 {
        bail!("composed {hc}x{wc} window exceeds the 16-pixel window generator limit");
    }
    let k = compose_kernels(&ta, (ha, wa), &tb, (hb, wb));
    Ok(HwFilter::conv_rect(a.fmt, hc, wc, &k)?.with_channels(a.geom.channels))
}

fn pair_report(a: &HwFilter, b: &HwFilter, fused: &HwFilter, lw: usize) -> Result<PairReport> {
    let before = FilterChain::new(vec![a.clone(), b.clone()])?;
    let after = FilterChain::new(vec![fused.clone()])?;
    let (ub, ua) = (before.resource_usage(lw), after.resource_usage(lw));
    Ok(PairReport {
        upstream: a.name().to_string(),
        downstream: b.name().to_string(),
        fused: fused.name().to_string(),
        latency_delta: after.datapath_latency() as i64 - before.datapath_latency() as i64,
        line_buffer_delta: after.line_buffer_bits(lw) as i64 - before.line_buffer_bits(lw) as i64,
        lut_delta: ua.luts as i64 - ub.luts as i64,
        ff_delta: ua.ffs as i64 - ub.ffs as i64,
        dsp_delta: ua.dsps as i64 - ub.dsps as i64,
        bram36_delta: ua.bram36 - ub.bram36,
    })
}

/// [`fuse_plan_with`] on the default deterministic reference frames and
/// report line width.
pub fn fuse_plan(plan: &CompiledPipeline) -> Result<(CompiledPipeline, FusionReport)> {
    let frames = accuracy::reference_frames(96, 64);
    fuse_plan_with(plan, &frames, REPORT_LINE_WIDTH)
}

/// Fuse every fusible adjacent pair of `plan`, greedily left to right to
/// a fixpoint (a fused stage may fuse again with its next neighbour), and
/// measure the fused plan against the unfused sequential oracle on
/// `frames`.  Errs — listing the per-boundary reasons — when *no*
/// boundary fuses.
pub fn fuse_plan_with(
    plan: &CompiledPipeline,
    frames: &[Frame],
    line_width: usize,
) -> Result<(CompiledPipeline, FusionReport)> {
    let mut stages: Vec<HwFilter> = plan.stages().to_vec();
    let mut pairs = Vec::new();
    let mut reasons: Vec<String> = Vec::new();
    let mut i = 0;
    while i + 1 < stages.len() {
        match try_fuse_pair(&stages[i], &stages[i + 1]) {
            Ok(fused) => {
                let lw = stages[..i].iter().fold(line_width, |w, hw| hw.geom.out_width(w));
                pairs.push(pair_report(&stages[i], &stages[i + 1], &fused, lw)?);
                stages[i] = fused;
                stages.remove(i + 1);
                // stay at i: the composed stage may fuse with the next
            }
            Err(e) => {
                reasons.push(format!(
                    "`{}` -> `{}`: {e:#}",
                    stages[i].name(),
                    stages[i + 1].name()
                ));
                i += 1;
            }
        }
    }
    if pairs.is_empty() {
        bail!(
            "no fusible stage boundary in `{}`:\n  {}",
            plan.name(),
            reasons.join("\n  ")
        );
    }
    let chain = FilterChain::new(stages)?;
    let fused_plan = CompiledPipeline::from_chain(chain, plan.mode());

    let fmt = fused_plan.stages().last().expect("plans have at least one stage").fmt;
    let mut acc = Accuracy::perfect();
    let mut measured = 0usize;
    for f in frames {
        if plan.check_frame(f).is_err() || fused_plan.check_frame(f).is_err() {
            continue;
        }
        let want = plan.run_frame_sequential(f);
        let got = fused_plan.run_frame_sequential(f);
        acc = acc.worst(accuracy::compare_frames(&want, &got, fmt));
        measured += 1;
    }
    if measured == 0 {
        bail!(
            "none of the {} reference frames fits the fused `{}` window; \
             pass larger frames to fused_with",
            frames.len(),
            fused_plan.name()
        );
    }

    let report = FusionReport {
        stages_before: plan.len(),
        stages_after: fused_plan.len(),
        usage_before: plan.resource_usage(line_width),
        usage_after: fused_plan.resource_usage(line_width),
        latency_before: plan.datapath_latency(),
        latency_after: fused_plan.datapath_latency(),
        line_buffer_bits_before: plan.line_buffer_bits(line_width),
        line_buffer_bits_after: fused_plan.line_buffer_bits(line_width),
        line_width,
        accuracy: acc,
        pairs,
    };
    Ok((fused_plan, report))
}
