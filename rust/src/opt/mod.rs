//! The plan optimizer — the first subsystem that *rewrites*
//! [`CompiledPipeline`](crate::pipeline::CompiledPipeline)s instead of
//! executing them.
//!
//! * [`fuse`] — conv fusion: compose adjacent stride-1 same-format
//!   linear convolutions into one wider stage
//!   ([`CompiledPipeline::fused`](crate::pipeline::CompiledPipeline::fused)),
//!   with an honest signed resource/latency delta and a measured
//!   accuracy drift.
//! * [`search`] — automatic per-stage `(m, e)` assignment over the
//!   25-format lattice against a PSNR / max-ulp target and/or a resource
//!   budget, emitting a Pareto front.
//! * [`accuracy`] — the scoring substrate both share: re-staging plans
//!   at other formats and measuring them against an f64-grade reference
//!   through real `Session` runs.
//!
//! Surfaced on the CLI as `fpspatial optimize` and as `--fuse` /
//! `--auto-fmt` on `run` / `pipeline` / `serve`.

pub mod accuracy;
pub mod fuse;
pub mod search;

pub use accuracy::{reference_frames, restage, restage_plan, Accuracy};
pub use fuse::{compose_kernels, linear_taps, FusionReport, PairReport};
pub use search::{
    evaluate_point, lattice, search_formats, ParetoPoint, ResourceBudget, SearchConfig,
    SearchResult,
};
