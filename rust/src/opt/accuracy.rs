//! Accuracy scoring for plan rewrites: re-stage a plan at other formats,
//! run it through a real [`Session`](crate::pipeline::Session), and
//! measure PSNR / max-ulp against an f64-grade reference.

use anyhow::{bail, Context, Result};

use crate::filters::{FilterChain, FilterKind, FilterSpec, HwFilter};
use crate::fpcore::{FloatFormat, OpMode};
use crate::pipeline::{CompiledPipeline, ExecPlan};
use crate::video::Frame;

/// The f64-equivalent reference format: `quantize` into it is the
/// identity on doubles, so a plan re-staged here computes the ideal
/// double-precision cascade.
pub const REFERENCE_FORMAT: FloatFormat = FloatFormat::new(52, 11);

/// Measured accuracy of one plan against a reference: worst-frame PSNR
/// (dB, capped — identical frames would otherwise be +inf) and the
/// largest per-pixel error in ulps of the plan's output format at the
/// reference magnitude.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accuracy {
    pub psnr: f64,
    pub max_ulp: f64,
}

impl Accuracy {
    /// PSNR cap standing in for "bit-identical" (also keeps the value
    /// JSON-encodable).
    pub const PSNR_CAP: f64 = 200.0;

    /// The identity element for [`Accuracy::worst`] folds.
    pub fn perfect() -> Self {
        Self { psnr: Self::PSNR_CAP, max_ulp: 0.0 }
    }

    /// Pessimistic merge: min PSNR, max ulp.
    pub fn worst(self, o: Self) -> Self {
        Self { psnr: self.psnr.min(o.psnr), max_ulp: self.max_ulp.max(o.max_ulp) }
    }
}

/// Deterministic evaluation frames the optimizer defaults to when the
/// caller supplies none: the structured test card plus two fixed-seed
/// noise frames (noise is the adversarial case for precision — no
/// spatial correlation for the filters to hide rounding under).
pub fn reference_frames(width: usize, height: usize) -> Vec<Frame> {
    vec![
        Frame::test_card(width, height),
        Frame::noise(width, height, 0xF5EA11),
        Frame::noise(width, height, 0x5EED5),
    ]
}

/// One ulp of `fmt` at the magnitude of `x` (clamped to the format's
/// normal range, so near-zero references don't divide by a denormal ulp).
fn ulp_at(x: f64, fmt: FloatFormat) -> f64 {
    let a = x.abs().max(fmt.min_normal());
    let e = a.log2().floor() as i32;
    2.0f64.powi(e - fmt.mantissa as i32)
}

/// Compare one output frame against its reference: PSNR over the frame,
/// max error in ulps of `fmt` at the reference magnitude.
pub fn compare_frames(reference: &Frame, got: &Frame, fmt: FloatFormat) -> Accuracy {
    assert_eq!(
        (reference.width, reference.height),
        (got.width, got.height),
        "accuracy comparison needs same-shape frames"
    );
    let psnr = reference.psnr(got).min(Accuracy::PSNR_CAP);
    let mut max_ulp = 0.0f64;
    for (r, g) in reference.data.iter().zip(&got.data) {
        let u = (r - g).abs() / ulp_at(*r, fmt);
        if u > max_ulp {
            max_ulp = u;
        }
    }
    Accuracy { psnr, max_ulp }
}

/// Rebuild one stage at another format, preserving its stride/channel
/// geometry.  Convolution stages (built-in or DSL) are rebuilt from
/// their extracted taps; ReLU/pool/built-in datapaths from their
/// constructors.  Non-linear DSL programs cannot be re-staged (the
/// source is gone) — that is a usable error, not a panic.
pub fn restage(hw: &HwFilter, fmt: FloatFormat) -> Result<HwFilter> {
    if fmt == hw.fmt {
        return Ok(hw.clone());
    }
    let g = hw.geom;
    let re = match &hw.spec {
        FilterSpec::Relu => HwFilter::relu(fmt),
        FilterSpec::Pool { k, stride, .. } => HwFilter::max_pool(fmt, *k, *stride)?,
        FilterSpec::Builtin(kind @ (FilterKind::Conv3x3 | FilterKind::Conv5x5)) => {
            let taps = super::fuse::linear_taps(&hw.netlist)
                .with_context(|| format!("re-staging conv stage `{}`", hw.name()))?;
            HwFilter::with_kernel(*kind, fmt, &taps)
        }
        FilterSpec::Builtin(kind) => HwFilter::new(*kind, fmt)?,
        FilterSpec::Dsl { name } => {
            let taps = super::fuse::linear_taps(&hw.netlist).with_context(|| {
                format!(
                    "stage `{name}` is a non-linear DSL program and cannot be \
                     re-staged; recompile it from source with an explicit format"
                )
            })?;
            HwFilter::conv_rect(fmt, g.win_h, g.win_w, &taps)?
        }
    };
    Ok(re.with_stride(g.stride).with_channels(g.channels))
}

/// Rebuild the whole plan with per-stage formats (same mode, same
/// geometry, same taps — only the arithmetic grids move).
pub fn restage_plan(plan: &CompiledPipeline, formats: &[FloatFormat]) -> Result<CompiledPipeline> {
    if formats.len() != plan.len() {
        bail!("{} formats supplied for a {}-stage plan", formats.len(), plan.len());
    }
    let stages = plan
        .stages()
        .iter()
        .zip(formats)
        .map(|(hw, &f)| restage(hw, f))
        .collect::<Result<Vec<_>>>()?;
    Ok(CompiledPipeline::from_chain(FilterChain::new(stages)?, plan.mode()))
}

/// The plan's ideal-arithmetic twin: every stage at
/// [`REFERENCE_FORMAT`], exact operators — the "f64 reference" accuracy
/// targets are measured against.
pub fn reference_plan(plan: &CompiledPipeline) -> Result<CompiledPipeline> {
    let stages = plan
        .stages()
        .iter()
        .map(|hw| restage(hw, REFERENCE_FORMAT))
        .collect::<Result<Vec<_>>>()?;
    Ok(CompiledPipeline::from_chain(FilterChain::new(stages)?, OpMode::Exact))
}

/// Run a plan over the evaluation frames through a real batched
/// [`Session`](crate::pipeline::Session) (the same executor production
/// uses — the search scores what will actually run).
pub fn run_plan(plan: &CompiledPipeline, frames: &[Frame]) -> Result<Vec<Frame>> {
    let mut sess = plan.session(ExecPlan::Batched)?;
    frames.iter().map(|f| sess.process(f)).collect()
}

/// Score `plan` against precomputed reference outputs (one per frame):
/// the worst-frame fold of [`compare_frames`] in the plan's output
/// format.
pub fn measure_against(
    plan: &CompiledPipeline,
    reference_outputs: &[Frame],
    frames: &[Frame],
) -> Result<Accuracy> {
    let fmt = plan.stages().last().expect("plans have at least one stage").fmt;
    let outs = run_plan(plan, frames)?;
    Ok(outs
        .iter()
        .zip(reference_outputs)
        .map(|(o, r)| compare_frames(r, o, fmt))
        .fold(Accuracy::perfect(), Accuracy::worst))
}
