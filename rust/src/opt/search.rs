//! Automatic per-stage format search: given an accuracy target (PSNR or
//! max-ulp vs the f64 reference) and/or a resource budget, walk per-stage
//! `(m, e)` assignments over the 25-format lattice and emit a Pareto
//! front of accuracy-vs-area tradeoffs.
//!
//! The search is deliberately simple — the paper's fig. 11 sweep is one
//! uniform axis; here we add a beam of greedy narrowings from the widest
//! lattice seed, which is enough to discover mixed-precision plans (a
//! wide first conv, narrow tail) the uniform sweep cannot express.  Every
//! candidate is scored by *running it*: a real batched `Session` on the
//! evaluation frames for accuracy, `estimate_chain` for area.  All
//! candidates are memoized, so the walk is deterministic given the frame
//! set.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::accuracy::{self, Accuracy};
use crate::fpcore::FloatFormat;
use crate::pipeline::CompiledPipeline;
use crate::video::Frame;

/// Mantissa notches of the search lattice (ascending).
pub const LATTICE_M: [u32; 5] = [4, 7, 10, 16, 23];
/// Exponent notches of the search lattice (ascending).
pub const LATTICE_E: [u32; 5] = [5, 6, 7, 8, 10];

/// The full 25-point `(m, e)` lattice, widest last.
pub fn lattice() -> Vec<FloatFormat> {
    let mut v = Vec::with_capacity(LATTICE_M.len() * LATTICE_E.len());
    for &m in &LATTICE_M {
        for &e in &LATTICE_E {
            v.push(FloatFormat::new(m, e));
        }
    }
    v
}

/// Optional per-axis resource ceilings a feasible plan must fit.
#[derive(Debug, Clone, Copy, Default)]
pub struct ResourceBudget {
    pub luts: Option<u64>,
    pub dsps: Option<u64>,
    pub bram_bits: Option<u64>,
}

/// Search parameters: what "good enough" means (accuracy targets, budget)
/// and how hard to look (beam width, pricing line width).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Feasible plans reach at least this PSNR (dB) vs the f64 reference.
    pub psnr_target: Option<f64>,
    /// Feasible plans stay at or under this many output-format ulps.
    pub max_ulp_target: Option<f64>,
    pub budget: ResourceBudget,
    /// Input line width area/line-buffers are priced at.
    pub line_width: usize,
    /// Beam width of the greedy narrowing walk.
    pub beam: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            psnr_target: None,
            max_ulp_target: None,
            budget: ResourceBudget::default(),
            line_width: 1920,
            beam: 4,
        }
    }
}

impl SearchConfig {
    /// Does `p` meet the accuracy targets (ignoring the budget)?
    pub fn accuracy_ok(&self, p: &ParetoPoint) -> bool {
        self.psnr_target.map_or(true, |t| p.psnr >= t)
            && self.max_ulp_target.map_or(true, |t| p.max_ulp <= t)
    }

    /// Does `p` meet the accuracy targets *and* fit the budget?
    pub fn feasible(&self, p: &ParetoPoint) -> bool {
        self.accuracy_ok(p)
            && self.budget.luts.map_or(true, |b| p.luts <= b)
            && self.budget.dsps.map_or(true, |b| p.dsps <= b)
            && self.budget.bram_bits.map_or(true, |b| p.bram_bits <= b)
    }
}

/// One evaluated format assignment: per-stage formats, measured accuracy
/// (worst frame), and estimated area at the config line width.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub formats: Vec<FloatFormat>,
    pub psnr: f64,
    pub max_ulp: f64,
    pub luts: u64,
    pub dsps: u64,
    pub bram_bits: u64,
}

impl ParetoPoint {
    /// `"m10e5,m7e5,…"` — stable display/tie-break key.
    pub fn format_names(&self) -> String {
        self.formats.iter().map(|f| f.name()).collect::<Vec<_>>().join(",")
    }

    /// Pareto dominance over (psnr ↑, max_ulp ↓, luts ↓, dsps ↓,
    /// bram_bits ↓): at least as good everywhere, strictly better
    /// somewhere.
    pub fn dominates(&self, o: &ParetoPoint) -> bool {
        let ge = self.psnr >= o.psnr
            && self.max_ulp <= o.max_ulp
            && self.luts <= o.luts
            && self.dsps <= o.dsps
            && self.bram_bits <= o.bram_bits;
        let strict = self.psnr > o.psnr
            || self.max_ulp < o.max_ulp
            || self.luts < o.luts
            || self.dsps < o.dsps
            || self.bram_bits < o.bram_bits;
        ge && strict
    }
}

/// What the search found: the non-dominated front (sorted by area), the
/// cheapest feasible point (if any candidate met the targets), and how
/// many distinct assignments were evaluated.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub front: Vec<ParetoPoint>,
    pub chosen: Option<ParetoPoint>,
    pub evaluated: usize,
}

fn notch_down(list: &[u32], v: u32) -> Option<u32> {
    list.iter().rev().find(|&&x| x < v).copied()
}

fn narrow_m(f: FloatFormat) -> Option<FloatFormat> {
    notch_down(&LATTICE_M, f.mantissa).map(|m| FloatFormat::new(m, f.exponent))
}

fn narrow_e(f: FloatFormat) -> Option<FloatFormat> {
    notch_down(&LATTICE_E, f.exponent).map(|e| FloatFormat::new(f.mantissa, e))
}

fn score_point(
    plan: &CompiledPipeline,
    refs: &[Frame],
    frames: &[Frame],
    formats: &[FloatFormat],
    line_width: usize,
) -> Result<ParetoPoint> {
    let cand = accuracy::restage_plan(plan, formats)?;
    let Accuracy { psnr, max_ulp } = accuracy::measure_against(&cand, refs, frames)?;
    let u = cand.resource_usage(line_width);
    Ok(ParetoPoint {
        formats: formats.to_vec(),
        psnr,
        max_ulp,
        luts: u.luts,
        dsps: u.dsps,
        bram_bits: cand.line_buffer_bits(line_width),
    })
}

/// Evaluate one explicit assignment outside a search (the CLI scores the
/// uniform-m10e5 baseline this way, against the same f64 reference).
pub fn evaluate_point(
    plan: &CompiledPipeline,
    frames: &[Frame],
    formats: &[FloatFormat],
    line_width: usize,
) -> Result<ParetoPoint> {
    if frames.is_empty() {
        bail!("format evaluation needs at least one frame");
    }
    let refs = accuracy::run_plan(&accuracy::reference_plan(plan)?, frames)?;
    score_point(plan, &refs, frames, formats, line_width)
}

/// Run the format search on `plan`, scoring accuracy on `frames`.
///
/// Two candidate generators feed one memoized evaluator:
/// 1. every uniform lattice assignment (25 points — the fig. 11 axis);
/// 2. a beam of width `cfg.beam` narrowing greedily from uniform
///    `m23e10`, one mantissa/exponent notch on one stage per step,
///    expanding only candidates that still meet the accuracy targets and
///    ranking beams by estimated LUTs.
///
/// Deterministic: candidates are generated in a fixed order, memoized by
/// format vector, and every ranking breaks ties on the format names.
pub fn search_formats(
    plan: &CompiledPipeline,
    frames: &[Frame],
    cfg: &SearchConfig,
) -> Result<SearchResult> {
    if frames.is_empty() {
        bail!("format search needs at least one evaluation frame");
    }
    if cfg.beam == 0 {
        bail!("beam width must be at least 1");
    }
    let refs = accuracy::run_plan(&accuracy::reference_plan(plan)?, frames)?;
    let n = plan.len();

    let mut order: Vec<ParetoPoint> = Vec::new();
    let mut memo: HashMap<Vec<(u32, u32)>, ParetoPoint> = HashMap::new();
    let mut eval = |formats: &[FloatFormat]| -> Result<ParetoPoint> {
        let key: Vec<(u32, u32)> = formats.iter().map(|f| (f.mantissa, f.exponent)).collect();
        if let Some(p) = memo.get(&key) {
            return Ok(p.clone());
        }
        let p = score_point(plan, &refs, frames, formats, cfg.line_width)?;
        memo.insert(key, p.clone());
        order.push(p.clone());
        Ok(p)
    };

    for fmt in lattice() {
        eval(&vec![fmt; n])?;
    }

    let wide = FloatFormat::new(*LATTICE_M.last().unwrap(), *LATTICE_E.last().unwrap());
    let mut beam: Vec<Vec<FloatFormat>> = vec![vec![wide; n]];
    loop {
        let mut next: Vec<(ParetoPoint, Vec<FloatFormat>)> = Vec::new();
        for b in &beam {
            for i in 0..n {
                for moved in [narrow_m(b[i]), narrow_e(b[i])] {
                    let Some(f) = moved else { continue };
                    let mut cand = b.clone();
                    cand[i] = f;
                    let p = eval(&cand)?;
                    if cfg.accuracy_ok(&p) {
                        next.push((p, cand));
                    }
                }
            }
        }
        if next.is_empty() {
            break;
        }
        next.sort_by(|a, b| {
            a.0.luts
                .cmp(&b.0.luts)
                .then(a.0.dsps.cmp(&b.0.dsps))
                .then(b.0.psnr.total_cmp(&a.0.psnr))
                .then(a.0.format_names().cmp(&b.0.format_names()))
        });
        next.dedup_by(|a, b| a.1 == b.1);
        next.truncate(cfg.beam);
        beam = next.into_iter().map(|(_, f)| f).collect();
    }

    let mut front: Vec<ParetoPoint> = order
        .iter()
        .filter(|p| !order.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();
    front.sort_by(|a, b| {
        a.luts
            .cmp(&b.luts)
            .then(b.psnr.total_cmp(&a.psnr))
            .then(a.format_names().cmp(&b.format_names()))
    });
    let chosen = front.iter().find(|p| cfg.feasible(p)).cloned();
    Ok(SearchResult { front, chosen, evaluated: order.len() })
}
