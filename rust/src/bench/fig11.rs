//! Fig. 11 regeneration: FPGA resource usage (LUT / FF / BRAM / DSP) for
//! the six filters across the five custom-float widths, against the Zybo
//! Z7-20 budget, including the float64 implementation failures.

use crate::bench::render_table;
use crate::filters::{FilterKind, HwFilter};
use crate::fpcore::format::FORMATS;
use crate::resources::{estimate, hls_sobel_usage, Usage, ZYBO_Z7_20};

/// One fig. 11 data point.
#[derive(Debug, Clone)]
pub struct Point {
    pub filter: String,
    pub format: String,
    pub width: u32,
    pub usage: Usage,
    pub fits: bool,
}

/// Line width used by the paper's resource runs (1080p).
pub const LINE_WIDTH: usize = 1920;

/// Compute all fig. 11 series.
pub fn run() -> Vec<Point> {
    let mut points = Vec::new();
    for (key, fmt) in FORMATS {
        for kind in [
            FilterKind::Conv3x3,
            FilterKind::Conv5x5,
            FilterKind::Median,
            FilterKind::Nlfilter,
            FilterKind::FpSobel,
        ] {
            let hw = HwFilter::new(kind, fmt).expect("fig. 11 sweeps netlist filters");
            let usage = estimate(&hw.netlist, Some((hw.geom, LINE_WIDTH)));
            points.push(Point {
                filter: kind.name().to_string(),
                format: key.to_string(),
                width: fmt.width(),
                fits: usage.fits(ZYBO_Z7_20),
                usage,
            });
        }
    }
    // the fixed-point comparator is format-independent (one series value)
    let hls = hls_sobel_usage(LINE_WIDTH);
    points.push(Point {
        filter: "hls_sobel".to_string(),
        format: "q16.8".to_string(),
        width: 24,
        fits: hls.fits(ZYBO_Z7_20),
        usage: hls,
    });
    points
}

/// Pretty-print as the four fig. 11 subplots (one table).
pub fn render(points: &[Point]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            let u = p.usage.utilization(ZYBO_Z7_20);
            vec![
                p.filter.clone(),
                p.format.clone(),
                format!("{}", p.usage.luts),
                format!("{:.2}%", u[0]),
                format!("{}", p.usage.ffs),
                format!("{:.2}%", u[1]),
                format!("{:.1}", p.usage.bram36),
                format!("{}", p.usage.dsps),
                if p.fits { "ok".into() } else { "FAILS".into() },
            ]
        })
        .collect();
    render_table(
        &["filter", "format", "LUT", "LUT%", "FF", "FF%", "BRAM36", "DSP", "impl"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_sweep_has_26_points() {
        let pts = run();
        assert_eq!(pts.len(), 5 * 5 + 1);
    }

    #[test]
    fn float64_failures_match_paper() {
        let pts = run();
        let get = |f: &str, fmt: &str| pts.iter().find(|p| p.filter == f && p.format == fmt).unwrap();
        assert!(!get("conv5x5", "f64").fits);
        assert!(!get("fp_sobel", "f64").fits);
        // everything at 16/24/32 bits fits
        for f in ["conv3x3", "conv5x5", "median", "nlfilter", "fp_sobel"] {
            for fmt in ["f16", "f24", "f32"] {
                assert!(get(f, fmt).fits, "{f} {fmt}");
            }
        }
    }

    #[test]
    fn orderings_match_figure() {
        let pts = run();
        let get = |f: &str, fmt: &str| &pts.iter().find(|p| p.filter == f && p.format == fmt).unwrap().usage;
        for fmt in ["f16", "f24", "f32", "f48"] {
            // conv5x5 > conv3x3 everywhere
            assert!(get("conv5x5", fmt).luts > get("conv3x3", fmt).luts);
            assert!(get("conv5x5", fmt).dsps > get("conv3x3", fmt).dsps);
            // median: zero DSP
            assert_eq!(get("median", fmt).dsps, 0);
            // nlfilter + fp_sobel lean on DSPs (poly datapaths)
            assert!(get("nlfilter", fmt).dsps > 0);
            assert!(get("fp_sobel", fmt).dsps > get("conv3x3", fmt).dsps);
        }
    }
}
