//! Table I regeneration: software vs hardware frame rates for
//! conv3x3 / conv5x5 / median / nlfilter at 480p / 720p / 1080p.
//!
//! * **Software rows** — measured wall-clock on this machine:
//!   - conv/median/sobel: the vectorized compiled baselines
//!     (`filters::software`, scipy-equivalent);
//!   - nlfilter: the *interpreted* generic-function path
//!     (`dsl::Interp`, MATLAB-`nlfilter`-equivalent), which is what the
//!     paper's 0.074 FPS measures.
//! * **Hardware rows** — the streaming datapath is proven II=1 by the RTL
//!   simulator, so the achieved rate is pixel-clock-bound:
//!   `FPS = 148.5 MHz / total pixels` (§IV-A) — 60 / 120 / ≈353.57 FPS.
//!   The cycle-simulator wall-clock rate is also reported (sim-Mpx/s).

use std::time::Duration;

use anyhow::Result;

use crate::bench::{render_table, timeit};
use crate::dsl::Interp;
use crate::filters::{conv, software, FilterKind, HwFilter};
use crate::fpcore::{FloatFormat, OpMode};
use crate::pipeline::{ExecPlan, Pipeline};
use crate::video::{Frame, TIMINGS};

/// One Table-I cell.
#[derive(Debug, Clone)]
pub struct Row {
    pub filter: String,
    pub resolution: String,
    pub software_fps: f64,
    pub hardware_fps: f64,
    /// Wall-clock rate of the cycle simulator (Mpixel/s) — the §Perf metric.
    pub sim_mpix_s: f64,
}

/// Paper Table I values for comparison (software column, Core-i7 scipy).
pub fn paper_software_fps(filter: &str, res: &str) -> Option<f64> {
    Some(match (filter, res) {
        ("conv3x3", "480p") => 295.71,
        ("conv3x3", "720p") => 67.34,
        ("conv3x3", "1080p") => 34.22,
        ("conv5x5", "480p") => 162.50,
        ("conv5x5", "720p") => 56.05,
        ("conv5x5", "1080p") => 22.94,
        ("median", "480p") => 57.23,
        ("median", "720p") => 16.58,
        ("median", "1080p") => 6.24,
        ("nlfilter", "480p") => 0.462,
        ("nlfilter", "720p") => 0.157,
        ("nlfilter", "1080p") => 0.074,
        _ => return None,
    })
}

const NLFILTER_DSL: &str = include_str!("../../../examples/dsl/nlfilter.dsl");

fn measure_software(kind: FilterKind, frame: &Frame, budget: Duration) -> f64 {
    match kind {
        FilterKind::Conv3x3 => {
            let k = conv::gaussian3x3();
            let s = timeit(|| { std::hint::black_box(software::conv_sw(frame, &k, 3)); }, budget, 50);
            s.per_sec()
        }
        FilterKind::Conv5x5 => {
            let k = conv::gaussian5x5();
            let s = timeit(|| { std::hint::black_box(software::conv_sw(frame, &k, 5)); }, budget, 50);
            s.per_sec()
        }
        FilterKind::Median => {
            let s = timeit(|| { std::hint::black_box(software::median_sw(frame)); }, budget, 50);
            s.per_sec()
        }
        FilterKind::Nlfilter => {
            // interpreted generic function — one frame is plenty slow
            let prog = crate::dsl::parse::parse(NLFILTER_DSL).expect("nlfilter dsl");
            let it = Interp::new_window(&prog).expect("window program");
            let t0 = std::time::Instant::now();
            std::hint::black_box(it.run_frame(frame).expect("interp"));
            1.0 / t0.elapsed().as_secs_f64()
        }
        _ => unreachable!(),
    }
}

fn measure_sim_rate(kind: FilterKind, frame: &Frame, fmt: FloatFormat, budget: Duration) -> f64 {
    let hw = HwFilter::new(kind, fmt).expect("Table-I filters are netlist-backed");
    let plan = Pipeline::from_stages([hw])
        .compile(OpMode::Exact)
        .expect("Table-I filters compile");
    // scalar session: the historical Table-I sim-rate metric (the
    // batched/tiled rates live in benches/hotpath.rs)
    let mut sess = plan.session(ExecPlan::Scalar).expect("scalar session");
    let mut out = Frame::new(frame.width, frame.height);
    let s = timeit(
        || {
            sess.process_into(frame, &mut out).expect("measurement frame streams");
            std::hint::black_box(&out);
        },
        budget,
        50,
    );
    (frame.width * frame.height) as f64 / s.mean.as_secs_f64() / 1e6
}

/// Run the full Table-I regeneration.
///
/// `quick` shrinks the measurement frames (software FPS is then
/// extrapolated by pixel count) so the suite stays fast in CI; the CLI
/// passes `quick=false` for full-size runs.
pub fn run(fmt: FloatFormat, quick: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for timing in TIMINGS {
        let (full_w, full_h) = (timing.h_active as usize, timing.v_active as usize);
        // measurement frame (possibly reduced)
        let (mw, mh) = if quick { (full_w / 4, full_h / 4) } else { (full_w, full_h) };
        let scale = (full_w * full_h) as f64 / (mw * mh) as f64;
        let frame = Frame::test_card(mw, mh);
        let budget = if quick { Duration::from_millis(30) } else { Duration::from_millis(300) };

        for kind in FilterKind::TABLE1 {
            let sw_fps = measure_software(kind, &frame, budget) / scale;
            let sim = measure_sim_rate(kind, &frame, fmt, budget);
            rows.push(Row {
                filter: kind.name().to_string(),
                resolution: timing.name.to_string(),
                software_fps: sw_fps,
                hardware_fps: timing.fpga_fps(),
                sim_mpix_s: sim,
            });
        }
    }
    Ok(rows)
}

/// Pretty-print the rows with the paper's values alongside.
pub fn render(rows: &[Row]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let paper_sw = paper_software_fps(&r.filter, &r.resolution)
                .map(|v| format!("{v:.3}"))
                .unwrap_or_default();
            let speedup = r.hardware_fps / r.software_fps;
            vec![
                r.filter.clone(),
                r.resolution.clone(),
                format!("{:.3}", r.software_fps),
                paper_sw,
                format!("{:.2}", r.hardware_fps),
                format!("{speedup:.1}x"),
                format!("{:.1}", r.sim_mpix_s),
            ]
        })
        .collect();
    render_table(
        &[
            "filter",
            "resolution",
            "sw FPS (measured)",
            "sw FPS (paper)",
            "hw FPS",
            "hw/sw",
            "sim Mpx/s",
        ],
        &table,
    )
}

/// The paper's headline: hardware nlfilter ≈ 810× software at 1080p.
pub fn headline_speedup(rows: &[Row]) -> Option<f64> {
    rows.iter()
        .find(|r| r.filter == "nlfilter" && r.resolution == "1080p")
        .map(|r| r.hardware_fps / r.software_fps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_shapes() {
        let rows = run(FloatFormat::new(10, 5), true).unwrap();
        assert_eq!(rows.len(), 12);
        // hardware rates are the paper's pixel-clock rates
        let hw1080: Vec<f64> = rows
            .iter()
            .filter(|r| r.resolution == "1080p")
            .map(|r| r.hardware_fps)
            .collect();
        assert!(hw1080.iter().all(|&f| (f - 60.0).abs() < 1e-9));
        // nlfilter software is by far the slowest filter at every resolution
        for res in ["480p", "720p", "1080p"] {
            let get = |f: &str| {
                rows.iter()
                    .find(|r| r.filter == f && r.resolution == res)
                    .unwrap()
                    .software_fps
            };
            assert!(get("nlfilter") < get("median"), "{res}");
            assert!(get("median") < get("conv3x3"), "{res}");
            assert!(get("conv5x5") < get("conv3x3"), "{res}");
        }
        // the hardware/software gap is largest for nlfilter (paper: ~810×)
        let s = headline_speedup(&rows).unwrap();
        assert!(s > 50.0, "headline speedup only {s:.0}x");
    }

    #[test]
    fn paper_reference_values_present() {
        assert_eq!(paper_software_fps("nlfilter", "1080p"), Some(0.074));
        assert_eq!(paper_software_fps("conv3x3", "480p"), Some(295.71));
        assert_eq!(paper_software_fps("bogus", "480p"), None);
    }
}
