//! Benchmark harnesses that regenerate the paper's evaluation artifacts
//! (Table I, fig. 11, the §III latency tables, and the design ablations).
//!
//! Used both by `cargo bench` (rust/benches/*.rs) and the CLI
//! (`fpspatial bench <name>`).  The offline crate set has no criterion;
//! [`timeit`] is a small warmup+repeat harness with min/mean reporting.

pub mod fig11;
pub mod table1;

use std::time::{Duration, Instant};

/// Timing statistics from [`timeit`].
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Measure `f`: one warmup call, then repeat until `min_time` elapses or
/// `max_iters` is reached (at least 3 iterations).
pub fn timeit(mut f: impl FnMut(), min_time: Duration, max_iters: u32) -> Stats {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < min_time || times.len() < 3) && (times.len() as u32) < max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    Stats {
        iters: times.len() as u32,
        mean: total / times.len() as u32,
        min: times.iter().min().copied().unwrap(),
    }
}

/// Render a simple aligned table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<width$} | ", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    ));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeit_reports() {
        let s = timeit(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            Duration::from_millis(5),
            1000,
        );
        assert!(s.iters >= 3);
        assert!(s.min <= s.mean);
    }

    #[test]
    fn table_render() {
        let t = render_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("| a   | bb |"));
        assert!(t.lines().count() == 4);
    }
}
