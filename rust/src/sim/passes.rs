//! Optimization passes of the tape compiler ([`super::kernel`]).
//!
//! The interpreters ([`super::engine`]) execute the [`Tape`] step by
//! step; the kernel compiler instead rewrites it through a short pass
//! pipeline before emitting direct-threaded code:
//!
//! 1. **constant folding** — any step whose inputs are all compile-time
//!    constants is evaluated *now* (with the exact `FpOps` the runtime
//!    would use, so Exact/Poly results are bit-identical) and its output
//!    becomes a new constant; `Mul` with one constant operand is
//!    rewritten to `MulConst`, `Max` with a constant second operand to
//!    `MaxConst`, and scheduler `Reg` copies are propagated away;
//! 2. **MAC fusion** — a `Mul`/`MulConst` whose single consumer is a
//!    later `Add` is sunk into it as one fused multiply-add step
//!    (`q(q(a·b) + c)` — both roundings preserved, operand order of the
//!    add preserved, so fused ≡ unfused bit for bit);
//! 3. **tree reduction** — a run of ≥ 2 consecutive `Add` steps (the
//!    paper's §III-B adder trees after MAC fusion) collapses into one
//!    `TreeReduce` superinstruction that executes the same adds in the
//!    same order with one dispatch;
//! 4. **max folding** — a left-fold `Max` chain (the pool stage's
//!    raster reduction) whose intermediates are single-use collapses
//!    into one `FoldMax` that never materializes them;
//! 5. **ReLU recognition** — `max_const(x, +0.0)` becomes the dedicated
//!    `Relu` instruction;
//! 6. **convert absorption** — a `Convert` whose single-use source is
//!    the final write of a fused superinstruction is folded into that
//!    write (`cvt: Some(fmt)` on the producer), so a chain stage whose
//!    netlist ends in a boundary `Convert` quantizes in the same
//!    dispatch that produced the value;
//! 7. **dead-slot elimination** — steps (and constants) that no output
//!    transitively depends on are removed;
//! 8. **register allocation** — the netlist's one-slot-per-signal
//!    scratch is compacted into a small reused arena (linear scan over
//!    the SSA tape; constants and outputs are pinned, a slot is reusable
//!    only *strictly after* its last read so block superinstructions
//!    can never alias their own operands).
//!
//! Every pass preserves bit-identity with the unfused sequence — the
//! rewrites only ever (a) batch dispatch, (b) skip materializing values
//! nothing reads, (c) evaluate the identical operation earlier, or
//! (d) fold a quantization into the write that produced its operand
//! (`quantize(x, f)` of a value ≡ writing that value pre-quantized).  The
//! one subtlety is operand order: IEEE `a+b`/`a·b` are bitwise
//! commutative for the non-NaN constants the builders produce, but
//! `f64::max` is not (±0.0), so `Max` rewrites keep the original
//! operand order exactly.

use std::collections::{HashMap, HashSet};

use super::engine::Tape;
use crate::fpcore::{ops::FpOps, FloatFormat, OpKind};

/// One step of the pass-pipeline IR: either an original tape op or a
/// fused superinstruction.  Slot indices refer to the netlist signal
/// space until [`Program::allocate_registers`] remaps them into the
/// compact arena.
#[derive(Debug, Clone)]
pub(crate) enum Hop {
    /// An unfused tape step (`d1` only meaningful for CAS).
    Op { op: OpKind, a: usize, b: usize, d: usize, d1: usize },
    /// `d = q(q(a·b) + c)`; `acc_first` keeps the add's original operand
    /// order (`q(c + q(a·b))`) for bitwise NaN-payload fidelity.
    /// `cvt` (all fused variants): an absorbed boundary `Convert` — the
    /// final write is additionally quantized to that format.
    Mac { a: usize, b: usize, c: usize, d: usize, acc_first: bool, cvt: Option<FloatFormat> },
    /// `d = q(q(a·imm) + c)` — MAC with a static coefficient.
    MacConst {
        a: usize,
        imm: f64,
        c: usize,
        d: usize,
        acc_first: bool,
        cvt: Option<FloatFormat>,
    },
    /// A run of adds executed in order under ONE dispatch: each entry is
    /// `[a, b, d]`, `d = q(a + b)`.  `cvt` applies to the LAST add's
    /// write only.
    TreeReduce { adds: Vec<[usize; 3]>, cvt: Option<FloatFormat> },
    /// `d = max(max(…max(terms[0], terms[1]), …), terms[k-1])` — the
    /// exact left fold, intermediates never materialized.
    FoldMax { terms: Vec<usize>, d: usize, cvt: Option<FloatFormat> },
    /// `d = max(a, +0.0)`.
    Relu { a: usize, d: usize, cvt: Option<FloatFormat> },
}

impl Hop {
    /// Slots this step reads, in evaluation order.
    pub(crate) fn reads(&self) -> Vec<usize> {
        match self {
            Hop::Op { op, a, b, .. } => match op.arity() {
                1 => vec![*a],
                _ => vec![*a, *b],
            },
            Hop::Mac { a, b, c, .. } => vec![*a, *b, *c],
            Hop::MacConst { a, c, .. } => vec![*a, *c],
            Hop::TreeReduce { adds, .. } => adds.iter().flat_map(|t| [t[0], t[1]]).collect(),
            Hop::FoldMax { terms, .. } => terms.clone(),
            Hop::Relu { a, .. } => vec![*a],
        }
    }

    /// Slots this step writes.
    pub(crate) fn writes(&self) -> Vec<usize> {
        match self {
            Hop::Op { op, d, d1, .. } => match op.outputs() {
                2 => vec![*d, *d1],
                _ => vec![*d],
            },
            Hop::Mac { d, .. } | Hop::MacConst { d, .. } => vec![*d],
            Hop::TreeReduce { adds, .. } => adds.iter().map(|t| t[2]).collect(),
            Hop::FoldMax { d, .. } | Hop::Relu { d, .. } => vec![*d],
        }
    }
}

/// Per-pass rewrite counts, kept on the compiled kernel for inspection
/// (`fpspatial compile --emit kernel`) and pinned by unit tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct PassStats {
    /// Tape steps before any pass.
    pub steps_in: usize,
    /// Steps evaluated at compile time (all-constant operands).
    pub folded: usize,
    /// `Reg` copies propagated away.
    pub copies: usize,
    /// `Mul`/`MulConst` steps absorbed into fused MACs.
    pub macs: usize,
    /// `TreeReduce` groups formed (and the adds they absorbed).
    pub tree_groups: usize,
    pub tree_adds: usize,
    /// `Max` chains folded (and the steps they absorbed).
    pub fold_maxes: usize,
    pub fold_max_terms: usize,
    /// `max_const(x, 0)` steps rewritten to `Relu`.
    pub relus: usize,
    /// Boundary `Convert` steps absorbed into their producer's write.
    pub converts_absorbed: usize,
    /// Steps removed as dead.
    pub dead: usize,
    /// Scratch slots before/after register allocation.
    pub slots_in: usize,
    pub slots_out: usize,
    /// Final superinstruction count.
    pub instrs_out: usize,
}

/// The mutable pass-pipeline state between [`Tape`] and instruction
/// emission.
#[derive(Debug, Clone)]
pub(crate) struct Program {
    pub(crate) ops: Vec<Hop>,
    /// `(slot, value)` constants to bake into the arena at executor
    /// construction.
    pub(crate) consts: Vec<(usize, f64)>,
    pub(crate) input_slots: Vec<usize>,
    pub(crate) output_slots: Vec<usize>,
    pub(crate) n_slots: usize,
}

impl Program {
    pub(crate) fn from_tape(tape: &Tape) -> Self {
        let ops = tape
            .steps
            .iter()
            .map(|s| Hop::Op { op: s.op, a: s.in0, b: s.in1, d: s.out0, d1: s.out1 })
            .collect();
        Self {
            ops,
            consts: tape.consts.clone(),
            input_slots: tape.input_slots.clone(),
            output_slots: tape.output_slots.clone(),
            n_slots: tape.n_signals,
        }
    }

    /// Pass 1: constant folding + const-operand rewrites + `Reg` copy
    /// propagation.  Returns `(folded, copies)`.
    ///
    /// Folding uses [`FpOps::apply`] — the very same evaluation the
    /// runtime would perform in this `(format, mode)` — so a folded
    /// value is bit-identical to the interpreted one.  `Mul` operand
    /// swaps are safe because IEEE multiplication is bitwise symmetric
    /// unless both operands are NaN (a NaN constant disables the
    /// rewrite); `Max` is rewritten only when the constant already sits
    /// in the second operand slot (`f64::max` is not symmetric in ±0.0).
    pub(crate) fn fold_constants(&mut self, fp: &FpOps) -> (usize, usize) {
        let mut cv: HashMap<usize, f64> =
            self.consts.iter().map(|&(s, v)| (s, v)).collect();
        // Reg-copy aliases: slot -> the slot it mirrors.
        let mut alias: HashMap<usize, usize> = HashMap::new();
        let out_set: HashSet<usize> = self.output_slots.iter().copied().collect();
        let res = |alias: &HashMap<usize, usize>, s: usize| *alias.get(&s).unwrap_or(&s);
        let mut folded = 0usize;
        let mut copies = 0usize;
        let mut kept: Vec<Hop> = Vec::with_capacity(self.ops.len());
        for hop in self.ops.drain(..) {
            let Hop::Op { op, a, b, d, d1 } = hop else { unreachable!("pass order") };
            let (a, b) = (res(&alias, a), res(&alias, b));
            // all-constant operands: evaluate now, drop the step
            let ca = cv.get(&a).copied();
            let cb = cv.get(&b).copied();
            let all_const = match op.arity() {
                1 => ca.is_some(),
                _ => ca.is_some() && cb.is_some(),
            };
            if all_const {
                let ins = [ca.unwrap_or(0.0), cb.unwrap_or(0.0)];
                let (r0, r1) = fp.apply(op, &ins[..op.arity()]);
                cv.insert(d, r0);
                if op.outputs() == 2 {
                    cv.insert(d1, r1.expect("two-output op"));
                }
                folded += 1;
                continue;
            }
            // Reg is a pure copy: alias it away unless the copy target
            // is an output port (the value must land in that slot).
            if matches!(op, OpKind::Reg) && !out_set.contains(&d) {
                alias.insert(d, a);
                copies += 1;
                continue;
            }
            let rewritten = match op {
                OpKind::Mul => match (ca, cb) {
                    (None, Some(c)) if !c.is_nan() => {
                        Hop::Op { op: OpKind::MulConst(c), a, b: 0, d, d1 }
                    }
                    (Some(c), None) if !c.is_nan() => {
                        Hop::Op { op: OpKind::MulConst(c), a: b, b: 0, d, d1 }
                    }
                    _ => Hop::Op { op, a, b, d, d1 },
                },
                // max(a, const) only when the const is ALREADY second
                OpKind::Max => match cb {
                    Some(c) if ca.is_none() => {
                        Hop::Op { op: OpKind::MaxConst(c), a, b: 0, d, d1 }
                    }
                    _ => Hop::Op { op, a, b, d, d1 },
                },
                _ => Hop::Op { op, a, b, d, d1 },
            };
            kept.push(rewritten);
        }
        self.ops = kept;
        // Constants = original + folded (+ aliased const reads resolved
        // above); dead ones are trimmed by eliminate_dead.
        let mut consts: Vec<(usize, f64)> = cv.into_iter().collect();
        consts.sort_unstable_by_key(|&(s, _)| s);
        self.consts = consts;
        (folded, copies)
    }

    /// Count how many steps read each slot (output ports count as one
    /// extra use so their defining step is never fused away).
    fn use_counts(&self) -> HashMap<usize, usize> {
        let mut uses: HashMap<usize, usize> = HashMap::new();
        for hop in &self.ops {
            for r in hop.reads() {
                *uses.entry(r).or_insert(0) += 1;
            }
        }
        for &o in &self.output_slots {
            *uses.entry(o).or_insert(0) += 1;
        }
        uses
    }

    /// Pass 2: fuse `Mul`/`MulConst` + `Add` into MACs.  Returns the
    /// number of multiplies absorbed.
    ///
    /// A multiply is sunk into its consuming add only when the add is
    /// its *sole* consumer and the product is not an output port.  The
    /// tape is SSA (the netlist builder writes each signal exactly
    /// once), so moving the multiply down to the add's position can
    /// never change any operand it reads.
    pub(crate) fn fuse_macs(&mut self) -> usize {
        let uses = self.use_counts();
        // def site (index into ops) of each slot, for Mul/MulConst only
        let mut mul_def: HashMap<usize, usize> = HashMap::new();
        for (i, hop) in self.ops.iter().enumerate() {
            if let Hop::Op { op: OpKind::Mul | OpKind::MulConst(_), d, .. } = hop {
                mul_def.insert(*d, i);
            }
        }
        let mut absorbed: HashSet<usize> = HashSet::new();
        let mut fused = 0usize;
        for j in 0..self.ops.len() {
            let Hop::Op { op: OpKind::Add, a, b, d, .. } = self.ops[j] else { continue };
            // try the first operand, then the second; fuse at most one
            let candidate = |slot: usize, absorbed: &HashSet<usize>| -> Option<usize> {
                let &i = mul_def.get(&slot)?;
                (i < j && uses.get(&slot) == Some(&1) && !absorbed.contains(&i)).then_some(i)
            };
            let (i, acc_first) = match candidate(a, &absorbed) {
                Some(i) => (i, false),
                None => match candidate(b, &absorbed) {
                    Some(i) => (i, true),
                    None => continue,
                },
            };
            let acc = if acc_first { a } else { b };
            let Hop::Op { op: mul_op, a: ma, b: mb, .. } = self.ops[i] else { unreachable!() };
            self.ops[j] = match mul_op {
                OpKind::Mul => Hop::Mac { a: ma, b: mb, c: acc, d, acc_first, cvt: None },
                OpKind::MulConst(imm) => {
                    Hop::MacConst { a: ma, imm, c: acc, d, acc_first, cvt: None }
                }
                _ => unreachable!("mul_def holds multiplies"),
            };
            absorbed.insert(i);
            fused += 1;
        }
        let mut k = 0usize;
        self.ops.retain(|_| {
            let keep = !absorbed.contains(&k);
            k += 1;
            keep
        });
        fused
    }

    /// Pass 3: collapse runs of ≥ 2 consecutive plain `Add` steps into
    /// one `TreeReduce`.  Returns `(groups, adds_absorbed)`.  The group
    /// executes the identical adds in the identical order — the fusion
    /// batches dispatch only, so bit-identity is structural.
    pub(crate) fn fuse_tree_reduce(&mut self) -> (usize, usize) {
        let mut out: Vec<Hop> = Vec::with_capacity(self.ops.len());
        let mut run: Vec<[usize; 3]> = Vec::new();
        let mut groups = 0usize;
        let mut adds = 0usize;
        let flush = |run: &mut Vec<[usize; 3]>,
                     out: &mut Vec<Hop>,
                     groups: &mut usize,
                     adds: &mut usize| {
            match run.len() {
                0 => {}
                1 => {
                    let t = run[0];
                    out.push(Hop::Op { op: OpKind::Add, a: t[0], b: t[1], d: t[2], d1: 0 });
                    run.clear();
                }
                n => {
                    *groups += 1;
                    *adds += n;
                    out.push(Hop::TreeReduce { adds: std::mem::take(run), cvt: None });
                }
            }
        };
        for hop in self.ops.drain(..) {
            match hop {
                Hop::Op { op: OpKind::Add, a, b, d, .. } => run.push([a, b, d]),
                other => {
                    flush(&mut run, &mut out, &mut groups, &mut adds);
                    out.push(other);
                }
            }
        }
        flush(&mut run, &mut out, &mut groups, &mut adds);
        self.ops = out;
        (groups, adds)
    }

    /// Pass 4: fold left-lean `Max` chains.  Returns `(chains,
    /// steps_absorbed)`.
    ///
    /// Only chains where each intermediate feeds the *first* operand of
    /// its single consuming `Max` are folded: `f64::max` is not
    /// symmetric (±0.0, NaN), so the fold preserves the exact
    /// evaluation order `max(max(max(t0,t1),t2),t3)`.
    pub(crate) fn fuse_fold_max(&mut self) -> (usize, usize) {
        let uses = self.use_counts();
        let out_set: HashSet<usize> = self.output_slots.iter().copied().collect();
        // def index of every plain Max step
        let mut max_def: HashMap<usize, usize> = HashMap::new();
        for (i, hop) in self.ops.iter().enumerate() {
            if let Hop::Op { op: OpKind::Max, d, .. } = hop {
                max_def.insert(*d, i);
            }
        }
        // consumer lookup: slot -> index of the Max reading it as
        // operand `a` (chains extend through the left operand only)
        let mut left_consumer: HashMap<usize, usize> = HashMap::new();
        for (i, hop) in self.ops.iter().enumerate() {
            if let Hop::Op { op: OpKind::Max, a, .. } = hop {
                left_consumer.insert(*a, i);
            }
        }
        let mut absorbed: HashSet<usize> = HashSet::new();
        let mut chains = 0usize;
        let mut steps = 0usize;
        let mut replace: Vec<(usize, Hop)> = Vec::new();
        for i in 0..self.ops.len() {
            if absorbed.contains(&i) {
                continue;
            }
            let Hop::Op { op: OpKind::Max, a, b, d, .. } = self.ops[i] else { continue };
            // chain head: `a` must NOT itself be a foldable Max link
            // (otherwise we'd start mid-chain and fold it twice)
            if let Some(&pi) = max_def.get(&a) {
                if pi < i && uses.get(&a) == Some(&1) && !out_set.contains(&a) {
                    continue; // handled when the walk reaches this link
                }
            }
            // walk down the left-fold chain
            let mut terms = vec![a, b];
            let mut tail = i;
            let mut cur_d = d;
            let mut links = vec![i];
            while uses.get(&cur_d) == Some(&1) && !out_set.contains(&cur_d) {
                let Some(&j) = left_consumer.get(&cur_d) else { break };
                if j <= tail {
                    break;
                }
                let Hop::Op { op: OpKind::Max, a: ja, b: jb, d: jd, .. } = self.ops[j] else {
                    break;
                };
                debug_assert_eq!(ja, cur_d);
                let _ = ja;
                terms.push(jb);
                tail = j;
                cur_d = jd;
                links.push(j);
            }
            if links.len() < 2 {
                continue;
            }
            chains += 1;
            steps += links.len();
            // the fold replaces the LAST link (all terms are defined by
            // then); earlier links vanish
            let (&last, earlier) = links.split_last().expect("len >= 2");
            replace.push((last, Hop::FoldMax { terms, d: cur_d, cvt: None }));
            absorbed.extend(earlier.iter().copied());
            absorbed.insert(last); // skip as a future chain head
        }
        for (idx, hop) in replace {
            self.ops[idx] = hop;
        }
        // remove the absorbed earlier links (replaced slots stay)
        let replaced: HashSet<usize> = self
            .ops
            .iter()
            .enumerate()
            .filter_map(|(i, h)| matches!(h, Hop::FoldMax { .. }).then_some(i))
            .collect();
        let mut k = 0usize;
        self.ops.retain(|_| {
            let keep = !absorbed.contains(&k) || replaced.contains(&k);
            k += 1;
            keep
        });
        (chains, steps)
    }

    /// Pass 5: `max_const(x, +0.0)` → `Relu`.  Strictly `+0.0` — a
    /// `-0.0` guard is a different function on `-0.0` inputs.
    pub(crate) fn rewrite_relu(&mut self) -> usize {
        let mut n = 0usize;
        for hop in &mut self.ops {
            if let Hop::Op { op: OpKind::MaxConst(c), a, d, .. } = hop {
                if c.to_bits() == 0.0f64.to_bits() {
                    *hop = Hop::Relu { a: *a, d: *d, cvt: None };
                    n += 1;
                }
            }
        }
        n
    }

    /// Pass 6: absorb boundary `Convert`s into the fused step that
    /// produced their operand.  Returns the number absorbed.
    ///
    /// A standalone `Convert(dst)` whose source slot is written by a
    /// fused superinstruction and read by nothing else is deleted; the
    /// producer's final write is retargeted to the convert's destination
    /// and tagged `cvt: Some(dst)` — the emitted instruction quantizes
    /// as it writes.  Bit-identical: `quantize(x, dst)` of a stored
    /// value equals storing `quantize(x, dst)` directly, and in the SSA
    /// tape the retargeted slot has no readers before the convert's
    /// original position.  This is what lets a chain stage's boundary
    /// format conversion ride inside the final MAC/tree-reduce dispatch
    /// instead of costing a separate per-pixel step (or, previously, a
    /// whole per-row pass in the chain runner).
    pub(crate) fn absorb_converts(&mut self) -> usize {
        let uses = self.use_counts();
        // final-write slot -> def index, fused producers w/o a cvt only
        let mut def: HashMap<usize, usize> = HashMap::new();
        for (i, hop) in self.ops.iter().enumerate() {
            let w = match hop {
                Hop::Mac { d, cvt: None, .. }
                | Hop::MacConst { d, cvt: None, .. }
                | Hop::FoldMax { d, cvt: None, .. }
                | Hop::Relu { d, cvt: None, .. } => *d,
                Hop::TreeReduce { adds, cvt: None } => adds.last().expect("non-empty run")[2],
                _ => continue,
            };
            def.insert(w, i);
        }
        let mut removed: HashSet<usize> = HashSet::new();
        let mut n = 0usize;
        for j in 0..self.ops.len() {
            let Hop::Op { op: OpKind::Convert(dst), a, d, .. } = self.ops[j] else { continue };
            // the producer's value must have no other reader (output
            // slots count as an extra use, so they never qualify)
            if uses.get(&a) != Some(&1) {
                continue;
            }
            let Some(&i) = def.get(&a) else { continue };
            if i >= j {
                continue;
            }
            match &mut self.ops[i] {
                Hop::Mac { d: pd, cvt, .. }
                | Hop::MacConst { d: pd, cvt, .. }
                | Hop::FoldMax { d: pd, cvt, .. }
                | Hop::Relu { d: pd, cvt, .. } => {
                    *pd = d;
                    *cvt = Some(dst);
                }
                Hop::TreeReduce { adds, cvt } => {
                    adds.last_mut().expect("non-empty run")[2] = d;
                    *cvt = Some(dst);
                }
                _ => unreachable!("def holds fused producers"),
            }
            def.remove(&a);
            removed.insert(j);
            n += 1;
        }
        if n > 0 {
            let mut k = 0usize;
            self.ops.retain(|_| {
                let keep = !removed.contains(&k);
                k += 1;
                keep
            });
        }
        n
    }

    /// Pass 7: drop steps (and constants) no output transitively needs.
    /// Backward liveness over the SSA tape; a multi-output step is kept
    /// if *any* of its outputs is live.
    pub(crate) fn eliminate_dead(&mut self) -> usize {
        let mut live: HashSet<usize> = self.output_slots.iter().copied().collect();
        let mut kept_rev: Vec<Hop> = Vec::with_capacity(self.ops.len());
        let mut dead = 0usize;
        for hop in self.ops.drain(..).rev() {
            if hop.writes().iter().any(|w| live.contains(w)) {
                for r in hop.reads() {
                    live.insert(r);
                }
                kept_rev.push(hop);
            } else {
                dead += 1;
            }
        }
        kept_rev.reverse();
        self.ops = kept_rev;
        self.consts.retain(|(s, _)| live.contains(s));
        dead
    }

    /// Pass 8: linear-scan register allocation into a compact arena.
    /// Returns the arena size.
    ///
    /// * inputs get the first arena slots (in port order, so the
    ///   executor's input copy is a contiguous prefix write);
    /// * constants are **pinned** (baked once at executor construction,
    ///   they must survive every evaluation);
    /// * output slots live to the end of the program;
    /// * a slot is reusable only when its tenant's last read is
    ///   *strictly before* the allocating step — so a block
    ///   superinstruction ([`Hop::TreeReduce`]/[`Hop::FoldMax`]), whose
    ///   reads and writes share one position, can never be assigned an
    ///   arena slot that aliases one of its own pending operands.
    pub(crate) fn allocate_registers(&mut self) -> usize {
        const INF: usize = usize::MAX;
        // positions: inputs/constants at 0, step k at k + 1
        let mut last_read: HashMap<usize, usize> = HashMap::new();
        for (k, hop) in self.ops.iter().enumerate() {
            for r in hop.reads() {
                last_read.insert(r, k + 1);
            }
        }
        let out_set: HashSet<usize> = self.output_slots.iter().copied().collect();
        let life = |slot: usize, last_read: &HashMap<usize, usize>| -> usize {
            if out_set.contains(&slot) {
                INF
            } else {
                last_read.get(&slot).copied().unwrap_or(0)
            }
        };
        let mut map: HashMap<usize, usize> = HashMap::new();
        // arena[i] = current tenant's last-read position (INF = pinned)
        let mut arena: Vec<usize> = Vec::new();
        for &s in &self.input_slots {
            map.insert(s, arena.len());
            arena.push(life(s, &last_read));
        }
        for &(s, _) in &self.consts {
            if let Some(&idx) = map.get(&s) {
                // a slot can't be both input and const, but stay safe
                arena[idx] = INF;
                continue;
            }
            map.insert(s, arena.len());
            arena.push(INF);
        }
        for (k, hop) in self.ops.iter().enumerate() {
            let p = k + 1;
            for w in hop.writes() {
                if map.contains_key(&w) {
                    continue; // SSA: never happens, but harmless
                }
                let idx = match arena.iter().position(|&lu| lu < p) {
                    Some(i) => i,
                    None => {
                        arena.push(0);
                        arena.len() - 1
                    }
                };
                arena[idx] = life(w, &last_read);
                map.insert(w, idx);
            }
        }
        // rewrite every slot reference through the map
        let m = |s: usize| -> usize {
            *map.get(&s).unwrap_or_else(|| panic!("slot {s} read before any write"))
        };
        for hop in &mut self.ops {
            match hop {
                Hop::Op { op, a, b, d, d1 } => {
                    *a = m(*a);
                    if op.arity() == 2 {
                        *b = m(*b);
                    } else {
                        *b = 0;
                    }
                    *d = m(*d);
                    if op.outputs() == 2 {
                        *d1 = m(*d1);
                    } else {
                        *d1 = 0;
                    }
                }
                Hop::Mac { a, b, c, d, .. } => {
                    *a = m(*a);
                    *b = m(*b);
                    *c = m(*c);
                    *d = m(*d);
                }
                Hop::MacConst { a, c, d, .. } => {
                    *a = m(*a);
                    *c = m(*c);
                    *d = m(*d);
                }
                Hop::TreeReduce { adds, .. } => {
                    for t in adds {
                        *t = [m(t[0]), m(t[1]), m(t[2])];
                    }
                }
                Hop::FoldMax { terms, d, .. } => {
                    for t in terms.iter_mut() {
                        *t = m(*t);
                    }
                    *d = m(*d);
                }
                Hop::Relu { a, d, .. } => {
                    *a = m(*a);
                    *d = m(*d);
                }
            }
        }
        self.input_slots = self.input_slots.iter().map(|&s| m(s)).collect();
        self.output_slots = self.output_slots.iter().map(|&s| m(s)).collect();
        self.consts = self.consts.iter().map(|&(s, v)| (m(s), v)).collect();
        self.n_slots = arena.len().max(1);
        self.n_slots
    }
}
