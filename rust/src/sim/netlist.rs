//! The scheduled-datapath IR shared by the DSL compiler, the built-in
//! filters, the cycle simulator, the SystemVerilog emitter and the
//! resource model.
//!
//! A [`Netlist`] is a topologically-ordered dataflow graph of pipelined
//! floating-point operators.  [`Builder`] constructs one and — on
//! [`Builder::build`] — *schedules* it: every signal gets a pipeline
//! latency `λ`, and every operator input edge gets the delay-matching
//! register count `Δ(sᵢ, sⱼ) = max(λ) − λ(sᵢ)` of §III-D.  The paper's
//! compiler performs exactly this pass when translating DSL code to
//! SystemVerilog (§V).

use crate::fpcore::{FloatFormat, OpKind};

/// Index of a signal (an operator output, input port, or constant).
pub type SignalId = usize;

/// Where a signal comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum SignalSrc {
    /// External input port (window pixel or scalar), by input index.
    Input(usize),
    /// Output `port` (0 or 1) of `nodes[node]`.
    Node { node: usize, port: usize },
    /// Compile-time constant (already quantized into the format).
    Const(f64),
}

/// One signal: a wire in the generated RTL.
#[derive(Debug, Clone)]
pub struct Signal {
    pub name: String,
    pub src: SignalSrc,
    /// Pipeline latency from the input ports, filled in by `build()`.
    pub latency: u32,
}

/// One pipelined operator instance.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: OpKind,
    /// Operand signals.
    pub ins: Vec<SignalId>,
    /// Delay registers inserted on each operand (Δ of §III-D); same length
    /// as `ins`.  Filled in by `build()`.
    pub in_delays: Vec<u32>,
    /// Output signals (1, or 2 for CAS).
    pub outs: Vec<SignalId>,
}

/// A scheduled datapath: evaluate `nodes` in order.
#[derive(Debug, Clone)]
pub struct Netlist {
    pub fmt: FloatFormat,
    /// Input port names in port order (e.g. `w00..w22`, or `x, y`).
    pub inputs: Vec<String>,
    /// Output ports: `(name, signal)`.
    pub outputs: Vec<(String, SignalId)>,
    pub signals: Vec<Signal>,
    pub nodes: Vec<Node>,
}

impl Netlist {
    /// Latency of an output port: cycles from input to that output.
    pub fn output_latency(&self, idx: usize) -> u32 {
        self.signals[self.outputs[idx].1].latency
    }

    /// The datapath latency: max over outputs (§III-D "λ" algebra).
    pub fn total_latency(&self) -> u32 {
        self.outputs
            .iter()
            .map(|&(_, s)| self.signals[s].latency)
            .max()
            .unwrap_or(0)
    }

    /// Total delay-matching registers inserted (Δ sums — each is one
    /// format-width register per cycle of delay).
    pub fn delay_registers(&self) -> u32 {
        self.nodes.iter().flat_map(|n| n.in_delays.iter()).sum()
    }

    /// Look up a signal id by name.
    pub fn signal_by_name(&self, name: &str) -> Option<SignalId> {
        self.signals.iter().position(|s| s.name == name)
    }

    /// Count of operator instances by kind-name (for resources/tests).
    pub fn op_count(&self, name: &str) -> usize {
        self.nodes.iter().filter(|n| n.op.name() == name).count()
    }

    /// JSON dump of the scheduled netlist (`fpspatial compile --emit
    /// netlist`): format, signals with their λ latencies, operator nodes
    /// with their Δ input delays — everything external tooling needs to
    /// re-render or re-schedule the datapath.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj, s, Json};
        let signals = self
            .signals
            .iter()
            .map(|sig| {
                let src = match &sig.src {
                    SignalSrc::Input(port) => {
                        obj(vec![("kind", s("input")), ("port", num(*port as f64))])
                    }
                    SignalSrc::Node { node, port } => obj(vec![
                        ("kind", s("node")),
                        ("node", num(*node as f64)),
                        ("port", num(*port as f64)),
                    ]),
                    SignalSrc::Const(v) => obj(vec![("kind", s("const")), ("value", num(*v))]),
                };
                obj(vec![
                    ("name", s(&sig.name)),
                    ("src", src),
                    ("latency", num(sig.latency as f64)),
                ])
            })
            .collect();
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                obj(vec![
                    ("op", op_to_json(&n.op)),
                    ("latency", num(n.op.latency() as f64)),
                    ("ins", Json::Arr(n.ins.iter().map(|&i| num(i as f64)).collect())),
                    (
                        "in_delays",
                        Json::Arr(n.in_delays.iter().map(|&d| num(d as f64)).collect()),
                    ),
                    ("outs", Json::Arr(n.outs.iter().map(|&o| num(o as f64)).collect())),
                ])
            })
            .collect();
        obj(vec![
            ("format", format_to_json(self.fmt)),
            ("inputs", Json::Arr(self.inputs.iter().map(|n| s(n)).collect())),
            (
                "outputs",
                Json::Arr(
                    self.outputs
                        .iter()
                        .map(|(name, sig)| {
                            obj(vec![("name", s(name)), ("signal", num(*sig as f64))])
                        })
                        .collect(),
                ),
            ),
            ("signals", Json::Arr(signals)),
            ("nodes", Json::Arr(nodes)),
            ("total_latency", num(self.total_latency() as f64)),
            ("delay_registers", num(self.delay_registers() as f64)),
        ])
    }

    /// Structural fingerprint of the datapath: two netlists share a hash
    /// exactly when they compute the same function the same way — format,
    /// input arity, output wiring, every signal source and every operator
    /// (kind + static parameter + operand/output wiring) in order.
    ///
    /// Deliberately EXCLUDED: signal/port *names* and the scheduler's
    /// `in_delays`/`latency` annotations.  Neither changes a functional
    /// evaluation, so a renamed-but-identical program (e.g. the same DSL
    /// file compiled under two module names, or N identical server
    /// streams) maps to the same compiled kernel in the
    /// [`KernelCache`](super::kernel::KernelCache).
    ///
    /// 128-bit FNV-1a — not cryptographic, but structural collisions need
    /// ~2⁶⁴ distinct netlists before birthday effects matter, far beyond
    /// any process lifetime; the cache key is only ever populated by
    /// netlists this process built.
    pub fn fingerprint(&self) -> u128 {
        /// Minimal FNV-1a/128 accumulator (no std hasher is 128-bit).
        struct Fnv(u128);
        impl Fnv {
            const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
            const PRIME: u128 = 0x0000000001000000000000000000013B;
            fn new() -> Self {
                Fnv(Self::OFFSET)
            }
            fn byte(&mut self, b: u8) {
                self.0 = (self.0 ^ b as u128).wrapping_mul(Self::PRIME);
            }
            fn u64(&mut self, v: u64) {
                for b in v.to_le_bytes() {
                    self.byte(b);
                }
            }
            fn usize(&mut self, v: usize) {
                self.u64(v as u64);
            }
            fn f64(&mut self, v: f64) {
                self.u64(v.to_bits());
            }
            fn fmt(&mut self, f: FloatFormat) {
                self.u64(f.mantissa as u64);
                self.u64(f.exponent as u64);
            }
        }
        let mut h = Fnv::new();
        h.fmt(self.fmt);
        h.usize(self.inputs.len());
        h.usize(self.outputs.len());
        for &(_, sig) in &self.outputs {
            h.usize(sig);
        }
        h.usize(self.signals.len());
        for sig in &self.signals {
            match sig.src {
                SignalSrc::Input(port) => {
                    h.byte(0);
                    h.usize(port);
                }
                SignalSrc::Node { node, port } => {
                    h.byte(1);
                    h.usize(node);
                    h.usize(port);
                }
                SignalSrc::Const(v) => {
                    h.byte(2);
                    h.f64(v);
                }
            }
        }
        h.usize(self.nodes.len());
        for n in &self.nodes {
            match n.op {
                OpKind::Add => h.byte(0),
                OpKind::Sub => h.byte(1),
                OpKind::Mul => h.byte(2),
                OpKind::MulConst(c) => {
                    h.byte(3);
                    h.f64(c);
                }
                OpKind::Div => h.byte(4),
                OpKind::Sqrt => h.byte(5),
                OpKind::Log2 => h.byte(6),
                OpKind::Exp2 => h.byte(7),
                OpKind::MaxConst(c) => {
                    h.byte(8);
                    h.f64(c);
                }
                OpKind::Max => h.byte(9),
                OpKind::Min => h.byte(10),
                OpKind::Rsh(s) => {
                    h.byte(11);
                    h.u64(s as u64);
                }
                OpKind::Lsh(s) => {
                    h.byte(12);
                    h.u64(s as u64);
                }
                OpKind::Cas => h.byte(13),
                OpKind::Convert(dst) => {
                    h.byte(14);
                    h.fmt(dst);
                }
                OpKind::Reg => h.byte(15),
            }
            h.usize(n.ins.len());
            for &i in &n.ins {
                h.usize(i);
            }
            h.usize(n.outs.len());
            for &o in &n.outs {
                h.usize(o);
            }
        }
        h.0
    }

    /// A copy of this netlist with a [`OpKind::Convert`] into `dst`
    /// appended on output port 0 — the *execution netlist* of a chain
    /// stage whose downstream neighbour runs a different format.  Folding
    /// the boundary converter into the stage program lets the kernel
    /// compiler absorb it into the final write (see
    /// `sim::passes::absorb_converts`) instead of the runner re-walking
    /// the completed row.  Scheduling stays consistent: the converted
    /// output picks up the converter's pipeline latency.
    pub fn with_output_convert(&self, dst: FloatFormat) -> Netlist {
        let mut nl = self.clone();
        let (name, sig) = nl.outputs[0].clone();
        let node_idx = nl.nodes.len();
        nl.signals.push(Signal {
            name: format!("{name}_cvt"),
            src: SignalSrc::Node { node: node_idx, port: 0 },
            latency: nl.signals[sig].latency + OpKind::Convert(dst).latency(),
        });
        let new_sig = nl.signals.len() - 1;
        nl.nodes.push(Node {
            op: OpKind::Convert(dst),
            ins: vec![sig],
            in_delays: vec![0],
            outs: vec![new_sig],
        });
        nl.outputs[0].1 = new_sig;
        nl
    }
}

/// JSON form of a format: `{"mantissa": m, "exponent": e, "width": w}`.
pub fn format_to_json(fmt: FloatFormat) -> crate::util::json::Json {
    use crate::util::json::{num, obj};
    obj(vec![
        ("mantissa", num(fmt.mantissa as f64)),
        ("exponent", num(fmt.exponent as f64)),
        ("width", num(fmt.width() as f64)),
    ])
}

/// JSON form of an operator, including its static parameter (constant
/// coefficient, shift amount, or converter destination format).
fn op_to_json(op: &OpKind) -> crate::util::json::Json {
    use crate::util::json::{num, obj, s};
    let mut pairs = vec![("kind", s(op.name()))];
    match op {
        OpKind::MulConst(c) | OpKind::MaxConst(c) => pairs.push(("value", num(*c))),
        OpKind::Rsh(n) | OpKind::Lsh(n) => pairs.push(("shift", num(*n as f64))),
        OpKind::Convert(dst) => pairs.push(("dst", format_to_json(*dst))),
        _ => {}
    }
    obj(pairs)
}

/// Netlist construction + scheduling.
pub struct Builder {
    fmt: FloatFormat,
    inputs: Vec<String>,
    outputs: Vec<(String, SignalId)>,
    signals: Vec<Signal>,
    nodes: Vec<Node>,
    next_tmp: usize,
}

impl Builder {
    pub fn new(fmt: FloatFormat) -> Self {
        Self {
            fmt,
            inputs: Vec::new(),
            outputs: Vec::new(),
            signals: Vec::new(),
            nodes: Vec::new(),
            next_tmp: 0,
        }
    }

    pub fn fmt(&self) -> FloatFormat {
        self.fmt
    }

    fn fresh_name(&mut self, base: &str) -> String {
        self.next_tmp += 1;
        format!("{base}_{}", self.next_tmp)
    }

    /// Declare an input port.
    pub fn input(&mut self, name: &str) -> SignalId {
        let idx = self.inputs.len();
        self.inputs.push(name.to_string());
        self.signals.push(Signal {
            name: name.to_string(),
            src: SignalSrc::Input(idx),
            latency: 0,
        });
        self.signals.len() - 1
    }

    /// A constant, quantized into the format at compile time (like the
    /// DSL's kernel literals → hex constants).
    pub fn constant(&mut self, v: f64) -> SignalId {
        let q = crate::fpcore::quantize(v, self.fmt);
        let name = self.fresh_name("const");
        self.signals.push(Signal {
            name,
            src: SignalSrc::Const(q),
            latency: 0,
        });
        self.signals.len() - 1
    }

    /// Add an operator node; returns its output signal(s).
    pub fn node(&mut self, op: OpKind, ins: &[SignalId]) -> Vec<SignalId> {
        assert_eq!(ins.len(), op.arity(), "{:?} arity", op);
        let node_idx = self.nodes.len();
        let n_outs = op.outputs();
        let mut outs = Vec::with_capacity(n_outs);
        for port in 0..n_outs {
            let name = self.fresh_name(op.name());
            self.signals.push(Signal {
                name,
                src: SignalSrc::Node { node: node_idx, port },
                latency: 0,
            });
            outs.push(self.signals.len() - 1);
        }
        self.nodes.push(Node {
            op,
            ins: ins.to_vec(),
            in_delays: vec![0; ins.len()],
            outs: outs.clone(),
        });
        outs
    }

    pub fn op1(&mut self, op: OpKind, a: SignalId) -> SignalId {
        self.node(op, &[a])[0]
    }

    pub fn op2(&mut self, op: OpKind, a: SignalId, b: SignalId) -> SignalId {
        self.node(op, &[a, b])[0]
    }

    pub fn add(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.op2(OpKind::Add, a, b)
    }

    pub fn mul(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.op2(OpKind::Mul, a, b)
    }

    pub fn mul_const(&mut self, a: SignalId, c: f64) -> SignalId {
        let q = crate::fpcore::quantize(c, self.fmt);
        self.op1(OpKind::MulConst(q), a)
    }

    pub fn div(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.op2(OpKind::Div, a, b)
    }

    pub fn sqrt(&mut self, a: SignalId) -> SignalId {
        self.op1(OpKind::Sqrt, a)
    }

    pub fn log2(&mut self, a: SignalId) -> SignalId {
        self.op1(OpKind::Log2, a)
    }

    pub fn exp2(&mut self, a: SignalId) -> SignalId {
        self.op1(OpKind::Exp2, a)
    }

    pub fn max_const(&mut self, a: SignalId, c: f64) -> SignalId {
        let q = crate::fpcore::quantize(c, self.fmt);
        self.op1(OpKind::MaxConst(q), a)
    }

    pub fn rsh(&mut self, a: SignalId, n: u32) -> SignalId {
        self.op1(OpKind::Rsh(n), a)
    }

    pub fn lsh(&mut self, a: SignalId, n: u32) -> SignalId {
        self.op1(OpKind::Lsh(n), a)
    }

    /// CMP_and_SWAP: returns `(min, max)`.
    pub fn cas(&mut self, a: SignalId, b: SignalId) -> (SignalId, SignalId) {
        let outs = self.node(OpKind::Cas, &[a, b]);
        (outs[0], outs[1])
    }

    /// The paper's recursive `AdderTree(N)` (§III-B): `N0 = 2^⌊log2 N⌋`
    /// pairwise stages, remainder recursively, summed last.
    pub fn adder_tree(&mut self, terms: &[SignalId]) -> SignalId {
        assert!(!terms.is_empty());
        if terms.len() == 1 {
            return terms[0];
        }
        let n = terms.len();
        let n0 = 1usize << (usize::BITS - 1 - n.leading_zeros());
        if n0 == n {
            // full pairwise tree
            let mut level = terms.to_vec();
            while level.len() > 1 {
                level = level
                    .chunks(2)
                    .map(|pair| self.add(pair[0], pair[1]))
                    .collect();
            }
            level[0]
        } else {
            let left = self.adder_tree(&terms[..n0]);
            let right = self.adder_tree(&terms[n0..]);
            self.add(left, right)
        }
    }

    /// Bose–Nelson SORT5 (fig. 7): 9 CAS; returns the sorted 5 signals.
    /// The CAS sequence must match `python/compile/kernels/ops.py::SORT5_CAS`.
    pub fn sort5(&mut self, vals: [SignalId; 5]) -> [SignalId; 5] {
        const SEQ: [(usize, usize); 9] =
            [(0, 1), (3, 4), (2, 4), (2, 3), (1, 4), (0, 3), (0, 2), (1, 3), (1, 2)];
        let mut v = vals;
        for (i, j) in SEQ {
            let (lo, hi) = self.cas(v[i], v[j]);
            v[i] = lo;
            v[j] = hi;
        }
        v
    }

    /// Declare an output port.
    pub fn output(&mut self, name: &str, sig: SignalId) {
        self.outputs.push((name.to_string(), sig));
    }

    /// Rename a signal (DSL variable names over generated temps).
    pub fn rename(&mut self, sig: SignalId, name: &str) {
        self.signals[sig].name = name.to_string();
    }

    /// Schedule and return the netlist: propagate latencies in topo order
    /// and set each operand's Δ delay (§III-D):
    /// `λ(out) = max_i(λ(inᵢ)) + L(op)`, `Δᵢ = max − λ(inᵢ)`.
    pub fn build(mut self) -> Netlist {
        for idx in 0..self.nodes.len() {
            let lat_in: Vec<u32> = self.nodes[idx]
                .ins
                .iter()
                .map(|&s| self.signals[s].latency)
                .collect();
            let max_in = lat_in.iter().copied().max().unwrap_or(0);
            let node = &mut self.nodes[idx];
            for (d, &l) in node.in_delays.iter_mut().zip(&lat_in) {
                *d = max_in - l;
            }
            let out_lat = max_in + node.op.latency();
            for &o in &node.outs.clone() {
                self.signals[o].latency = out_lat;
            }
        }
        Netlist {
            fmt: self.fmt,
            inputs: self.inputs,
            outputs: self.outputs,
            signals: self.signals,
            nodes: self.nodes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::FloatFormat;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    /// The paper's §V walk-through: z = sqrt((x·y)/(x+y)); m (mul, λ=2)
    /// must be delayed by Δ=4 to meet s (add, λ=6) at the divider.
    #[test]
    fn fig12_schedule() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s = b.add(x, y);
        let d = b.div(m, s);
        let z = b.sqrt(d);
        b.output("z", z);
        let nl = b.build();

        assert_eq!(nl.signals[m].latency, 2);
        assert_eq!(nl.signals[s].latency, 6);
        // divider: Δ(m) = 4, Δ(s) = 0
        let div_node = &nl.nodes[2];
        assert_eq!(div_node.in_delays, vec![4, 0]);
        assert_eq!(nl.signals[d].latency, 6 + 7);
        assert_eq!(nl.signals[z].latency, 13 + 5);
        assert_eq!(nl.total_latency(), 18);
        assert_eq!(nl.delay_registers(), 4);
    }

    #[test]
    fn adder_tree_structure_9() {
        // AdderTree(9): 8 adders, latency 4·L_ADD = 24 (§III-B)
        let mut b = Builder::new(F16);
        let ins: Vec<_> = (0..9).map(|i| b.input(&format!("p{i}"))).collect();
        let out = b.adder_tree(&ins);
        b.output("sum", out);
        let nl = b.build();
        assert_eq!(nl.op_count("adder"), 8);
        assert_eq!(nl.total_latency(), 24);
    }

    #[test]
    fn adder_tree_structure_25() {
        // AdderTree(25) = AT(16) + AT(9): 24 adders, latency 5·L_ADD = 30
        let mut b = Builder::new(F16);
        let ins: Vec<_> = (0..25).map(|i| b.input(&format!("p{i}"))).collect();
        let out = b.adder_tree(&ins);
        b.output("sum", out);
        let nl = b.build();
        assert_eq!(nl.op_count("adder"), 24);
        assert_eq!(nl.total_latency(), 30);
    }

    #[test]
    fn sort5_has_9_cas_latency_12() {
        // §III-C: SORT5 = 9 CAS in 6 stages × 2 cycles = 12
        let mut b = Builder::new(F16);
        let ins: Vec<_> = (0..5).map(|i| b.input(&format!("a{i}"))).collect();
        let sorted = b.sort5([ins[0], ins[1], ins[2], ins[3], ins[4]]);
        b.output("median", sorted[2]);
        let nl = b.build();
        assert_eq!(nl.op_count("cmp_and_swap"), 9);
        assert_eq!(nl.total_latency(), 12);
    }

    #[test]
    fn constants_are_quantized() {
        let mut b = Builder::new(F16);
        let c = b.constant(0.0313);
        let nl_sig = &b.signals[c];
        match nl_sig.src {
            SignalSrc::Const(v) => assert_eq!(v, 0.03131103515625),
            _ => panic!(),
        }
    }

    #[test]
    fn json_dump_round_trips_and_carries_the_schedule() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s_ = b.add(x, y);
        let d = b.div(m, s_);
        let k = b.mul_const(d, 0.5);
        b.output("z", k);
        let nl = b.build();
        let txt = nl.to_json().to_string();
        let v = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(v.get("total_latency").unwrap().as_usize(), Some(15));
        assert_eq!(v.get("format").unwrap().get("mantissa").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("format").unwrap().get("width").unwrap().as_usize(), Some(16));
        assert_eq!(v.get("inputs").unwrap().as_arr().unwrap().len(), 2);
        let nodes = v.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), 4);
        // the divider carries the §V Δ = [4, 0] schedule
        let div = &nodes[2];
        assert_eq!(div.get("op").unwrap().get("kind").unwrap().as_str(), Some("div"));
        let delays: Vec<usize> = div
            .get("in_delays")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(delays, vec![4, 0]);
        // mult_const serializes its coefficient
        assert_eq!(nodes[3].get("op").unwrap().get("value").unwrap().as_f64(), Some(0.5));
        // a Convert node serializes its destination format
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let c = b.op1(crate::fpcore::OpKind::Convert(FloatFormat::new(16, 7)), x);
        b.output("y", c);
        let nl = b.build();
        let v = crate::util::json::Json::parse(&nl.to_json().to_string()).unwrap();
        let op = v.get("nodes").unwrap().as_arr().unwrap()[0].get("op").unwrap().clone();
        assert_eq!(op.get("kind").unwrap().as_str(), Some("fmt_convert"));
        assert_eq!(op.get("dst").unwrap().get("mantissa").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn cas_outputs_share_latency() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let (lo, hi) = b.cas(x, y);
        b.output("lo", lo);
        b.output("hi", hi);
        let nl = b.build();
        assert_eq!(nl.signals[lo].latency, 2);
        assert_eq!(nl.signals[hi].latency, 2);
    }
}
