//! The tape compiler: direct-threaded fused kernels + the process-wide
//! kernel cache.
//!
//! [`super::engine::BatchEngine`] interprets the scheduled tape — one
//! `match s.op` dispatch per step per 16-lane block — which dominates
//! short tapes like ReLU and 2×2 pooling.  This module compiles the tape
//! instead (dependency-free, stable Rust, no JIT):
//!
//! ```text
//! Tape ──► passes (fold / fuse MAC / TreeReduce / FoldMax / Relu /
//!          DCE / regalloc, see super::passes) ──► CompiledKernel
//! ```
//!
//! A [`CompiledKernel`] is *direct-threaded code*: a flat array of
//! [`Instr`]s, each carrying a monomorphized `fn(&Instr, &mut KernelCtx)`
//! pointer whose body runs the full 16-lane loop for its (possibly
//! fused) op.  Per-op-mode specialization is baked at compile time —
//! `Div`/`Sqrt`/`Log2`/`Exp2` emit either the Exact or the Poly body, so
//! the hot loop never consults [`OpMode`]; `Rsh`/`Lsh`/`MulConst` all
//! collapse into one multiply-by-immediate with the scale precomputed as
//! bits.  Executing a kernel is `for i in instrs { (i.f)(i, &mut ctx) }`
//! — zero per-step matching.
//!
//! The compiled kernel is immutable and shared: [`KernelExec`] pairs an
//! `Arc<CompiledKernel>` with a private scratch arena, and the global
//! [`KernelCache`] keys kernels on `(Netlist::fingerprint(), OpMode)` so
//! every `Session`, pool worker and `FrameServer` stream running the
//! same filter compiles it exactly once per process.
//!
//! Bit-identity with the interpreters is enforced by the parity suites
//! (`tests/batch_parity.rs`, `tests/chain_parity.rs`), the per-pass unit
//! tests below, and the fused-vs-unfused property rows in
//! `tests/properties.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use super::engine::Tape;
use super::netlist::Netlist;
use super::passes::{Hop, PassStats, Program};
use crate::fpcore::format::FloatFormat;
use crate::fpcore::ops::{FpOps, OpKind, OpMode};
use crate::fpcore::poly;
use crate::fpcore::quantize::quantize;
use crate::util::{Lane, LANES};

/// Execution context handed to every instruction body: the scratch
/// arena plus the (format-bound) operator evaluator.
pub struct KernelCtx<'a> {
    lanes: &'a mut [Lane],
    ops: &'a FpOps,
}

/// A direct-threaded instruction body.
type OpFn = for<'x> fn(&Instr, &mut KernelCtx<'x>);

/// One direct-threaded instruction.  `a`/`b`/`c` are input arena slots,
/// `d`/`d1` outputs, `imm` a baked immediate (coefficient or shift
/// scale), `fmt` the destination format for `Convert`, and `ext` the
/// slot payload of block superinstructions (`TreeReduce` triples /
/// `FoldMax` terms).  All slot indices are validated `< n_slots` at
/// compile time; the bodies index unchecked.
pub struct Instr {
    f: OpFn,
    a: u32,
    b: u32,
    c: u32,
    d: u32,
    d1: u32,
    imm: f64,
    fmt: FloatFormat,
    ext: Box<[u32]>,
    name: &'static str,
}

// ---------------------------------------------------------------------------
// instruction bodies
//
// Every body follows the BatchEngine pattern: copy the input lanes out
// (so an output slot may alias an input slot), then a branch-free
// 16-lane loop.  SAFETY for all `get_unchecked`: `compile()` validates
// every slot index (including `ext`) against `n_slots`, and `KernelExec`
// allocates exactly `n_slots` lanes.
// ---------------------------------------------------------------------------

macro_rules! bin_body {
    ($fname:ident, $m:ident) => {
        fn $fname(i: &Instr, ctx: &mut KernelCtx) {
            let ops = ctx.ops;
            let l = &mut *ctx.lanes;
            unsafe {
                let a = *l.get_unchecked(i.a as usize);
                let b = *l.get_unchecked(i.b as usize);
                let o = l.get_unchecked_mut(i.d as usize);
                for j in 0..LANES {
                    o[j] = ops.$m(a[j], b[j]);
                }
            }
        }
    };
}

bin_body!(k_add, add);
bin_body!(k_sub, sub);
bin_body!(k_mul, mul);
bin_body!(k_max, max);
bin_body!(k_min, min);

/// `MulConst` / `Rsh` / `Lsh` — multiply by a baked immediate (shifts
/// lower to their exact power-of-two scale, same arithmetic as
/// `FpOps::rsh`/`lsh` minus the per-call scale rebuild).
fn k_mul_imm(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.mul(a[j], i.imm);
        }
    }
}

fn k_max_imm(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.max_const(a[j], i.imm);
        }
    }
}

/// `max(x, +0.0)` — the recognized ReLU; selection only, never rounds.
fn k_relu(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = a[j].max(0.0);
        }
    }
}

macro_rules! un_exact_body {
    ($fname:ident, $e:expr) => {
        fn $fname(i: &Instr, ctx: &mut KernelCtx) {
            let ops = ctx.ops;
            let l = &mut *ctx.lanes;
            unsafe {
                let a = *l.get_unchecked(i.a as usize);
                let o = l.get_unchecked_mut(i.d as usize);
                for j in 0..LANES {
                    let f: fn(f64) -> f64 = $e;
                    o[j] = quantize(f(a[j]), ops.fmt);
                }
            }
        }
    };
}

un_exact_body!(k_sqrt_exact, |x| x.sqrt());
un_exact_body!(k_log2_exact, |x| x.log2());
un_exact_body!(k_exp2_exact, |x| x.exp2());

fn k_sqrt_poly(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(poly::poly_sqrt(a[j], ops.sqrt_cfg), ops.fmt);
        }
    }
}

fn k_log2_poly(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(poly::poly_log2(a[j], ops.log2_cfg), ops.fmt);
        }
    }
}

fn k_exp2_poly(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(poly::poly_exp2(a[j], ops.exp2_cfg), ops.fmt);
        }
    }
}

fn k_div_exact(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let b = *l.get_unchecked(i.b as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(a[j] / b[j], ops.fmt);
        }
    }
}

fn k_div_poly(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let b = *l.get_unchecked(i.b as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(poly::poly_div(a[j], b[j], ops.recip_cfg), ops.fmt);
        }
    }
}

fn k_convert(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(a[j], i.fmt);
        }
    }
}

/// A `Reg` copy that survived propagation (its target is an output
/// port).
fn k_copy(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        *l.get_unchecked_mut(i.d as usize) = a;
    }
}

fn k_cas(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let b = *l.get_unchecked(i.b as usize);
        let mut lo = [0.0; LANES];
        let mut hi = [0.0; LANES];
        for j in 0..LANES {
            let (l_, h_) = ops.cas(a[j], b[j]);
            lo[j] = l_;
            hi[j] = h_;
        }
        *l.get_unchecked_mut(i.d as usize) = lo;
        *l.get_unchecked_mut(i.d1 as usize) = hi;
    }
}

/// `d = q(q(a·b) + c)` — both roundings of the unfused pair preserved.
fn k_mac(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let b = *l.get_unchecked(i.b as usize);
        let c = *l.get_unchecked(i.c as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.add(ops.mul(a[j], b[j]), c[j]);
        }
    }
}

/// MAC with the accumulator as the add's *first* operand:
/// `d = q(c + q(a·b))`.
fn k_mac_rev(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let b = *l.get_unchecked(i.b as usize);
        let c = *l.get_unchecked(i.c as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.add(c[j], ops.mul(a[j], b[j]));
        }
    }
}

/// `d = q(q(a·imm) + c)` — coefficient MAC (the conv hot path).
fn k_mac_imm(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let c = *l.get_unchecked(i.c as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.add(ops.mul(a[j], i.imm), c[j]);
        }
    }
}

/// `d = q(c + q(a·imm))`.
fn k_mac_imm_rev(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let c = *l.get_unchecked(i.c as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = ops.add(c[j], ops.mul(a[j], i.imm));
        }
    }
}

/// A run of adds under one dispatch: `ext` is `[a, b, d]` triples,
/// executed in order — the adder tree exactly as the interpreter ran
/// it, minus the per-add dispatch.
fn k_tree_reduce(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    for t in i.ext.chunks_exact(3) {
        unsafe {
            let a = *l.get_unchecked(t[0] as usize);
            let b = *l.get_unchecked(t[1] as usize);
            let o = l.get_unchecked_mut(t[2] as usize);
            for j in 0..LANES {
                o[j] = ops.add(a[j], b[j]);
            }
        }
    }
}

/// Exact left fold `max(max(…max(t0,t1),…),tk)`; intermediates live in
/// a register, never the arena.
fn k_fold_max(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let mut acc = *l.get_unchecked(*i.ext.get_unchecked(0) as usize);
        for t in &i.ext[1..] {
            let v = *l.get_unchecked(*t as usize);
            for j in 0..LANES {
                acc[j] = acc[j].max(v[j]);
            }
        }
        *l.get_unchecked_mut(i.d as usize) = acc;
    }
}

// ---------------------------------------------------------------------------
// cvt-fused bodies: identical arithmetic to their plain counterparts,
// with the FINAL write additionally quantized to `i.fmt` — an absorbed
// boundary `Convert` (see `passes::Program::absorb_converts`).  Bit-
// identical to running the plain body then a standalone `k_convert`:
// `quantize(store(x))` ≡ `store(quantize(x))`.
// ---------------------------------------------------------------------------

macro_rules! mac_cvt_body {
    ($fname:ident, $expr:expr) => {
        fn $fname(i: &Instr, ctx: &mut KernelCtx) {
            let ops = ctx.ops;
            let l = &mut *ctx.lanes;
            unsafe {
                let a = *l.get_unchecked(i.a as usize);
                let b = *l.get_unchecked(i.b as usize);
                let c = *l.get_unchecked(i.c as usize);
                let o = l.get_unchecked_mut(i.d as usize);
                for j in 0..LANES {
                    let f: fn(&FpOps, f64, f64, f64, f64) -> f64 = $expr;
                    o[j] = quantize(f(ops, a[j], b[j], c[j], i.imm), i.fmt);
                }
            }
        }
    };
}

mac_cvt_body!(k_mac_cvt, |ops, a, b, c, _| ops.add(ops.mul(a, b), c));
mac_cvt_body!(k_mac_rev_cvt, |ops, a, b, c, _| ops.add(c, ops.mul(a, b)));
mac_cvt_body!(k_mac_imm_cvt, |ops, a, _, c, imm| ops.add(ops.mul(a, imm), c));
mac_cvt_body!(k_mac_imm_rev_cvt, |ops, a, _, c, imm| ops.add(c, ops.mul(a, imm)));

/// `d = q_fmt(max(a, +0.0))`.
fn k_relu_cvt(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let a = *l.get_unchecked(i.a as usize);
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(a[j].max(0.0), i.fmt);
        }
    }
}

/// `k_tree_reduce` with the LAST add's write quantized to `i.fmt` (the
/// intermediate adds stay in the kernel's native format, exactly as the
/// unfused sequence computed them).
fn k_tree_reduce_cvt(i: &Instr, ctx: &mut KernelCtx) {
    let ops = ctx.ops;
    let l = &mut *ctx.lanes;
    let n = i.ext.len();
    for (k, t) in i.ext.chunks_exact(3).enumerate() {
        let last = (k + 1) * 3 == n;
        unsafe {
            let a = *l.get_unchecked(t[0] as usize);
            let b = *l.get_unchecked(t[1] as usize);
            let o = l.get_unchecked_mut(t[2] as usize);
            if last {
                for j in 0..LANES {
                    o[j] = quantize(ops.add(a[j], b[j]), i.fmt);
                }
            } else {
                for j in 0..LANES {
                    o[j] = ops.add(a[j], b[j]);
                }
            }
        }
    }
}

/// `k_fold_max` with the single store quantized to `i.fmt`.
fn k_fold_max_cvt(i: &Instr, ctx: &mut KernelCtx) {
    let l = &mut *ctx.lanes;
    unsafe {
        let mut acc = *l.get_unchecked(*i.ext.get_unchecked(0) as usize);
        for t in &i.ext[1..] {
            let v = *l.get_unchecked(*t as usize);
            for j in 0..LANES {
                acc[j] = acc[j].max(v[j]);
            }
        }
        let o = l.get_unchecked_mut(i.d as usize);
        for j in 0..LANES {
            o[j] = quantize(acc[j], i.fmt);
        }
    }
}

// ---------------------------------------------------------------------------
// emission
// ---------------------------------------------------------------------------

fn emit(hop: &Hop, mode: OpMode) -> Instr {
    let mut ins = Instr {
        f: k_copy,
        a: 0,
        b: 0,
        c: 0,
        d: 0,
        d1: 0,
        imm: 0.0,
        fmt: FloatFormat::new(52, 11),
        ext: Box::new([]),
        name: "copy",
    };
    match hop {
        Hop::Op { op, a, b, d, d1 } => {
            ins.a = *a as u32;
            ins.b = *b as u32;
            ins.d = *d as u32;
            ins.d1 = *d1 as u32;
            let (f, name): (OpFn, &'static str) = match op {
                OpKind::Add => (k_add, "add"),
                OpKind::Sub => (k_sub, "sub"),
                OpKind::Mul => (k_mul, "mul"),
                OpKind::MulConst(c) => {
                    ins.imm = *c;
                    (k_mul_imm, "mul_imm")
                }
                OpKind::Div => match mode {
                    OpMode::Exact => (k_div_exact, "div"),
                    OpMode::Poly => (k_div_poly, "div_poly"),
                },
                OpKind::Sqrt => match mode {
                    OpMode::Exact => (k_sqrt_exact, "sqrt"),
                    OpMode::Poly => (k_sqrt_poly, "sqrt_poly"),
                },
                OpKind::Log2 => match mode {
                    OpMode::Exact => (k_log2_exact, "log2"),
                    OpMode::Poly => (k_log2_poly, "log2_poly"),
                },
                OpKind::Exp2 => match mode {
                    OpMode::Exact => (k_exp2_exact, "exp2"),
                    OpMode::Poly => (k_exp2_poly, "exp2_poly"),
                },
                OpKind::MaxConst(c) => {
                    ins.imm = *c;
                    (k_max_imm, "max_imm")
                }
                OpKind::Max => (k_max, "max"),
                OpKind::Min => (k_min, "min"),
                // shifts: exact power-of-two scale, baked as bits — the
                // same arithmetic FpOps::rsh/lsh performs per call
                OpKind::Rsh(n) => {
                    ins.imm = f64::from_bits(((1023 - n) as u64) << 52);
                    (k_mul_imm, "rsh")
                }
                OpKind::Lsh(n) => {
                    ins.imm = f64::from_bits(((1023 + n) as u64) << 52);
                    (k_mul_imm, "lsh")
                }
                OpKind::Cas => (k_cas, "cas"),
                OpKind::Convert(dst) => {
                    ins.fmt = *dst;
                    (k_convert, "convert")
                }
                OpKind::Reg => (k_copy, "copy"),
            };
            ins.f = f;
            ins.name = name;
        }
        Hop::Mac { a, b, c, d, acc_first, cvt } => {
            ins.a = *a as u32;
            ins.b = *b as u32;
            ins.c = *c as u32;
            ins.d = *d as u32;
            let (f, name): (OpFn, &'static str) = match (*acc_first, cvt) {
                (true, None) => (k_mac_rev, "mac_rev"),
                (false, None) => (k_mac, "mac"),
                (true, Some(fm)) => {
                    ins.fmt = *fm;
                    (k_mac_rev_cvt, "mac_rev_cvt")
                }
                (false, Some(fm)) => {
                    ins.fmt = *fm;
                    (k_mac_cvt, "mac_cvt")
                }
            };
            ins.f = f;
            ins.name = name;
        }
        Hop::MacConst { a, imm, c, d, acc_first, cvt } => {
            ins.a = *a as u32;
            ins.c = *c as u32;
            ins.d = *d as u32;
            ins.imm = *imm;
            let (f, name): (OpFn, &'static str) = match (*acc_first, cvt) {
                (true, None) => (k_mac_imm_rev, "mac_imm_rev"),
                (false, None) => (k_mac_imm, "mac_imm"),
                (true, Some(fm)) => {
                    ins.fmt = *fm;
                    (k_mac_imm_rev_cvt, "mac_imm_rev_cvt")
                }
                (false, Some(fm)) => {
                    ins.fmt = *fm;
                    (k_mac_imm_cvt, "mac_imm_cvt")
                }
            };
            ins.f = f;
            ins.name = name;
        }
        Hop::TreeReduce { adds, cvt } => {
            ins.ext = adds
                .iter()
                .flat_map(|t| t.iter().map(|&s| s as u32))
                .collect::<Vec<u32>>()
                .into_boxed_slice();
            ins.d = adds.last().map(|t| t[2] as u32).unwrap_or(0);
            let (f, name): (OpFn, &'static str) = match cvt {
                None => (k_tree_reduce, "tree_reduce"),
                Some(fm) => {
                    ins.fmt = *fm;
                    (k_tree_reduce_cvt, "tree_reduce_cvt")
                }
            };
            ins.f = f;
            ins.name = name;
        }
        Hop::FoldMax { terms, d, cvt } => {
            ins.ext = terms.iter().map(|&s| s as u32).collect::<Vec<u32>>().into_boxed_slice();
            ins.d = *d as u32;
            let (f, name): (OpFn, &'static str) = match cvt {
                None => (k_fold_max, "fold_max"),
                Some(fm) => {
                    ins.fmt = *fm;
                    (k_fold_max_cvt, "fold_max_cvt")
                }
            };
            ins.f = f;
            ins.name = name;
        }
        Hop::Relu { a, d, cvt } => {
            ins.a = *a as u32;
            ins.d = *d as u32;
            let (f, name): (OpFn, &'static str) = match cvt {
                None => (k_relu, "relu"),
                Some(fm) => {
                    ins.fmt = *fm;
                    (k_relu_cvt, "relu_cvt")
                }
            };
            ins.f = f;
            ins.name = name;
        }
    }
    ins
}

/// One human-readable listing line per final hop (arena slot space),
/// for `compile --emit kernel` and `CompiledKernel::dump`.
fn listing_line(hop: &Hop) -> String {
    match hop {
        Hop::Op { op, a, b, d, d1 } => match (op.arity(), op.outputs()) {
            (1, _) => match op {
                OpKind::MulConst(c) => format!("mul_imm     s{d} <- s{a} * {c}"),
                OpKind::MaxConst(c) => format!("max_imm     s{d} <- max(s{a}, {c})"),
                OpKind::Rsh(n) => format!("rsh         s{d} <- s{a} * 2^-{n}"),
                OpKind::Lsh(n) => format!("lsh         s{d} <- s{a} * 2^{n}"),
                OpKind::Convert(f) => format!("convert     s{d} <- s{a} as {f}"),
                _ => format!("{:<11} s{d} <- s{a}", op.name()),
            },
            (_, 2) => format!("cas         s{d}, s{d1} <- sort2(s{a}, s{b})"),
            _ => format!("{:<11} s{d} <- s{a}, s{b}", op.name()),
        },
        Hop::Mac { a, b, c, d, acc_first, cvt } => {
            let base = if *acc_first {
                format!("mac         s{d} <- s{c} + s{a}*s{b}")
            } else {
                format!("mac         s{d} <- s{a}*s{b} + s{c}")
            };
            with_cvt(base, cvt)
        }
        Hop::MacConst { a, imm, c, d, acc_first, cvt } => {
            let base = if *acc_first {
                format!("mac_imm     s{d} <- s{c} + s{a}*{imm}")
            } else {
                format!("mac_imm     s{d} <- s{a}*{imm} + s{c}")
            };
            with_cvt(base, cvt)
        }
        Hop::TreeReduce { adds, cvt } => {
            let d = adds.last().map(|t| t[2]).unwrap_or(0);
            with_cvt(format!("tree_reduce s{d} <- {} adds", adds.len()), cvt)
        }
        Hop::FoldMax { terms, d, cvt } => {
            let ts: Vec<String> = terms.iter().map(|t| format!("s{t}")).collect();
            with_cvt(format!("fold_max    s{d} <- max({})", ts.join(", ")), cvt)
        }
        Hop::Relu { a, d, cvt } => with_cvt(format!("relu        s{d} <- max(s{a}, 0)"), cvt),
    }
}

/// Append the absorbed-convert annotation, if any.
fn with_cvt(base: String, cvt: &Option<FloatFormat>) -> String {
    match cvt {
        Some(f) => format!("{base} as {f}"),
        None => base,
    }
}

// ---------------------------------------------------------------------------
// CompiledKernel
// ---------------------------------------------------------------------------

/// An immutable compiled kernel: direct-threaded instructions plus the
/// arena layout.  Shared across executors via `Arc` (scratch lives in
/// [`KernelExec`], never here).
pub struct CompiledKernel {
    ops: FpOps,
    instrs: Vec<Instr>,
    n_slots: usize,
    input_slots: Vec<usize>,
    output_slots: Vec<usize>,
    /// `(arena slot, value)` — baked into fresh executors once.
    consts: Vec<(usize, f64)>,
    stats: PassStats,
    fingerprint: u128,
    listing: Vec<String>,
}

impl CompiledKernel {
    pub fn n_inputs(&self) -> usize {
        self.input_slots.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.output_slots.len()
    }

    pub fn stats(&self) -> PassStats {
        self.stats
    }

    pub fn fingerprint(&self) -> u128 {
        self.fingerprint
    }

    /// The `compile --emit kernel` dump: header + per-pass counters +
    /// one line per instruction.
    pub fn dump(&self) -> String {
        let s = &self.stats;
        let mut out = String::new();
        out.push_str(&format!(
            "kernel {:032x} fmt={} mode={:?}\n",
            self.fingerprint, self.ops.fmt, self.ops.mode
        ));
        out.push_str(&format!(
            "  tape: {} steps, {} slots -> {} instrs, {} slots\n",
            s.steps_in, s.slots_in, s.instrs_out, s.slots_out
        ));
        out.push_str(&format!(
            "  passes: folded {}, copies {}, macs {}, tree {}/{}, fold_max {}/{}, relu {}, cvt {}, dead {}\n",
            s.folded,
            s.copies,
            s.macs,
            s.tree_groups,
            s.tree_adds,
            s.fold_maxes,
            s.fold_max_terms,
            s.relus,
            s.converts_absorbed,
            s.dead
        ));
        for (k, line) in self.listing.iter().enumerate() {
            out.push_str(&format!("  {k:3}  {line}\n"));
        }
        out
    }
}

/// Compile a netlist's tape into a direct-threaded kernel for one
/// numeric mode.  Deterministic; bit-identical to the interpreters by
/// construction (each pass preserves the evaluated sequence — see
/// `super::passes`).
pub fn compile(nl: &Netlist, mode: OpMode) -> CompiledKernel {
    let tape = Tape::new(nl);
    let fp = FpOps::with_mode(nl.fmt, mode);
    let mut prog = Program::from_tape(&tape);
    let mut stats = PassStats {
        steps_in: tape.steps.len(),
        slots_in: tape.n_signals,
        ..PassStats::default()
    };
    let (folded, copies) = prog.fold_constants(&fp);
    stats.folded = folded;
    stats.copies = copies;
    stats.macs = prog.fuse_macs();
    let (tg, ta) = prog.fuse_tree_reduce();
    stats.tree_groups = tg;
    stats.tree_adds = ta;
    let (fm, fmt_) = prog.fuse_fold_max();
    stats.fold_maxes = fm;
    stats.fold_max_terms = fmt_;
    stats.relus = prog.rewrite_relu();
    stats.converts_absorbed = prog.absorb_converts();
    stats.dead = prog.eliminate_dead();
    stats.slots_out = prog.allocate_registers();
    stats.instrs_out = prog.ops.len();

    let listing: Vec<String> = prog.ops.iter().map(listing_line).collect();
    let instrs: Vec<Instr> = prog.ops.iter().map(|h| emit(h, mode)).collect();

    // Validate every slot the unchecked bodies will touch.
    let n = prog.n_slots;
    let ck = |s: usize| assert!(s < n, "kernel slot {s} out of arena ({n})");
    for i in &instrs {
        ck(i.a as usize);
        ck(i.b as usize);
        ck(i.c as usize);
        ck(i.d as usize);
        ck(i.d1 as usize);
        for &e in i.ext.iter() {
            ck(e as usize);
        }
    }
    for &s in prog.input_slots.iter().chain(prog.output_slots.iter()) {
        ck(s);
    }
    for &(s, _) in &prog.consts {
        ck(s);
    }

    CompiledKernel {
        ops: fp,
        instrs,
        n_slots: n,
        input_slots: prog.input_slots,
        output_slots: prog.output_slots,
        consts: prog.consts,
        stats,
        fingerprint: nl.fingerprint(),
        listing,
    }
}

// ---------------------------------------------------------------------------
// KernelExec
// ---------------------------------------------------------------------------

/// A kernel executor: shared compiled code + a private scratch arena.
/// Drop-in for `BatchEngine::eval_lanes` on the hot path.
pub struct KernelExec {
    kernel: Arc<CompiledKernel>,
    lanes: Vec<Lane>,
}

impl KernelExec {
    pub fn new(kernel: Arc<CompiledKernel>) -> Self {
        let mut lanes = vec![[0.0; LANES]; kernel.n_slots];
        for &(slot, v) in &kernel.consts {
            lanes[slot] = [v; LANES];
        }
        Self { kernel, lanes }
    }

    /// Build an executor through the process-wide [`KernelCache`] —
    /// the same netlist/mode compiles once per process.
    pub fn for_netlist(nl: &Netlist, mode: OpMode) -> Self {
        Self::new(KernelCache::global().get_or_compile(nl, mode))
    }

    pub fn kernel(&self) -> &Arc<CompiledKernel> {
        &self.kernel
    }

    pub fn n_inputs(&self) -> usize {
        self.kernel.input_slots.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.kernel.output_slots.len()
    }

    /// Evaluate one 16-lane block — same contract as
    /// `BatchEngine::eval_lanes`.
    pub fn eval_lanes(&mut self, inputs: &[Lane], out: &mut [Lane]) {
        debug_assert_eq!(inputs.len(), self.kernel.input_slots.len());
        for (lane, &slot) in inputs.iter().zip(&self.kernel.input_slots) {
            self.lanes[slot] = *lane;
        }
        let mut ctx = KernelCtx { lanes: &mut self.lanes, ops: &self.kernel.ops };
        for i in &self.kernel.instrs {
            (i.f)(i, &mut ctx);
        }
        for (o, &slot) in out.iter_mut().zip(&self.kernel.output_slots) {
            *o = self.lanes[slot];
        }
    }
}

// ---------------------------------------------------------------------------
// KernelCache
// ---------------------------------------------------------------------------

/// Cache counters (process lifetime).  `hits`/`misses`/`evictions` are
/// cumulative — tests must assert *deltas*, the cache is shared across
/// the whole test binary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

/// The process-wide compiled-kernel cache, keyed on
/// `(Netlist::fingerprint(), OpMode)`.  Every `Session`, pool worker and
/// server stream running a structurally identical filter shares one
/// `Arc<CompiledKernel>`; 64 streams of conv3x3 compile once.
///
/// The map is bounded: at most `cap` entries, least-recently-used
/// evicted first (the format search compiles hundreds of re-staged
/// variants per run — unbounded, a long-lived server doing searches
/// would accrete kernels forever).  Eviction only drops the cache's
/// `Arc`; executors built from an evicted kernel keep running it, and
/// the next request for that netlist simply recompiles.  The default
/// cap (1024) is far above any steady-state working set; override with
/// `FPSPATIAL_KERNEL_CACHE_CAP`.
///
/// The map lock is held *across* compilation so two threads racing on
/// the same key never compile twice.  Compiles are milliseconds and
/// happen once per distinct filter, so the critical section is cold.
pub struct KernelCache {
    /// fingerprint/mode -> (kernel, last-use tick).
    map: Mutex<HashMap<(u128, OpMode), (Arc<CompiledKernel>, u64)>>,
    cap: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl KernelCache {
    /// Default entry cap of the global cache.
    pub const DEFAULT_CAPACITY: usize = 1024;

    fn new(cap: usize) -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            cap: cap.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// A private cache with an explicit entry cap (tests; the global
    /// instance reads `FPSPATIAL_KERNEL_CACHE_CAP`).  Caps below 1 are
    /// raised to 1.
    pub fn with_capacity(cap: usize) -> Self {
        Self::new(cap)
    }

    /// The process-wide instance.
    pub fn global() -> &'static KernelCache {
        static CACHE: OnceLock<KernelCache> = OnceLock::new();
        CACHE.get_or_init(|| {
            let cap = std::env::var("FPSPATIAL_KERNEL_CACHE_CAP")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(Self::DEFAULT_CAPACITY);
            KernelCache::new(cap)
        })
    }

    /// Look up (or compile and insert) the kernel for `nl` in `mode`,
    /// evicting the least-recently-used entry if the cache is full.
    pub fn get_or_compile(&self, nl: &Netlist, mode: OpMode) -> Arc<CompiledKernel> {
        let key = (nl.fingerprint(), mode);
        // a kernel is pure data — a poisoned lock means a panic during
        // some unrelated compile; the map itself is still coherent
        let mut map = self.map.lock().unwrap_or_else(PoisonError::into_inner);
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        if let Some(entry) = map.get_mut(&key) {
            entry.1 = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&entry.0);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if map.len() >= self.cap {
            let victim = map.iter().min_by_key(|(_, (_, t))| *t).map(|(k, _)| *k);
            if let Some(victim) = victim {
                map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let k = Arc::new(compile(nl, mode));
        map.insert(key, (Arc::clone(&k), now));
        k
    }

    pub fn stats(&self) -> CacheStats {
        let entries = self.map.lock().unwrap_or_else(PoisonError::into_inner).len();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::FloatFormat;
    use crate::sim::engine::Engine;
    use crate::sim::netlist::Builder;
    use crate::util::rng::Rng;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    /// Assert the kernel is bit-identical to the scalar oracle on random
    /// inputs, lane by lane.  Compiles directly (not via the global
    /// cache) so per-test stats stay local.
    fn assert_parity(nl: &Netlist, mode: OpMode, seed: u64) -> PassStats {
        let kernel = Arc::new(compile(nl, mode));
        let stats = kernel.stats();
        let mut ker = KernelExec::new(kernel);
        let mut eng = Engine::new(nl, mode);
        let n_in = nl.inputs.len();
        let n_out = nl.outputs.len();
        let mut rng = Rng::new(seed);
        for _ in 0..8 {
            let mut in_lanes = vec![[0.0; LANES]; n_in];
            for lane in in_lanes.iter_mut() {
                for v in lane.iter_mut() {
                    *v = quantize(rng.uniform(-255.0, 255.0), nl.fmt);
                }
            }
            let mut out_lanes = vec![[0.0; LANES]; n_out];
            ker.eval_lanes(&in_lanes, &mut out_lanes);
            for j in 0..LANES {
                let ins: Vec<f64> = in_lanes.iter().map(|l| l[j]).collect();
                let want = eng.eval(&ins);
                for (p, w) in out_lanes.iter().zip(&want) {
                    assert_eq!(
                        p[j].to_bits(),
                        w.to_bits(),
                        "lane {j}: kernel {} vs oracle {} ({mode:?})",
                        p[j],
                        w
                    );
                }
            }
        }
        stats
    }

    fn fig12() -> Netlist {
        // z = sqrt((x*y)/(x+y))
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s = b.add(x, y);
        let d = b.div(m, s);
        let z = b.sqrt(d);
        b.output("z", z);
        b.build()
    }

    #[test]
    fn fig12_parity_both_modes() {
        let nl = fig12();
        assert_parity(&nl, OpMode::Exact, 0xA11CE);
        assert_parity(&nl, OpMode::Poly, 0xB0B);
    }

    #[test]
    fn conv_tape_fuses_macs_and_tree() {
        // 3x3 convolution body: 9 coefficient multiplies + adder tree
        let mut b = Builder::new(F16);
        let taps: Vec<_> = (0..9).map(|i| b.input(&format!("t{i}"))).collect();
        let prods: Vec<_> =
            taps.iter().enumerate().map(|(i, &t)| b.mul_const(t, 0.0625 * (i + 1) as f64)).collect();
        let sum = b.adder_tree(&prods);
        b.output("y", sum);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0xC0FFEE);
        assert!(stats.macs >= 1, "expected MAC fusion, got {stats:?}");
        assert!(
            stats.macs + stats.tree_adds >= 1,
            "expected adder-tree compaction, got {stats:?}"
        );
        assert!(stats.instrs_out < stats.steps_in, "no compaction: {stats:?}");
        assert_parity(&nl, OpMode::Poly, 0xC0FFEE);
    }

    #[test]
    fn maxpool_tape_folds_max_chain() {
        // 2x2 pool: max(max(max(a,b),c),d) — left fold by construction
        let mut b = Builder::new(F16);
        let a = b.input("a");
        let x = b.input("b");
        let c = b.input("c");
        let d = b.input("d");
        let m0 = b.op2(OpKind::Max, a, x);
        let m1 = b.op2(OpKind::Max, m0, c);
        let m2 = b.op2(OpKind::Max, m1, d);
        b.output("y", m2);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0x9001);
        assert_eq!(stats.fold_maxes, 1, "{stats:?}");
        assert_eq!(stats.fold_max_terms, 3, "{stats:?}");
        assert_eq!(stats.instrs_out, 1, "{stats:?}");
    }

    #[test]
    fn relu_recognized() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.max_const(x, 0.0);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0x2E1);
        assert_eq!(stats.relus, 1, "{stats:?}");
        assert_eq!(stats.instrs_out, 1, "{stats:?}");
    }

    #[test]
    fn negative_zero_guard_not_rewritten_to_relu() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.max_const(x, -0.0);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0x2E2);
        assert_eq!(stats.relus, 0, "-0.0 guard must stay max_imm: {stats:?}");
    }

    #[test]
    fn constant_subtrees_fold() {
        // y = x * (2 + 3) — the add folds away at compile time
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let c2 = b.constant(2.0);
        let c3 = b.constant(3.0);
        let s = b.add(c2, c3);
        let y = b.mul(x, s);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0xF01D);
        assert!(stats.folded >= 1, "{stats:?}");
        // the surviving multiply is a mul_imm (const operand rewritten)
        assert_eq!(stats.instrs_out, 1, "{stats:?}");
    }

    #[test]
    fn dead_steps_eliminated() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let live = b.add(x, y);
        let _dead = b.mul(x, y); // no output reads this
        b.output("z", live);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0xDEAD);
        assert!(stats.dead >= 1, "{stats:?}");
    }

    #[test]
    fn regalloc_compacts_and_median_survives() {
        // sort5 CAS network — heavy slot churn, two-output steps
        let mut b = Builder::new(F16);
        let vals = [
            b.input("a"),
            b.input("b"),
            b.input("c"),
            b.input("d"),
            b.input("e"),
        ];
        let sorted = b.sort5(vals);
        b.output("med", sorted[2]);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0x3ED1A);
        assert!(
            stats.slots_out <= stats.slots_in,
            "regalloc grew the arena: {stats:?}"
        );
    }

    #[test]
    fn fingerprint_ignores_names_but_not_structure() {
        let mk = |in_name: &str, out_name: &str, k: f64| {
            let mut b = Builder::new(F16);
            let x = b.input(in_name);
            let y = b.mul_const(x, k);
            b.output(out_name, y);
            b.build()
        };
        let a = mk("x", "y", 0.5);
        let renamed = mk("px", "py", 0.5);
        let diff_coeff = mk("x", "y", 0.25);
        assert_eq!(a.fingerprint(), renamed.fingerprint(), "names must not matter");
        assert_ne!(a.fingerprint(), diff_coeff.fingerprint(), "coefficients must matter");

        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.max_const(x, 0.5);
        b.output("y", y);
        let diff_op = b.build();
        assert_ne!(a.fingerprint(), diff_op.fingerprint(), "ops must matter");
    }

    #[test]
    fn cache_compiles_each_netlist_once() {
        let cache = KernelCache::global();
        let nl = fig12();
        let before = cache.stats();
        let k1 = cache.get_or_compile(&nl, OpMode::Exact);
        let k2 = cache.get_or_compile(&nl, OpMode::Exact);
        let k3 = cache.get_or_compile(&nl, OpMode::Poly);
        let after = cache.stats();
        assert!(Arc::ptr_eq(&k1, &k2), "same (netlist, mode) must share the kernel");
        assert!(!Arc::ptr_eq(&k1, &k3), "modes must not share kernels");
        // deltas: first Exact may hit (another test may have warmed it);
        // the second Exact lookup is a guaranteed hit
        assert!(after.hits >= before.hits + 1);
        assert!(after.misses <= before.misses + 2);
        assert!(after.entries >= 2);
    }

    #[test]
    fn boundary_convert_absorbed_into_mac() {
        // conv-style body (coefficient MACs) ending in a boundary
        // Convert — the chain stage shape exec_netlist() produces
        let wide = FloatFormat::new(16, 7);
        let mut b = Builder::new(wide);
        let taps: Vec<_> = (0..9).map(|i| b.input(&format!("t{i}"))).collect();
        let prods: Vec<_> = taps.iter().map(|&t| b.mul_const(t, 0.0625)).collect();
        let sum = b.adder_tree(&prods);
        let y = b.op1(OpKind::Convert(F16), sum);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0xCAB1E);
        assert_eq!(stats.converts_absorbed, 1, "{stats:?}");
        assert_parity(&nl, OpMode::Poly, 0xCAB1E);
        let dump = compile(&nl, OpMode::Exact).dump();
        assert!(
            dump.contains(" as float16(10,5)"),
            "absorbed convert missing from listing:\n{dump}"
        );
        assert!(!dump.contains(" convert "), "standalone convert survived:\n{dump}");
    }

    #[test]
    fn boundary_convert_absorbed_into_tree_reduce_and_fold_max() {
        let wide = FloatFormat::new(16, 7);
        // plain adder tree (no muls) -> TreeReduce + Convert
        let mut b = Builder::new(wide);
        let ins: Vec<_> = (0..8).map(|i| b.input(&format!("x{i}"))).collect();
        let sum = b.adder_tree(&ins);
        let y = b.op1(OpKind::Convert(F16), sum);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0x7EE);
        assert_eq!(stats.converts_absorbed, 1, "tree_reduce: {stats:?}");

        // max fold -> FoldMax + Convert
        let mut b = Builder::new(wide);
        let a = b.input("a");
        let x = b.input("b");
        let c = b.input("c");
        let d = b.input("d");
        let m0 = b.op2(OpKind::Max, a, x);
        let m1 = b.op2(OpKind::Max, m0, c);
        let m2 = b.op2(OpKind::Max, m1, d);
        let y = b.op1(OpKind::Convert(F16), m2);
        b.output("y", y);
        let nl = b.build();
        let stats = assert_parity(&nl, OpMode::Exact, 0xF01D3);
        assert_eq!(stats.converts_absorbed, 1, "fold_max: {stats:?}");
    }

    #[test]
    fn cache_evicts_lru_and_recompiles() {
        let cache = KernelCache::with_capacity(2);
        let mk = |k: f64| {
            let mut b = Builder::new(F16);
            let x = b.input("x");
            let y = b.mul_const(x, k);
            b.output("y", y);
            b.build()
        };
        let (na, nb, nc) = (mk(0.5), mk(0.25), mk(0.125));
        let ka1 = cache.get_or_compile(&na, OpMode::Exact);
        let kb1 = cache.get_or_compile(&nb, OpMode::Exact);
        // touch `na` so `nb` becomes the LRU victim
        let ka2 = cache.get_or_compile(&na, OpMode::Exact);
        assert!(Arc::ptr_eq(&ka1, &ka2));
        let _kc = cache.get_or_compile(&nc, OpMode::Exact);
        let s = cache.stats();
        assert_eq!(s.entries, 2, "{s:?}");
        assert_eq!(s.evictions, 1, "{s:?}");
        // the recently-used entry survived the eviction...
        let ka3 = cache.get_or_compile(&na, OpMode::Exact);
        assert!(Arc::ptr_eq(&ka1, &ka3), "MRU entry must survive eviction");
        // ...and the evicted program recompiles to a working kernel
        let kb2 = cache.get_or_compile(&nb, OpMode::Exact);
        assert!(!Arc::ptr_eq(&kb1, &kb2), "evicted kernel must recompile fresh");
        assert_eq!(kb2.fingerprint(), kb1.fingerprint());
        let mut ex = KernelExec::new(kb2);
        let mut out = [[0.0; LANES]];
        ex.eval_lanes(&[[8.0; LANES]], &mut out);
        assert_eq!(out[0][0], 2.0, "recompiled kernel must still evaluate");
    }

    #[test]
    fn kernel_dump_mentions_fusions() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let w = b.input("w");
        let acc = b.input("acc");
        let p = b.mul(x, w);
        let s = b.add(p, acc);
        let y = b.max_const(s, 0.0);
        b.output("y", y);
        let nl = b.build();
        let k = compile(&nl, OpMode::Exact);
        let dump = k.dump();
        assert!(dump.contains("mac"), "{dump}");
        assert!(dump.contains("relu"), "{dump}");
        assert!(dump.contains("kernel"), "{dump}");
    }
}
