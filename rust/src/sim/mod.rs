//! Cycle-accurate datapath simulation substrate.
//!
//! * [`netlist`] — the scheduled-datapath IR + builder (λ/Δ algebra of
//!   §III-D);
//! * [`engine`] — fast functional evaluator (interpreter baseline);
//! * [`kernel`] + [`passes`] — the tape compiler: fused direct-threaded
//!   kernels (the benchmark hot path) and the process-wide kernel cache;
//! * [`rtl`] — register-transfer-level simulator with real pipeline and
//!   delay registers, used to *prove* schedules correct.

pub mod engine;
pub mod kernel;
pub mod netlist;
pub(crate) mod passes;
pub mod rtl;

pub use engine::{BatchEngine, Engine, Lane, LANES};
pub use kernel::{compile, CacheStats, CompiledKernel, KernelCache, KernelExec};
pub use netlist::{Builder, Netlist, SignalId, SignalSrc};
pub use passes::PassStats;
pub use rtl::RtlSim;
