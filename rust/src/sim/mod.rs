//! Cycle-accurate datapath simulation substrate.
//!
//! * [`netlist`] — the scheduled-datapath IR + builder (λ/Δ algebra of
//!   §III-D);
//! * [`engine`] — fast functional evaluator (the benchmark hot path);
//! * [`rtl`] — register-transfer-level simulator with real pipeline and
//!   delay registers, used to *prove* schedules correct.

pub mod engine;
pub mod netlist;
pub mod rtl;

pub use engine::{BatchEngine, Engine, Lane, LANES};
pub use netlist::{Builder, Netlist, SignalId, SignalSrc};
pub use rtl::RtlSim;
