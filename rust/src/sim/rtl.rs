//! Register-transfer-level simulator: executes a scheduled netlist cycle
//! by cycle with real pipeline registers and Δ delay lines.
//!
//! This is the proof that the scheduler's latency algebra (§III-D) is
//! correct: every operator's result is only visible `latency` cycles after
//! its operands were sampled, and every Δ delay line is a genuine shift
//! register.  The RTL output at cycle `t ≥ λ_total` must equal the
//! functional engine's output for input vector `t − λ_total` — asserted by
//! the cross-check tests and the `verify` CLI command.

use super::netlist::{Netlist, SignalSrc};
use crate::fpcore::{ops::FpOps, OpMode};

/// A ring-buffer shift register of fixed depth ≥ 1.
#[derive(Debug, Clone)]
struct ShiftReg {
    buf: Vec<f64>,
    head: usize,
}

impl ShiftReg {
    fn new(depth: usize) -> Self {
        Self { buf: vec![0.0; depth.max(1)], head: 0 }
    }

    /// Push `v`, pop the value pushed `depth` cycles ago.
    #[inline]
    fn step(&mut self, v: f64) -> f64 {
        let out = self.buf[self.head];
        self.buf[self.head] = v;
        self.head += 1;
        if self.head == self.buf.len() {
            self.head = 0;
        }
        out
    }
}

struct RtlNode {
    /// Operand delay lines (None for Δ = 0).
    in_delays: Vec<Option<ShiftReg>>,
    /// The operator's internal pipeline (depth = latency).
    pipe0: ShiftReg,
    pipe1: Option<ShiftReg>, // CAS second output
}

/// Cycle-accurate simulator state.
pub struct RtlSim<'a> {
    nl: &'a Netlist,
    ops: FpOps,
    nodes: Vec<RtlNode>,
    /// Post-edge visible value of every signal this cycle.
    cur: Vec<f64>,
    cycle: u64,
}

impl<'a> RtlSim<'a> {
    pub fn new(nl: &'a Netlist, mode: OpMode) -> Self {
        let ops = FpOps::with_mode(nl.fmt, mode);
        let mut cur = vec![0.0; nl.signals.len()];
        for (i, s) in nl.signals.iter().enumerate() {
            if let SignalSrc::Const(c) = s.src {
                cur[i] = c;
            }
        }
        let nodes = nl
            .nodes
            .iter()
            .map(|n| RtlNode {
                in_delays: n
                    .in_delays
                    .iter()
                    .map(|&d| if d == 0 { None } else { Some(ShiftReg::new(d as usize)) })
                    .collect(),
                pipe0: ShiftReg::new(n.op.latency() as usize),
                pipe1: (n.op.outputs() == 2).then(|| ShiftReg::new(n.op.latency() as usize)),
            })
            .collect();
        Self { nl, ops, nodes, cur, cycle: 0 }
    }

    /// Advance one clock: drive the input ports, return the output-port
    /// values visible *this* cycle (valid once `cycle > total_latency`).
    pub fn step(&mut self, inputs: &[f64]) -> Vec<f64> {
        debug_assert_eq!(inputs.len(), self.nl.inputs.len());
        // Input ports present their new values at this edge.
        for (i, s) in self.nl.signals.iter().enumerate() {
            if let SignalSrc::Input(port) = s.src {
                self.cur[i] = inputs[port];
            }
        }
        // Nodes are stored in topological order; processing them in order
        // within one edge is safe because every op has latency ≥ 1 (no
        // combinational paths).
        for (node, rtl) in self.nl.nodes.iter().zip(&mut self.nodes) {
            // Sample operands through their Δ delay lines.
            let mut operands = [0.0f64; 2];
            for (k, (&sig, dl)) in node.ins.iter().zip(&mut rtl.in_delays).enumerate() {
                let raw = self.cur[sig];
                operands[k] = match dl {
                    Some(reg) => reg.step(raw),
                    None => raw,
                };
            }
            let (r0, r1) = self.ops.apply(node.op, &operands[..node.op.arity()]);
            self.cur[node.outs[0]] = rtl.pipe0.step(r0);
            if let (Some(pipe1), Some(r1)) = (&mut rtl.pipe1, r1) {
                self.cur[node.outs[1]] = pipe1.step(r1);
            }
        }
        self.cycle += 1;
        self.nl
            .outputs
            .iter()
            .map(|&(_, s)| self.cur[s])
            .collect()
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::FloatFormat;
    use crate::sim::engine::Engine;
    use crate::sim::netlist::Builder;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn fig12_netlist() -> Netlist {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s = b.add(x, y);
        let d = b.div(m, s);
        let z = b.sqrt(d);
        b.output("z", z);
        b.build()
    }

    /// The RTL sim must produce, at cycle t, the functional result of the
    /// inputs fed at cycle t − λ_total: one result per cycle (II = 1).
    #[test]
    fn rtl_matches_functional_with_total_latency() {
        let nl = fig12_netlist();
        let lat = nl.total_latency() as usize;
        assert_eq!(lat, 18);

        let mut rtl = RtlSim::new(&nl, OpMode::Exact);
        let mut func = Engine::new(&nl, OpMode::Exact);

        // Deterministic pseudo-random input stream.
        let stream: Vec<[f64; 2]> = (0..200)
            .map(|i| {
                let a = ((i * 37 + 11) % 251) as f64 + 1.0;
                let b = ((i * 91 + 3) % 239) as f64 + 1.0;
                [a, b]
            })
            .collect();

        let mut rtl_out = Vec::new();
        for s in &stream {
            rtl_out.push(rtl.step(s)[0]);
        }
        for (t, s) in stream.iter().enumerate() {
            let want = func.eval(s)[0];
            let got_idx = t + lat;
            if got_idx < rtl_out.len() {
                assert_eq!(
                    rtl_out[got_idx], want,
                    "pixel {t}: rtl[{got_idx}] != functional"
                );
            }
        }
    }

    /// Deliberately mis-scheduled netlist: zeroing the Δ delays must break
    /// the time alignment (negative control for the scheduler).
    #[test]
    fn zeroed_delays_break_alignment() {
        let mut nl = fig12_netlist();
        for n in &mut nl.nodes {
            for d in &mut n.in_delays {
                *d = 0;
            }
        }
        let lat = 18usize; // unchanged op latencies
        let mut rtl = RtlSim::new(&nl, OpMode::Exact);
        let mut func = Engine::new(&nl, OpMode::Exact);
        let stream: Vec<[f64; 2]> = (0..120)
            .map(|i| [((i * 53) % 97) as f64 + 2.0, ((i * 29) % 83) as f64 + 2.0])
            .collect();
        let mut rtl_out = Vec::new();
        for s in &stream {
            rtl_out.push(rtl.step(s)[0]);
        }
        let mismatches = stream
            .iter()
            .enumerate()
            .filter(|&(t, s)| {
                let want = func.eval(s)[0];
                t + lat < rtl_out.len() && rtl_out[t + lat] != want
            })
            .count();
        assert!(mismatches > 50, "only {mismatches} mismatches");
    }

    #[test]
    fn cas_rtl_both_ports_aligned() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let (lo, hi) = b.cas(x, y);
        b.output("lo", lo);
        b.output("hi", hi);
        let nl = b.build();
        let mut rtl = RtlSim::new(&nl, OpMode::Exact);
        let mut outs = Vec::new();
        for i in 0..10 {
            outs.push(rtl.step(&[(10 - i) as f64, i as f64]));
        }
        // λ = 2: outputs at t are inputs from t-2
        assert_eq!(outs[2], vec![0.0, 10.0]); // inputs at t=0: (10, 0)
        assert_eq!(outs[3], vec![1.0, 9.0]); // inputs at t=1: (9, 1)
    }
}
