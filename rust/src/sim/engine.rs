//! Functional (per-pixel) netlist evaluator.
//!
//! Evaluates a scheduled [`Netlist`] one input vector at a time, ignoring
//! pipeline timing (which cannot change the *values* of a feed-forward
//! II=1 datapath — the RTL-level simulator in `rtl.rs` proves that the
//! schedule lines the same values up in time).  This is the hot path of
//! every hardware-model benchmark, so it precompiles the graph into a
//! flat tape.

use super::netlist::{Netlist, SignalSrc};
use crate::fpcore::{ops::FpOps, OpKind, OpMode};

/// A flat, cache-friendly compiled form of one netlist node.
#[derive(Debug, Clone)]
struct Step {
    op: OpKind,
    in0: usize,
    in1: usize, // unused for unary ops
    out0: usize,
    out1: usize, // only for CAS
}

/// Compiled netlist evaluator.
pub struct Engine {
    ops: FpOps,
    steps: Vec<Step>,
    /// Scratch value slots, one per signal.
    values: Vec<f64>,
    /// Input signal slots in port order.
    input_slots: Vec<usize>,
    /// Output signal slots in port order.
    output_slots: Vec<usize>,
}

impl Engine {
    pub fn new(nl: &Netlist, mode: OpMode) -> Self {
        let ops = FpOps::with_mode(nl.fmt, mode);
        let mut values = vec![0.0; nl.signals.len()];
        // Constants never change: bake them into the scratch once.
        for (i, s) in nl.signals.iter().enumerate() {
            if let SignalSrc::Const(c) = s.src {
                values[i] = c;
            }
        }
        let input_slots = (0..nl.inputs.len())
            .map(|port| {
                nl.signals
                    .iter()
                    .position(|s| s.src == SignalSrc::Input(port))
                    .expect("input signal")
            })
            .collect();
        let output_slots = nl.outputs.iter().map(|&(_, s)| s).collect();
        let steps: Vec<Step> = nl
            .nodes
            .iter()
            .map(|n| Step {
                op: n.op,
                in0: n.ins[0],
                in1: *n.ins.get(1).unwrap_or(&0),
                out0: n.outs[0],
                out1: *n.outs.get(1).unwrap_or(&0),
            })
            .collect();
        // validate every slot for the unchecked hot-loop accesses
        let n_vals = values.len();
        for s in &steps {
            assert!(s.in0 < n_vals && s.in1 < n_vals && s.out0 < n_vals && s.out1 < n_vals);
        }
        Self { ops, steps, values, input_slots, output_slots }
    }

    pub fn n_inputs(&self) -> usize {
        self.input_slots.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.output_slots.len()
    }

    /// Evaluate one input vector; returns the outputs in port order.
    pub fn eval(&mut self, inputs: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.output_slots.len()];
        self.eval_into(inputs, &mut out);
        out
    }

    /// Allocation-free evaluation into a caller buffer (hot path).
    #[inline]
    pub fn eval_into(&mut self, inputs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(inputs.len(), self.input_slots.len());
        for (&slot, &v) in self.input_slots.iter().zip(inputs) {
            self.values[slot] = v;
        }
        let v = &mut self.values;
        for s in &self.steps {
            // SAFETY: all slot indices were validated against values.len()
            // in Engine::new (signals are append-only at build time).
            unsafe {
                let a = *v.get_unchecked(s.in0);
                let b = *v.get_unchecked(s.in1);
                // fully inlined dispatch — no operand arrays on the hot path
                match s.op {
                    OpKind::Add => *v.get_unchecked_mut(s.out0) = self.ops.add(a, b),
                    OpKind::Sub => *v.get_unchecked_mut(s.out0) = self.ops.sub(a, b),
                    OpKind::Mul => *v.get_unchecked_mut(s.out0) = self.ops.mul(a, b),
                    OpKind::MulConst(c) => *v.get_unchecked_mut(s.out0) = self.ops.mul(a, c),
                    OpKind::Div => *v.get_unchecked_mut(s.out0) = self.ops.div(a, b),
                    OpKind::Sqrt => *v.get_unchecked_mut(s.out0) = self.ops.sqrt(a),
                    OpKind::Log2 => *v.get_unchecked_mut(s.out0) = self.ops.log2(a),
                    OpKind::Exp2 => *v.get_unchecked_mut(s.out0) = self.ops.exp2(a),
                    OpKind::MaxConst(c) => {
                        *v.get_unchecked_mut(s.out0) = self.ops.max_const(a, c)
                    }
                    OpKind::Max => *v.get_unchecked_mut(s.out0) = self.ops.max(a, b),
                    OpKind::Min => *v.get_unchecked_mut(s.out0) = self.ops.min(a, b),
                    OpKind::Rsh(n) => *v.get_unchecked_mut(s.out0) = self.ops.rsh(a, n),
                    OpKind::Lsh(n) => *v.get_unchecked_mut(s.out0) = self.ops.lsh(a, n),
                    OpKind::Cas => {
                        let (lo, hi) = self.ops.cas(a, b);
                        *v.get_unchecked_mut(s.out0) = lo;
                        *v.get_unchecked_mut(s.out1) = hi;
                    }
                    OpKind::Reg => *v.get_unchecked_mut(s.out0) = a,
                }
            }
        }
        for (o, &slot) in out.iter_mut().zip(&self.output_slots) {
            *o = v[slot];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::FloatFormat;
    use crate::sim::netlist::Builder;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn fig12_netlist() -> Netlist {
        // z = sqrt((x*y)/(x+y))
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s = b.add(x, y);
        let d = b.div(m, s);
        let z = b.sqrt(d);
        b.output("z", z);
        b.build()
    }

    #[test]
    fn fig12_numerics_exact_mode() {
        let nl = fig12_netlist();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let out = eng.eval(&[3.0, 6.0]);
        // (3·6)/(3+6) = 2 → sqrt(2), all exactly representable steps
        let want = crate::fpcore::quantize(2.0_f64.sqrt(), F16);
        assert_eq!(out[0], want);
    }

    #[test]
    fn fig12_poly_mode_close() {
        let nl = fig12_netlist();
        let mut exact = Engine::new(&nl, OpMode::Exact);
        let mut poly = Engine::new(&nl, OpMode::Poly);
        for (x, y) in [(3.0, 6.0), (10.0, 2.5), (255.0, 1.0)] {
            let a = exact.eval(&[x, y])[0];
            let b = poly.eval(&[x, y])[0];
            assert!((a - b).abs() <= a.abs() * 0.01, "({x},{y}): {a} vs {b}");
        }
    }

    #[test]
    fn cas_engine_outputs_both_ports() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let (lo, hi) = b.cas(x, y);
        b.output("lo", lo);
        b.output("hi", hi);
        let nl = b.build();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[5.0, 2.0]), vec![2.0, 5.0]);
        assert_eq!(eng.eval(&[2.0, 5.0]), vec![2.0, 5.0]);
    }

    #[test]
    fn constants_persist_across_evals() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let c = b.constant(2.0);
        let m = b.mul(x, c);
        b.output("y", m);
        let nl = b.build();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[3.0])[0], 6.0);
        assert_eq!(eng.eval(&[4.0])[0], 8.0);
    }
}
