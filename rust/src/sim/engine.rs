//! Functional (per-pixel) netlist evaluators.
//!
//! Evaluates a scheduled [`Netlist`] ignoring pipeline timing (which
//! cannot change the *values* of a feed-forward II=1 datapath — the
//! RTL-level simulator in `rtl.rs` proves that the schedule lines the
//! same values up in time).  This is the hot path of every
//! hardware-model benchmark, so the graph is precompiled into a flat
//! [`Tape`] shared by two execution engines:
//!
//! * [`Engine`] — scalar: one input vector per call, one `f64` scratch
//!   slot per signal.  Simple and allocation-free, but every tape step
//!   pays its dispatch (`match` on the op) for a single window, and the
//!   dataflow dependencies of the netlist serialize the FP units.
//! * [`BatchEngine`] — lane-batched (structure-of-arrays): each signal's
//!   scratch slot is a fixed-width lane array `[f64; LANES]` holding the
//!   same wire for [`LANES`] *consecutive windows*.  Each tape step
//!   dispatches once and then runs a tight `for j in 0..LANES` loop, so
//!   the per-step overhead is amortized 16× and — because the lanes are
//!   independent — the inner loops auto-vectorize and the CPU can
//!   overlap the floating-point latency across lanes instead of waiting
//!   on the netlist's dependency chain.  This is the software analogue
//!   of the paper's many-windows-per-clock hardware replication.
//!
//! Lane-transposed inputs are produced without per-window copies by
//! `video::WindowGenerator::process_frame_lanes`; ragged right-edge
//! chunks (width not a multiple of [`LANES`]) are handled by the
//! producer replicating the last valid window into the spare lanes, so
//! the engine itself always computes full lanes.

use super::netlist::{Netlist, SignalSrc};
use crate::fpcore::{ops::FpOps, OpKind, OpMode};

pub use crate::util::{Lane, LANES};

/// A flat, cache-friendly compiled form of one netlist node.
///
/// `pub(crate)` so the tape compiler ([`super::kernel`]) can consume the
/// same lowering the interpreters run — one `Netlist → Tape` front end,
/// two back ends.
#[derive(Debug, Clone)]
pub(crate) struct Step {
    pub(crate) op: OpKind,
    pub(crate) in0: usize,
    pub(crate) in1: usize, // unused for unary ops
    pub(crate) out0: usize,
    pub(crate) out1: usize, // only for CAS
}

/// The compiled netlist: topologically-ordered steps plus the port→slot
/// maps, independent of the execution layout (scalar or lane-batched).
#[derive(Debug, Clone)]
pub(crate) struct Tape {
    pub(crate) steps: Vec<Step>,
    /// `(slot, value)` for every compile-time constant.
    pub(crate) consts: Vec<(usize, f64)>,
    /// Input signal slots in port order.
    pub(crate) input_slots: Vec<usize>,
    /// Output signal slots in port order.
    pub(crate) output_slots: Vec<usize>,
    /// Total signal count (scratch size).
    pub(crate) n_signals: usize,
}

impl Tape {
    pub(crate) fn new(nl: &Netlist) -> Self {
        let consts: Vec<(usize, f64)> = nl
            .signals
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s.src {
                SignalSrc::Const(c) => Some((i, c)),
                _ => None,
            })
            .collect();
        let input_slots: Vec<usize> = (0..nl.inputs.len())
            .map(|port| {
                nl.signals
                    .iter()
                    .position(|s| s.src == SignalSrc::Input(port))
                    .expect("input signal")
            })
            .collect();
        let output_slots: Vec<usize> = nl.outputs.iter().map(|&(_, s)| s).collect();
        let steps: Vec<Step> = nl
            .nodes
            .iter()
            .map(|n| Step {
                op: n.op,
                in0: n.ins[0],
                in1: *n.ins.get(1).unwrap_or(&0),
                out0: n.outs[0],
                out1: *n.outs.get(1).unwrap_or(&0),
            })
            .collect();
        // validate every slot for the unchecked hot-loop accesses
        let n_signals = nl.signals.len();
        for s in &steps {
            assert!(
                s.in0 < n_signals && s.in1 < n_signals && s.out0 < n_signals && s.out1 < n_signals
            );
        }
        for &(slot, _) in &consts {
            assert!(slot < n_signals);
        }
        for &slot in input_slots.iter().chain(&output_slots) {
            assert!(slot < n_signals);
        }
        Self { steps, consts, input_slots, output_slots, n_signals }
    }
}

/// Compiled netlist evaluator (scalar: one window per call).
pub struct Engine {
    ops: FpOps,
    tape: Tape,
    /// Scratch value slots, one per signal.
    values: Vec<f64>,
}

impl Engine {
    pub fn new(nl: &Netlist, mode: OpMode) -> Self {
        let ops = FpOps::with_mode(nl.fmt, mode);
        let tape = Tape::new(nl);
        let mut values = vec![0.0; tape.n_signals];
        // Constants never change: bake them into the scratch once.
        for &(slot, c) in &tape.consts {
            values[slot] = c;
        }
        Self { ops, tape, values }
    }

    pub fn n_inputs(&self) -> usize {
        self.tape.input_slots.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.tape.output_slots.len()
    }

    /// Evaluate one input vector; returns the outputs in port order.
    /// Allocates the result — tests/examples only; hot paths use
    /// [`Engine::eval_into`].
    pub fn eval(&mut self, inputs: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.tape.output_slots.len()];
        self.eval_into(inputs, &mut out);
        out
    }

    /// Allocation-free evaluation into a caller buffer (hot path).
    #[inline]
    pub fn eval_into(&mut self, inputs: &[f64], out: &mut [f64]) {
        debug_assert_eq!(inputs.len(), self.tape.input_slots.len());
        for (&slot, &v) in self.tape.input_slots.iter().zip(inputs) {
            self.values[slot] = v;
        }
        let v = &mut self.values;
        for s in &self.tape.steps {
            // SAFETY: all slot indices were validated against values.len()
            // in Tape::new (signals are append-only at build time).
            unsafe {
                let a = *v.get_unchecked(s.in0);
                let b = *v.get_unchecked(s.in1);
                // fully inlined dispatch — no operand arrays on the hot path
                match s.op {
                    OpKind::Add => *v.get_unchecked_mut(s.out0) = self.ops.add(a, b),
                    OpKind::Sub => *v.get_unchecked_mut(s.out0) = self.ops.sub(a, b),
                    OpKind::Mul => *v.get_unchecked_mut(s.out0) = self.ops.mul(a, b),
                    OpKind::MulConst(c) => *v.get_unchecked_mut(s.out0) = self.ops.mul(a, c),
                    OpKind::Div => *v.get_unchecked_mut(s.out0) = self.ops.div(a, b),
                    OpKind::Sqrt => *v.get_unchecked_mut(s.out0) = self.ops.sqrt(a),
                    OpKind::Log2 => *v.get_unchecked_mut(s.out0) = self.ops.log2(a),
                    OpKind::Exp2 => *v.get_unchecked_mut(s.out0) = self.ops.exp2(a),
                    OpKind::MaxConst(c) => {
                        *v.get_unchecked_mut(s.out0) = self.ops.max_const(a, c)
                    }
                    OpKind::Max => *v.get_unchecked_mut(s.out0) = self.ops.max(a, b),
                    OpKind::Min => *v.get_unchecked_mut(s.out0) = self.ops.min(a, b),
                    OpKind::Rsh(n) => *v.get_unchecked_mut(s.out0) = self.ops.rsh(a, n),
                    OpKind::Lsh(n) => *v.get_unchecked_mut(s.out0) = self.ops.lsh(a, n),
                    OpKind::Cas => {
                        let (lo, hi) = self.ops.cas(a, b);
                        *v.get_unchecked_mut(s.out0) = lo;
                        *v.get_unchecked_mut(s.out1) = hi;
                    }
                    OpKind::Convert(dst) => {
                        *v.get_unchecked_mut(s.out0) = self.ops.convert(a, dst)
                    }
                    OpKind::Reg => *v.get_unchecked_mut(s.out0) = a,
                }
            }
        }
        for (o, &slot) in out.iter_mut().zip(&self.tape.output_slots) {
            *o = v[slot];
        }
    }
}

/// Lane-batched netlist evaluator (structure-of-arrays).
///
/// Numerically identical to [`Engine`]: every lane applies exactly the
/// same `FpOps` sequence a scalar evaluation would, so outputs are
/// bit-identical lane by lane (asserted by `tests/batch_parity.rs`).
pub struct BatchEngine {
    ops: FpOps,
    tape: Tape,
    /// Scratch lanes, one `[f64; LANES]` per signal.
    lanes: Vec<Lane>,
}

impl BatchEngine {
    pub fn new(nl: &Netlist, mode: OpMode) -> Self {
        let ops = FpOps::with_mode(nl.fmt, mode);
        let tape = Tape::new(nl);
        let mut lanes = vec![[0.0; LANES]; tape.n_signals];
        // Constants never change: broadcast them across the lanes once.
        for &(slot, c) in &tape.consts {
            lanes[slot] = [c; LANES];
        }
        Self { ops, tape, lanes }
    }

    pub fn n_inputs(&self) -> usize {
        self.tape.input_slots.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.tape.output_slots.len()
    }

    /// Evaluate [`LANES`] windows at once.  `inputs` holds one lane array
    /// per input port (lane `j` = window `j`); `out` receives one lane
    /// array per output port.  Lanes never interact, so callers with a
    /// ragged tail simply ignore the spare output lanes.
    #[inline]
    pub fn eval_lanes(&mut self, inputs: &[Lane], out: &mut [Lane]) {
        debug_assert_eq!(inputs.len(), self.tape.input_slots.len());
        debug_assert_eq!(out.len(), self.tape.output_slots.len());
        for (&slot, lane) in self.tape.input_slots.iter().zip(inputs) {
            self.lanes[slot] = *lane;
        }
        let l = &mut self.lanes;
        let ops = self.ops;
        for s in &self.tape.steps {
            // SAFETY: all slot indices were validated against the signal
            // count in Tape::new.  Operands are copied out before the
            // output lane is borrowed, so in-place steps stay sound.
            unsafe {
                let a = *l.get_unchecked(s.in0);
                let b = *l.get_unchecked(s.in1);
                // dispatch once per step, then a branch-free lane loop
                match s.op {
                    OpKind::Add => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.add(a[j], b[j]);
                        }
                    }
                    OpKind::Sub => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.sub(a[j], b[j]);
                        }
                    }
                    OpKind::Mul => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.mul(a[j], b[j]);
                        }
                    }
                    OpKind::MulConst(c) => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.mul(a[j], c);
                        }
                    }
                    OpKind::Div => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.div(a[j], b[j]);
                        }
                    }
                    OpKind::Sqrt => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.sqrt(a[j]);
                        }
                    }
                    OpKind::Log2 => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.log2(a[j]);
                        }
                    }
                    OpKind::Exp2 => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.exp2(a[j]);
                        }
                    }
                    OpKind::MaxConst(c) => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.max_const(a[j], c);
                        }
                    }
                    OpKind::Max => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.max(a[j], b[j]);
                        }
                    }
                    OpKind::Min => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.min(a[j], b[j]);
                        }
                    }
                    OpKind::Rsh(n) => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.rsh(a[j], n);
                        }
                    }
                    OpKind::Lsh(n) => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.lsh(a[j], n);
                        }
                    }
                    OpKind::Cas => {
                        let mut lo = [0.0; LANES];
                        let mut hi = [0.0; LANES];
                        for j in 0..LANES {
                            let (l_, h_) = ops.cas(a[j], b[j]);
                            lo[j] = l_;
                            hi[j] = h_;
                        }
                        *l.get_unchecked_mut(s.out0) = lo;
                        *l.get_unchecked_mut(s.out1) = hi;
                    }
                    OpKind::Convert(dst) => {
                        let o = l.get_unchecked_mut(s.out0);
                        for j in 0..LANES {
                            o[j] = ops.convert(a[j], dst);
                        }
                    }
                    OpKind::Reg => *l.get_unchecked_mut(s.out0) = a,
                }
            }
        }
        for (o, &slot) in out.iter_mut().zip(&self.tape.output_slots) {
            *o = l[slot];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::FloatFormat;
    use crate::sim::netlist::Builder;
    use crate::util::rng::Rng;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn fig12_netlist() -> Netlist {
        // z = sqrt((x*y)/(x+y))
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let m = b.mul(x, y);
        let s = b.add(x, y);
        let d = b.div(m, s);
        let z = b.sqrt(d);
        b.output("z", z);
        b.build()
    }

    #[test]
    fn fig12_numerics_exact_mode() {
        let nl = fig12_netlist();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        let out = eng.eval(&[3.0, 6.0]);
        // (3·6)/(3+6) = 2 → sqrt(2), all exactly representable steps
        let want = crate::fpcore::quantize(2.0_f64.sqrt(), F16);
        assert_eq!(out[0], want);
    }

    #[test]
    fn fig12_poly_mode_close() {
        let nl = fig12_netlist();
        let mut exact = Engine::new(&nl, OpMode::Exact);
        let mut poly = Engine::new(&nl, OpMode::Poly);
        for (x, y) in [(3.0, 6.0), (10.0, 2.5), (255.0, 1.0)] {
            let a = exact.eval(&[x, y])[0];
            let b = poly.eval(&[x, y])[0];
            assert!((a - b).abs() <= a.abs() * 0.01, "({x},{y}): {a} vs {b}");
        }
    }

    #[test]
    fn cas_engine_outputs_both_ports() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let (lo, hi) = b.cas(x, y);
        b.output("lo", lo);
        b.output("hi", hi);
        let nl = b.build();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[5.0, 2.0]), vec![2.0, 5.0]);
        assert_eq!(eng.eval(&[2.0, 5.0]), vec![2.0, 5.0]);
    }

    #[test]
    fn constants_persist_across_evals() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let c = b.constant(2.0);
        let m = b.mul(x, c);
        b.output("y", m);
        let nl = b.build();
        let mut eng = Engine::new(&nl, OpMode::Exact);
        assert_eq!(eng.eval(&[3.0])[0], 6.0);
        assert_eq!(eng.eval(&[4.0])[0], 8.0);
    }

    #[test]
    fn batch_matches_scalar_lane_by_lane() {
        let nl = fig12_netlist();
        for mode in [OpMode::Exact, OpMode::Poly] {
            let mut scalar = Engine::new(&nl, mode);
            let mut batch = BatchEngine::new(&nl, mode);
            let mut rng = Rng::new(0xBEEF);
            let mut xs = [0.0; LANES];
            let mut ys = [0.0; LANES];
            for j in 0..LANES {
                xs[j] = rng.uniform(0.5, 255.0);
                ys[j] = rng.uniform(0.5, 255.0);
            }
            let mut out = [[0.0; LANES]; 1];
            batch.eval_lanes(&[xs, ys], &mut out);
            for j in 0..LANES {
                let want = scalar.eval(&[xs[j], ys[j]])[0];
                assert!(
                    out[0][j] == want || (out[0][j].is_nan() && want.is_nan()),
                    "lane {j}: {} vs {}",
                    out[0][j],
                    want
                );
            }
        }
    }

    #[test]
    fn batch_cas_both_outputs() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let y = b.input("y");
        let (lo, hi) = b.cas(x, y);
        b.output("lo", lo);
        b.output("hi", hi);
        let nl = b.build();
        let mut batch = BatchEngine::new(&nl, OpMode::Exact);
        let mut xs = [0.0; LANES];
        let mut ys = [0.0; LANES];
        for j in 0..LANES {
            xs[j] = j as f64;
            ys[j] = (LANES - j) as f64;
        }
        let mut out = [[0.0; LANES]; 2];
        batch.eval_lanes(&[xs, ys], &mut out);
        for j in 0..LANES {
            assert_eq!(out[0][j], xs[j].min(ys[j]));
            assert_eq!(out[1][j], xs[j].max(ys[j]));
        }
    }

    #[test]
    fn batch_constants_broadcast_and_persist() {
        let mut b = Builder::new(F16);
        let x = b.input("x");
        let c = b.constant(2.0);
        let m = b.mul(x, c);
        b.output("y", m);
        let nl = b.build();
        let mut batch = BatchEngine::new(&nl, OpMode::Exact);
        let xs = [3.0; LANES];
        let mut out = [[0.0; LANES]; 1];
        batch.eval_lanes(&[xs], &mut out);
        assert_eq!(out[0], [6.0; LANES]);
        batch.eval_lanes(&[[4.0; LANES]], &mut out);
        assert_eq!(out[0], [8.0; LANES]);
    }
}
