//! # fpspatial
//!
//! Reproduction of *"Fast Generation of Custom Floating-Point Spatial
//! Filters on FPGAs"* (Campos, Edirisinghe, Chesnokov, Larkin, 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains the paper's entire generation system:
//!
//! * [`fpcore`] — custom `float(m, e)` arithmetic: bit-level rounding model
//!   and the pipelined operator set (add/mul/div/sqrt/log2/exp2/shift/CAS)
//!   with the paper's latencies and piecewise-polynomial approximations.
//! * [`dsl`] — the domain-specific language of §V: lexer, parser, type
//!   checker, latency-balancing scheduler (the Δ formula of §III-D) and the
//!   SystemVerilog code generator.
//! * [`video`] — streaming-video substrate: timing generation with blanking
//!   intervals, frame sources, and the line-buffer window generator of
//!   §III-A.
//! * [`sim`] — cycle-accurate simulator for scheduled datapaths fed by the
//!   window generator (the "FPGA" of the evaluation).
//! * [`filters`] — built-in spatial filters (§III): linear convolutions with
//!   recursive adder trees, the Bose–Nelson median, the generic non-linear
//!   filter of eq. 2, and Sobel (floating-point and fixed-point/HLS-style).
//! * [`resources`] — the FPGA resource model (LUT/FF/BRAM/DSP) that
//!   regenerates fig. 11 against the Zybo Z7-20 budget.
//! * [`runtime`] — PJRT loader/executor for the AOT-lowered JAX/Pallas
//!   artifacts (the golden numerics reference and Table I software rows).
//! * [`pipeline`] — **the one execution API**: the [`pipeline::Pipeline`]
//!   builder compiles ordered (mixed-precision) stages into an immutable
//!   [`pipeline::CompiledPipeline`] plan, executed by reusable
//!   [`pipeline::Session`]s under one of four [`pipeline::ExecPlan`]
//!   strategies (scalar / batched / tiled / streaming).
//! * [`opt`] — the plan optimizer: conv fusion and automatic per-stage
//!   format search with a Pareto front — rewrites
//!   [`pipeline::CompiledPipeline`]s instead of executing them.
//! * [`coordinator`] — shared workload helpers ([`coordinator::synth_sequence`]);
//!   the legacy `run_*` shims are gone — execution goes through [`pipeline`].
//! * [`bench`] — harnesses that regenerate every table and figure of the
//!   paper's evaluation (Table I, Figure 11, latency tables, ablations).
//! * [`cli`] — the `fpspatial` command line (argument parsing + dispatch),
//!   library-hosted so the end-to-end tests drive it in-process.

// Hot loops index fixed-width lane arrays and ring buffers by position on
// purpose (the indexed form is what auto-vectorizes and mirrors the RTL);
// the iterator rewrite clippy suggests obscures that.
#![allow(clippy::needless_range_loop)]

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod dsl;
pub mod filters;
pub mod fpcore;
pub mod opt;
pub mod pipeline;
pub mod resources;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod video;
