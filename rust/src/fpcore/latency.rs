//! Pipeline latencies of the custom floating-point operators.
//!
//! Values from the paper (§III-B footnote 2, §III-C, §III-D footnotes
//! 7–10/13 and the §V compiler walk-through):
//!
//! | op            | cycles | source                                   |
//! |---------------|--------|------------------------------------------|
//! | add / sub     | 6      | footnote 2 / 10                          |
//! | mul           | 2      | footnote 8                               |
//! | div           | 7      | footnote 13 (deg-3, 4-segment poly)      |
//! | sqrt          | 5      | footnote 9 (deg-2, 4-segment poly)       |
//! | log2          | 5      | footnote 11 ("both have latency 5")      |
//! | exp2          | 6      | derived: f^δ = max(1)+mul(2)+exp2 = 9    |
//! | max / min     | 1      | footnote 7                               |
//! | fp shift      | 1      | §III-D step 5                            |
//! | CMP_and_SWAP  | 2      | §III-C                                   |
//! | fmt_convert   | 2      | derived: re-bias adder + the same RNE    |
//! |               |        | round/pack tail every arith block ends   |
//! |               |        | with (no paper value — converters sit    |
//! |               |        | between cascade stages, §"mixed chains") |
//!
//! Every operator has a throughput of one result per cycle (fully
//! pipelined), so latency only determines the delay-matching registers the
//! scheduler inserts (§III-D Δ formula).

/// Latency in pipeline cycles.
pub type Latency = u32;

pub const L_ADD: Latency = 6;
pub const L_SUB: Latency = 6;
pub const L_MUL: Latency = 2;
pub const L_DIV: Latency = 7;
pub const L_SQRT: Latency = 5;
pub const L_LOG2: Latency = 5;
pub const L_EXP2: Latency = 6;
pub const L_MAX: Latency = 1;
pub const L_MIN: Latency = 1;
pub const L_SHIFT: Latency = 1;
pub const L_CAS: Latency = 2;
/// Inter-format converter (`float(m,e) → float(m',e')`): exponent
/// re-bias (1 cycle) + round/pack with saturate/flush (1 cycle).
pub const L_CVT: Latency = 2;
/// Register copy inserted for delay matching — one cycle per stage.
pub const L_REG: Latency = 1;

/// `AdderTree(N)` latency: `L_ADD · ⌈log2 N⌉` (§III-B design rule).
pub fn adder_tree_latency(n_inputs: u32) -> Latency {
    if n_inputs <= 1 {
        return 0;
    }
    L_ADD * ceil_log2(n_inputs)
}

/// ⌈log2 n⌉ for n ≥ 1.
pub fn ceil_log2(n: u32) -> u32 {
    debug_assert!(n >= 1);
    32 - (n - 1).leading_zeros()
}

/// Number of adder-tree stages for `n` inputs: `⌊log2 n⌋` per the paper's
/// footnote 1 (`AdderTree(8)` is "a 3-stage pipeline of eight adders"... of
/// seven adders structurally; the paper counts stages, we count both).
pub fn adder_tree_stages(n_inputs: u32) -> u32 {
    if n_inputs <= 1 {
        return 0;
    }
    ceil_log2(n_inputs)
}

/// Number of 2-input adders in `AdderTree(n)` — always `n - 1`.
pub fn adder_tree_adders(n_inputs: u32) -> u32 {
    n_inputs.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(ceil_log2(16), 4);
        assert_eq!(ceil_log2(25), 5);
    }

    #[test]
    fn paper_adder_tree_latencies() {
        // AdderTree(8): 3 stages × L_ADD = 18; AdderTree(9): 4 × L_ADD = 24
        assert_eq!(adder_tree_latency(8), 3 * L_ADD);
        assert_eq!(adder_tree_latency(9), 4 * L_ADD);
        // 5×5 conv: AdderTree(16) takes 4·L_ADD; AdderTree(25) takes 5·L_ADD
        assert_eq!(adder_tree_latency(16), 4 * L_ADD);
        assert_eq!(adder_tree_latency(25), 5 * L_ADD);
    }

    #[test]
    fn paper_nlfilter_branch_latencies() {
        // §III-D: f^α = max(1) + mul(2) + sqrt(5) + add(6) + rsh(1) = 15
        assert_eq!(L_MAX + L_MUL + L_SQRT + L_ADD + L_SHIFT, 15);
        // f^β = max(1) + mul(2) + log2(5) + add(6) + lsh(1) = 15
        assert_eq!(L_MAX + L_MUL + L_LOG2 + L_ADD + L_SHIFT, 15);
        // f^δ = max(1) + mul(2) + exp2(6) = 9
        assert_eq!(L_MAX + L_MUL + L_EXP2, 9);
        // f^φ = max(f^β, f^δ) + cas(2) + div(7) = 24
        assert_eq!(15 + L_CAS + L_DIV, 24);
    }

    #[test]
    fn adder_count() {
        assert_eq!(adder_tree_adders(9), 8);
        assert_eq!(adder_tree_adders(25), 24);
        assert_eq!(adder_tree_adders(1), 0);
    }
}
