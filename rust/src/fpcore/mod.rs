//! Custom floating-point arithmetic — the paper's `float(m, e)` library.
//!
//! A format `float(m, e)` has 1 sign bit, an `m`-bit mantissa (fraction)
//! and an `e`-bit exponent with bias `2^(e-1) - 1`.  Conventions (mirrored
//! bit-for-bit by `python/compile/kernels/quantize.py`):
//!
//! * exponent field 0 encodes zero; subnormals flush to zero;
//! * the all-ones exponent is a normal exponent (no inf/NaN encodings —
//!   FPGA datapaths saturate); overflow saturates to the largest finite
//!   value `(2 - 2^-m) · 2^emax`;
//! * rounding is round-to-nearest, ties-to-even.
//!
//! Operators come in two numeric modes ([`ops::OpMode`]):
//!
//! * **Exact** — IEEE-double op, then rounded into the format.  This is the
//!   golden contract shared with the JAX layer (bit-exact for `m ≤ 50`).
//! * **Poly** — the paper's hardware datapaths: division via a 4-segment
//!   degree-3 reciprocal polynomial, square root via a 4-segment degree-2
//!   polynomial (footnotes 9/13), log2/exp2 likewise.  Used for the
//!   accuracy-vs-hardware ablation (bench `ablation`).

pub mod convert;
pub mod encode;
pub mod format;
pub mod latency;
pub mod ops;
pub mod poly;
pub mod quantize;

pub use convert::{convert, FmtConvert};
pub use format::{FloatFormat, FORMATS, FORMAT_KEYS};
pub use latency::Latency;
pub use ops::{OpKind, OpMode};
pub use quantize::quantize;
