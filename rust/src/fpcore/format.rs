//! The `float(m, e)` format descriptor and the paper's five widths.

use std::fmt;

/// A custom floating-point format with `mantissa` fraction bits and
/// `exponent` exponent bits (plus one sign bit).
///
/// The paper evaluates float16(10,5), float24(16,7), float32(23,8),
/// float48(39,8) and float64(53,10) — see [`FORMATS`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FloatFormat {
    /// Mantissa (fraction) bits, excluding the implicit leading one.
    pub mantissa: u32,
    /// Exponent field bits.
    pub exponent: u32,
}

impl FloatFormat {
    pub const fn new(mantissa: u32, exponent: u32) -> Self {
        Self { mantissa, exponent }
    }

    /// Exponent bias: `2^(e-1) - 1`.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exponent - 1)) - 1
    }

    /// Smallest normal (unbiased) exponent — field value 1.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest (unbiased) exponent — the all-ones field is *normal*.
    pub const fn emax(&self) -> i32 {
        (1 << self.exponent) - 1 - self.bias()
    }

    /// Total storage width in bits (sign + exponent + mantissa).
    pub const fn width(&self) -> u32 {
        1 + self.mantissa + self.exponent
    }

    /// Largest finite value: `(2 - 2^-m) · 2^emax`.
    /// Built directly as IEEE-754 bits (hot path: called per quantize).
    #[inline]
    pub fn max_value(&self) -> f64 {
        let exp_field = (self.emax() + 1023) as u64;
        let m = self.mantissa.min(52);
        let frac = ((1u64 << m) - 1) << (52 - m);
        f64::from_bits((exp_field << 52) | frac)
    }

    /// Smallest normal magnitude: `2^emin` (direct bit construction).
    #[inline]
    pub fn min_normal(&self) -> f64 {
        f64::from_bits(((self.emin() + 1023) as u64) << 52)
    }

    /// Short key, e.g. `m10e5`.
    pub fn name(&self) -> String {
        format!("m{}e{}", self.mantissa, self.exponent)
    }

    /// Machine epsilon of the format (ulp of 1.0): `2^-m`.
    pub fn ulp(&self) -> f64 {
        (-(self.mantissa as i32)).exp2_i()
    }
}

impl fmt::Display for FloatFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "float{}({},{})", self.width(), self.mantissa, self.exponent)
    }
}

/// Exact `2^n` for integer `n` (no rounding for any in-range exponent).
trait Exp2I {
    fn exp2_i(self) -> f64;
}

impl Exp2I for i32 {
    fn exp2_i(self) -> f64 {
        // f64::powi(2.0, n) is exact for 2^n; out-of-range saturates to
        // inf/0 which is what the callers want.
        2.0_f64.powi(self)
    }
}

/// The paper's five evaluated formats, in fig. 11 sweep order.
pub const FORMATS: [(&str, FloatFormat); 5] = [
    ("f16", FloatFormat::new(10, 5)),
    ("f24", FloatFormat::new(16, 7)),
    ("f32", FloatFormat::new(23, 8)),
    ("f48", FloatFormat::new(39, 8)),
    ("f64", FloatFormat::new(53, 10)),
];

/// Format keys in sweep order.
pub const FORMAT_KEYS: [&str; 5] = ["f16", "f24", "f32", "f48", "f64"];

/// Look a format up by key (`"f16"`) or by spec (`"m10e5"` / `"10,5"`).
pub fn lookup(key: &str) -> Option<FloatFormat> {
    if let Some((_, f)) = FORMATS.iter().find(|(k, _)| *k == key) {
        return Some(*f);
    }
    // "m10e5"
    if let Some(rest) = key.strip_prefix('m') {
        if let Some((m, e)) = rest.split_once('e') {
            if let (Ok(m), Ok(e)) = (m.parse(), e.parse()) {
                return Some(FloatFormat::new(m, e));
            }
        }
    }
    // "10,5"
    if let Some((m, e)) = key.split_once(',') {
        if let (Ok(m), Ok(e)) = (m.trim().parse(), e.trim().parse()) {
            return Some(FloatFormat::new(m, e));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_match_paper() {
        let widths: Vec<u32> = FORMATS.iter().map(|(_, f)| f.width()).collect();
        assert_eq!(widths, vec![16, 24, 32, 48, 64]);
    }

    #[test]
    fn f16_parameters() {
        let f = FloatFormat::new(10, 5);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.emin(), -14);
        assert_eq!(f.emax(), 16);
        assert_eq!(f.max_value(), (2.0 - 2.0_f64.powi(-10)) * 2.0_f64.powi(16));
        assert_eq!(f.min_normal(), 2.0_f64.powi(-14));
    }

    #[test]
    fn f64_parameters() {
        let f = FloatFormat::new(53, 10);
        assert_eq!(f.bias(), 511);
        assert_eq!(f.emax(), 512);
        assert_eq!(f.width(), 64);
    }

    #[test]
    fn lookup_variants() {
        assert_eq!(lookup("f16"), Some(FloatFormat::new(10, 5)));
        assert_eq!(lookup("m16e7"), Some(FloatFormat::new(16, 7)));
        assert_eq!(lookup("23,8"), Some(FloatFormat::new(23, 8)));
        assert_eq!(lookup("bogus"), None);
    }

    #[test]
    fn display() {
        assert_eq!(FloatFormat::new(10, 5).to_string(), "float16(10,5)");
    }
}
