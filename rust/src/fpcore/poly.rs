//! Piecewise-polynomial approximations of the transcendental operators.
//!
//! The paper's hardware computes division with a "four segments, degree-3
//! polynomial approximation" (footnote 13) and square root with a "four
//! segments, degree-2 polynomial approximation" (footnote 9); log2/exp2 are
//! built the same way.  We reproduce those datapaths: range-reduce to a
//! small interval, pick the segment from the top mantissa bits, evaluate a
//! low-degree polynomial (Horner — one DSP per multiply in the RTL), and
//! re-apply the exponent.
//!
//! Coefficients are fitted at startup by least squares on a dense sample of
//! each segment (the paper's generator fits offline; numerically this is
//! the same thing).  Fits are cached per `(op, config)`.
//!
//! The `ablation` bench sweeps `segments`/`degree` to show the
//! precision-vs-DSP-cost tradeoff the paper's custom-FP argument rests on.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::quantize::{frexp, ldexp};

/// Configuration of a piecewise polynomial datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PolyConfig {
    /// Number of equal-width segments over the reduced domain.
    pub segments: u32,
    /// Polynomial degree per segment.
    pub degree: u32,
}

impl PolyConfig {
    pub const fn new(segments: u32, degree: u32) -> Self {
        Self { segments, degree }
    }
}

/// Paper defaults (footnotes 9/13).
pub const SQRT_CFG: PolyConfig = PolyConfig::new(4, 2);
pub const RECIP_CFG: PolyConfig = PolyConfig::new(4, 3);
pub const LOG2_CFG: PolyConfig = PolyConfig::new(4, 2);
pub const EXP2_CFG: PolyConfig = PolyConfig::new(4, 2);

/// A fitted piecewise polynomial over `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct PiecewisePoly {
    lo: f64,
    hi: f64,
    seg_width: f64,
    /// Per-segment coefficients, highest degree first (Horner order).
    coeffs: Vec<Vec<f64>>,
}

impl PiecewisePoly {
    /// Least-squares fit of `f` over `[lo, hi)` with `cfg.segments` equal
    /// segments of degree `cfg.degree`.
    pub fn fit(f: impl Fn(f64) -> f64, lo: f64, hi: f64, cfg: PolyConfig) -> Self {
        let n_seg = cfg.segments as usize;
        let deg = cfg.degree as usize;
        let seg_width = (hi - lo) / n_seg as f64;
        // Interpolate at Chebyshev nodes: near-minimax per segment, like the
        // offline fits a hardware generator ships in its coefficient ROMs.
        let mut coeffs = Vec::with_capacity(n_seg);
        for s in 0..n_seg {
            let s_lo = lo + s as f64 * seg_width;
            // deg+1 Chebyshev nodes mapped onto the segment, in the local
            // coordinate t = (x - s_lo) / seg_width ∈ [0,1] (what the RTL
            // feeds the DSPs: the low mantissa bits).
            let n_nodes = deg + 1;
            let ts: Vec<f64> = (0..n_nodes)
                .map(|i| {
                    let theta = std::f64::consts::PI * (2.0 * i as f64 + 1.0)
                        / (2.0 * n_nodes as f64);
                    0.5 + 0.5 * theta.cos()
                })
                .collect();
            let ys: Vec<f64> = ts.iter().map(|&t| f(s_lo + t * seg_width)).collect();
            coeffs.push(lstsq_poly(&ts, &ys, deg));
        }
        Self { lo, hi, seg_width, coeffs }
    }

    /// Evaluate at `x ∈ [lo, hi)` (clamped).
    pub fn eval(&self, x: f64) -> f64 {
        let xi = x.clamp(self.lo, self.hi - 1e-12);
        let mut s = ((xi - self.lo) / self.seg_width) as usize;
        if s >= self.coeffs.len() {
            s = self.coeffs.len() - 1;
        }
        let t = (xi - (self.lo + s as f64 * self.seg_width)) / self.seg_width;
        horner(&self.coeffs[s], t)
    }

    /// Maximum relative error of the fit against `f` on a dense grid.
    pub fn max_rel_error(&self, f: impl Fn(f64) -> f64, grid: usize) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..grid {
            let x = self.lo + (self.hi - self.lo) * (i as f64 + 0.5) / grid as f64;
            let exact = f(x);
            if exact != 0.0 {
                worst = worst.max(((self.eval(x) - exact) / exact).abs());
            }
        }
        worst
    }

    /// Multiplies (≈ DSP blocks) per evaluation: Horner of degree d uses d.
    pub fn mults_per_eval(&self) -> u32 {
        (self.coeffs[0].len() - 1) as u32
    }

    /// Per-segment coefficients (highest degree first) — consumed by the
    /// SystemVerilog library emitter's coefficient ROMs.
    pub fn segment_coeffs(&self) -> &[Vec<f64>] {
        &self.coeffs
    }
}

/// Horner evaluation, coefficients highest-degree-first.
fn horner(c: &[f64], t: f64) -> f64 {
    let mut acc = c[0];
    for &k in &c[1..] {
        acc = acc * t + k;
    }
    acc
}

/// Least-squares polynomial fit via normal equations + Gaussian elimination.
/// Returns coefficients highest-degree-first.  Degree ≤ 3 keeps the system
/// tiny and well-conditioned on the unit interval.
fn lstsq_poly(ts: &[f64], ys: &[f64], deg: usize) -> Vec<f64> {
    let n = deg + 1;
    let mut ata = vec![vec![0.0f64; n]; n];
    let mut atb = vec![0.0f64; n];
    for (&t, &y) in ts.iter().zip(ys) {
        // powers t^deg .. t^0 (highest first to match Horner order)
        let mut pows = vec![0.0; n];
        let mut p = 1.0;
        for i in (0..n).rev() {
            pows[i] = p;
            p *= t;
        }
        for i in 0..n {
            for j in 0..n {
                ata[i][j] += pows[i] * pows[j];
            }
            atb[i] += pows[i] * y;
        }
    }
    gauss_solve(&mut ata, &mut atb);
    atb
}

/// In-place Gaussian elimination with partial pivoting; solution left in `b`.
fn gauss_solve(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for j in col..n {
            a[col][j] /= d;
        }
        b[col] /= d;
        for row in 0..n {
            if row != col && a[row][col] != 0.0 {
                let factor = a[row][col];
                for j in col..n {
                    a[row][j] -= factor * a[col][j];
                }
                b[row] -= factor * b[col];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Range-reduced transcendental ops (paper datapaths).
// ---------------------------------------------------------------------------

/// Keyed cache of fitted polynomials.
fn cache() -> &'static Mutex<HashMap<(&'static str, PolyConfig), PiecewisePoly>> {
    static CACHE: OnceLock<Mutex<HashMap<(&'static str, PolyConfig), PiecewisePoly>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn fitted(name: &'static str, cfg: PolyConfig, build: impl Fn() -> PiecewisePoly) -> PiecewisePoly {
    let mut guard = cache().lock().unwrap();
    guard.entry((name, cfg)).or_insert_with(build).clone()
}

/// sqrt via the paper's datapath: reduce to `m ∈ [1, 4)` (absorbing the
/// exponent parity), evaluate the segment polynomial, re-apply `2^(e/2)`.
/// Negative input → NaN (hardware-undefined; kernels guard inputs).
pub fn poly_sqrt(x: f64, cfg: PolyConfig) -> f64 {
    if x < 0.0 {
        return f64::NAN;
    }
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let p = fitted("sqrt", cfg, || PiecewisePoly::fit(f64::sqrt, 1.0, 4.0, cfg));
    let (m2, e) = frexp(x); // x = m2·2^e, m2 ∈ [0.5,1)
    let mut m = m2 * 2.0; // ∈ [1,2)
    let mut eu = e - 1;
    if eu.rem_euclid(2) != 0 {
        m *= 2.0; // ∈ [2,4)
        eu -= 1;
    }
    ldexp(p.eval(m), eu / 2)
}

/// Reciprocal via the degree-3 segment polynomial on `[1, 2)`.
pub fn poly_recip(x: f64, cfg: PolyConfig) -> f64 {
    if x == 0.0 {
        return f64::INFINITY.copysign(x);
    }
    if !x.is_finite() {
        return if x.is_nan() { x } else { 0.0_f64.copysign(x) };
    }
    let p = fitted("recip", cfg, || PiecewisePoly::fit(|v| 1.0 / v, 1.0, 2.0, cfg));
    let (m2, e) = frexp(x.abs());
    let m = m2 * 2.0;
    let eu = e - 1;
    ldexp(p.eval(m), -eu).copysign(x)
}

/// Division `a / b = a · recip(b)` — the hardware multiplies by the
/// polynomial reciprocal (one extra DSP multiply).
pub fn poly_div(a: f64, b: f64, cfg: PolyConfig) -> f64 {
    a * poly_recip(b, cfg)
}

/// log2 via `e + poly(m)` with `m ∈ [1, 2)`.  Non-positive input → NaN/-inf.
pub fn poly_log2(x: f64, cfg: PolyConfig) -> f64 {
    if x < 0.0 {
        return f64::NAN;
    }
    if x == 0.0 {
        return f64::NEG_INFINITY;
    }
    if !x.is_finite() {
        return x;
    }
    let p = fitted("log2", cfg, || PiecewisePoly::fit(f64::log2, 1.0, 2.0, cfg));
    let (m2, e) = frexp(x);
    let m = m2 * 2.0;
    let eu = (e - 1) as f64;
    eu + p.eval(m)
}

/// exp2 via `2^n · poly(f)` with `x = n + f`, `f ∈ [0, 1)`.
pub fn poly_exp2(x: f64, cfg: PolyConfig) -> f64 {
    if !x.is_finite() {
        return if x.is_nan() { x } else if x > 0.0 { x } else { 0.0 };
    }
    let p = fitted("exp2", cfg, || PiecewisePoly::fit(f64::exp2, 0.0, 1.0, cfg));
    let n = x.floor();
    let f = x - n;
    ldexp(p.eval(f), n as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sqrt_paper_config_accuracy() {
        // 4-segment degree-2 fit: plenty for a 10-bit mantissa (2^-11 ≈ 5e-4)
        let cfg = SQRT_CFG;
        for x in [1.0, 2.0, 3.9, 0.5, 100.0, 1e-4, 6.25] {
            let got = poly_sqrt(x, cfg);
            let want = x.sqrt();
            assert!(
                ((got - want) / want).abs() < 1.5e-3,
                "sqrt({x}): {got} vs {want}"
            );
        }
        assert_eq!(poly_sqrt(0.0, cfg), 0.0);
        assert!(poly_sqrt(-1.0, cfg).is_nan());
    }

    #[test]
    fn sqrt_exact_at_powers_of_four() {
        // exponent handling: sqrt(4^k · m) = 2^k sqrt(m)
        let cfg = SQRT_CFG;
        let r1 = poly_sqrt(2.0, cfg);
        let r4 = poly_sqrt(8.0, cfg);
        assert!((r4 / r1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn recip_paper_config_accuracy() {
        let cfg = RECIP_CFG;
        for x in [1.0, 1.5, 1.999, 3.0, 0.1, 255.0, -2.0] {
            let got = poly_recip(x, cfg);
            let want = 1.0 / x;
            assert!(
                ((got - want) / want).abs() < 1e-4,
                "recip({x}): {got} vs {want}"
            );
        }
    }

    #[test]
    fn div_matches_recip_times() {
        let cfg = RECIP_CFG;
        let got = poly_div(10.0, 3.0, cfg);
        assert!((got - 10.0 / 3.0).abs() / (10.0 / 3.0) < 1e-4);
    }

    #[test]
    fn log2_accuracy() {
        let cfg = LOG2_CFG;
        for x in [1.0, 2.0, 10.0, 0.5, 255.0, 65025.0] {
            let got = poly_log2(x, cfg);
            let want = x.log2();
            // absolute error bound near log2(1)=0
            assert!((got - want).abs() < 1e-3, "log2({x}): {got} vs {want}");
        }
        assert_eq!(poly_log2(0.0, cfg), f64::NEG_INFINITY);
    }

    #[test]
    fn exp2_accuracy() {
        let cfg = EXP2_CFG;
        for x in [0.0, 0.5, 1.0, 3.3, -2.7, 7.98] {
            let got = poly_exp2(x, cfg);
            let want = x.exp2();
            assert!(
                ((got - want) / want).abs() < 2e-4,
                "exp2({x}): {got} vs {want}"
            );
        }
    }

    #[test]
    fn more_segments_reduce_error() {
        let coarse = PiecewisePoly::fit(f64::sqrt, 1.0, 4.0, PolyConfig::new(2, 2));
        let fine = PiecewisePoly::fit(f64::sqrt, 1.0, 4.0, PolyConfig::new(16, 2));
        let ec = coarse.max_rel_error(f64::sqrt, 4096);
        let ef = fine.max_rel_error(f64::sqrt, 4096);
        assert!(ef < ec / 10.0, "16 segments ({ef}) vs 2 ({ec})");
    }

    #[test]
    fn higher_degree_reduces_error() {
        let d1 = PiecewisePoly::fit(|v| 1.0 / v, 1.0, 2.0, PolyConfig::new(4, 1));
        let d3 = PiecewisePoly::fit(|v| 1.0 / v, 1.0, 2.0, PolyConfig::new(4, 3));
        let e1 = d1.max_rel_error(|v| 1.0 / v, 4096);
        let e3 = d3.max_rel_error(|v| 1.0 / v, 4096);
        assert!(e3 < e1 / 100.0);
    }

    #[test]
    fn mults_per_eval_is_degree() {
        let p = PiecewisePoly::fit(f64::sqrt, 1.0, 4.0, PolyConfig::new(4, 2));
        assert_eq!(p.mults_per_eval(), 2);
    }

    #[test]
    fn horner_matches_naive() {
        let c = [2.0, -3.0, 0.5]; // 2t² − 3t + 0.5
        let t = 0.37;
        assert!((horner(&c, t) - (2.0 * t * t - 3.0 * t + 0.5)).abs() < 1e-15);
    }

    #[test]
    fn lstsq_recovers_exact_polynomial() {
        let ts: Vec<f64> = (0..64).map(|i| i as f64 / 64.0).collect();
        let ys: Vec<f64> = ts.iter().map(|&t| 1.5 * t * t - 0.25 * t + 3.0).collect();
        let c = lstsq_poly(&ts, &ys, 2);
        assert!((c[0] - 1.5).abs() < 1e-9);
        assert!((c[1] + 0.25).abs() < 1e-9);
        assert!((c[2] - 3.0).abs() < 1e-9);
    }
}
