//! The pipelined operator set: numerics (`FpOps`) and metadata (`OpKind`).
//!
//! `FpOps` evaluates one operator in a given format and numeric mode,
//! always rounding the result into the format — exactly what one pipelined
//! RTL block does per clock.  `OpKind` is the shared vocabulary between the
//! DSL compiler, the cycle simulator and the resource model.

use super::format::FloatFormat;
use super::latency::{self, Latency};
use super::poly::{self, PolyConfig};
use super::quantize::quantize;

/// Numeric mode of the transcendental datapaths.
///
/// `Hash` because `(Netlist::fingerprint, OpMode)` keys the process-wide
/// compiled-kernel cache (`sim::kernel::KernelCache`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OpMode {
    /// IEEE-double op then round — the golden contract shared with JAX.
    #[default]
    Exact,
    /// The paper's piecewise-polynomial hardware datapaths (footnotes 9/13).
    Poly,
}

/// Operator vocabulary.  Shift amounts are static (exponent ±N wiring);
/// everything else is a 1- or 2-input pipelined block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    Add,
    Sub,
    Mul,
    /// Multiply by a static coefficient (still a DSP multiply).
    MulConst(f64),
    Div,
    Sqrt,
    Log2,
    Exp2,
    /// max(x, constant) — the eq. 2 guard (1-cycle compare/select).
    MaxConst(f64),
    Max,
    Min,
    /// Floating-point right shift: exponent − N (divide by 2^N).
    Rsh(u32),
    /// Floating-point left shift: exponent + N (multiply by 2^N).
    Lsh(u32),
    /// CMP_and_SWAP: 2 in, 2 out (min, max).
    Cas,
    /// Inter-format converter: re-round the (netlist-format) input into
    /// the payload format — the `fmt_converter` block between the stages
    /// of a mixed-precision cascade (see `fpcore::convert`).
    Convert(FloatFormat),
    /// Pure delay register (inserted by the scheduler for Δ matching).
    Reg,
}

impl OpKind {
    /// Pipeline latency in cycles (paper values — see `latency.rs`).
    pub fn latency(&self) -> Latency {
        match self {
            OpKind::Add | OpKind::Sub => latency::L_ADD,
            OpKind::Mul | OpKind::MulConst(_) => latency::L_MUL,
            OpKind::Div => latency::L_DIV,
            OpKind::Sqrt => latency::L_SQRT,
            OpKind::Log2 => latency::L_LOG2,
            OpKind::Exp2 => latency::L_EXP2,
            OpKind::MaxConst(_) | OpKind::Max | OpKind::Min => latency::L_MAX,
            OpKind::Rsh(_) | OpKind::Lsh(_) => latency::L_SHIFT,
            OpKind::Cas => latency::L_CAS,
            OpKind::Convert(_) => latency::L_CVT,
            OpKind::Reg => latency::L_REG,
        }
    }

    /// Number of data inputs.
    pub fn arity(&self) -> usize {
        match self {
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Max
            | OpKind::Min | OpKind::Cas => 2,
            _ => 1,
        }
    }

    /// Number of outputs (CAS produces two).
    pub fn outputs(&self) -> usize {
        match self {
            OpKind::Cas => 2,
            _ => 1,
        }
    }

    /// Canonical lowercase name (DSL function name / SV module prefix).
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Add => "adder",
            OpKind::Sub => "sub",
            OpKind::Mul => "mult",
            OpKind::MulConst(_) => "mult_const",
            OpKind::Div => "div",
            OpKind::Sqrt => "sqrt",
            OpKind::Log2 => "log2",
            OpKind::Exp2 => "exp2",
            OpKind::MaxConst(_) => "max_const",
            OpKind::Max => "max",
            OpKind::Min => "min",
            OpKind::Rsh(_) => "fp_rsh",
            OpKind::Lsh(_) => "fp_lsh",
            OpKind::Cas => "cmp_and_swap",
            OpKind::Convert(_) => "fmt_convert",
            OpKind::Reg => "reg",
        }
    }
}

/// Operator evaluator for one `(format, mode)` pair.
#[derive(Debug, Clone, Copy)]
pub struct FpOps {
    pub fmt: FloatFormat,
    pub mode: OpMode,
    /// Polynomial configs (used in `OpMode::Poly`).
    pub sqrt_cfg: PolyConfig,
    pub recip_cfg: PolyConfig,
    pub log2_cfg: PolyConfig,
    pub exp2_cfg: PolyConfig,
}

impl FpOps {
    pub fn exact(fmt: FloatFormat) -> Self {
        Self::with_mode(fmt, OpMode::Exact)
    }

    pub fn with_mode(fmt: FloatFormat, mode: OpMode) -> Self {
        Self {
            fmt,
            mode,
            sqrt_cfg: poly::SQRT_CFG,
            recip_cfg: poly::RECIP_CFG,
            log2_cfg: poly::LOG2_CFG,
            exp2_cfg: poly::EXP2_CFG,
        }
    }

    #[inline]
    fn q(&self, x: f64) -> f64 {
        quantize(x, self.fmt)
    }

    #[inline]
    pub fn add(&self, a: f64, b: f64) -> f64 {
        self.q(a + b)
    }

    #[inline]
    pub fn sub(&self, a: f64, b: f64) -> f64 {
        self.q(a - b)
    }

    #[inline]
    pub fn mul(&self, a: f64, b: f64) -> f64 {
        self.q(a * b)
    }

    #[inline]
    pub fn div(&self, a: f64, b: f64) -> f64 {
        match self.mode {
            OpMode::Exact => self.q(a / b),
            OpMode::Poly => self.q(poly::poly_div(a, b, self.recip_cfg)),
        }
    }

    #[inline]
    pub fn sqrt(&self, a: f64) -> f64 {
        match self.mode {
            OpMode::Exact => self.q(a.sqrt()),
            OpMode::Poly => self.q(poly::poly_sqrt(a, self.sqrt_cfg)),
        }
    }

    #[inline]
    pub fn log2(&self, a: f64) -> f64 {
        match self.mode {
            OpMode::Exact => self.q(a.log2()),
            OpMode::Poly => self.q(poly::poly_log2(a, self.log2_cfg)),
        }
    }

    #[inline]
    pub fn exp2(&self, a: f64) -> f64 {
        match self.mode {
            OpMode::Exact => self.q(a.exp2()),
            OpMode::Poly => self.q(poly::poly_exp2(a, self.exp2_cfg)),
        }
    }

    /// max(a, c) — exact compare/select, never rounds (c must be a format
    /// value; the DSL quantizes literals at compile time).
    #[inline]
    pub fn max_const(&self, a: f64, c: f64) -> f64 {
        a.max(c)
    }

    #[inline]
    pub fn max(&self, a: f64, b: f64) -> f64 {
        a.max(b)
    }

    #[inline]
    pub fn min(&self, a: f64, b: f64) -> f64 {
        a.min(b)
    }

    /// Floating-point right shift: exponent decrement — `a / 2^n` (exact
    /// in doubles; format flush at the boundary via quantize).  The scale
    /// constant is built as bits (no powi loop on the hot path).
    #[inline]
    pub fn rsh(&self, a: f64, n: u32) -> f64 {
        let scale = f64::from_bits(((1023 - n) as u64) << 52); // 2^-n
        self.q(a * scale)
    }

    /// Floating-point left shift: exponent increment — `a · 2^n`.
    #[inline]
    pub fn lsh(&self, a: f64, n: u32) -> f64 {
        let scale = f64::from_bits(((1023 + n) as u64) << 52); // 2^n
        self.q(a * scale)
    }

    /// Inter-format conversion: re-round into `dst` — mode-independent
    /// (the destination grid alone defines rounding/saturation/flush;
    /// see `fpcore::convert` for the boundary semantics).
    #[inline]
    pub fn convert(&self, a: f64, dst: FloatFormat) -> f64 {
        quantize(a, dst)
    }

    /// CMP_and_SWAP — `(min, max)`; pure selection, exact.
    #[inline]
    pub fn cas(&self, a: f64, b: f64) -> (f64, f64) {
        if a > b {
            (b, a)
        } else {
            (a, b)
        }
    }

    /// Evaluate `op` on `ins`, returning up to two outputs.
    pub fn apply(&self, op: OpKind, ins: &[f64]) -> (f64, Option<f64>) {
        match op {
            OpKind::Add => (self.add(ins[0], ins[1]), None),
            OpKind::Sub => (self.sub(ins[0], ins[1]), None),
            OpKind::Mul => (self.mul(ins[0], ins[1]), None),
            OpKind::MulConst(c) => (self.mul(ins[0], c), None),
            OpKind::Div => (self.div(ins[0], ins[1]), None),
            OpKind::Sqrt => (self.sqrt(ins[0]), None),
            OpKind::Log2 => (self.log2(ins[0]), None),
            OpKind::Exp2 => (self.exp2(ins[0]), None),
            OpKind::MaxConst(c) => (self.max_const(ins[0], c), None),
            OpKind::Max => (self.max(ins[0], ins[1]), None),
            OpKind::Min => (self.min(ins[0], ins[1]), None),
            OpKind::Rsh(n) => (self.rsh(ins[0], n), None),
            OpKind::Lsh(n) => (self.lsh(ins[0], n), None),
            OpKind::Cas => {
                let (lo, hi) = self.cas(ins[0], ins[1]);
                (lo, Some(hi))
            }
            OpKind::Convert(dst) => (self.convert(ins[0], dst), None),
            OpKind::Reg => (ins[0], None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn add_rounds_into_format() {
        let ops = FpOps::exact(F16);
        // 1 + 2^-11 rounds back to 1 in float16(10,5)
        assert_eq!(ops.add(1.0, 2.0_f64.powi(-11)), 1.0);
        assert_eq!(ops.add(1.5, 0.25), 1.75);
    }

    #[test]
    fn shifts_are_exponent_moves() {
        let ops = FpOps::exact(F16);
        assert_eq!(ops.rsh(6.0, 1), 3.0);
        assert_eq!(ops.lsh(3.0, 3), 24.0);
        // shifting below the format range flushes
        assert_eq!(ops.rsh(F16.min_normal(), 1), 0.0);
        // shifting above saturates
        assert_eq!(ops.lsh(F16.max_value(), 1), F16.max_value());
    }

    #[test]
    fn cas_orders_pairs() {
        let ops = FpOps::exact(F16);
        assert_eq!(ops.cas(3.0, 1.0), (1.0, 3.0));
        assert_eq!(ops.cas(1.0, 3.0), (1.0, 3.0));
        assert_eq!(ops.cas(2.0, 2.0), (2.0, 2.0));
    }

    #[test]
    fn poly_mode_close_to_exact() {
        let ex = FpOps::exact(F16);
        let po = FpOps::with_mode(F16, OpMode::Poly);
        for x in [2.0, 10.0, 100.0, 0.5] {
            // within one f16 ulp: poly error < 2^-11 relative
            let a = ex.sqrt(x);
            let b = po.sqrt(x);
            assert!((a - b).abs() <= a.abs() * 2.0_f64.powi(-9), "sqrt({x})");
        }
    }

    #[test]
    fn apply_matches_direct() {
        let ops = FpOps::exact(F16);
        assert_eq!(ops.apply(OpKind::Add, &[1.0, 2.0]).0, 3.0);
        assert_eq!(ops.apply(OpKind::Cas, &[5.0, 2.0]), (2.0, Some(5.0)));
        assert_eq!(ops.apply(OpKind::MulConst(0.5), &[4.0]).0, 2.0);
        assert_eq!(ops.apply(OpKind::Reg, &[7.0]).0, 7.0);
    }

    #[test]
    fn convert_rounds_into_the_destination_not_the_netlist_format() {
        // an f24 engine converting into f16 must land on the f16 grid
        let f24 = FloatFormat::new(16, 7);
        let ops = FpOps::with_mode(f24, OpMode::Poly); // mode-independent
        let x = 0.1;
        assert_eq!(ops.convert(x, F16), quantize(x, F16));
        assert_eq!(ops.apply(OpKind::Convert(F16), &[x]).0, quantize(x, F16));
        // widening through apply is exact on format values
        let q = quantize(0.1, F16);
        assert_eq!(ops.apply(OpKind::Convert(f24), &[q]).0, q);
    }

    #[test]
    fn latencies_match_paper() {
        assert_eq!(OpKind::Add.latency(), 6);
        assert_eq!(OpKind::Mul.latency(), 2);
        assert_eq!(OpKind::Div.latency(), 7);
        assert_eq!(OpKind::Sqrt.latency(), 5);
        assert_eq!(OpKind::Log2.latency(), 5);
        assert_eq!(OpKind::Exp2.latency(), 6);
        assert_eq!(OpKind::Cas.latency(), 2);
        assert_eq!(OpKind::Rsh(1).latency(), 1);
        assert_eq!(OpKind::MaxConst(1.0).latency(), 1);
        assert_eq!(OpKind::Convert(F16).latency(), 2);
    }

    #[test]
    fn arity_and_outputs() {
        assert_eq!(OpKind::Cas.arity(), 2);
        assert_eq!(OpKind::Cas.outputs(), 2);
        assert_eq!(OpKind::Sqrt.arity(), 1);
        assert_eq!(OpKind::Add.outputs(), 1);
    }
}
