//! Round an IEEE double into a `float(m, e)` value.
//!
//! Mirrors `python/compile/kernels/quantize.py` exactly: both sides compute
//! in doubles with the same frexp/ldexp/rint sequence, so results agree
//! bit-for-bit for mantissa widths ≤ 50 (checked by the PJRT-vs-sim
//! integration tests).

use super::format::FloatFormat;

/// Decompose `x` (finite, non-zero) as `mant · 2^exp` with `mant ∈ [0.5, 1)`.
pub fn frexp(x: f64) -> (f64, i32) {
    debug_assert!(x.is_finite());
    if x == 0.0 {
        return (0.0, 0);
    }
    let bits = x.to_bits();
    let exp_field = ((bits >> 52) & 0x7ff) as i32;
    if exp_field == 0 {
        // subnormal: scale into the normal range first
        let (m, e) = frexp(x * 2.0_f64.powi(64));
        return (m, e - 64);
    }
    let e = exp_field - 1022; // frexp convention: mant in [0.5, 1)
    let mant = f64::from_bits((bits & !(0x7ffu64 << 52)) | (1022u64 << 52));
    (mant, e)
}

/// Exact `x · 2^n` with stepwise scaling to avoid spurious intermediate
/// overflow/underflow.  Exact whenever every intermediate is a normal
/// double, which holds for every custom-format range we quantize into.
pub fn ldexp(mut x: f64, mut n: i32) -> f64 {
    const STEP: i32 = 600;
    while n > STEP {
        x *= 2.0_f64.powi(STEP);
        n -= STEP;
    }
    while n < -STEP {
        x *= 2.0_f64.powi(-STEP);
        n += STEP;
    }
    x * 2.0_f64.powi(n)
}

/// Round `x` to the nearest `float(m, e)` value (ties to even), flushing
/// subnormals to zero and saturating overflow to the largest finite value.
/// NaN propagates (hardware never produces it: kernels guard with max(·,1)).
///
/// Hot path (§Perf): round-to-nearest-even at mantissa bit `m` is done
/// directly on the IEEE-754 bit pattern — `bits + (half − 1 + lsb)` then
/// truncate — which also handles the mantissa-overflow exponent carry.
/// Equivalent to [`quantize_ref`] for every normal double (differential
/// test below); subnormal inputs take the reference path (they always
/// flush for the formats in use, but exactness is kept anyway).
#[inline]
pub fn quantize(x: f64, fmt: FloatFormat) -> f64 {
    let m = fmt.mantissa;
    if m > 50 {
        return quantize_ref(x, fmt);
    }
    let bits = x.to_bits();
    let sign = bits & (1u64 << 63);
    let abs = bits & !(1u64 << 63);
    const EXP_MASK: u64 = 0x7ff0_0000_0000_0000;
    if abs >= EXP_MASK {
        // inf (saturate) or NaN (propagate) — and subnormals below
        return quantize_ref(x, fmt);
    }
    if abs < (1u64 << 52) {
        // zero or subnormal double: reference path (always flushes here)
        return quantize_ref(x, fmt);
    }
    // round the 52-bit fraction to m bits, ties to even, carrying into the
    // exponent when the mantissa overflows
    let shift = 52 - m;
    let lsb = (abs >> shift) & 1;
    let half_minus_1 = (1u64 << (shift - 1)) - 1;
    let r = (abs + half_minus_1 + lsb) & !((1u64 << shift) - 1);
    let q = f64::from_bits(r);
    // flush / saturate at the format boundary
    let q = if q < fmt.min_normal() {
        0.0
    } else if q > fmt.max_value() {
        fmt.max_value()
    } else {
        q
    };
    f64::from_bits(q.to_bits() | sign)
}

/// Reference implementation: the frexp/ldexp/rint sequence mirrored by
/// `python/compile/kernels/quantize.py` (kept as the differential oracle
/// and for the slow paths).
pub fn quantize_ref(x: f64, fmt: FloatFormat) -> f64 {
    if x.is_nan() {
        return x;
    }
    let s = if x.is_sign_negative() { -1.0 } else { 1.0 };
    let a = x.abs();
    let m = fmt.mantissa as i32;

    let mut q = if a == 0.0 {
        0.0
    } else if m <= 50 {
        if a.is_infinite() {
            f64::INFINITY
        } else {
            let (_, exp) = frexp(a);
            let e_unb = exp - 1; // a = (2·mant) · 2^e_unb, 2·mant ∈ [1, 2)
            let scaled = ldexp(a, m - e_unb);
            ldexp(scaled.round_ties_even(), e_unb - m)
        }
    } else {
        // m ≥ 52: a double cannot be narrowed further; clamp only.
        a
    };

    // Flush subnormals; saturate overflow.
    if q < fmt.min_normal() {
        q = 0.0;
    }
    if q > fmt.max_value() {
        q = fmt.max_value();
    }
    s * q
}

/// True iff `x` is exactly representable in `fmt`.
pub fn is_representable(x: f64, fmt: FloatFormat) -> bool {
    quantize(x, fmt) == x || (x.is_nan() && quantize(x, fmt).is_nan())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::format::FORMATS;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn frexp_basics() {
        assert_eq!(frexp(1.0), (0.5, 1));
        assert_eq!(frexp(0.75), (0.75, 0));
        assert_eq!(frexp(8.0), (0.5, 4));
        let (m, e) = frexp(5e-324); // smallest subnormal
        assert_eq!(ldexp(m, e), 5e-324);
        assert!((0.5..1.0).contains(&m));
    }

    #[test]
    fn ldexp_exactness() {
        assert_eq!(ldexp(1.5, 10), 1536.0);
        assert_eq!(ldexp(1.0, -14), 2.0_f64.powi(-14));
        assert_eq!(ldexp(1.0, 1030), f64::INFINITY);
    }

    #[test]
    fn identity_values() {
        for (_, f) in FORMATS {
            for v in [0.0, 1.0, -1.0, 2.0, 1.5, 0.5, -0.25] {
                assert_eq!(quantize(v, f), v, "{v} in {f}");
            }
        }
    }

    #[test]
    fn rounding_ties_to_even() {
        // halfway between 1 and 1+2^-10 -> even -> 1
        assert_eq!(quantize(1.0 + 2.0_f64.powi(-11), F16), 1.0);
        // halfway between 1+2^-10 and 1+2^-9 -> even -> 1+2^-9
        assert_eq!(
            quantize(1.0 + 3.0 * 2.0_f64.powi(-11), F16),
            1.0 + 2.0_f64.powi(-9)
        );
        // just above halfway rounds up
        assert_eq!(
            quantize(1.0 + 2.0_f64.powi(-11) + 2.0_f64.powi(-30), F16),
            1.0 + 2.0_f64.powi(-10)
        );
    }

    #[test]
    fn saturation_and_flush() {
        assert_eq!(quantize(1e30, F16), F16.max_value());
        assert_eq!(quantize(-1e30, F16), -F16.max_value());
        assert_eq!(quantize(2.0_f64.powi(-20), F16), 0.0);
        assert_eq!(quantize(f64::INFINITY, F16), F16.max_value());
        assert_eq!(quantize(f64::NEG_INFINITY, F16), -F16.max_value());
    }

    #[test]
    fn mantissa_carry_rounds_up_exponent() {
        assert_eq!(quantize(2.0 - 2.0_f64.powi(-12), F16), 2.0);
    }

    #[test]
    fn nan_propagates() {
        assert!(quantize(f64::NAN, F16).is_nan());
    }

    #[test]
    fn idempotent() {
        for v in [0.1, 3.14159, 255.0, 1e-4, 7.5, 1e4, -0.3] {
            let q = quantize(v, F16);
            assert_eq!(quantize(q, F16), q);
        }
    }

    #[test]
    fn m53_is_clamp_only() {
        let f = FloatFormat::new(53, 10);
        let x = 1.0 + 2.0_f64.powi(-52);
        assert_eq!(quantize(x, f), x);
    }

    #[test]
    fn exhaustive_f16_fixed_points() {
        // every encodable float16(10,5) quantizes to itself
        let f = F16;
        for e_field in 1..(1 << f.exponent) {
            let e = e_field - f.bias();
            for m_field in (0..(1u64 << f.mantissa)).step_by(37) {
                let v = (1.0 + m_field as f64 * 2.0_f64.powi(-(f.mantissa as i32)))
                    * ldexp(1.0, e);
                assert_eq!(quantize(v, f), v);
                assert_eq!(quantize(-v, f), -v);
            }
        }
    }

    #[test]
    fn fast_path_matches_reference_differentially() {
        use crate::util::rng::Rng;
        for (_, fmt) in FORMATS {
            let mut rng = Rng::new(0xABCD + fmt.mantissa as u64);
            for _ in 0..20_000 {
                let x = rng.wide_float(fmt.emin() - 4, fmt.emax() + 4);
                let fast = quantize(x, fmt);
                let slow = quantize_ref(x, fmt);
                assert!(
                    fast == slow || (fast.is_nan() && slow.is_nan()),
                    "{fmt}: {x} -> fast {fast} vs ref {slow}"
                );
            }
            // edge values
            for x in [
                0.0,
                -0.0,
                f64::INFINITY,
                f64::NEG_INFINITY,
                f64::NAN,
                f64::MIN_POSITIVE / 2.0,
                fmt.max_value(),
                fmt.max_value() * 1.0000001,
                fmt.min_normal(),
                fmt.min_normal() * 0.9999999,
            ] {
                let fast = quantize(x, fmt);
                let slow = quantize_ref(x, fmt);
                assert!(
                    fast == slow || (fast.is_nan() && slow.is_nan()),
                    "{fmt}: edge {x} -> fast {fast} vs ref {slow}"
                );
            }
        }
    }

    #[test]
    fn fast_path_tie_cases() {
        // exact ties around 1.0 in f16: must round to even
        assert_eq!(quantize(1.0 + 2.0_f64.powi(-11), F16), 1.0);
        assert_eq!(
            quantize(1.0 + 3.0 * 2.0_f64.powi(-11), F16),
            1.0 + 2.0_f64.powi(-9)
        );
        // mantissa all-ones + tie: carries into the exponent
        let just_below_2 = 2.0 - 2.0_f64.powi(-11); // tie between 2-2^-10 and 2
        assert_eq!(quantize(just_below_2, F16), 2.0);
    }

    /// Differential (bit-hack vs reference) at the mantissa-overflow
    /// exponent carry: an all-ones mantissa that rounds up must carry into
    /// the exponent — at every binade, including the step into saturation.
    #[test]
    fn boundary_mantissa_carry_differential() {
        for (_, fmt) in FORMATS {
            if fmt.mantissa > 50 {
                continue;
            }
            let m = fmt.mantissa as i32;
            for e in [fmt.emin(), 0, 7, fmt.emax() - 1, fmt.emax()] {
                // largest format value in binade e, then nudge toward the
                // next binade: tie (carries, ties-to-even), just below
                // (rounds down), just above (carries)
                let top = (2.0 - 2.0_f64.powi(-m)) * ldexp(1.0, e);
                let half_ulp = ldexp(1.0, e - m - 1);
                for x in [top + half_ulp, top + half_ulp * 0.999, top + half_ulp * 1.001] {
                    let fast = quantize(x, fmt);
                    let slow = quantize_ref(x, fmt);
                    assert_eq!(fast, slow, "{fmt}: carry case {x}");
                    assert_eq!(quantize(-x, fmt), -slow, "{fmt}: carry case -{x}");
                }
                // the tie itself must land exactly on the next binade —
                // or saturate at the top one
                let want = if e == fmt.emax() { fmt.max_value() } else { ldexp(1.0, e + 1) };
                assert_eq!(quantize(top + half_ulp, fmt), want, "{fmt} e={e}");
            }
        }
    }

    /// Differential around the subnormal flush-to-zero boundary: values
    /// straddling min_normal, values that round *up to* min_normal, and
    /// the deep-subnormal range.
    #[test]
    fn boundary_subnormal_flush_differential() {
        for (_, fmt) in FORMATS {
            if fmt.mantissa > 50 {
                continue;
            }
            let mn = fmt.min_normal();
            for x in [
                mn,
                mn * (1.0 + 1e-14),
                mn * (1.0 - 1e-14), // rounds back up to mn: kept
                mn * 0.75,          // rounds to mn/2 or mn: boundary
                mn * 0.5,
                mn * 0.5 * (1.0 - 1e-14),
                mn * 1e-3,
                5e-324, // smallest subnormal double
            ] {
                let fast = quantize(x, fmt);
                let slow = quantize_ref(x, fmt);
                assert_eq!(fast, slow, "{fmt}: flush case {x}");
                assert_eq!(quantize(-x, fmt), -slow, "{fmt}: flush case -{x}");
                assert!(fast == 0.0 || fast.abs() >= mn, "{fmt}: {x} -> {fast} is subnormal");
            }
            // exactly representable at the bottom stays put
            assert_eq!(quantize(mn, fmt), mn);
        }
    }

    /// Differential at saturation: everything from just below max-finite
    /// through infinity clamps to max-finite with the input's sign.
    #[test]
    fn boundary_saturation_differential() {
        for (_, fmt) in FORMATS {
            if fmt.mantissa > 50 {
                continue;
            }
            let max = fmt.max_value();
            for x in [
                max,
                max * (1.0 - 1e-14), // rounds back up to max
                max * (1.0 + 1e-14), // above: saturates
                max * 2.0,
                max * 1e6,
                f64::MAX,
                f64::INFINITY,
            ] {
                let fast = quantize(x, fmt);
                let slow = quantize_ref(x, fmt);
                assert_eq!(fast, slow, "{fmt}: saturation case {x}");
                assert_eq!(fast, max, "{fmt}: {x} must saturate");
                assert_eq!(quantize(-x, fmt), -max, "{fmt}: -{x} must saturate");
            }
        }
    }

    /// The `m > 50` fallback threshold: m=50 is the last bit-hack width,
    /// m=51..=53 take the clamp-only reference path.
    #[test]
    fn boundary_m50_fallback_threshold() {
        let tie = 1.0 + 2.0_f64.powi(-52);
        // m=50: fast path still rounds (ties-to-even -> drops the bit)
        let f50 = FloatFormat::new(50, 10);
        assert_eq!(quantize(tie, f50), 1.0);
        assert_eq!(quantize(tie, f50), quantize_ref(tie, f50));
        // m=51 and up: clamp-only — the double passes through
        for m in [51u32, 52, 53] {
            let f = FloatFormat::new(m, 10);
            assert_eq!(quantize(tie, f), tie, "m={m}");
            assert_eq!(quantize(tie, f), quantize_ref(tie, f), "m={m}");
        }
        // and a random differential sweep right at the threshold
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x50FA11);
        for _ in 0..5_000 {
            let x = rng.wide_float(-30, 30);
            assert_eq!(quantize(x, f50), quantize_ref(x, f50), "{x}");
        }
    }

    #[test]
    fn matches_python_reference_vectors() {
        // Spot values cross-checked against python quantize_py (same algo).
        assert_eq!(quantize(0.0313, F16), 0.03131103515625);
        assert_eq!(quantize(255.0, F16), 255.0);
        assert_eq!(quantize(0.1, F16), 0.0999755859375);
        assert_eq!(quantize(3.14159265, F16), 3.140625);
    }
}
