//! Bit-level encode/decode of `float(m, e)` values.
//!
//! Used by the DSL code generator to emit kernel constants as hex literals
//! (the paper's §V example: `K[1][1] = 6.75` → `16'h46c0` in float16(10,5))
//! and by the fixed-point/HLS comparison paths.

use super::format::FloatFormat;
use super::quantize::{frexp, ldexp, quantize};

/// Encode a double into the `fmt` bit pattern `(s, exp_field, mantissa)`
/// packed MSB-first into a u64.  The value is quantized first, so any
/// double is accepted.  Zero encodes as all-zero bits (sign preserved).
pub fn encode(x: f64, fmt: FloatFormat) -> u64 {
    let q = quantize(x, fmt);
    let sign = if q.is_sign_negative() { 1u64 } else { 0u64 };
    let a = q.abs();
    let (exp_field, man_field) = if a == 0.0 || q.is_nan() {
        (0u64, 0u64)
    } else {
        let (_, exp) = frexp(a);
        let e_unb = exp - 1; // a = mant · 2^e_unb, mant ∈ [1, 2)
        let mant = ldexp(a, -e_unb); // ∈ [1, 2)
        let frac = mant - 1.0;
        let man_bits = if fmt.mantissa <= 52 {
            // exact: frac has at most `mantissa` significant bits post-quantize
            (frac * 2.0_f64.powi(fmt.mantissa.min(52) as i32)).round() as u64
                * (1u64 << fmt.mantissa.saturating_sub(52).min(12))
        } else {
            (frac * 2.0_f64.powi(52)).round() as u64
        };
        let e_field = (e_unb + fmt.bias()) as u64;
        (e_field, man_bits)
    };
    (sign << (fmt.width() - 1)) | (exp_field << fmt.mantissa) | man_field
}

/// Decode a `fmt` bit pattern back to a double.  Exponent field 0 is zero
/// (subnormals are not encoded); all other fields are normal values.
pub fn decode(bits: u64, fmt: FloatFormat) -> f64 {
    let sign = if (bits >> (fmt.width() - 1)) & 1 == 1 { -1.0 } else { 1.0 };
    let exp_field = (bits >> fmt.mantissa) & ((1u64 << fmt.exponent) - 1);
    let man_field = bits & ((1u64 << fmt.mantissa.min(63)) - 1);
    if exp_field == 0 {
        return 0.0 * sign;
    }
    let e_unb = exp_field as i32 - fmt.bias();
    let mant = 1.0 + man_field as f64 * 2.0_f64.powi(-(fmt.mantissa.min(52) as i32));
    sign * ldexp(mant, e_unb)
}

/// Format a value as the SystemVerilog hex literal the DSL emits,
/// e.g. `16'h46c0`.
pub fn to_sv_literal(x: f64, fmt: FloatFormat) -> String {
    let w = fmt.width();
    let hex_digits = w.div_ceil(4) as usize;
    format!("{}'h{:0width$x}", w, encode(x, fmt), width = hex_digits)
}

#[cfg(test)]
mod tests {
    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn paper_example_6_75() {
        // §V: K[1][1] = 6.75 = 1.6875 · 2^2 → s=0, exp=17, m=704 → 0x46c0
        assert_eq!(encode(6.75, F16), 0x46c0);
        assert_eq!(to_sv_literal(6.75, F16), "16'h46c0");
        assert_eq!(decode(0x46c0, F16), 6.75);
    }

    #[test]
    fn round_trip_f16() {
        for v in [1.0, -1.0, 0.5, 255.0, 0.03131103515625, 1.5, -6.75] {
            let q = quantize(v, F16);
            assert_eq!(decode(encode(q, F16), F16), q, "{v}");
        }
    }

    #[test]
    fn zero_encodes_all_zero_exp() {
        assert_eq!(encode(0.0, F16) & 0x7fff, 0);
        assert_eq!(decode(0, F16), 0.0);
    }

    #[test]
    fn sign_bit() {
        let p = encode(1.0, F16);
        let n = encode(-1.0, F16);
        assert_eq!(n, p | 0x8000);
    }

    #[test]
    fn round_trip_f32_format() {
        let f = FloatFormat::new(23, 8);
        for v in [3.14159265_f64, 1e-3, 1e6, -42.0] {
            let q = quantize(v, f);
            assert_eq!(decode(encode(q, f), f), q);
        }
    }

    #[test]
    fn sv_literal_width() {
        let f24 = FloatFormat::new(16, 7);
        let lit = to_sv_literal(1.0, f24);
        assert!(lit.starts_with("24'h"));
        assert_eq!(lit.len(), 4 + 6); // 24'h + 6 hex digits
    }
}
