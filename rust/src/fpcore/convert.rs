//! Inter-format conversion — the `fmt_converter` block between the
//! stages of a mixed-precision cascade.
//!
//! The paper's premise is that each operator picks the cheapest format
//! that still meets its accuracy target; that only pays off in a chain
//! when a wide-format stage can feed a narrow-format stage (per-layer
//! precision tuning in the style of FPGA Caffe / Solovyev et al.).  The
//! boundary needs *defined* semantics, which this module pins down:
//!
//! * a converter takes a `src`-format value and produces the nearest
//!   `dst`-format value — exactly [`quantize`] into `dst`, so the whole
//!   library shares one rounding contract:
//!   round-to-nearest ties-to-even, subnormals of the destination flush
//!   to zero, overflow saturates to the destination's largest finite
//!   value (sign preserved);
//! * **widening** (`dst` ⊇ `src`: at least as many mantissa bits and a
//!   covering exponent range) is exact — the round trip
//!   `src → dst → src` is the identity ([`FmtConvert::is_lossless`]);
//! * **narrowing** rounds, and is idempotent: converting an
//!   already-converted value again is a no-op.
//!
//! In hardware the block is an exponent re-bias plus the same RNE
//! round/pack tail every arithmetic operator ends with —
//! [`latency::L_CVT`] = 2 cycles, priced by `resources::op_cost` via
//! [`crate::fpcore::OpKind::Convert`].

use std::fmt;

use super::format::FloatFormat;
use super::latency::{self, Latency};
use super::quantize::quantize;

/// Convert a `src`-format value to the nearest `dst`-format value.
///
/// `src` does not influence the result (the destination grid alone
/// determines rounding/saturation/flush); it is kept in the signature
/// because the *hardware* block is parameterized by both geometries and
/// callers should state which boundary they are converting across.
#[inline]
pub fn convert(x: f64, _src: FloatFormat, dst: FloatFormat) -> f64 {
    quantize(x, dst)
}

/// One inter-stage converter: `src → dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FmtConvert {
    pub src: FloatFormat,
    pub dst: FloatFormat,
}

impl FmtConvert {
    pub const fn new(src: FloatFormat, dst: FloatFormat) -> Self {
        Self { src, dst }
    }

    /// Same format on both sides — the boundary is a plain wire.
    pub fn is_identity(&self) -> bool {
        self.src == self.dst
    }

    /// True iff every `src` value is exactly representable in `dst`
    /// (pure widening): enough mantissa bits and a covering exponent
    /// range.  Subnormals never occur (the library flushes them), so
    /// normal-range coverage is the whole condition.
    pub fn is_lossless(&self) -> bool {
        self.dst.mantissa >= self.src.mantissa
            && self.dst.emax() >= self.src.emax()
            && self.dst.emin() <= self.src.emin()
    }

    /// Convert one value.
    #[inline]
    pub fn apply(&self, x: f64) -> f64 {
        quantize(x, self.dst)
    }

    /// Convert a row in place (the fused chain hands rows across stage
    /// boundaries — one contiguous pass, auto-vectorizable).
    #[inline]
    pub fn apply_row(&self, row: &mut [f64]) {
        for v in row {
            *v = quantize(*v, self.dst);
        }
    }

    /// Pipeline latency of the hardware block.
    pub const fn latency(&self) -> Latency {
        latency::L_CVT
    }
}

impl fmt::Display for FmtConvert {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} -> {}", self.src, self.dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::format::FORMATS;
    use crate::util::rng::Rng;

    const F16: FloatFormat = FloatFormat::new(10, 5);
    const F24: FloatFormat = FloatFormat::new(16, 7);
    const F14: FloatFormat = FloatFormat::new(7, 6);

    #[test]
    fn narrowing_is_exactly_quantize() {
        let c = FmtConvert::new(F24, F16);
        let mut rng = Rng::new(0xC0417);
        for _ in 0..2000 {
            let x = quantize(rng.wide_float(F24.emin(), F24.emax()), F24);
            assert_eq!(c.apply(x).to_bits(), quantize(x, F16).to_bits(), "{x}");
        }
    }

    #[test]
    fn widening_round_trip_is_identity() {
        // f16 ⊂ f24 ⊂ f32 ⊂ f64 (f48 covers f32's range with more bits)
        let wide = FmtConvert::new(F16, F24);
        let back = FmtConvert::new(F24, F16);
        assert!(wide.is_lossless());
        let mut rng = Rng::new(0x1D);
        for _ in 0..2000 {
            let x = quantize(rng.wide_float(F16.emin(), F16.emax()), F16);
            let y = wide.apply(x);
            assert_eq!(y.to_bits(), x.to_bits(), "widening must be exact: {x}");
            assert_eq!(back.apply(y).to_bits(), x.to_bits(), "round trip: {x}");
        }
    }

    #[test]
    fn narrowing_is_idempotent() {
        let c = FmtConvert::new(F24, F14);
        let mut rng = Rng::new(0x1DE);
        for _ in 0..2000 {
            let x = rng.wide_float(F24.emin() - 2, F24.emax() + 2);
            let y = c.apply(x);
            assert_eq!(c.apply(y).to_bits(), y.to_bits(), "{x}");
            // and the result is always a dst-format value
            assert_eq!(quantize(y, F14).to_bits(), y.to_bits(), "{x}");
        }
    }

    #[test]
    fn saturation_and_flush_at_the_dst_range() {
        // float14(7,6) has a much smaller range than float24(16,7)
        let c = FmtConvert::new(F24, FloatFormat::new(6, 3));
        let dst = c.dst;
        assert_eq!(c.apply(1e6), dst.max_value());
        assert_eq!(c.apply(-1e6), -dst.max_value());
        assert_eq!(c.apply(dst.min_normal() / 4.0), 0.0);
        assert_eq!(c.apply(0.0), 0.0);
    }

    #[test]
    fn lossless_matrix_over_the_paper_formats() {
        // paper sweep: f16 ⊆ f24 ⊆ f32 ⊆ f48 ⊆ f64 is lossless upward
        for i in 0..FORMATS.len() {
            for j in 0..FORMATS.len() {
                let c = FmtConvert::new(FORMATS[i].1, FORMATS[j].1);
                if j >= i {
                    assert!(c.is_lossless(), "{} -> {}", FORMATS[i].0, FORMATS[j].0);
                } else {
                    assert!(!c.is_lossless(), "{} -> {}", FORMATS[i].0, FORMATS[j].0);
                }
            }
        }
        // more mantissa but a *smaller* exponent range is not lossless
        assert!(!FmtConvert::new(F16, FloatFormat::new(20, 4)).is_lossless());
    }

    #[test]
    fn identity_boundary() {
        let c = FmtConvert::new(F16, F16);
        assert!(c.is_identity());
        assert!(c.is_lossless());
        // on format values the identity converter is a no-op
        for v in [0.0, 1.5, -255.0, 0.0999755859375] {
            let q = quantize(v, F16);
            assert_eq!(c.apply(q).to_bits(), q.to_bits());
        }
    }

    #[test]
    fn apply_row_matches_apply() {
        let c = FmtConvert::new(F24, F16);
        let mut rng = Rng::new(7);
        let mut row: Vec<f64> = (0..97).map(|_| rng.uniform(-300.0, 300.0)).collect();
        let want: Vec<f64> = row.iter().map(|&v| c.apply(v)).collect();
        c.apply_row(&mut row);
        assert_eq!(row, want);
    }

    #[test]
    fn latency_is_l_cvt() {
        assert_eq!(FmtConvert::new(F16, F24).latency(), latency::L_CVT);
        assert_eq!(latency::L_CVT, 2);
    }
}
