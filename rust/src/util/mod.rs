//! Small self-contained utilities (the offline environment has no
//! clap/serde/criterion/proptest — these stand in; DESIGN.md §Substitutions).

pub mod json;
pub mod rng;
