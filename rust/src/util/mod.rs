//! Small self-contained utilities (the offline environment has no
//! clap/serde/criterion/proptest — these stand in; DESIGN.md §Substitutions).

pub mod json;
pub mod rng;

/// Fixed lane width of the batched hot path: the window generator emits
/// lane-transposed tap buffers of `LANES` consecutive windows and the
/// batched netlist engine evaluates one tape step across all of them
/// before moving on (structure-of-arrays, SIMD/ILP friendly).  Shared
/// here so `video` and `sim` agree without depending on each other.
pub const LANES: usize = 16;

/// One lane-batch of values for a single wire/tap: the same signal
/// across [`LANES`] consecutive windows.
pub type Lane = [f64; LANES];
