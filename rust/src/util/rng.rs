//! Deterministic xorshift64* PRNG — the offline environment has no `rand`
//! crate; this is used for synthetic frames, property-style tests and
//! workload generation.  Deterministic seeding keeps every experiment
//! reproducible.

/// xorshift64* generator (Vigna 2016).  Not cryptographic; plenty for
/// workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Random f64 spanning many binades (for property tests): sign ·
    /// mantissa · 2^e with e uniform in [e_lo, e_hi].
    pub fn wide_float(&mut self, e_lo: i32, e_hi: i32) -> f64 {
        let m = 1.0 + self.next_f64();
        let e = e_lo + self.below((e_hi - e_lo + 1) as u64) as i32;
        let s = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        s * m * 2.0_f64.powi(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform(10.0, 20.0);
            assert!((10.0..20.0).contains(&v));
        }
    }

    #[test]
    fn roughly_uniform_mean() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..10_000).map(|_| r.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn wide_float_spans_binades() {
        let mut r = Rng::new(9);
        let mut small = false;
        let mut big = false;
        for _ in 0..1000 {
            let v = r.wide_float(-10, 10).abs();
            small |= v < 0.01;
            big |= v > 100.0;
        }
        assert!(small && big);
    }
}
