//! Minimal JSON parser/writer (the offline crate set has no serde_json).
//! Supports the subset we need: objects, arrays, strings, numbers, bools,
//! null — enough for artifacts/manifest.json and bench result files.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(txt.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let txt = r#"[{"file": "a.hlo.txt", "mantissa": 10, "format": null, "set": "golden"}]"#;
        let v = Json::parse(txt).unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(e.get("mantissa").unwrap().as_usize(), Some(10));
        assert_eq!(e.get("format"), Some(&Json::Null));
    }

    #[test]
    fn round_trip() {
        let txt = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null}"#;
        let v = Json::parse(txt).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }
}
