//! Video timing model: active/blanking geometry and pixel clocks.
//!
//! Reproduces the paper's §IV-A arithmetic: at 1080p the stream is
//! 2200 × 1125 total pixels (220 blanking columns + 45 blanking lines), so
//! a 148.5 MHz pixel clock yields exactly 60 FPS; running the smaller
//! timings at the same 148.5 MHz clock gives 120 FPS (720p) and
//! ≈ 353.57 FPS (480p) — footnote 15's `FPS = 60 · 148.5 / fᵢ`.

/// One video mode: active size plus total (with blanking) size and the
/// mode's native pixel clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VideoTiming {
    pub name: &'static str,
    pub h_active: u32,
    pub v_active: u32,
    pub h_total: u32,
    pub v_total: u32,
    /// Native pixel clock of the mode, Hz.
    pub native_clock_hz: f64,
}

/// The FPGA system clock the paper runs every filter at (1080p HDMI rate).
pub const FPGA_CLOCK_HZ: f64 = 148.5e6;

/// CEA-861 1920×1080p60: 2200×1125 total @ 148.5 MHz.
pub const T1080P: VideoTiming = VideoTiming {
    name: "1080p",
    h_active: 1920,
    v_active: 1080,
    h_total: 2200,
    v_total: 1125,
    native_clock_hz: 148.5e6,
};

/// CEA-861 1280×720p60: 1650×750 total @ 74.25 MHz.
pub const T720P: VideoTiming = VideoTiming {
    name: "720p",
    h_active: 1280,
    v_active: 720,
    h_total: 1650,
    v_total: 750,
    native_clock_hz: 74.25e6,
};

/// 640×480p60 (paper: fᵢ = 25.2 MHz): 800×525 total.
pub const T480P: VideoTiming = VideoTiming {
    name: "480p",
    h_active: 640,
    v_active: 480,
    h_total: 800,
    v_total: 525,
    native_clock_hz: 25.2e6,
};

/// The three Table-I resolutions in paper order.
pub const TIMINGS: [VideoTiming; 3] = [T480P, T720P, T1080P];

impl VideoTiming {
    /// Total pixels per frame including blanking.
    pub fn total_pixels(&self) -> u64 {
        self.h_total as u64 * self.v_total as u64
    }

    /// Active pixels per frame.
    pub fn active_pixels(&self) -> u64 {
        self.h_active as u64 * self.v_active as u64
    }

    /// Frames per second when streamed at `clock_hz`.
    pub fn fps_at(&self, clock_hz: f64) -> f64 {
        clock_hz / self.total_pixels() as f64
    }

    /// Native frame rate (≈ 60 FPS for all three modes).
    pub fn native_fps(&self) -> f64 {
        self.fps_at(self.native_clock_hz)
    }

    /// FPS at the paper's 148.5 MHz FPGA clock (Table I hardware rows).
    pub fn fpga_fps(&self) -> f64 {
        self.fps_at(FPGA_CLOCK_HZ)
    }

    /// Nanoseconds available per output pixel at the FPGA clock
    /// (§IV-A: "nearly 6.734 ns" at 148.5 MHz).
    pub fn ns_per_pixel(&self) -> f64 {
        1e9 / FPGA_CLOCK_HZ
    }

    /// Look a timing up by name ("480p" | "720p" | "1080p").
    pub fn by_name(name: &str) -> Option<VideoTiming> {
        TIMINGS.iter().copied().find(|t| t.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_rates_are_60fps() {
        for t in TIMINGS {
            let fps = t.native_fps();
            assert!((fps - 60.0).abs() < 0.1, "{}: {fps}", t.name);
        }
    }

    #[test]
    fn paper_fpga_rates() {
        // Table I hardware row: 60 / 120 / ≈353.57 FPS
        assert!((T1080P.fpga_fps() - 60.0).abs() < 1e-9);
        assert!((T720P.fpga_fps() - 120.0).abs() < 1e-9);
        assert!((T480P.fpga_fps() - 353.57).abs() < 0.01);
    }

    #[test]
    fn footnote15_formula() {
        // FPS = 60 · 148.5 / fᵢ
        for t in [T720P, T480P] {
            let formula = 60.0 * 148.5e6 / t.native_clock_hz;
            assert!((t.fpga_fps() - formula).abs() / formula < 2e-3, "{}", t.name);
        }
    }

    #[test]
    fn blanking_1080p_matches_paper() {
        // "2200 × 1125 pixels resulting from additional 220 blanking
        //  [columns] and 45 blanking [lines]"
        assert_eq!(T1080P.h_total - T1080P.h_active, 280); // CEA: 280 total H-blank
        assert_eq!(T1080P.v_total - T1080P.v_active, 45);
        assert_eq!(T1080P.total_pixels(), 2200 * 1125);
    }

    #[test]
    fn ns_per_pixel() {
        assert!((T1080P.ns_per_pixel() - 6.734).abs() < 0.01);
    }
}
