//! Streaming-video substrate: timing (blanking/pixel clocks), frames
//! (PGM I/O + synthetic patterns) and the line-buffer window generator of
//! §III-A.

pub mod frame;
pub mod timing;
pub mod window;

pub use frame::Frame;
pub use timing::{VideoTiming, FPGA_CLOCK_HZ, T1080P, T480P, T720P, TIMINGS};
pub use window::{map_windows, StageGeometry, WindowGenerator};
