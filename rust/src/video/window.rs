//! Streaming window generator (§III-A): forms an H×W pixel neighbourhood
//! from a raster-scan stream using H−1 line buffers, with replicate border
//! handling.
//!
//! Hardware shape (fig. 1 / fig. 2): the pixel stream is written into a
//! circular set of line buffers (dual-port BRAM in the FPGA — see
//! [`WindowGenerator::line_buffer_bits`]); H×W window registers shift
//! horizontally each cycle; border muxes replicate edge pixels so the
//! filter sees a full window at every active position.  The generator
//! emits exactly one window per active pixel (II = 1); the window centred
//! on pixel (y, x) is complete once pixel (y+p, x+p) has arrived, so the
//! structural latency is `p` lines + `p` pixels ([`WindowGenerator::window_latency_cycles`]).

use super::frame::Frame;

/// Streaming H×W window generator over a W-wide video line.
pub struct WindowGenerator {
    ksize: usize,
    width: usize,
    /// `ksize` line buffers used as a ring (the hardware needs only
    /// `ksize − 1` BRAM lines plus the live input line; we model the same
    /// capacity: `ksize − 1` buffered + current).
    lines: Vec<Vec<f64>>,
    /// Next row index to write (ring position).
    row: usize,
    /// Pixels received in the current line.
    col: usize,
    /// Total rows received.
    rows_in: usize,
}

impl WindowGenerator {
    /// `ksize` must be odd (3, 5, ...).
    pub fn new(ksize: usize, width: usize) -> Self {
        assert!(ksize % 2 == 1 && ksize >= 3, "odd window sizes only");
        assert!(width >= ksize, "line shorter than the window");
        Self {
            ksize,
            width,
            lines: vec![vec![0.0; width]; ksize],
            row: 0,
            col: 0,
            rows_in: 0,
        }
    }

    /// Line-buffer storage the FPGA needs: `(ksize−1) · width · bits`
    /// (§III-A: a kernel of height H requires H−1 line buffers).
    pub fn line_buffer_bits(&self, word_bits: u32) -> u64 {
        (self.ksize as u64 - 1) * self.width as u64 * word_bits as u64
    }

    /// Cycles between a pixel entering and its centred window emerging:
    /// `p` full lines + `p` pixels.
    pub fn window_latency_cycles(&self) -> u64 {
        let p = (self.ksize / 2) as u64;
        p * self.width as u64 + p
    }

    /// Border columns: per-element clamped reads.
    #[inline]
    fn emit_clamped(
        &self,
        row_ring: &[usize; 16],
        k: usize,
        p: usize,
        x: usize,
        w: usize,
        window: &mut [f64],
    ) {
        let mut idx = 0;
        for wy in 0..k {
            let line = &self.lines[row_ring[wy]];
            for wx in 0..k {
                let want_col = x as isize + wx as isize - p as isize;
                let cx = want_col.clamp(0, (w - 1) as isize) as usize;
                window[idx] = line[cx];
                idx += 1;
            }
        }
    }

    /// Stream a whole frame through the generator, invoking `sink(x, y,
    /// &window)` once per pixel in raster order.  `window` is the
    /// `ksize²` neighbourhood (raster order) centred on `(x, y)` with
    /// replicate borders — bit-identical to `jnp.pad(mode='edge')`.
    ///
    /// Internally this holds only `ksize` line buffers (never the whole
    /// frame), exactly like the hardware.
    pub fn process_frame(&mut self, frame: &Frame, mut sink: impl FnMut(usize, usize, &[f64])) {
        assert_eq!(frame.width, self.width, "frame width mismatch");
        let k = self.ksize;
        let p = k / 2;
        let h = frame.height;
        let w = self.width;
        let mut window = vec![0.0f64; k * k];

        // Reset per-frame streaming state.
        self.row = 0;
        self.col = 0;
        self.rows_in = 0;

        for ay in 0..h + p {
            // Row `ay` arrives (or, past the bottom, the last row is
            // replicated — the paper's border registers).
            let src_y = ay.min(h - 1);
            let dst = self.row;
            for x in 0..w {
                self.lines[dst][x] = frame.get(x, src_y);
            }
            self.row = (self.row + 1) % k;
            self.rows_in += 1;

            // Once `p` extra rows have arrived we can emit line `cy`.
            if ay < p {
                continue;
            }
            let cy = ay - p;
            // Resolve the ring position of each window row once per line
            // (replicate-clamped at the top/bottom borders) — hot path.
            let mut row_ring = [0usize; 16];
            for (wy, slot) in row_ring.iter_mut().take(k).enumerate() {
                let want_row = cy as isize + wy as isize - p as isize;
                let clamped = want_row.clamp(0, (h - 1) as isize) as usize;
                // `clamped` is within the last `k` rows received:
                // rows_in-1 is row `ay`, stored at ring position row-1.
                let age = ay - clamped; // 0 ..= k-1
                debug_assert!(age < k);
                *slot = (self.row + k - 1 - age) % k;
            }
            // Left border (clamped columns), interior (contiguous copies),
            // right border (clamped columns).
            for x in 0..p.min(w) {
                self.emit_clamped(&row_ring, k, p, x, w, &mut window);
                sink(x, cy, &window);
            }
            for x in p..w.saturating_sub(p) {
                let start = x - p;
                for wy in 0..k {
                    let line = &self.lines[row_ring[wy]];
                    window[wy * k..wy * k + k].copy_from_slice(&line[start..start + k]);
                }
                sink(x, cy, &window);
            }
            for x in w.saturating_sub(p).max(p)..w {
                self.emit_clamped(&row_ring, k, p, x, w, &mut window);
                sink(x, cy, &window);
            }
        }
    }
}

/// Convenience: apply `f(window) -> pixel` over a frame via the streaming
/// window generator.
pub fn map_windows(frame: &Frame, ksize: usize, mut f: impl FnMut(&[f64]) -> f64) -> Frame {
    let mut out = Frame::new(frame.width, frame.height);
    let mut gen = WindowGenerator::new(ksize, frame.width);
    gen.process_frame(frame, |x, y, w| {
        out.set(x, y, f(w));
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference window via whole-frame clamped indexing.
    fn ref_window(frame: &Frame, cx: usize, cy: usize, k: usize) -> Vec<f64> {
        let p = k as isize / 2;
        let mut out = Vec::with_capacity(k * k);
        for wy in -p..=p {
            for wx in -p..=p {
                out.push(frame.get_clamped(cx as isize + wx, cy as isize + wy));
            }
        }
        out
    }

    #[test]
    fn windows_match_reference_3x3() {
        let f = Frame::noise(13, 9, 42);
        let mut gen = WindowGenerator::new(3, 13);
        let mut count = 0;
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 3)[..], "at ({x},{y})");
            count += 1;
        });
        assert_eq!(count, 13 * 9);
    }

    #[test]
    fn windows_match_reference_5x5() {
        let f = Frame::noise(11, 8, 7);
        let mut gen = WindowGenerator::new(5, 11);
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 5)[..], "at ({x},{y})");
        });
    }

    #[test]
    fn raster_order_and_full_coverage() {
        let f = Frame::gradient(6, 5);
        let mut gen = WindowGenerator::new(3, 6);
        let mut seen = Vec::new();
        gen.process_frame(&f, |x, y, _| seen.push((x, y)));
        let want: Vec<(usize, usize)> =
            (0..5).flat_map(|y| (0..6).map(move |x| (x, y))).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn reusable_across_frames() {
        let f1 = Frame::noise(8, 6, 1);
        let f2 = Frame::noise(8, 6, 2);
        let mut gen = WindowGenerator::new(3, 8);
        let mut out1 = Vec::new();
        gen.process_frame(&f1, |_, _, w| out1.push(w[4]));
        let mut out2 = Vec::new();
        gen.process_frame(&f2, |_, _, w| out2.push(w[4]));
        assert_eq!(out1, f1.data);
        assert_eq!(out2, f2.data);
    }

    #[test]
    fn line_buffer_accounting() {
        let g3 = WindowGenerator::new(3, 1920);
        // 2 line buffers × 1920 × 16 bits
        assert_eq!(g3.line_buffer_bits(16), 2 * 1920 * 16);
        let g5 = WindowGenerator::new(5, 1920);
        assert_eq!(g5.line_buffer_bits(64), 4 * 1920 * 64);
    }

    #[test]
    fn latency_model() {
        let g = WindowGenerator::new(3, 1920);
        assert_eq!(g.window_latency_cycles(), 1920 + 1);
        let g5 = WindowGenerator::new(5, 640);
        assert_eq!(g5.window_latency_cycles(), 2 * 640 + 2);
    }

    #[test]
    fn map_windows_center_tap() {
        let f = Frame::test_card(10, 10);
        let out = map_windows(&f, 3, |w| w[4]);
        assert_eq!(out.data, f.data);
    }
}
