//! Streaming window generator (§III-A): forms an H×W pixel neighbourhood
//! from a raster-scan stream using H−1 line buffers, with replicate border
//! handling.
//!
//! Hardware shape (fig. 1 / fig. 2): the pixel stream is written into a
//! circular set of line buffers (dual-port BRAM in the FPGA — see
//! [`WindowGenerator::line_buffer_bits`]); H×W window registers shift
//! horizontally each cycle; border muxes replicate edge pixels so the
//! filter sees a full window at every active position.  The generator
//! emits exactly one window per active pixel (II = 1); the window centred
//! on pixel (y, x) is complete once pixel (y+p, x+p) has arrived, so the
//! structural latency is `p` lines + `p` pixels ([`WindowGenerator::window_latency_cycles`]).
//!
//! Two traversal extensions feed the batched/tiled software hot path:
//!
//! * **Row bands** — [`WindowGenerator::process_band`] streams only rows
//!   `[y0, y1)` of a frame (still reading the `p` context rows above and
//!   below straight from the source, clamped at the real frame borders),
//!   so the coordinator can shard a single frame across workers and each
//!   band is bit-identical to the same rows of a whole-frame pass.
//! * **Lane batches** — [`WindowGenerator::process_band_lanes`] emits
//!   *lane-transposed* tap buffers: `ksize²` arrays of [`LANES`] doubles,
//!   where buffer `t` lane `j` is tap `t` of the window centred on column
//!   `x0 + j`.  Interior chunks fill each tap with one contiguous
//!   `copy_from_slice` from a line buffer (consecutive windows read
//!   consecutive columns for a fixed tap), so there is no per-window
//!   gather; ragged right-edge chunks replicate the last valid window
//!   into the spare lanes so consumers always see full lanes of sane
//!   values.
//! * **Row push** — [`WindowGenerator::push_row`] /
//!   [`WindowGenerator::push_finish`] invert the control flow: the caller
//!   feeds rows one at a time (a chained filter stage consuming the rows
//!   an upstream stage produces) and the generator emits each output row
//!   as soon as its `p` look-ahead rows have arrived.  A push session
//!   over rows `0..h` followed by `push_finish` is bit-identical to
//!   [`WindowGenerator::process_frame`] over the same `h`-row frame —
//!   this is what lets `filters::FilterChain` fuse N window generators
//!   into one streaming pass with only O(N · ksize) line buffers live.

use anyhow::{bail, Result};

use super::frame::Frame;
pub use crate::util::{Lane, LANES};

/// Streaming H×W window generator over a W-wide video line.
pub struct WindowGenerator {
    ksize: usize,
    width: usize,
    /// `ksize` line buffers used as a ring (the hardware needs only
    /// `ksize − 1` BRAM lines plus the live input line; we model the same
    /// capacity: `ksize − 1` buffered + current).
    lines: Vec<Vec<f64>>,
    /// Next row index to write (ring position).
    row: usize,
    /// Rows fed in the current push session ([`WindowGenerator::begin_push`]).
    pushed: usize,
    /// Reusable `ksize²` window scratch for the per-row push API (the
    /// band traversals keep their own per-call scratch).
    scratch: Vec<f64>,
    /// Reusable tap-lane scratch for the lane-batched push API.
    scratch_lanes: Vec<Lane>,
}

impl WindowGenerator {
    /// Window sizes the streaming runtime supports: odd (3, 5, ...) and at
    /// most 16 (the fixed capacity of the row-ring resolution buffer).
    pub fn validate_ksize(ksize: usize) -> Result<()> {
        if ksize % 2 == 0 || ksize < 3 {
            bail!("window size must be an odd integer >= 3 (got {ksize})");
        }
        if ksize > 16 {
            bail!("window size {ksize} exceeds the row ring capacity of 16");
        }
        Ok(())
    }

    /// Build a generator for `ksize`×`ksize` windows over `width`-pixel
    /// lines.  Errors (instead of panicking) on an even `ksize`, `ksize`
    /// outside 3..=16, or a line shorter than the window.
    pub fn new(ksize: usize, width: usize) -> Result<Self> {
        Self::validate_ksize(ksize)?;
        if width < ksize {
            bail!("line of {width} pixels is shorter than the {ksize}-wide window");
        }
        Ok(Self {
            ksize,
            width,
            lines: vec![vec![0.0; width]; ksize],
            row: 0,
            pushed: 0,
            scratch: Vec::new(),
            scratch_lanes: Vec::new(),
        })
    }

    /// Take the push-path window scratch, sized (allocates only once —
    /// the buffer is handed back by [`WindowGenerator::put_scratch`]).
    fn take_scratch(&mut self) -> Vec<f64> {
        let mut s = std::mem::take(&mut self.scratch);
        s.resize(self.ksize * self.ksize, 0.0);
        s
    }

    fn put_scratch(&mut self, s: Vec<f64>) {
        self.scratch = s;
    }

    /// Take the push-path tap-lane scratch (every slot the emitter hands
    /// to a sink is written first, so stale values never leak).
    fn take_scratch_lanes(&mut self) -> Vec<Lane> {
        let mut s = std::mem::take(&mut self.scratch_lanes);
        s.resize(self.ksize * self.ksize, [0.0; LANES]);
        s
    }

    fn put_scratch_lanes(&mut self, s: Vec<Lane>) {
        self.scratch_lanes = s;
    }

    /// Reuse `slot`'s generator when it already matches `(ksize, width)`,
    /// otherwise (re)build it; returns the ready generator.  The one
    /// cache-invalidation rule shared by every generator cache
    /// (`HwFilter`, the coordinator workers).
    pub fn reuse(
        slot: &mut Option<WindowGenerator>,
        ksize: usize,
        width: usize,
    ) -> Result<&mut WindowGenerator> {
        let stale = match slot.as_ref() {
            Some(g) => g.width() != width || g.ksize() != ksize,
            None => true,
        };
        if stale {
            *slot = Some(WindowGenerator::new(ksize, width)?);
        }
        Ok(slot.as_mut().unwrap())
    }

    pub fn ksize(&self) -> usize {
        self.ksize
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Line-buffer storage the FPGA needs: `(ksize−1) · width · bits`
    /// (§III-A: a kernel of height H requires H−1 line buffers).
    pub fn line_buffer_bits(&self, word_bits: u32) -> u64 {
        (self.ksize as u64 - 1) * self.width as u64 * word_bits as u64
    }

    /// Cycles between a pixel entering and its centred window emerging:
    /// `p` full lines + `p` pixels.
    pub fn window_latency_cycles(&self) -> u64 {
        let p = (self.ksize / 2) as u64;
        p * self.width as u64 + p
    }

    /// Border columns: per-element clamped reads.
    #[inline]
    fn emit_clamped(
        &self,
        row_ring: &[usize; 16],
        k: usize,
        p: usize,
        x: usize,
        w: usize,
        window: &mut [f64],
    ) {
        let mut idx = 0;
        for wy in 0..k {
            let line = &self.lines[row_ring[wy]];
            for wx in 0..k {
                let want_col = x as isize + wx as isize - p as isize;
                let cx = want_col.clamp(0, (w - 1) as isize) as usize;
                window[idx] = line[cx];
                idx += 1;
            }
        }
    }

    /// Feed source row `ay` (replicate-clamped at the bottom border) into
    /// the line-buffer ring.
    #[inline]
    fn feed_row(&mut self, frame: &Frame, ay: usize) {
        let src_y = ay.min(frame.height - 1);
        let dst = self.row;
        let base = src_y * frame.width;
        self.lines[dst].copy_from_slice(&frame.data[base..base + frame.width]);
        self.row = (self.row + 1) % self.ksize;
    }

    /// Resolve the ring position of each window row once per line
    /// (replicate-clamped at the top/bottom borders) — hot path.
    #[inline]
    fn resolve_row_ring(&self, ay: usize, cy: usize, h: usize) -> [usize; 16] {
        let k = self.ksize;
        let p = k / 2;
        let mut row_ring = [0usize; 16];
        for (wy, slot) in row_ring.iter_mut().take(k).enumerate() {
            let want_row = cy as isize + wy as isize - p as isize;
            let clamped = want_row.clamp(0, (h - 1) as isize) as usize;
            // `clamped` is within the last `k` rows received; the most
            // recent (row `ay`) sits at ring position row-1.
            let age = ay - clamped; // 0 ..= k-1
            debug_assert!(age < k);
            *slot = (self.row + k - 1 - age) % k;
        }
        row_ring
    }

    /// Emit the complete output row `cy` (most recent input row `ay`,
    /// frame height `h` for border clamping) through `sink`, using
    /// `window` as the `ksize²` scratch buffer — the shared body of the
    /// band traversal and the row-push API.
    fn emit_row_to(
        &self,
        ay: usize,
        cy: usize,
        h: usize,
        window: &mut [f64],
        sink: &mut impl FnMut(usize, usize, &[f64]),
    ) {
        let k = self.ksize;
        let p = k / 2;
        let w = self.width;
        let row_ring = self.resolve_row_ring(ay, cy, h);
        // Left border (clamped columns), interior (contiguous copies),
        // right border (clamped columns).
        for x in 0..p.min(w) {
            self.emit_clamped(&row_ring, k, p, x, w, window);
            sink(x, cy, window);
        }
        for x in p..w.saturating_sub(p) {
            let start = x - p;
            for wy in 0..k {
                let line = &self.lines[row_ring[wy]];
                window[wy * k..wy * k + k].copy_from_slice(&line[start..start + k]);
            }
            sink(x, cy, window);
        }
        for x in w.saturating_sub(p).max(p)..w {
            self.emit_clamped(&row_ring, k, p, x, w, window);
            sink(x, cy, window);
        }
    }

    /// Lane-batched body of [`WindowGenerator::emit_row_to`]: emit output
    /// row `cy` as chunks of up to [`LANES`] lane-transposed windows.
    fn emit_row_lanes_to(
        &self,
        ay: usize,
        cy: usize,
        h: usize,
        taps: &mut [Lane],
        sink: &mut impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        let k = self.ksize;
        let p = k / 2;
        let w = self.width;
        let row_ring = self.resolve_row_ring(ay, cy, h);
        let mut x0 = 0;
        while x0 < w {
            let n = LANES.min(w - x0);
            // A chunk is interior when every window it covers reads
            // only in-range columns: leftmost tap `x0 − p`, rightmost
            // tap `x0 + n − 1 + p`.
            if x0 >= p && x0 + n - 1 + p < w {
                for wy in 0..k {
                    let line = &self.lines[row_ring[wy]];
                    for wx in 0..k {
                        let base = x0 + wx - p;
                        taps[wy * k + wx][..n].copy_from_slice(&line[base..base + n]);
                    }
                }
            } else {
                for wy in 0..k {
                    let line = &self.lines[row_ring[wy]];
                    for wx in 0..k {
                        let tap = &mut taps[wy * k + wx];
                        for (j, t) in tap.iter_mut().take(n).enumerate() {
                            let want_col = (x0 + j + wx) as isize - p as isize;
                            let cx = want_col.clamp(0, (w - 1) as isize) as usize;
                            *t = line[cx];
                        }
                    }
                }
            }
            if n < LANES {
                // Replicate the last valid window into the spare
                // lanes: keeps the batched engine's unused lanes on
                // sane values (no stale garbage / denormal stalls).
                for tap in taps.iter_mut() {
                    let last = tap[n - 1];
                    for t in tap.iter_mut().skip(n) {
                        *t = last;
                    }
                }
            }
            sink(x0, cy, n, taps);
            x0 += n;
        }
    }

    /// Stream a whole frame through the generator, invoking `sink(x, y,
    /// &window)` once per pixel in raster order.  `window` is the
    /// `ksize²` neighbourhood (raster order) centred on `(x, y)` with
    /// replicate borders — bit-identical to `jnp.pad(mode='edge')`.
    ///
    /// Internally this holds only `ksize` line buffers (never the whole
    /// frame), exactly like the hardware.
    pub fn process_frame(&mut self, frame: &Frame, sink: impl FnMut(usize, usize, &[f64])) {
        self.process_band(frame, 0, frame.height, sink);
    }

    /// Stream only output rows `[y0, y1)` of `frame` (a horizontal band),
    /// invoking `sink` exactly as [`WindowGenerator::process_frame`] does
    /// for those rows.  The `p` context rows above/below the band are
    /// read from the frame (clamped at the real frame borders), so band
    /// outputs are bit-identical to the same rows of a whole-frame pass —
    /// this is what lets the coordinator tile one frame across workers.
    pub fn process_band(
        &mut self,
        frame: &Frame,
        y0: usize,
        y1: usize,
        mut sink: impl FnMut(usize, usize, &[f64]),
    ) {
        assert_eq!(frame.width, self.width, "frame width mismatch");
        assert!(y0 < y1 && y1 <= frame.height, "bad band [{y0}, {y1})");
        let k = self.ksize;
        let p = k / 2;
        let h = frame.height;
        let mut window = vec![0.0f64; k * k];

        // Reset per-call streaming state.
        self.row = 0;

        for ay in y0.saturating_sub(p)..y1 + p {
            // Row `ay` arrives (or, past the bottom, the last row is
            // replicated — the paper's border registers).
            self.feed_row(frame, ay);

            // Once `p` extra rows have arrived we can emit line `cy`.
            if ay < y0 + p {
                continue;
            }
            self.emit_row_to(ay, ay - p, h, &mut window, &mut sink);
        }
    }

    /// Lane-batched traversal of a whole frame: see
    /// [`WindowGenerator::process_band_lanes`].
    pub fn process_frame_lanes(
        &mut self,
        frame: &Frame,
        sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        self.process_band_lanes(frame, 0, frame.height, sink);
    }

    /// Lane-batched traversal of output rows `[y0, y1)`: for each row,
    /// invoke `sink(x0, y, n, taps)` per chunk of up to [`LANES`]
    /// consecutive window centres, left to right.  `taps` holds `ksize²`
    /// lane arrays in window raster order; `taps[t][j]` is tap `t` of the
    /// window centred on `(x0 + j, y)` for `j < n`.  Lanes `n..LANES`
    /// (ragged right edge) replicate window `n − 1`, so consumers can
    /// evaluate full lanes unconditionally and ignore the spares.
    ///
    /// Windows are numerically identical to the scalar traversal; only
    /// the layout differs (lane-transposed, filled by contiguous per-tap
    /// line-buffer copies on interior chunks instead of per-window
    /// gathers).
    pub fn process_band_lanes(
        &mut self,
        frame: &Frame,
        y0: usize,
        y1: usize,
        mut sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        assert_eq!(frame.width, self.width, "frame width mismatch");
        assert!(y0 < y1 && y1 <= frame.height, "bad band [{y0}, {y1})");
        let k = self.ksize;
        let p = k / 2;
        let h = frame.height;
        let mut taps = vec![[0.0f64; LANES]; k * k];

        // Reset per-call streaming state.
        self.row = 0;

        for ay in y0.saturating_sub(p)..y1 + p {
            self.feed_row(frame, ay);
            if ay < y0 + p {
                continue;
            }
            self.emit_row_lanes_to(ay, ay - p, h, &mut taps, &mut sink);
        }
    }

    // --- row-push streaming (fused filter chains) -------------------------

    /// Start a push session: the caller will feed rows top to bottom with
    /// [`WindowGenerator::push_row`] / [`WindowGenerator::push_row_lanes`]
    /// and close the frame with the matching `push_finish` call.
    pub fn begin_push(&mut self) {
        self.row = 0;
        self.pushed = 0;
    }

    /// Feed `row` into the ring; returns `(ay, cy)` when output row `cy`
    /// is ready to emit (`ay` = the row index just fed).
    fn feed_push(&mut self, row: &[f64]) -> Option<(usize, usize)> {
        assert_eq!(row.len(), self.width, "pushed row width mismatch");
        self.lines[self.row].copy_from_slice(row);
        self.row = (self.row + 1) % self.ksize;
        let ay = self.pushed;
        self.pushed += 1;
        let p = self.ksize / 2;
        if ay >= p {
            Some((ay, ay - p))
        } else {
            None
        }
    }

    /// Feed the most recent row again (bottom-border replication during
    /// `push_finish` — the paper's border registers).
    fn replay_last_row(&mut self) {
        let k = self.ksize;
        let dst = self.row;
        let src = (dst + k - 1) % k; // k >= 3, so src != dst
        if src < dst {
            let (lo, hi) = self.lines.split_at_mut(dst);
            hi[0].copy_from_slice(&lo[src]);
        } else {
            let (lo, hi) = self.lines.split_at_mut(src);
            lo[dst].copy_from_slice(&hi[0]);
        }
        self.row = (dst + 1) % k;
    }

    /// Push one source row (top to bottom); once `p` look-ahead rows have
    /// arrived, the now-complete output row is emitted through `sink`
    /// exactly as [`WindowGenerator::process_frame`] would emit it.  Each
    /// push emits zero or one full output rows.
    pub fn push_row(&mut self, row: &[f64], mut sink: impl FnMut(usize, usize, &[f64])) {
        if let Some((ay, cy)) = self.feed_push(row) {
            let mut window = self.take_scratch();
            // All rows the window reads are fed (bottom clamp inactive:
            // pushed == ay + 1), so pass `pushed` as the height.
            self.emit_row_to(ay, cy, self.pushed, &mut window, &mut sink);
            self.put_scratch(window);
        }
    }

    /// Close a push session: replicate the last pushed row `p` times
    /// (bottom border) and emit the remaining `min(p, h)` output rows.
    /// After this the session is over; call
    /// [`WindowGenerator::begin_push`] before pushing the next frame.
    pub fn push_finish(&mut self, mut sink: impl FnMut(usize, usize, &[f64])) {
        let h = self.pushed;
        let p = self.ksize / 2;
        if h == 0 {
            return;
        }
        let mut window = self.take_scratch();
        for ay in h..h + p {
            self.replay_last_row();
            if ay < p {
                continue; // h < p: the window is still filling
            }
            self.emit_row_to(ay, ay - p, h, &mut window, &mut sink);
        }
        self.put_scratch(window);
        self.pushed = 0;
    }

    /// Lane-batched [`WindowGenerator::push_row`]: the emitted row arrives
    /// as chunks of up to [`LANES`] lane-transposed windows, exactly as
    /// [`WindowGenerator::process_frame_lanes`] would emit it.
    pub fn push_row_lanes(
        &mut self,
        row: &[f64],
        mut sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        if let Some((ay, cy)) = self.feed_push(row) {
            let mut taps = self.take_scratch_lanes();
            self.emit_row_lanes_to(ay, cy, self.pushed, &mut taps, &mut sink);
            self.put_scratch_lanes(taps);
        }
    }

    /// Lane-batched [`WindowGenerator::push_finish`].
    pub fn push_finish_lanes(&mut self, mut sink: impl FnMut(usize, usize, usize, &[Lane])) {
        let h = self.pushed;
        let p = self.ksize / 2;
        if h == 0 {
            return;
        }
        let mut taps = self.take_scratch_lanes();
        for ay in h..h + p {
            self.replay_last_row();
            if ay < p {
                continue;
            }
            self.emit_row_lanes_to(ay, ay - p, h, &mut taps, &mut sink);
        }
        self.put_scratch_lanes(taps);
        self.pushed = 0;
    }
}

/// Convenience: apply `f(window) -> pixel` over a frame via the streaming
/// window generator.
pub fn map_windows(frame: &Frame, ksize: usize, mut f: impl FnMut(&[f64]) -> f64) -> Frame {
    let mut out = Frame::new(frame.width, frame.height);
    let mut gen =
        WindowGenerator::new(ksize, frame.width).unwrap_or_else(|e| panic!("map_windows: {e}"));
    gen.process_frame(frame, |x, y, w| {
        out.set(x, y, f(w));
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference window via whole-frame clamped indexing.
    fn ref_window(frame: &Frame, cx: usize, cy: usize, k: usize) -> Vec<f64> {
        let p = k as isize / 2;
        let mut out = Vec::with_capacity(k * k);
        for wy in -p..=p {
            for wx in -p..=p {
                out.push(frame.get_clamped(cx as isize + wx, cy as isize + wy));
            }
        }
        out
    }

    #[test]
    fn windows_match_reference_3x3() {
        let f = Frame::noise(13, 9, 42);
        let mut gen = WindowGenerator::new(3, 13).unwrap();
        let mut count = 0;
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 3)[..], "at ({x},{y})");
            count += 1;
        });
        assert_eq!(count, 13 * 9);
    }

    #[test]
    fn windows_match_reference_5x5() {
        let f = Frame::noise(11, 8, 7);
        let mut gen = WindowGenerator::new(5, 11).unwrap();
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 5)[..], "at ({x},{y})");
        });
    }

    #[test]
    fn raster_order_and_full_coverage() {
        let f = Frame::gradient(6, 5);
        let mut gen = WindowGenerator::new(3, 6).unwrap();
        let mut seen = Vec::new();
        gen.process_frame(&f, |x, y, _| seen.push((x, y)));
        let want: Vec<(usize, usize)> =
            (0..5).flat_map(|y| (0..6).map(move |x| (x, y))).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn reusable_across_frames() {
        let f1 = Frame::noise(8, 6, 1);
        let f2 = Frame::noise(8, 6, 2);
        let mut gen = WindowGenerator::new(3, 8).unwrap();
        let mut out1 = Vec::new();
        gen.process_frame(&f1, |_, _, w| out1.push(w[4]));
        let mut out2 = Vec::new();
        gen.process_frame(&f2, |_, _, w| out2.push(w[4]));
        assert_eq!(out1, f1.data);
        assert_eq!(out2, f2.data);
    }

    #[test]
    fn bands_match_whole_frame() {
        for k in [3usize, 5] {
            let f = Frame::noise(17, 13, 99);
            let mut gen = WindowGenerator::new(k, 17).unwrap();
            for (y0, y1) in [(0, 4), (3, 9), (9, 13), (0, 13), (12, 13)] {
                let mut seen = Vec::new();
                gen.process_band(&f, y0, y1, |x, y, w| {
                    assert_eq!(w, &ref_window(&f, x, y, k)[..], "k={k} at ({x},{y})");
                    seen.push((x, y));
                });
                let want: Vec<(usize, usize)> =
                    (y0..y1).flat_map(|y| (0..17).map(move |x| (x, y))).collect();
                assert_eq!(seen, want, "band [{y0},{y1}) coverage");
            }
        }
    }

    #[test]
    fn lanes_match_scalar_windows() {
        // widths: below one lane, exact multiple, ragged
        for (w, h, k) in [(7usize, 6usize, 3usize), (32, 9, 3), (37, 11, 5)] {
            let f = Frame::noise(w, h, w as u64);
            let mut gen = WindowGenerator::new(k, w).unwrap();
            let mut covered = 0usize;
            gen.process_frame_lanes(&f, |x0, y, n, taps| {
                assert!((1..=LANES).contains(&n));
                assert_eq!(taps.len(), k * k);
                for j in 0..LANES {
                    // lanes past n replicate window n-1
                    let cx = if j < n { x0 + j } else { x0 + n - 1 };
                    let want = ref_window(&f, cx, y, k);
                    for (t, lane) in taps.iter().enumerate() {
                        assert_eq!(
                            lane[j], want[t],
                            "w={w} k={k} chunk x0={x0} y={y} lane {j} tap {t}"
                        );
                    }
                }
                covered += n;
            });
            assert_eq!(covered, w * h);
        }
    }

    #[test]
    fn band_lanes_match_scalar_windows() {
        let f = Frame::noise(21, 10, 5);
        let mut gen = WindowGenerator::new(3, 21).unwrap();
        let mut covered = 0usize;
        gen.process_band_lanes(&f, 4, 8, |x0, y, n, taps| {
            assert!((4..8).contains(&y));
            for j in 0..n {
                let want = ref_window(&f, x0 + j, y, 3);
                for (t, lane) in taps.iter().enumerate() {
                    assert_eq!(lane[j], want[t], "x0={x0} y={y} lane {j} tap {t}");
                }
            }
            covered += n;
        });
        assert_eq!(covered, 21 * 4);
    }

    #[test]
    fn line_buffer_accounting() {
        let g3 = WindowGenerator::new(3, 1920).unwrap();
        // 2 line buffers × 1920 × 16 bits
        assert_eq!(g3.line_buffer_bits(16), 2 * 1920 * 16);
        let g5 = WindowGenerator::new(5, 1920).unwrap();
        assert_eq!(g5.line_buffer_bits(64), 4 * 1920 * 64);
    }

    #[test]
    fn latency_model() {
        let g = WindowGenerator::new(3, 1920).unwrap();
        assert_eq!(g.window_latency_cycles(), 1920 + 1);
        let g5 = WindowGenerator::new(5, 640).unwrap();
        assert_eq!(g5.window_latency_cycles(), 2 * 640 + 2);
    }

    #[test]
    fn map_windows_center_tap() {
        let f = Frame::test_card(10, 10);
        let out = map_windows(&f, 3, |w| w[4]);
        assert_eq!(out.data, f.data);
    }

    #[test]
    fn construction_rejects_bad_parameters() {
        // even ksize
        let e = WindowGenerator::new(4, 32).unwrap_err();
        assert!(e.to_string().contains("odd"), "{e}");
        // ksize below the minimum
        let e = WindowGenerator::new(1, 32).unwrap_err();
        assert!(e.to_string().contains("odd"), "{e}");
        // ksize above the ring capacity
        let e = WindowGenerator::new(17, 32).unwrap_err();
        assert!(e.to_string().contains("16"), "{e}");
        // line shorter than the window
        let e = WindowGenerator::new(5, 4).unwrap_err();
        assert!(e.to_string().contains("shorter"), "{e}");
        // and the good cases still construct
        assert!(WindowGenerator::new(3, 3).is_ok());
        assert!(WindowGenerator::new(15, 16).is_ok());
    }

    #[test]
    fn reuse_rebuilds_and_propagates_errors() {
        let mut slot = None;
        let g = WindowGenerator::reuse(&mut slot, 3, 8).unwrap();
        assert_eq!((g.ksize(), g.width()), (3, 8));
        // matching parameters keep the instance
        WindowGenerator::reuse(&mut slot, 3, 8).unwrap();
        // a bad rebuild surfaces the construction error
        assert!(WindowGenerator::reuse(&mut slot, 5, 4).is_err());
    }

    /// Push sessions are bit-identical to whole-frame processing for every
    /// ksize/height relation, including h <= p (more border rows than
    /// content).
    #[test]
    fn push_rows_match_process_frame() {
        for (w, h, k) in [
            (13usize, 9usize, 3usize),
            (11, 8, 5),
            (9, 2, 5), // h <= p
            (7, 1, 3), // single row
            (37, 6, 3),
        ] {
            let f = Frame::noise(w, h, (w + h + k) as u64);
            let mut gen = WindowGenerator::new(k, w).unwrap();
            let mut want = Vec::new();
            gen.process_frame(&f, |x, y, win| want.push((x, y, win.to_vec())));

            let mut got = Vec::new();
            gen.begin_push();
            for y in 0..h {
                gen.push_row(&f.data[y * w..(y + 1) * w], |x, cy, win| {
                    got.push((x, cy, win.to_vec()));
                });
            }
            gen.push_finish(|x, cy, win| got.push((x, cy, win.to_vec())));
            assert_eq!(got, want, "w={w} h={h} k={k}");
        }
    }

    #[test]
    fn push_lanes_match_process_frame_lanes() {
        for (w, h, k) in [(7usize, 6usize, 3usize), (33, 9, 3), (37, 7, 5)] {
            let f = Frame::noise(w, h, 17 * w as u64 + h as u64);
            let mut gen = WindowGenerator::new(k, w).unwrap();
            let mut want = Vec::new();
            gen.process_frame_lanes(&f, |x0, y, n, taps| want.push((x0, y, n, taps.to_vec())));

            let mut got = Vec::new();
            gen.begin_push();
            for y in 0..h {
                gen.push_row_lanes(&f.data[y * w..(y + 1) * w], |x0, cy, n, taps| {
                    got.push((x0, cy, n, taps.to_vec()));
                });
            }
            gen.push_finish_lanes(|x0, cy, n, taps| got.push((x0, cy, n, taps.to_vec())));
            assert_eq!(got.len(), want.len(), "w={w} h={h} k={k}");
            for (g, wnt) in got.iter().zip(&want) {
                assert_eq!(g, wnt, "w={w} h={h} k={k}");
            }
        }
    }

    #[test]
    fn push_sessions_are_reusable() {
        let f1 = Frame::noise(8, 6, 1);
        let f2 = Frame::noise(8, 6, 2);
        let mut gen = WindowGenerator::new(3, 8).unwrap();
        for f in [&f1, &f2] {
            let mut centres = Vec::new();
            gen.begin_push();
            for y in 0..f.height {
                gen.push_row(&f.data[y * 8..(y + 1) * 8], |_, _, w| centres.push(w[4]));
            }
            gen.push_finish(|_, _, w| centres.push(w[4]));
            assert_eq!(centres, f.data);
        }
    }
}
