//! Streaming window generator (§III-A): forms an H×W pixel neighbourhood
//! from a raster-scan stream using H−1 line buffers, with replicate border
//! handling.
//!
//! Hardware shape (fig. 1 / fig. 2): the pixel stream is written into a
//! circular set of line buffers (dual-port BRAM in the FPGA — see
//! [`WindowGenerator::line_buffer_bits`]); H×W window registers shift
//! horizontally each cycle; border muxes replicate edge pixels so the
//! filter sees a full window at every active position.  The generator
//! emits one window per *output* pixel; the window centred on input pixel
//! (y, x) is complete once pixel (y + p_bot, x + p_right) has arrived, so
//! the structural latency is `p_bot` lines + `p_right` pixels
//! ([`WindowGenerator::window_latency_cycles`]).
//!
//! A stage's geometry is a [`StageGeometry`]: a `win_h × win_w` window
//! (rectangular, even sizes allowed — max-pool uses 2×2), a stride ≥ 1
//! (output centres sit on input pixels `(oy·s, ox·s)`, output dims are
//! `ceil(n/s)` — the replicate clamp makes this ceil-mode pooling), and a
//! channel count C (the frame is C vertically stacked planes of height
//! `height/C`; planes are windowed independently — borders clamp at plane
//! edges, never across planes).  Odd square windows centre as before:
//! `p_top = (win_h−1)/2`, `p_bot = win_h/2` (and likewise horizontally),
//! which for even windows yields the top-left-aligned ceil-mode pooling
//! convention.
//!
//! Three traversal shapes feed the batched/tiled software hot path — all
//! coordinates handed to sinks are **output** coordinates:
//!
//! * **Row bands** — [`WindowGenerator::process_band`] emits only output
//!   rows `[b0, b1)` of a frame (reading the context rows the windows
//!   need straight from the source, clamped at the real plane borders),
//!   so a frame can be sharded across workers with each band
//!   bit-identical to the same rows of a whole-frame pass.
//! * **Lane batches** — [`WindowGenerator::process_band_lanes`] emits
//!   *lane-transposed* tap buffers: `win_h·win_w` arrays of [`LANES`]
//!   doubles, where buffer `t` lane `j` is tap `t` of the window for
//!   output column `x0 + j`.  Stride-1 interior chunks fill each tap with
//!   one contiguous `copy_from_slice` from a line buffer; border or
//!   strided chunks gather per lane; ragged right-edge chunks replicate
//!   the last valid window into the spare lanes.
//! * **Row push** — [`WindowGenerator::push_row`] /
//!   [`WindowGenerator::push_finish`] invert the control flow: the caller
//!   feeds plane rows one at a time (a chained filter stage consuming the
//!   rows an upstream stage produces) and the generator emits each output
//!   row as soon as its look-ahead rows have arrived.  A push session
//!   over rows `0..h` followed by `push_finish` is bit-identical to
//!   [`WindowGenerator::process_frame`] over the same `h`-row plane;
//!   [`WindowGenerator::begin_push_at`] starts a session mid-plane for
//!   banded chain execution.  This is what lets `filters::FilterChain`
//!   fuse N window generators into one streaming pass with only
//!   O(Σ win_hᵢ) line buffers live.

use anyhow::{bail, Result};

use super::frame::Frame;
pub use crate::util::{Lane, LANES};

/// The window/traversal geometry of one pipeline stage: window shape,
/// stride, and input channel-plane count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageGeometry {
    /// Window height (1..=16; even allowed — pooling).
    pub win_h: usize,
    /// Window width (1..=16; even allowed).
    pub win_w: usize,
    /// Output centres sit on input pixels `(oy·stride, ox·stride)`;
    /// output dims are `ceil(n/stride)` per axis (ceil-mode).
    pub stride: usize,
    /// Channel planes stacked vertically in the frame (`height % C == 0`);
    /// each plane is windowed independently.
    pub channels: usize,
}

impl StageGeometry {
    /// Square `k×k`, stride 1, single plane — the classic filter shape.
    pub const fn square(k: usize) -> Self {
        Self { win_h: k, win_w: k, stride: 1, channels: 1 }
    }

    /// Rectangular `win_h×win_w`, stride 1, single plane.
    pub const fn rect(win_h: usize, win_w: usize) -> Self {
        Self { win_h, win_w, stride: 1, channels: 1 }
    }

    pub const fn with_stride(mut self, stride: usize) -> Self {
        self.stride = stride;
        self
    }

    pub const fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }

    /// Rows the window extends above its centre.
    pub const fn p_top(&self) -> usize {
        (self.win_h - 1) / 2
    }

    /// Rows the window extends below its centre (the vertical look-ahead).
    pub const fn p_bot(&self) -> usize {
        self.win_h / 2
    }

    /// Columns the window extends left of its centre.
    pub const fn p_left(&self) -> usize {
        (self.win_w - 1) / 2
    }

    /// Columns the window extends right of its centre.
    pub const fn p_right(&self) -> usize {
        self.win_w / 2
    }

    /// Taps per window (`win_h · win_w`).
    pub const fn taps(&self) -> usize {
        self.win_h * self.win_w
    }

    pub const fn is_square(&self) -> bool {
        self.win_h == self.win_w
    }

    /// Output width for a `w`-pixel input line.
    pub const fn out_width(&self, w: usize) -> usize {
        w.div_ceil(self.stride)
    }

    /// Output frame height for an `h`-row input frame (C planes of
    /// `h/C` rows each shrink to `ceil((h/C)/stride)` rows).
    pub const fn out_height(&self, h: usize) -> usize {
        self.channels * (h / self.channels).div_ceil(self.stride)
    }

    /// `(out_width, out_height)` for a `w×h` input frame.
    pub const fn out_dims(&self, w: usize, h: usize) -> (usize, usize) {
        (self.out_width(w), self.out_height(h))
    }

    /// What the streaming runtime can traverse: each window axis 1..=16
    /// (the fixed row-ring capacity), stride ≥ 1, channels ≥ 1.
    pub fn validate(&self) -> Result<()> {
        for (axis, v) in [("height", self.win_h), ("width", self.win_w)] {
            if v == 0 {
                bail!("window {axis} must be at least 1 (got 0)");
            }
            if v > 16 {
                bail!("window {axis} {v} exceeds the row ring capacity of 16");
            }
        }
        if self.stride == 0 {
            bail!("stride must be at least 1 (got 0)");
        }
        if self.channels == 0 {
            bail!("channel count must be at least 1 (got 0)");
        }
        Ok(())
    }
}

impl std::fmt::Display for StageGeometry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.win_h, self.win_w)?;
        if self.stride > 1 {
            write!(f, "/s{}", self.stride)?;
        }
        if self.channels > 1 {
            write!(f, " x{}ch", self.channels)?;
        }
        Ok(())
    }
}

/// Streaming `win_h×win_w` window generator over a W-wide video line.
pub struct WindowGenerator {
    geom: StageGeometry,
    width: usize,
    /// `win_h` line buffers used as a ring (the hardware needs only
    /// `win_h − 1` BRAM lines plus the live input line; we model the same
    /// capacity: `win_h − 1` buffered + current).
    lines: Vec<Vec<f64>>,
    /// Next row index to write (ring position).
    row: usize,
    /// Rows fed in the current push session ([`WindowGenerator::begin_push`]).
    pushed: usize,
    /// Absolute plane row of the first pushed row
    /// ([`WindowGenerator::begin_push_at`]) — 0 for whole-plane sessions.
    push_start: usize,
    /// Reusable `win_h·win_w` window scratch for the per-row push API (the
    /// band traversals keep their own per-call scratch).
    scratch: Vec<f64>,
    /// Reusable tap-lane scratch for the lane-batched push API.
    scratch_lanes: Vec<Lane>,
}

impl WindowGenerator {
    /// Window shapes a *filter* (netlist/DSL) stage supports: odd
    /// (3, 5, ...) and at most 16 per axis.  Selection stages (ReLU,
    /// max-pool) bypass this — the generator itself accepts any
    /// [`StageGeometry::validate`]-clean shape, even sizes included.
    pub fn validate_filter_window(win_h: usize, win_w: usize) -> Result<()> {
        for (axis, v) in [("height", win_h), ("width", win_w)] {
            if v % 2 == 0 || v < 3 {
                bail!("filter window {axis} must be an odd integer >= 3 (got {v})");
            }
            if v > 16 {
                bail!("window {axis} {v} exceeds the row ring capacity of 16");
            }
        }
        Ok(())
    }

    /// Square-window spelling of [`WindowGenerator::validate_filter_window`].
    pub fn validate_ksize(ksize: usize) -> Result<()> {
        Self::validate_filter_window(ksize, ksize)
    }

    /// Build a generator for square stride-1 single-plane `ksize×ksize`
    /// windows over `width`-pixel lines — the classic filter shape.
    pub fn new(ksize: usize, width: usize) -> Result<Self> {
        Self::with_geometry(StageGeometry::square(ksize), width)
    }

    /// Build a generator for an arbitrary [`StageGeometry`].  Errors
    /// (instead of panicking) on a geometry outside the ring capacity or
    /// a line narrower than the window.
    pub fn with_geometry(geom: StageGeometry, width: usize) -> Result<Self> {
        geom.validate()?;
        if width < geom.win_w {
            bail!("line of {width} pixels is shorter than the {}-wide window", geom.win_w);
        }
        Ok(Self {
            geom,
            width,
            lines: vec![vec![0.0; width]; geom.win_h],
            row: 0,
            pushed: 0,
            push_start: 0,
            scratch: Vec::new(),
            scratch_lanes: Vec::new(),
        })
    }

    /// Take the push-path window scratch, sized (allocates only once —
    /// the buffer is handed back by [`WindowGenerator::put_scratch`]).
    fn take_scratch(&mut self) -> Vec<f64> {
        let mut s = std::mem::take(&mut self.scratch);
        s.resize(self.geom.taps(), 0.0);
        s
    }

    fn put_scratch(&mut self, s: Vec<f64>) {
        self.scratch = s;
    }

    /// Take the push-path tap-lane scratch (every slot the emitter hands
    /// to a sink is written first, so stale values never leak).
    fn take_scratch_lanes(&mut self) -> Vec<Lane> {
        let mut s = std::mem::take(&mut self.scratch_lanes);
        s.resize(self.geom.taps(), [0.0; LANES]);
        s
    }

    fn put_scratch_lanes(&mut self, s: Vec<Lane>) {
        self.scratch_lanes = s;
    }

    /// Reuse `slot`'s generator when it already matches `(geom, width)`,
    /// otherwise (re)build it; returns the ready generator.  The one
    /// cache-invalidation rule shared by every generator cache (session
    /// workers, the chain runner).
    pub fn reuse(
        slot: &mut Option<WindowGenerator>,
        geom: StageGeometry,
        width: usize,
    ) -> Result<&mut WindowGenerator> {
        let stale = match slot.as_ref() {
            Some(g) => g.width() != width || g.geom() != geom,
            None => true,
        };
        if stale {
            *slot = Some(WindowGenerator::with_geometry(geom, width)?);
        }
        Ok(slot.as_mut().unwrap())
    }

    pub fn geom(&self) -> StageGeometry {
        self.geom
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Line-buffer storage the FPGA needs: `(win_h−1) · width · C · bits`
    /// (§III-A: a kernel of height H requires H−1 line buffers, per
    /// channel plane).
    pub fn line_buffer_bits(&self, word_bits: u32) -> u64 {
        (self.geom.win_h as u64 - 1)
            * self.width as u64
            * self.geom.channels as u64
            * word_bits as u64
    }

    /// Cycles between a pixel entering and its centred window emerging:
    /// `p_bot` full lines + `p_right` pixels (the look-ahead half of the
    /// window).
    pub fn window_latency_cycles(&self) -> u64 {
        self.geom.p_bot() as u64 * self.width as u64 + self.geom.p_right() as u64
    }

    /// Clamped-column window fill (borders and strided centres).
    #[inline]
    fn emit_clamped(&self, row_ring: &[usize; 16], x: usize, w: usize, window: &mut [f64]) {
        let (kh, kw) = (self.geom.win_h, self.geom.win_w);
        let pl = self.geom.p_left() as isize;
        let mut idx = 0;
        for wy in 0..kh {
            let line = &self.lines[row_ring[wy]];
            for wx in 0..kw {
                let want_col = x as isize + wx as isize - pl;
                let cx = want_col.clamp(0, (w - 1) as isize) as usize;
                window[idx] = line[cx];
                idx += 1;
            }
        }
    }

    /// Feed plane row `ay` (replicate-clamped at the bottom border) into
    /// the line-buffer ring.  `plane` is one channel plane of `ph` rows.
    #[inline]
    fn feed_plane_row(&mut self, plane: &[f64], ph: usize, ay: usize) {
        let src_y = ay.min(ph - 1);
        let base = src_y * self.width;
        let dst = self.row;
        self.lines[dst].copy_from_slice(&plane[base..base + self.width]);
        self.row = (self.row + 1) % self.geom.win_h;
    }

    /// Resolve the ring position of each window row once per line
    /// (replicate-clamped at the top/bottom borders) — hot path.
    #[inline]
    fn resolve_row_ring(&self, ay: usize, cy: usize, h: usize) -> [usize; 16] {
        let kh = self.geom.win_h;
        let pt = self.geom.p_top() as isize;
        let mut row_ring = [0usize; 16];
        for (wy, slot) in row_ring.iter_mut().take(kh).enumerate() {
            let want_row = cy as isize + wy as isize - pt;
            let clamped = want_row.clamp(0, (h - 1) as isize) as usize;
            // `clamped` is within the last `win_h` rows received; the most
            // recent (row `ay`) sits at ring position row-1.
            let age = ay - clamped; // 0 ..= win_h-1
            debug_assert!(age < kh);
            *slot = (self.row + kh - 1 - age) % kh;
        }
        row_ring
    }

    /// Emit the complete output row `oy` (input centre row `cy`, most
    /// recent input row `ay`, plane height `h` for border clamping)
    /// through `sink(ox, oy, &window)`, one call per output column —
    /// the shared body of the band traversal and the row-push API.
    fn emit_row_to(
        &self,
        ay: usize,
        cy: usize,
        oy: usize,
        h: usize,
        window: &mut [f64],
        sink: &mut impl FnMut(usize, usize, &[f64]),
    ) {
        let (kh, kw) = (self.geom.win_h, self.geom.win_w);
        let (pl, pr) = (self.geom.p_left(), self.geom.p_right());
        let s = self.geom.stride;
        let w = self.width;
        let row_ring = self.resolve_row_ring(ay, cy, h);
        let out_w = self.geom.out_width(w);
        for ox in 0..out_w {
            let x = ox * s;
            if x >= pl && x + pr < w {
                // Interior centre: contiguous per-row copies.
                let start = x - pl;
                for wy in 0..kh {
                    let line = &self.lines[row_ring[wy]];
                    window[wy * kw..wy * kw + kw].copy_from_slice(&line[start..start + kw]);
                }
            } else {
                self.emit_clamped(&row_ring, x, w, window);
            }
            sink(ox, oy, window);
        }
    }

    /// Lane-batched body of [`WindowGenerator::emit_row_to`]: emit output
    /// row `oy` as chunks of up to [`LANES`] lane-transposed windows over
    /// consecutive output columns.
    fn emit_row_lanes_to(
        &self,
        ay: usize,
        cy: usize,
        oy: usize,
        h: usize,
        taps: &mut [Lane],
        sink: &mut impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        let (kh, kw) = (self.geom.win_h, self.geom.win_w);
        let (pl, pr) = (self.geom.p_left(), self.geom.p_right());
        let s = self.geom.stride;
        let w = self.width;
        let row_ring = self.resolve_row_ring(ay, cy, h);
        let out_w = self.geom.out_width(w);
        let mut x0 = 0; // output-column chunk start
        while x0 < out_w {
            let n = LANES.min(out_w - x0);
            // A stride-1 chunk is interior when every window it covers
            // reads only in-range columns: leftmost tap `x0 − p_left`,
            // rightmost tap `x0 + n − 1 + p_right`.
            if s == 1 && x0 >= pl && x0 + n - 1 + pr < w {
                for wy in 0..kh {
                    let line = &self.lines[row_ring[wy]];
                    for wx in 0..kw {
                        let base = x0 + wx - pl;
                        taps[wy * kw + wx][..n].copy_from_slice(&line[base..base + n]);
                    }
                }
            } else {
                // Strided or border chunk: clamped per-lane gather.
                for wy in 0..kh {
                    let line = &self.lines[row_ring[wy]];
                    for wx in 0..kw {
                        let tap = &mut taps[wy * kw + wx];
                        for (j, t) in tap.iter_mut().take(n).enumerate() {
                            let want_col = ((x0 + j) * s + wx) as isize - pl as isize;
                            let cx = want_col.clamp(0, (w - 1) as isize) as usize;
                            *t = line[cx];
                        }
                    }
                }
            }
            if n < LANES {
                // Replicate the last valid window into the spare
                // lanes: keeps the batched engine's unused lanes on
                // sane values (no stale garbage / denormal stalls).
                for tap in taps.iter_mut() {
                    let last = tap[n - 1];
                    for t in tap.iter_mut().skip(n) {
                        *t = last;
                    }
                }
            }
            sink(x0, oy, n, taps);
            x0 += n;
        }
    }

    /// Stream a whole frame through the generator, invoking `sink(ox, oy,
    /// &window)` once per *output* pixel in raster order.  `window` is the
    /// `win_h·win_w` neighbourhood (raster order) centred on input pixel
    /// `(oy·stride, ox·stride)` within its channel plane, with replicate
    /// borders — bit-identical to `jnp.pad(mode='edge')`.
    ///
    /// Internally this holds only `win_h` line buffers (never the whole
    /// frame), exactly like the hardware.
    pub fn process_frame(&mut self, frame: &Frame, sink: impl FnMut(usize, usize, &[f64])) {
        let oh = self.geom.out_height(frame.height);
        self.process_band(frame, 0, oh, sink);
    }

    /// Stream only *output* rows `[b0, b1)` of `frame` (a horizontal
    /// band of the output), invoking `sink` exactly as
    /// [`WindowGenerator::process_frame`] does for those rows.  The
    /// context rows the windows need are read from the frame (clamped at
    /// the real plane borders), so band outputs are bit-identical to the
    /// same rows of a whole-frame pass — this is what lets the session
    /// tile one frame across workers.  Bands spanning channel-plane
    /// boundaries are handled plane by plane.
    pub fn process_band(
        &mut self,
        frame: &Frame,
        b0: usize,
        b1: usize,
        mut sink: impl FnMut(usize, usize, &[f64]),
    ) {
        assert_eq!(frame.width, self.width, "frame width mismatch");
        let c = self.geom.channels;
        assert!(frame.height % c == 0, "frame height {} not divisible into {c} planes", frame.height);
        let ph = frame.height / c;
        let oph = ph.div_ceil(self.geom.stride);
        assert!(b0 < b1 && b1 <= c * oph, "bad band [{b0}, {b1})");
        let w = self.width;
        let mut window = vec![0.0f64; self.geom.taps()];
        for ci in 0..c {
            let lo = b0.max(ci * oph);
            let hi = b1.min((ci + 1) * oph);
            if lo >= hi {
                continue;
            }
            let base = ci * oph;
            let plane = &frame.data[ci * ph * w..(ci + 1) * ph * w];
            self.plane_band(plane, ph, lo - base, hi - base, &mut window, &mut |ox, oy, win| {
                sink(ox, base + oy, win)
            });
        }
    }

    /// Single-plane band core: feed exactly the input rows output rows
    /// `[b0, b1)` need, emitting each output row the moment its last
    /// input row arrives.
    fn plane_band(
        &mut self,
        plane: &[f64],
        ph: usize,
        b0: usize,
        b1: usize,
        window: &mut [f64],
        sink: &mut impl FnMut(usize, usize, &[f64]),
    ) {
        let (pt, pb, s) = (self.geom.p_top(), self.geom.p_bot(), self.geom.stride);
        self.row = 0;
        let a = (b0 * s).saturating_sub(pt);
        let end = (b1 - 1) * s + pb; // may pass the plane bottom: feed clamps
        let mut next_oy = b0;
        for ay in a..=end {
            self.feed_plane_row(plane, ph, ay);
            while next_oy < b1 && ay >= next_oy * s + pb {
                self.emit_row_to(ay, next_oy * s, next_oy, ph, window, sink);
                next_oy += 1;
            }
        }
    }

    /// Lane-batched traversal of a whole frame: see
    /// [`WindowGenerator::process_band_lanes`].
    pub fn process_frame_lanes(
        &mut self,
        frame: &Frame,
        sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        let oh = self.geom.out_height(frame.height);
        self.process_band_lanes(frame, 0, oh, sink);
    }

    /// Lane-batched traversal of output rows `[b0, b1)`: for each row,
    /// invoke `sink(x0, oy, n, taps)` per chunk of up to [`LANES`]
    /// consecutive *output* columns, left to right.  `taps` holds
    /// `win_h·win_w` lane arrays in window raster order; `taps[t][j]` is
    /// tap `t` of the window for output column `x0 + j` for `j < n`.
    /// Lanes `n..LANES` (ragged right edge) replicate window `n − 1`, so
    /// consumers can evaluate full lanes unconditionally and ignore the
    /// spares.
    ///
    /// Windows are numerically identical to the scalar traversal; only
    /// the layout differs (lane-transposed, filled by contiguous per-tap
    /// line-buffer copies on stride-1 interior chunks instead of
    /// per-window gathers).
    pub fn process_band_lanes(
        &mut self,
        frame: &Frame,
        b0: usize,
        b1: usize,
        mut sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        assert_eq!(frame.width, self.width, "frame width mismatch");
        let c = self.geom.channels;
        assert!(frame.height % c == 0, "frame height {} not divisible into {c} planes", frame.height);
        let ph = frame.height / c;
        let oph = ph.div_ceil(self.geom.stride);
        assert!(b0 < b1 && b1 <= c * oph, "bad band [{b0}, {b1})");
        let w = self.width;
        let mut taps = vec![[0.0f64; LANES]; self.geom.taps()];
        for ci in 0..c {
            let lo = b0.max(ci * oph);
            let hi = b1.min((ci + 1) * oph);
            if lo >= hi {
                continue;
            }
            let base = ci * oph;
            let plane = &frame.data[ci * ph * w..(ci + 1) * ph * w];
            self.plane_band_lanes(
                plane,
                ph,
                lo - base,
                hi - base,
                &mut taps,
                &mut |x0, oy, n, t| sink(x0, base + oy, n, t),
            );
        }
    }

    fn plane_band_lanes(
        &mut self,
        plane: &[f64],
        ph: usize,
        b0: usize,
        b1: usize,
        taps: &mut [Lane],
        sink: &mut impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        let (pt, pb, s) = (self.geom.p_top(), self.geom.p_bot(), self.geom.stride);
        self.row = 0;
        let a = (b0 * s).saturating_sub(pt);
        let end = (b1 - 1) * s + pb;
        let mut next_oy = b0;
        for ay in a..=end {
            self.feed_plane_row(plane, ph, ay);
            while next_oy < b1 && ay >= next_oy * s + pb {
                self.emit_row_lanes_to(ay, next_oy * s, next_oy, ph, taps, sink);
                next_oy += 1;
            }
        }
    }

    // --- row-push streaming (fused filter chains) -------------------------

    /// Start a whole-plane push session: the caller will feed rows top to
    /// bottom with [`WindowGenerator::push_row`] /
    /// [`WindowGenerator::push_row_lanes`] and close the plane with the
    /// matching `push_finish` call.
    pub fn begin_push(&mut self) {
        self.begin_push_at(0);
    }

    /// Start a push session whose first fed row is absolute plane row
    /// `start` (banded chain execution).  When `start > 0` the emitted
    /// output rows begin at the first centre whose window is entirely
    /// fed (`cy ≥ start + p_top`, aligned to the stride grid); the
    /// caller is responsible for feeding enough context rows that the
    /// rows it needs satisfy that bound.
    pub fn begin_push_at(&mut self, start: usize) {
        self.row = 0;
        self.pushed = 0;
        self.push_start = start;
    }

    /// Feed `row` into the ring; returns the absolute plane row just fed.
    fn feed_push(&mut self, row: &[f64]) -> usize {
        assert_eq!(row.len(), self.width, "pushed row width mismatch");
        self.lines[self.row].copy_from_slice(row);
        self.row = (self.row + 1) % self.geom.win_h;
        let ay = self.push_start + self.pushed;
        self.pushed += 1;
        ay
    }

    /// First centre row a push session may emit: 0 for whole-plane
    /// sessions (top border clamps), `start + p_top` mid-plane (every
    /// window row must have been fed).
    fn push_cy_min(&self) -> usize {
        if self.push_start == 0 {
            0
        } else {
            self.push_start + self.geom.p_top()
        }
    }

    /// Feed the most recent row again (bottom-border replication during
    /// `push_finish` — the paper's border registers).
    fn replay_last_row(&mut self) {
        let k = self.geom.win_h;
        let dst = self.row;
        let src = (dst + k - 1) % k; // only called when p_bot >= 1, so k >= 2 and src != dst
        if src < dst {
            let (lo, hi) = self.lines.split_at_mut(dst);
            hi[0].copy_from_slice(&lo[src]);
        } else {
            let (lo, hi) = self.lines.split_at_mut(src);
            lo[dst].copy_from_slice(&hi[0]);
        }
        self.row = (dst + 1) % k;
    }

    /// Push one plane row (top to bottom); once the look-ahead rows have
    /// arrived and the completed centre sits on the stride grid, the
    /// now-complete output row is emitted through `sink` exactly as
    /// [`WindowGenerator::process_frame`] would emit it.  Each push emits
    /// zero or one full output rows.
    pub fn push_row(&mut self, row: &[f64], mut sink: impl FnMut(usize, usize, &[f64])) {
        let ay = self.feed_push(row);
        let (pb, s) = (self.geom.p_bot(), self.geom.stride);
        if ay < pb {
            return;
        }
        let cy = ay - pb;
        if cy < self.push_cy_min() || cy % s != 0 {
            return;
        }
        let mut window = self.take_scratch();
        // All rows the window reads are fed (bottom clamp inactive), so
        // pass `ay + 1` as the plane height.
        self.emit_row_to(ay, cy, cy / s, ay + 1, &mut window, &mut sink);
        self.put_scratch(window);
    }

    /// Close a push session: replicate the last pushed row `p_bot` times
    /// (bottom border) and emit the remaining output rows whose centres
    /// are on the stride grid.  After this the session is over; call
    /// [`WindowGenerator::begin_push`] before pushing the next plane.
    pub fn push_finish(&mut self, mut sink: impl FnMut(usize, usize, &[f64])) {
        if self.pushed == 0 {
            return;
        }
        let h = self.push_start + self.pushed;
        let (pb, s) = (self.geom.p_bot(), self.geom.stride);
        let cy_min = self.push_cy_min();
        let mut window = self.take_scratch();
        for ay in h..h + pb {
            self.replay_last_row();
            if ay < pb {
                continue; // h < p_bot: the window is still filling
            }
            let cy = ay - pb;
            if cy < cy_min || cy % s != 0 {
                continue;
            }
            self.emit_row_to(ay, cy, cy / s, h, &mut window, &mut sink);
        }
        self.put_scratch(window);
        self.pushed = 0;
    }

    /// Lane-batched [`WindowGenerator::push_row`]: the emitted row arrives
    /// as chunks of up to [`LANES`] lane-transposed windows, exactly as
    /// [`WindowGenerator::process_frame_lanes`] would emit it.
    pub fn push_row_lanes(
        &mut self,
        row: &[f64],
        mut sink: impl FnMut(usize, usize, usize, &[Lane]),
    ) {
        let ay = self.feed_push(row);
        let (pb, s) = (self.geom.p_bot(), self.geom.stride);
        if ay < pb {
            return;
        }
        let cy = ay - pb;
        if cy < self.push_cy_min() || cy % s != 0 {
            return;
        }
        let mut taps = self.take_scratch_lanes();
        self.emit_row_lanes_to(ay, cy, cy / s, ay + 1, &mut taps, &mut sink);
        self.put_scratch_lanes(taps);
    }

    /// Lane-batched [`WindowGenerator::push_finish`].
    pub fn push_finish_lanes(&mut self, mut sink: impl FnMut(usize, usize, usize, &[Lane])) {
        if self.pushed == 0 {
            return;
        }
        let h = self.push_start + self.pushed;
        let (pb, s) = (self.geom.p_bot(), self.geom.stride);
        let cy_min = self.push_cy_min();
        let mut taps = self.take_scratch_lanes();
        for ay in h..h + pb {
            self.replay_last_row();
            if ay < pb {
                continue;
            }
            let cy = ay - pb;
            if cy < cy_min || cy % s != 0 {
                continue;
            }
            self.emit_row_lanes_to(ay, cy, cy / s, h, &mut taps, &mut sink);
        }
        self.put_scratch_lanes(taps);
        self.pushed = 0;
    }
}

/// Convenience: apply `f(window) -> pixel` over a frame via the streaming
/// window generator (square stride-1 single-plane windows).
pub fn map_windows(frame: &Frame, ksize: usize, mut f: impl FnMut(&[f64]) -> f64) -> Frame {
    let mut out = Frame::new(frame.width, frame.height);
    let mut gen =
        WindowGenerator::new(ksize, frame.width).unwrap_or_else(|e| panic!("map_windows: {e}"));
    gen.process_frame(frame, |x, y, w| {
        out.set(x, y, f(w));
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference window via whole-frame clamped indexing (square k).
    fn ref_window(frame: &Frame, cx: usize, cy: usize, k: usize) -> Vec<f64> {
        ref_window_g(frame, StageGeometry::square(k), 0, cx, cy)
    }

    /// Reference window for any geometry: output pixel `(ox, oy)` of
    /// plane `ci`, clamped gathers within the plane.
    fn ref_window_g(
        frame: &Frame,
        g: StageGeometry,
        ci: usize,
        ox: usize,
        oy: usize,
    ) -> Vec<f64> {
        let ph = frame.height / g.channels;
        let (cx, cy) = (ox * g.stride, oy * g.stride);
        let mut out = Vec::with_capacity(g.taps());
        for wy in 0..g.win_h {
            let want_row = cy as isize + wy as isize - g.p_top() as isize;
            let py = want_row.clamp(0, (ph - 1) as isize) as usize;
            for wx in 0..g.win_w {
                let want_col = cx as isize + wx as isize - g.p_left() as isize;
                let px = want_col.clamp(0, (frame.width - 1) as isize) as usize;
                out.push(frame.data[(ci * ph + py) * frame.width + px]);
            }
        }
        out
    }

    #[test]
    fn windows_match_reference_3x3() {
        let f = Frame::noise(13, 9, 42);
        let mut gen = WindowGenerator::new(3, 13).unwrap();
        let mut count = 0;
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 3)[..], "at ({x},{y})");
            count += 1;
        });
        assert_eq!(count, 13 * 9);
    }

    #[test]
    fn windows_match_reference_5x5() {
        let f = Frame::noise(11, 8, 7);
        let mut gen = WindowGenerator::new(5, 11).unwrap();
        gen.process_frame(&f, |x, y, w| {
            assert_eq!(w, &ref_window(&f, x, y, 5)[..], "at ({x},{y})");
        });
    }

    #[test]
    fn rect_windows_match_reference() {
        for (wh, ww) in [(3usize, 5usize), (5, 3), (1, 3), (3, 1), (1, 1)] {
            let f = Frame::noise(12, 9, (wh * 16 + ww) as u64);
            let g = StageGeometry::rect(wh, ww);
            let mut gen = WindowGenerator::with_geometry(g, 12).unwrap();
            let mut count = 0;
            gen.process_frame(&f, |x, y, w| {
                assert_eq!(w, &ref_window_g(&f, g, 0, x, y)[..], "{wh}x{ww} at ({x},{y})");
                count += 1;
            });
            assert_eq!(count, 12 * 9, "{wh}x{ww}");
        }
    }

    #[test]
    fn strided_windows_subsample_the_frame() {
        for (w, h, k, s) in [(13usize, 9usize, 3usize, 2usize), (16, 8, 3, 2), (11, 7, 5, 3)] {
            let f = Frame::noise(w, h, (w + h + s) as u64);
            let g = StageGeometry::square(k).with_stride(s);
            let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
            let mut seen = Vec::new();
            gen.process_frame(&f, |ox, oy, win| {
                assert_eq!(win, &ref_window_g(&f, g, 0, ox, oy)[..], "s={s} at ({ox},{oy})");
                seen.push((ox, oy));
            });
            let (ow, oh) = g.out_dims(w, h);
            assert_eq!((ow, oh), (w.div_ceil(s), h.div_ceil(s)));
            let want: Vec<(usize, usize)> =
                (0..oh).flat_map(|y| (0..ow).map(move |x| (x, y))).collect();
            assert_eq!(seen, want, "w={w} h={h} k={k} s={s} coverage");
        }
    }

    #[test]
    fn even_pool_window_is_top_left_aligned() {
        // 2x2 window: p_top = p_left = 0, p_bot = p_right = 1 — the
        // window for output (oy, ox) covers input rows/cols
        // [2oy, 2oy+1] x [2ox, 2ox+1] (clamped), i.e. ceil-mode pooling.
        let f = Frame::noise(7, 5, 3);
        let g = StageGeometry::rect(2, 2).with_stride(2);
        assert_eq!((g.p_top(), g.p_bot(), g.p_left(), g.p_right()), (0, 1, 0, 1));
        let mut gen = WindowGenerator::with_geometry(g, 7).unwrap();
        let mut count = 0;
        gen.process_frame(&f, |ox, oy, win| {
            let gc = |x: usize, y: usize| f.get_clamped(x as isize, y as isize);
            let want = [
                gc(2 * ox, 2 * oy),
                gc(2 * ox + 1, 2 * oy),
                gc(2 * ox, 2 * oy + 1),
                gc(2 * ox + 1, 2 * oy + 1),
            ];
            assert_eq!(win, &want[..], "at ({ox},{oy})");
            count += 1;
        });
        assert_eq!(count, 4 * 3); // ceil(7/2) x ceil(5/2)
    }

    #[test]
    fn channel_planes_are_independent() {
        // Two stacked planes: windows clamp at each plane's own borders,
        // output rows are plane-local rows offset by the plane index.
        let (w, ph, c) = (9usize, 6usize, 2usize);
        let f = Frame::noise(w, ph * c, 11);
        let g = StageGeometry::square(3).with_channels(c);
        let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
        let mut seen = Vec::new();
        gen.process_frame(&f, |ox, oy, win| {
            let (ci, oy_p) = (oy / ph, oy % ph);
            assert_eq!(win, &ref_window_g(&f, g, ci, ox, oy_p)[..], "at ({ox},{oy})");
            seen.push((ox, oy));
        });
        assert_eq!(seen.len(), w * ph * c);
        // strided multi-channel output height folds per plane
        let gs = g.with_stride(2);
        assert_eq!(gs.out_dims(w, ph * c), (5, 2 * 3));
        let mut gen = WindowGenerator::with_geometry(gs, w).unwrap();
        let mut count = 0;
        gen.process_frame(&f, |ox, oy, win| {
            let oph = ph.div_ceil(2);
            let (ci, oy_p) = (oy / oph, oy % oph);
            assert_eq!(win, &ref_window_g(&f, gs, ci, ox, oy_p)[..], "at ({ox},{oy})");
            count += 1;
        });
        assert_eq!(count, 5 * 6);
    }

    #[test]
    fn raster_order_and_full_coverage() {
        let f = Frame::gradient(6, 5);
        let mut gen = WindowGenerator::new(3, 6).unwrap();
        let mut seen = Vec::new();
        gen.process_frame(&f, |x, y, _| seen.push((x, y)));
        let want: Vec<(usize, usize)> =
            (0..5).flat_map(|y| (0..6).map(move |x| (x, y))).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn reusable_across_frames() {
        let f1 = Frame::noise(8, 6, 1);
        let f2 = Frame::noise(8, 6, 2);
        let mut gen = WindowGenerator::new(3, 8).unwrap();
        let mut out1 = Vec::new();
        gen.process_frame(&f1, |_, _, w| out1.push(w[4]));
        let mut out2 = Vec::new();
        gen.process_frame(&f2, |_, _, w| out2.push(w[4]));
        assert_eq!(out1, f1.data);
        assert_eq!(out2, f2.data);
    }

    #[test]
    fn bands_match_whole_frame() {
        for k in [3usize, 5] {
            let f = Frame::noise(17, 13, 99);
            let mut gen = WindowGenerator::new(k, 17).unwrap();
            for (y0, y1) in [(0, 4), (3, 9), (9, 13), (0, 13), (12, 13)] {
                let mut seen = Vec::new();
                gen.process_band(&f, y0, y1, |x, y, w| {
                    assert_eq!(w, &ref_window(&f, x, y, k)[..], "k={k} at ({x},{y})");
                    seen.push((x, y));
                });
                let want: Vec<(usize, usize)> =
                    (y0..y1).flat_map(|y| (0..17).map(move |x| (x, y))).collect();
                assert_eq!(seen, want, "band [{y0},{y1}) coverage");
            }
        }
    }

    #[test]
    fn strided_bands_match_whole_frame() {
        let f = Frame::noise(17, 13, 3);
        let g = StageGeometry::square(3).with_stride(2);
        let mut gen = WindowGenerator::with_geometry(g, 17).unwrap();
        let mut whole = Vec::new();
        gen.process_frame(&f, |x, y, w| whole.push((x, y, w.to_vec())));
        let oh = g.out_height(13); // 7
        for (b0, b1) in [(0, 3), (2, 5), (5, oh), (0, oh), (oh - 1, oh)] {
            let mut band = Vec::new();
            gen.process_band(&f, b0, b1, |x, y, w| band.push((x, y, w.to_vec())));
            let want: Vec<_> =
                whole.iter().filter(|(_, y, _)| (b0..b1).contains(y)).cloned().collect();
            assert_eq!(band, want, "band [{b0},{b1})");
        }
    }

    #[test]
    fn lanes_match_scalar_windows() {
        // widths: below one lane, exact multiple, ragged
        for (w, h, k) in [(7usize, 6usize, 3usize), (32, 9, 3), (37, 11, 5)] {
            let f = Frame::noise(w, h, w as u64);
            let mut gen = WindowGenerator::new(k, w).unwrap();
            let mut covered = 0usize;
            gen.process_frame_lanes(&f, |x0, y, n, taps| {
                assert!((1..=LANES).contains(&n));
                assert_eq!(taps.len(), k * k);
                for j in 0..LANES {
                    // lanes past n replicate window n-1
                    let cx = if j < n { x0 + j } else { x0 + n - 1 };
                    let want = ref_window(&f, cx, y, k);
                    for (t, lane) in taps.iter().enumerate() {
                        assert_eq!(
                            lane[j], want[t],
                            "w={w} k={k} chunk x0={x0} y={y} lane {j} tap {t}"
                        );
                    }
                }
                covered += n;
            });
            assert_eq!(covered, w * h);
        }
    }

    #[test]
    fn strided_lanes_match_scalar_windows() {
        for (w, h, g) in [
            (21usize, 10usize, StageGeometry::square(3).with_stride(2)),
            (37, 9, StageGeometry::rect(2, 2).with_stride(2)),
            (19, 8, StageGeometry::rect(3, 5).with_stride(3)),
        ] {
            let f = Frame::noise(w, h, (w * h) as u64);
            let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
            let mut covered = 0usize;
            gen.process_frame_lanes(&f, |x0, y, n, taps| {
                for j in 0..LANES {
                    let ox = if j < n { x0 + j } else { x0 + n - 1 };
                    let want = ref_window_g(&f, g, 0, ox, y);
                    for (t, lane) in taps.iter().enumerate() {
                        assert_eq!(lane[j], want[t], "{g} x0={x0} y={y} lane {j} tap {t}");
                    }
                }
                covered += n;
            });
            assert_eq!(covered, g.out_width(w) * g.out_height(h), "{g}");
        }
    }

    #[test]
    fn band_lanes_match_scalar_windows() {
        let f = Frame::noise(21, 10, 5);
        let mut gen = WindowGenerator::new(3, 21).unwrap();
        let mut covered = 0usize;
        gen.process_band_lanes(&f, 4, 8, |x0, y, n, taps| {
            assert!((4..8).contains(&y));
            for j in 0..n {
                let want = ref_window(&f, x0 + j, y, 3);
                for (t, lane) in taps.iter().enumerate() {
                    assert_eq!(lane[j], want[t], "x0={x0} y={y} lane {j} tap {t}");
                }
            }
            covered += n;
        });
        assert_eq!(covered, 21 * 4);
    }

    #[test]
    fn line_buffer_accounting() {
        let g3 = WindowGenerator::new(3, 1920).unwrap();
        // 2 line buffers × 1920 × 16 bits
        assert_eq!(g3.line_buffer_bits(16), 2 * 1920 * 16);
        let g5 = WindowGenerator::new(5, 1920).unwrap();
        assert_eq!(g5.line_buffer_bits(64), 4 * 1920 * 64);
        // per channel plane
        let gc = WindowGenerator::with_geometry(
            StageGeometry::square(3).with_channels(3),
            1920,
        )
        .unwrap();
        assert_eq!(gc.line_buffer_bits(16), 2 * 1920 * 3 * 16);
        // a 2x2 pool window needs one line buffer
        let gp =
            WindowGenerator::with_geometry(StageGeometry::rect(2, 2).with_stride(2), 1920).unwrap();
        assert_eq!(gp.line_buffer_bits(16), 1920 * 16);
    }

    #[test]
    fn latency_model() {
        let g = WindowGenerator::new(3, 1920).unwrap();
        assert_eq!(g.window_latency_cycles(), 1920 + 1);
        let g5 = WindowGenerator::new(5, 640).unwrap();
        assert_eq!(g5.window_latency_cycles(), 2 * 640 + 2);
        // look-ahead half of a 2x2 window: one line + one pixel
        let gp =
            WindowGenerator::with_geometry(StageGeometry::rect(2, 2).with_stride(2), 640).unwrap();
        assert_eq!(gp.window_latency_cycles(), 640 + 1);
        // 1x1 (ReLU) has no window latency at all
        let g1 = WindowGenerator::with_geometry(StageGeometry::rect(1, 1), 640).unwrap();
        assert_eq!(g1.window_latency_cycles(), 0);
    }

    #[test]
    fn map_windows_center_tap() {
        let f = Frame::test_card(10, 10);
        let out = map_windows(&f, 3, |w| w[4]);
        assert_eq!(out.data, f.data);
    }

    #[test]
    fn construction_rejects_bad_parameters() {
        // zero-size axes
        let e = WindowGenerator::with_geometry(StageGeometry::rect(0, 3), 32).unwrap_err();
        assert!(e.to_string().contains("height"), "{e}");
        let e = WindowGenerator::with_geometry(StageGeometry::rect(3, 0), 32).unwrap_err();
        assert!(e.to_string().contains("width"), "{e}");
        // axes above the ring capacity
        let e = WindowGenerator::new(17, 32).unwrap_err();
        assert!(e.to_string().contains("16"), "{e}");
        let e = WindowGenerator::with_geometry(StageGeometry::rect(3, 17), 32).unwrap_err();
        assert!(e.to_string().contains("16") && e.to_string().contains("width"), "{e}");
        // zero stride / zero channels
        let e = WindowGenerator::with_geometry(StageGeometry::square(3).with_stride(0), 32)
            .unwrap_err();
        assert!(e.to_string().contains("stride"), "{e}");
        let e = WindowGenerator::with_geometry(StageGeometry::square(3).with_channels(0), 32)
            .unwrap_err();
        assert!(e.to_string().contains("channel"), "{e}");
        // line shorter than the window
        let e = WindowGenerator::new(5, 4).unwrap_err();
        assert!(e.to_string().contains("shorter"), "{e}");
        // and the good cases still construct — even windows included
        assert!(WindowGenerator::new(3, 3).is_ok());
        assert!(WindowGenerator::new(15, 16).is_ok());
        assert!(WindowGenerator::with_geometry(StageGeometry::rect(2, 2).with_stride(2), 8).is_ok());
    }

    #[test]
    fn filter_window_validation_names_the_axis() {
        // even sizes are generator-legal but filter-illegal, per axis
        let e = WindowGenerator::validate_filter_window(4, 3).unwrap_err();
        assert!(e.to_string().contains("odd") && e.to_string().contains("height"), "{e}");
        let e = WindowGenerator::validate_filter_window(3, 4).unwrap_err();
        assert!(e.to_string().contains("odd") && e.to_string().contains("width"), "{e}");
        let e = WindowGenerator::validate_filter_window(1, 3).unwrap_err();
        assert!(e.to_string().contains("odd"), "{e}");
        let e = WindowGenerator::validate_filter_window(3, 17).unwrap_err();
        assert!(e.to_string().contains("16"), "{e}");
        assert!(WindowGenerator::validate_filter_window(3, 5).is_ok());
        // the square spelling survives
        assert!(WindowGenerator::validate_ksize(2).is_err());
        assert!(WindowGenerator::validate_ksize(17).is_err());
        assert!(WindowGenerator::validate_ksize(5).is_ok());
    }

    #[test]
    fn reuse_rebuilds_and_propagates_errors() {
        let mut slot = None;
        let g = WindowGenerator::reuse(&mut slot, StageGeometry::square(3), 8).unwrap();
        assert_eq!((g.geom(), g.width()), (StageGeometry::square(3), 8));
        // matching parameters keep the instance
        WindowGenerator::reuse(&mut slot, StageGeometry::square(3), 8).unwrap();
        // a geometry change rebuilds
        let g = WindowGenerator::reuse(&mut slot, StageGeometry::square(3).with_stride(2), 8)
            .unwrap();
        assert_eq!(g.geom().stride, 2);
        // a bad rebuild surfaces the construction error
        assert!(WindowGenerator::reuse(&mut slot, StageGeometry::square(5), 4).is_err());
    }

    /// Push sessions are bit-identical to whole-frame processing for every
    /// geometry/height relation, including h <= p (more border rows than
    /// content), strides and rectangular/even windows.
    #[test]
    fn push_rows_match_process_frame() {
        for (w, h, g) in [
            (13usize, 9usize, StageGeometry::square(3)),
            (11, 8, StageGeometry::square(5)),
            (9, 2, StageGeometry::square(5)), // h <= p
            (7, 1, StageGeometry::square(3)), // single row
            (37, 6, StageGeometry::square(3)),
            (13, 9, StageGeometry::square(3).with_stride(2)),
            (12, 7, StageGeometry::rect(2, 2).with_stride(2)),
            (11, 9, StageGeometry::rect(3, 5)),
            (10, 6, StageGeometry::rect(1, 1)), // ReLU shape
            (15, 8, StageGeometry::square(5).with_stride(3)),
        ] {
            let f = Frame::noise(w, h, (w + h + g.win_h) as u64);
            let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
            let mut want = Vec::new();
            gen.process_frame(&f, |x, y, win| want.push((x, y, win.to_vec())));

            let mut got = Vec::new();
            gen.begin_push();
            for y in 0..h {
                gen.push_row(&f.data[y * w..(y + 1) * w], |x, cy, win| {
                    got.push((x, cy, win.to_vec()));
                });
            }
            gen.push_finish(|x, cy, win| got.push((x, cy, win.to_vec())));
            assert_eq!(got, want, "w={w} h={h} {g}");
        }
    }

    /// A mid-plane push session (`begin_push_at`) fed from the first row
    /// a band's windows need emits exactly the band's output rows.
    #[test]
    fn push_at_matches_process_band() {
        for (g, lo) in [
            (StageGeometry::square(3), 4usize),
            (StageGeometry::square(5), 3),
            (StageGeometry::square(3).with_stride(2), 2),
            (StageGeometry::rect(2, 2).with_stride(2), 3),
        ] {
            let (w, h) = (11usize, 13usize);
            let f = Frame::noise(w, h, (g.win_h + lo) as u64);
            let oh = g.out_height(h);
            let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
            let mut want = Vec::new();
            gen.process_band(&f, lo, oh, |x, y, win| want.push((x, y, win.to_vec())));

            // feed from the first input row the band's windows touch
            let a = (lo * g.stride).saturating_sub(g.p_top());
            let mut got = Vec::new();
            gen.begin_push_at(a);
            for y in a..h {
                gen.push_row(&f.data[y * w..(y + 1) * w], |x, cy, win| {
                    got.push((x, cy, win.to_vec()));
                });
            }
            gen.push_finish(|x, cy, win| got.push((x, cy, win.to_vec())));
            // a == 0 top-clamps and emits from row 0: drop the extras
            let got: Vec<_> = got.into_iter().filter(|(_, y, _)| *y >= lo).collect();
            assert_eq!(got, want, "{g} lo={lo}");
        }
    }

    #[test]
    fn push_lanes_match_process_frame_lanes() {
        for (w, h, g) in [
            (7usize, 6usize, StageGeometry::square(3)),
            (33, 9, StageGeometry::square(3)),
            (37, 7, StageGeometry::square(5)),
            (33, 9, StageGeometry::square(3).with_stride(2)),
            (21, 8, StageGeometry::rect(2, 2).with_stride(2)),
        ] {
            let f = Frame::noise(w, h, 17 * w as u64 + h as u64);
            let mut gen = WindowGenerator::with_geometry(g, w).unwrap();
            let mut want = Vec::new();
            gen.process_frame_lanes(&f, |x0, y, n, taps| want.push((x0, y, n, taps.to_vec())));

            let mut got = Vec::new();
            gen.begin_push();
            for y in 0..h {
                gen.push_row_lanes(&f.data[y * w..(y + 1) * w], |x0, cy, n, taps| {
                    got.push((x0, cy, n, taps.to_vec()));
                });
            }
            gen.push_finish_lanes(|x0, cy, n, taps| got.push((x0, cy, n, taps.to_vec())));
            assert_eq!(got.len(), want.len(), "w={w} h={h} {g}");
            for (gt, wnt) in got.iter().zip(&want) {
                assert_eq!(gt, wnt, "w={w} h={h} {g}");
            }
        }
    }

    #[test]
    fn push_sessions_are_reusable() {
        let f1 = Frame::noise(8, 6, 1);
        let f2 = Frame::noise(8, 6, 2);
        let mut gen = WindowGenerator::new(3, 8).unwrap();
        for f in [&f1, &f2] {
            let mut centres = Vec::new();
            gen.begin_push();
            for y in 0..f.height {
                gen.push_row(&f.data[y * 8..(y + 1) * 8], |_, _, w| centres.push(w[4]));
            }
            gen.push_finish(|_, _, w| centres.push(w[4]));
            assert_eq!(centres, f.data);
        }
    }

    #[test]
    fn geometry_output_dims() {
        let g = StageGeometry::square(3);
        assert_eq!(g.out_dims(640, 480), (640, 480));
        let g = StageGeometry::square(3).with_stride(2);
        assert_eq!(g.out_dims(7, 5), (4, 3));
        assert_eq!(g.out_dims(8, 6), (4, 3));
        let g = StageGeometry::rect(2, 2).with_stride(2).with_channels(3);
        assert_eq!(g.out_dims(10, 9), (5, 3 * 2)); // planes of 3 rows -> 2
        assert_eq!(StageGeometry::square(3).with_channels(2).out_dims(8, 6), (8, 6));
    }
}
