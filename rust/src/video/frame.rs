//! Grayscale frames: storage, PGM I/O and synthetic test patterns.
//!
//! Pixels are doubles in `[0, 255]` (the custom-float datapaths quantize
//! internally).  PGM (P2/P5) is supported so real images can be run
//! through the pipelines and results inspected with standard tools.

use anyhow::{bail, Context, Result};
use std::io::Write as _;
use std::path::Path;

use crate::util::rng::Rng;

/// A single grayscale frame (row-major doubles).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub width: usize,
    pub height: usize,
    pub data: Vec<f64>,
}

impl Frame {
    pub fn new(width: usize, height: usize) -> Self {
        Self { width, height, data: vec![0.0; width * height] }
    }

    pub fn from_fn(width: usize, height: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(width * height);
        for y in 0..height {
            for x in 0..width {
                data.push(f(x, y));
            }
        }
        Self { width, height, data }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> f64 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: f64) {
        self.data[y * self.width + x] = v;
    }

    /// Replicate-clamped read (matches jnp.pad mode='edge').
    #[inline]
    pub fn get_clamped(&self, x: isize, y: isize) -> f64 {
        let xc = x.clamp(0, self.width as isize - 1) as usize;
        let yc = y.clamp(0, self.height as isize - 1) as usize;
        self.get(xc, yc)
    }

    // --- synthetic patterns (workload generators) -------------------------

    /// Smooth diagonal gradient, range [0, 255].
    pub fn gradient(width: usize, height: usize) -> Self {
        Self::from_fn(width, height, |x, y| {
            255.0 * (x + y) as f64 / (width + height - 2).max(1) as f64
        })
    }

    /// Checkerboard with `cell`-pixel squares (edge-rich: exercises Sobel).
    pub fn checkerboard(width: usize, height: usize, cell: usize) -> Self {
        Self::from_fn(width, height, |x, y| {
            if ((x / cell) + (y / cell)) % 2 == 0 {
                255.0
            } else {
                0.0
            }
        })
    }

    /// Uniform noise in [0, 255] (denoising workloads).
    pub fn noise(width: usize, height: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Self::from_fn(width, height, |_, _| rng.uniform(0.0, 255.0).floor())
    }

    /// Gradient corrupted by salt-and-pepper noise with probability `p`
    /// (the median filter's motivating workload, §III-C).
    pub fn salt_pepper(width: usize, height: usize, p: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let base = Self::gradient(width, height);
        Self::from_fn(width, height, |x, y| {
            let r = rng.next_f64();
            if r < p / 2.0 {
                0.0
            } else if r < p {
                255.0
            } else {
                base.get(x, y)
            }
        })
    }

    /// Natural-image-like test card: smooth shading + circles + bars.
    /// Deterministic, structured, non-trivial at every scale.
    pub fn test_card(width: usize, height: usize) -> Self {
        let (wf, hf) = (width as f64, height as f64);
        Self::from_fn(width, height, |x, y| {
            let (xf, yf) = (x as f64, y as f64);
            let shade = 96.0 + 64.0 * (xf / wf) + 32.0 * (yf / hf);
            let cx = wf * 0.5;
            let cy = hf * 0.5;
            let r = ((xf - cx).powi(2) + (yf - cy).powi(2)).sqrt();
            let ring = if (r / (wf * 0.08)).fract() < 0.5 { 40.0 } else { -40.0 };
            let bars = if x % 16 < 2 { 60.0 } else { 0.0 };
            (shade + ring * (-r / (wf * 0.4)).exp() + bars).clamp(0.0, 255.0)
        })
    }

    // --- metrics -----------------------------------------------------------

    /// Peak signal-to-noise ratio against a reference frame (dB, 255 peak).
    pub fn psnr(&self, other: &Frame) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        let mse: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / self.data.len() as f64;
        if mse == 0.0 {
            return f64::INFINITY;
        }
        10.0 * (255.0 * 255.0 / mse).log10()
    }

    /// Maximum absolute pixel difference.
    pub fn max_abs_diff(&self, other: &Frame) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    // --- PGM I/O -------------------------------------------------------------

    /// Load a PGM (P2 ascii or P5 binary, maxval ≤ 255).
    pub fn load_pgm(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        parse_pgm(&bytes)
    }

    /// Save as binary PGM (P5), clamping/rounding pixels to [0, 255].
    pub fn save_pgm(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = Vec::with_capacity(self.data.len() + 32);
        write!(out, "P5\n{} {}\n255\n", self.width, self.height)?;
        out.extend(
            self.data
                .iter()
                .map(|&v| v.round().clamp(0.0, 255.0) as u8),
        );
        std::fs::write(&path, out)
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }
}

fn parse_pgm(bytes: &[u8]) -> Result<Frame> {
    // Tokenize the header: magic, width, height, maxval (comments start '#').
    let mut pos = 0usize;
    let mut tokens: Vec<String> = Vec::new();
    while tokens.len() < 4 && pos < bytes.len() {
        match bytes[pos] {
            b'#' => {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
            }
            c if c.is_ascii_whitespace() => pos += 1,
            _ => {
                let start = pos;
                while pos < bytes.len()
                    && !bytes[pos].is_ascii_whitespace()
                    && bytes[pos] != b'#'
                {
                    pos += 1;
                }
                tokens.push(String::from_utf8_lossy(&bytes[start..pos]).into_owned());
            }
        }
    }
    if tokens.len() < 4 {
        bail!("truncated PGM header");
    }
    let magic = tokens[0].as_str();
    let width: usize = tokens[1].parse().context("PGM width")?;
    let height: usize = tokens[2].parse().context("PGM height")?;
    let maxval: u32 = tokens[3].parse().context("PGM maxval")?;
    if maxval == 0 || maxval > 255 {
        bail!("unsupported PGM maxval {maxval}");
    }
    let n = width * height;
    let data: Vec<f64> = match magic {
        "P5" => {
            pos += 1; // single whitespace after maxval
            let raster = &bytes[pos..];
            if raster.len() < n {
                bail!("P5 raster too short: {} < {n}", raster.len());
            }
            raster[..n].iter().map(|&b| b as f64).collect()
        }
        "P2" => {
            let text = String::from_utf8_lossy(&bytes[pos..]);
            let vals: Vec<f64> = text
                .split_whitespace()
                .take(n)
                .map(|t| t.parse::<f64>().unwrap_or(0.0))
                .collect();
            if vals.len() < n {
                bail!("P2 raster too short: {} < {n}", vals.len());
            }
            vals
        }
        other => bail!("unsupported PGM magic {other:?}"),
    };
    Ok(Frame { width, height, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_round_trip() {
        let f = Frame::test_card(37, 23);
        let path = std::env::temp_dir().join("fpspatial_test_card.pgm");
        f.save_pgm(&path).unwrap();
        let g = Frame::load_pgm(&path).unwrap();
        assert_eq!(g.width, 37);
        assert_eq!(g.height, 23);
        // save rounds to u8: within 0.5
        assert!(f.max_abs_diff(&g) <= 0.5);
    }

    #[test]
    fn p2_parse() {
        let txt = b"P2\n# comment\n3 2\n255\n0 128 255\n10 20 30\n";
        let f = parse_pgm(txt).unwrap();
        assert_eq!((f.width, f.height), (3, 2));
        assert_eq!(f.get(1, 0), 128.0);
        assert_eq!(f.get(2, 1), 30.0);
    }

    #[test]
    fn clamped_reads() {
        let f = Frame::gradient(4, 4);
        assert_eq!(f.get_clamped(-3, -3), f.get(0, 0));
        assert_eq!(f.get_clamped(10, 2), f.get(3, 2));
    }

    #[test]
    fn psnr_identical_is_inf() {
        let f = Frame::noise(8, 8, 1);
        assert!(f.psnr(&f).is_infinite());
    }

    #[test]
    fn salt_pepper_density() {
        let f = Frame::salt_pepper(100, 100, 0.2, 3);
        let extremes = f
            .data
            .iter()
            .filter(|&&v| v == 0.0 || v == 255.0)
            .count();
        // ≈ 20% ± some gradient pixels that happen to be 0/255
        assert!((1000..3500).contains(&extremes), "{extremes}");
    }

    #[test]
    fn patterns_in_range() {
        for f in [
            Frame::gradient(16, 16),
            Frame::checkerboard(16, 16, 4),
            Frame::noise(16, 16, 5),
            Frame::test_card(32, 32),
        ] {
            assert!(f.data.iter().all(|&v| (0.0..=255.0).contains(&v)));
        }
    }
}
