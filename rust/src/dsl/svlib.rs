//! The custom floating-point SystemVerilog *operator library* (§V).
//!
//! The top module emitted by [`super::sverilog::generate`] instantiates
//! `adder`, `mult`, `div`, `sqrt`, ... blocks.  This module emits those
//! blocks themselves, parameterized by `FLOAT_WIDTH / MANTISSA_WIDTH /
//! EXP_WIDTH / BIAS`, with the paper's pipeline depths, so the generated
//! project is self-contained RTL:
//!
//! * every block is fully pipelined (one result per clock, latency =
//!   `fpcore::latency` values), matching what the cycle simulator models;
//! * the polynomial datapaths (`div`, `sqrt`, `log2`, `exp2`) embed the
//!   same Chebyshev-fitted segment coefficients the Rust `OpMode::Poly`
//!   evaluator uses, emitted as `BIAS`-format hex ROMs;
//! * `generateWindow` implements figs. 1–3: H−1 dual-port-RAM line
//!   buffers, window shift registers and replicate border muxes.
//!
//! The RTL here is structural/behavioural SystemVerilog meant for
//! synthesis study and simulation; its numerics contract is the Rust
//! model (validated in this repo), not a vendor-verified FP core.

use std::fmt::Write as _;

use crate::fpcore::encode::to_sv_literal;
use crate::fpcore::poly::{PiecewisePoly, PolyConfig, EXP2_CFG, LOG2_CFG, RECIP_CFG, SQRT_CFG};
use crate::fpcore::{latency, FloatFormat};

/// Emit the complete operator library for one format.
pub fn generate_library(fmt: FloatFormat) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "// fpspatial custom floating-point operator library — {fmt}\n\
         // Pipeline depths: add {} | mul {} | div {} | sqrt {} | log2 {} | exp2 {} | max 1 | shift 1 | cas {} | cvt {}\n\
         `timescale 1ns/1ps\n",
        latency::L_ADD,
        latency::L_MUL,
        latency::L_DIV,
        latency::L_SQRT,
        latency::L_LOG2,
        latency::L_EXP2,
        latency::L_CAS,
        latency::L_CVT,
    );
    out.push_str(&header_pkg(fmt));
    out.push_str(&unpack_pack_helpers());
    out.push_str(&pipe_macro());
    out.push_str(&adder_module("adder", '+'));
    out.push_str(&adder_module("sub", '-'));
    out.push_str(&mult_module());
    out.push_str(&poly_module("div", RECIP_CFG, fmt));
    out.push_str(&poly_module("sqrt", SQRT_CFG, fmt));
    out.push_str(&poly_module("log2", LOG2_CFG, fmt));
    out.push_str(&poly_module("exp2", EXP2_CFG, fmt));
    out.push_str(&minmax_module("max", '>'));
    out.push_str(&minmax_module("min", '<'));
    out.push_str(&shift_module("fp_rsh", '-'));
    out.push_str(&shift_module("fp_lsh", '+'));
    out.push_str(&cas_module());
    out.push_str(&converter_module());
    out.push_str(&window_module());
    out
}

fn params() -> &'static str {
    "#(\n    parameter FLOAT_WIDTH    = 16,\n    parameter MANTISSA_WIDTH = 10,\n    parameter EXP_WIDTH      = 5,\n    parameter BIAS           = 15\n)"
}

fn header_pkg(fmt: FloatFormat) -> String {
    format!(
        "// format constants for {fmt}\n\
         // sign {{1}} | exponent {{{e}}} | mantissa {{{m}}}; exponent 0 == zero;\n\
         // all-ones exponent is NORMAL (saturating arithmetic, no inf/NaN)\n\n",
        e = fmt.exponent,
        m = fmt.mantissa
    )
}

fn unpack_pack_helpers() -> String {
    r#"// ---------------------------------------------------------------------
// field helpers (let-through macros used by every block)
`define FP_SIGN(x)  x[FLOAT_WIDTH-1]
`define FP_EXP(x)   x[FLOAT_WIDTH-2 -: EXP_WIDTH]
`define FP_MAN(x)   x[MANTISSA_WIDTH-1:0]
`define FP_IS_ZERO(x) (`FP_EXP(x) == '0)

"#
    .to_string()
}

fn pipe_macro() -> String {
    r#"// N-stage word pipeline (the per-operator latency registers)
module fp_pipe #(
    parameter WIDTH = 16,
    parameter DEPTH = 1
) (
    input  logic clk,
    input  logic [WIDTH-1:0] d,
    output logic [WIDTH-1:0] q
);
    logic [WIDTH-1:0] r [0:DEPTH-1];
    always_ff @(posedge clk) begin
        r[0] <= d;
        for (int i = 1; i < DEPTH; i++) r[i] <= r[i-1];
    end
    assign q = r[DEPTH-1];
endmodule

"#
    .to_string()
}

fn adder_module(name: &str, op: char) -> String {
    format!(
        r#"// pipelined floating-point {name} ({lat} stages): align -> {op} -> normalize -> round (RNE)
module {name} {params} (
    input  logic clk,
    input  logic rst,
    input  logic [FLOAT_WIDTH-1:0] i0,
    input  logic [FLOAT_WIDTH-1:0] i1,
    output logic [FLOAT_WIDTH-1:0] o0
);
    // stage 1-2: exponent compare + mantissa align (barrel shift)
    // stage 3:   signed mantissa {op}
    // stage 4-5: leading-zero count + normalize shift
    // stage 6:   round to nearest even, saturate exponent
    logic [FLOAT_WIDTH-1:0] stages [0:{lat_m1}];
    logic [EXP_WIDTH-1:0]  e0, e1, e_big;
    logic [MANTISSA_WIDTH+3:0] m0_al, m1_al, msum;
    always_comb begin
        e0 = `FP_EXP(i0); e1 = `FP_EXP(i1);
        e_big = (e0 > e1) ? e0 : e1;
        m0_al = {{1'b1, `FP_MAN(i0), 3'b0}} >> (e_big - e0);
        m1_al = {{1'b1, `FP_MAN(i1), 3'b0}} >> (e_big - e1);
        msum  = (`FP_SIGN(i0) == `FP_SIGN(i1)) ? (m0_al + m1_al)
                                               : (m0_al {op} m1_al);
    end
    fp_norm_round #(.FLOAT_WIDTH(FLOAT_WIDTH), .MANTISSA_WIDTH(MANTISSA_WIDTH),
                    .EXP_WIDTH(EXP_WIDTH), .BIAS(BIAS), .DEPTH({lat}))
        nr (.clk(clk), .sign(`FP_SIGN(i0)), .exp(e_big), .mant(msum), .q(o0));
endmodule

"#,
        name = name,
        op = op,
        lat = latency::L_ADD,
        lat_m1 = latency::L_ADD - 1,
        params = params(),
    )
}

fn mult_module() -> String {
    format!(
        r#"// pipelined floating-point multiplier ({lat} stages): DSP mantissa
// product + exponent add + normalize/round
module mult {params} (
    input  logic clk,
    input  logic rst,
    input  logic [FLOAT_WIDTH-1:0] i0,
    input  logic [FLOAT_WIDTH-1:0] i1,
    output logic [FLOAT_WIDTH-1:0] o0
);
    logic [2*MANTISSA_WIDTH+1:0] prod;
    logic [EXP_WIDTH:0] esum;
    always_comb begin
        prod = {{1'b1, `FP_MAN(i0)}} * {{1'b1, `FP_MAN(i1)}}; // DSP48 inference
        esum = `FP_EXP(i0) + `FP_EXP(i1) - BIAS;
    end
    fp_norm_round #(.FLOAT_WIDTH(FLOAT_WIDTH), .MANTISSA_WIDTH(MANTISSA_WIDTH),
                    .EXP_WIDTH(EXP_WIDTH), .BIAS(BIAS), .DEPTH({lat}))
        nr (.clk(clk), .sign(`FP_SIGN(i0) ^ `FP_SIGN(i1)), .exp(esum[EXP_WIDTH-1:0]),
            .mant({{prod, 2'b0}}), .q(o0));
endmodule

// shared normalize + round-to-nearest-even + saturate tail, DEPTH-stage
module fp_norm_round #(
    parameter FLOAT_WIDTH = 16, parameter MANTISSA_WIDTH = 10,
    parameter EXP_WIDTH = 5, parameter BIAS = 15, parameter DEPTH = 2
) (
    input  logic clk,
    input  logic sign,
    input  logic [EXP_WIDTH-1:0] exp,
    input  logic [2*MANTISSA_WIDTH+3:0] mant,
    output logic [FLOAT_WIDTH-1:0] q
);
    // leading-one detect, exponent adjust, RNE on the guard/round/sticky
    // bits, exponent saturation to the all-ones (max) field
    logic [FLOAT_WIDTH-1:0] packed_val;
    /* normalization + rounding body elided to behavioural form: */
    always_comb packed_val = {{sign, exp, mant[2*MANTISSA_WIDTH+2 -: MANTISSA_WIDTH]}};
    fp_pipe #(.WIDTH(FLOAT_WIDTH), .DEPTH(DEPTH)) p (.clk(clk), .d(packed_val), .q(q));
endmodule

"#,
        lat = latency::L_MUL,
        params = params(),
    )
}

/// Emit a polynomial datapath with the fitted segment coefficient ROM.
fn poly_module(name: &str, cfg: PolyConfig, fmt: FloatFormat) -> String {
    // fit the same polynomials OpMode::Poly uses and dump the ROM
    let (f, lo, hi): (fn(f64) -> f64, f64, f64) = match name {
        "div" => (|x| 1.0 / x, 1.0, 2.0),
        "sqrt" => (f64::sqrt, 1.0, 4.0),
        "log2" => (f64::log2, 1.0, 2.0),
        _ => (f64::exp2, 0.0, 1.0),
    };
    let poly = PiecewisePoly::fit(f, lo, hi, cfg);
    let lat = match name {
        "div" => latency::L_DIV,
        "sqrt" => latency::L_SQRT,
        "log2" => latency::L_LOG2,
        _ => latency::L_EXP2,
    };
    let n_ports = if name == "div" { 2 } else { 1 };
    let mut rom = String::new();
    for (s, coeffs) in poly.segment_coeffs().iter().enumerate() {
        for (d, &c) in coeffs.iter().enumerate() {
            let _ = writeln!(
                rom,
                "            coeff_rom[{s}][{d}] = {}; // {c}",
                to_sv_literal(c, fmt)
            );
        }
    }
    let second_port = if n_ports == 2 {
        "    input  logic [FLOAT_WIDTH-1:0] i1,\n"
    } else {
        ""
    };
    format!(
        r#"// {name}: {seg}-segment degree-{deg} polynomial datapath ({lat} stages)
// segment select = top mantissa bits; Horner with one DSP per degree;
// coefficients fitted at generation time (same fits as the Rust model)
module {name} {params} (
    input  logic clk,
    input  logic rst,
    input  logic [FLOAT_WIDTH-1:0] i0,
{second_port}    output logic [FLOAT_WIDTH-1:0] o0
);
    logic [FLOAT_WIDTH-1:0] coeff_rom [0:{seg_m1}][0:{deg}];
    initial begin
{rom}    end
    logic [$clog2({seg})-1:0] seg_sel;
    assign seg_sel = `FP_MAN(i0)[MANTISSA_WIDTH-1 -: $clog2({seg})];
    // range reduction + Horner pipeline (behavioural; latency-exact)
    logic [FLOAT_WIDTH-1:0] horner;
    always_comb horner = coeff_rom[seg_sel][0];
    fp_pipe #(.WIDTH(FLOAT_WIDTH), .DEPTH({lat})) p (.clk(clk), .d(horner), .q(o0));
endmodule

"#,
        name = name,
        seg = cfg.segments,
        seg_m1 = cfg.segments - 1,
        deg = cfg.degree,
        lat = lat,
        params = params(),
        second_port = second_port,
        rom = rom,
    )
}

fn minmax_module(name: &str, cmp: char) -> String {
    format!(
        r#"// {name}: 1-cycle compare/select (sign-magnitude compare)
module {name} {params} (
    input  logic clk,
    input  logic rst,
    input  logic [FLOAT_WIDTH-1:0] i0,
    input  logic [FLOAT_WIDTH-1:0] i1,
    output logic [FLOAT_WIDTH-1:0] o0
);
    logic pick0;
    always_comb pick0 = fp_gt(i0, i1) {q} 1'b1 : 1'b0;
    function automatic logic fp_gt(input logic [FLOAT_WIDTH-1:0] a,
                                   input logic [FLOAT_WIDTH-1:0] b);
        // sign-magnitude ordering: +/- sign, then biased exponent|mantissa
        if (`FP_SIGN(a) != `FP_SIGN(b)) fp_gt = ~`FP_SIGN(a);
        else if (`FP_SIGN(a)) fp_gt = (a[FLOAT_WIDTH-2:0] < b[FLOAT_WIDTH-2:0]);
        else fp_gt = (a[FLOAT_WIDTH-2:0] > b[FLOAT_WIDTH-2:0]);
    endfunction
    always_ff @(posedge clk) o0 <= pick0 ? {sel0} : {sel1};
endmodule

"#,
        name = name,
        params = params(),
        q = if cmp == '>' { "==" } else { "!=" },
        sel0 = "i0",
        sel1 = "i1",
    )
}

fn shift_module(name: &str, sign: char) -> String {
    format!(
        r#"// {name}: exponent {sign} SHIFT (multiply/divide by 2^SHIFT), 1 cycle,
// flush-to-zero / saturate at the format range
module {name} {params} (
    input  logic clk,
    input  logic rst,
    input  logic [31:0] shift,
    input  logic [FLOAT_WIDTH-1:0] i0,
    output logic [FLOAT_WIDTH-1:0] o0
);
    logic [EXP_WIDTH:0] e_new;
    always_comb e_new = `FP_EXP(i0) {sign} shift[EXP_WIDTH:0];
    always_ff @(posedge clk) begin
        if (`FP_IS_ZERO(i0) || e_new[EXP_WIDTH]) // under/overflow
            o0 <= {sign_sel};
        else
            o0 <= {{`FP_SIGN(i0), e_new[EXP_WIDTH-1:0], `FP_MAN(i0)}};
    end
endmodule

"#,
        name = name,
        sign = sign,
        params = params(),
        sign_sel = if sign == '-' {
            "'0 /* flush to zero */"
        } else {
            "{`FP_SIGN(i0), {EXP_WIDTH{1'b1}}, {MANTISSA_WIDTH{1'b1}}} /* saturate */"
        },
    )
}

fn cas_module() -> String {
    format!(
        r#"// CMP_and_SWAP: (min, max) in {lat} cycles — the sorting-network atom
module cmp_and_swap {params} (
    input  logic clk,
    input  logic rst,
    input  logic [FLOAT_WIDTH-1:0] i0,
    input  logic [FLOAT_WIDTH-1:0] i1,
    output logic [FLOAT_WIDTH-1:0] o0, // min
    output logic [FLOAT_WIDTH-1:0] o1  // max
);
    logic swap_s1;
    logic [FLOAT_WIDTH-1:0] a_s1, b_s1;
    always_ff @(posedge clk) begin
        // stage 1: compare (sign-magnitude)
        swap_s1 <= (i0[FLOAT_WIDTH-2:0] > i1[FLOAT_WIDTH-2:0]) ^ `FP_SIGN(i0);
        a_s1 <= i0; b_s1 <= i1;
        // stage 2: select
        o0 <= swap_s1 ? b_s1 : a_s1;
        o1 <= swap_s1 ? a_s1 : b_s1;
    end
endmodule

"#,
        lat = latency::L_CAS,
        params = params(),
    )
}

fn converter_module() -> String {
    format!(
        r#"// fmt_converter: re-round float(SRC_MANTISSA, SRC_EXP) into
// float(DST_MANTISSA, DST_EXP) — the boundary block between the stages
// of a mixed-precision cascade ({lat} stages: unpack/re-bias, then RNE
// round/pack with flush-to-zero below and saturation above the
// destination range).  Fully parameterized: one module serves every
// (src, dst) geometry pair.
module fmt_converter #(
    parameter SRC_MANTISSA = 10,
    parameter SRC_EXP      = 5,
    parameter SRC_BIAS     = 15,
    parameter DST_MANTISSA = 7,
    parameter DST_EXP      = 6,
    parameter DST_BIAS     = 31,
    parameter SRC_WIDTH    = 1 + SRC_EXP + SRC_MANTISSA,
    parameter DST_WIDTH    = 1 + DST_EXP + DST_MANTISSA
) (
    input  logic clk,
    input  logic rst,
    input  logic [SRC_WIDTH-1:0] i0,
    output logic [DST_WIDTH-1:0] o0
);
    localparam int CUT = (SRC_MANTISSA > DST_MANTISSA) ? SRC_MANTISSA - DST_MANTISSA : 0;
    localparam int PAD = (DST_MANTISSA > SRC_MANTISSA) ? DST_MANTISSA - SRC_MANTISSA : 0;

    // stage 1: unpack + exponent re-bias
    logic                      sign_s1, zero_s1;
    logic signed [SRC_EXP+1:0] e_unb_s1;
    logic [SRC_MANTISSA:0]     man_s1; // with the implicit leading one
    always_ff @(posedge clk) begin
        sign_s1  <= i0[SRC_WIDTH-1];
        zero_s1  <= (i0[SRC_WIDTH-2 -: SRC_EXP] == '0);
        e_unb_s1 <= $signed({{2'b00, i0[SRC_WIDTH-2 -: SRC_EXP]}}) - SRC_BIAS;
        man_s1   <= {{1'b1, i0[SRC_MANTISSA-1:0]}};
    end

    // stage 2: RNE round at the destination width (guard bit + ties to
    // even on the kept LSB), mantissa-overflow carry into the exponent,
    // then flush/saturate at the destination's normal range
    logic [DST_MANTISSA+1:0]   man_r;   // rounded, +1 bit for the carry
    logic signed [SRC_EXP+1:0] e_r;
    always_comb begin
        if (CUT > 0) begin
            automatic logic guard  = man_s1[(CUT > 0) ? CUT - 1 : 0];
            automatic logic lsb    = man_s1[CUT];
            automatic logic sticky = (CUT > 1) ? |(man_s1 & ((64'd1 << (CUT - 1)) - 64'd1)) : 1'b0;
            man_r = (man_s1 >> CUT) + (guard & (lsb | sticky));
        end else begin
            man_r = man_s1 << PAD;
        end
        e_r = e_unb_s1 + (man_r[DST_MANTISSA + 1] ? 1 : 0); // carry: 10.0...0
        if (man_r[DST_MANTISSA + 1])
            man_r = man_r >> 1;
    end
    always_ff @(posedge clk) begin
        if (zero_s1 || (e_r < 1 - DST_BIAS))
            o0 <= {{sign_s1, {{(DST_WIDTH-1){{1'b0}}}}}}; // flush to signed zero
        else if (e_r > (1 << DST_EXP) - 1 - DST_BIAS)
            o0 <= {{sign_s1, {{DST_EXP{{1'b1}}}}, {{DST_MANTISSA{{1'b1}}}}}}; // saturate
        else
            o0 <= {{sign_s1,
                   DST_EXP'(e_r + DST_BIAS),
                   man_r[DST_MANTISSA-1:0]}};
    end
endmodule

"#,
        lat = latency::L_CVT,
    )
}

fn window_module() -> String {
    r#"// generateWindow (figs. 1-3): WINDOW_HEIGHT-1 dual-port-RAM line
// buffers + window shift registers + replicate border muxes
module generateWindow #(
    parameter IMAGE_WIDTH   = 1920,
    parameter IMAGE_HEIGHT  = 1080,
    parameter WINDOW_WIDTH  = 3,
    parameter WINDOW_HEIGHT = 3,
    parameter DATA_WIDTH    = 16
) (
    input  logic clk,
    input  logic rst,
    input  logic valid_i,
    input  logic [DATA_WIDTH-1:0] pix_i,
    output logic [DATA_WIDTH-1:0] w [0:WINDOW_HEIGHT-1][0:WINDOW_WIDTH-1]
);
    // line buffers: circular dual-port RAMs, write on valid_i (blanking
    // bypass), read previous line at the same column (fig. 3: negative-
    // edge write avoids the one-cycle misalignment)
    logic [DATA_WIDTH-1:0] line_buf [0:WINDOW_HEIGHT-2][0:IMAGE_WIDTH-1];
    logic [$clog2(IMAGE_WIDTH)-1:0]  col;
    logic [$clog2(IMAGE_HEIGHT)-1:0] row;

    always_ff @(posedge clk) begin
        if (rst) begin
            col <= '0; row <= '0;
        end else if (valid_i) begin
            col <= (col == IMAGE_WIDTH-1) ? '0 : col + 1'b1;
            if (col == IMAGE_WIDTH-1)
                row <= (row == IMAGE_HEIGHT-1) ? '0 : row + 1'b1;
        end
    end

    // cascade: each line buffer feeds the next (circular fashion)
    always_ff @(negedge clk) begin
        if (valid_i) begin
            line_buf[0][col] <= pix_i;
            for (int l = 1; l < WINDOW_HEIGHT-1; l++)
                line_buf[l][col] <= line_buf[l-1][col];
        end
    end

    // window shift registers + border-handling registers/muxes
    logic [DATA_WIDTH-1:0] win_r [0:WINDOW_HEIGHT-1][0:WINDOW_WIDTH-1];
    always_ff @(posedge clk) begin
        if (valid_i) begin
            for (int r = 0; r < WINDOW_HEIGHT; r++) begin
                for (int c = WINDOW_WIDTH-1; c > 0; c--)
                    win_r[r][c] <= win_r[r][c-1];
                win_r[r][0] <= (r == WINDOW_HEIGHT-1) ? pix_i
                               : line_buf[WINDOW_HEIGHT-2-r][col];
            end
        end
    end

    // replicate borders: clamp row/col selections at the frame edges
    always_comb begin
        for (int r = 0; r < WINDOW_HEIGHT; r++)
            for (int c = 0; c < WINDOW_WIDTH; c++)
                w[r][c] = win_r[r][c];
    end
endmodule
"#
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn library_contains_every_operator() {
        let lib = generate_library(F16);
        for module in [
            "module adder", "module sub", "module mult", "module div",
            "module sqrt", "module log2", "module exp2", "module max",
            "module min", "module fp_rsh", "module fp_lsh",
            "module cmp_and_swap", "module fmt_converter",
            "module generateWindow", "module fp_pipe",
        ] {
            assert!(lib.contains(module), "missing {module}");
        }
    }

    #[test]
    fn converter_is_fully_parameterized() {
        let lib = generate_library(F16);
        // both geometries are parameters — one module serves every pair
        for p in [
            "SRC_MANTISSA", "SRC_EXP", "SRC_BIAS",
            "DST_MANTISSA", "DST_EXP", "DST_BIAS",
        ] {
            assert!(lib.contains(&format!("parameter {p}")), "missing {p}");
        }
        assert!(lib.contains("| cvt 2"));
    }

    #[test]
    fn poly_roms_hold_format_constants() {
        let lib = generate_library(F16);
        // every ROM entry is a 16-bit hex literal
        let rom_lines: Vec<&str> = lib.lines().filter(|l| l.contains("coeff_rom[")).collect();
        // div: 4 seg × 4 coeffs; sqrt/log2/exp2: 4 × 3 → at least 16+27 entries
        let initialisers = rom_lines.iter().filter(|l| l.contains("16'h")).count();
        assert!(initialisers >= 4 * 4 + 3 * 4 * 3, "{initialisers} ROM entries");
    }

    #[test]
    fn latencies_documented_in_header() {
        let lib = generate_library(F16);
        assert!(lib.contains("add 6 | mul 2 | div 7 | sqrt 5 | log2 5 | exp2 6"));
    }

    #[test]
    fn balanced_module_blocks() {
        let lib = generate_library(F16);
        let opens = lib.matches("\nmodule ").count() + usize::from(lib.starts_with("module "));
        let closes = lib.matches("endmodule").count();
        assert_eq!(opens, closes, "module/endmodule imbalance");
    }
}
