//! Abstract syntax of the custom floating-point DSL (§V, figs. 12/14/16).
//!
//! The language is untimed and sequential: one operation per statement,
//! assigned to a declared `float` variable.  The compiler (lower.rs) turns
//! the program into a scheduled netlist; timing (Δ delays, pipeline
//! stages) never appears in the source.

/// A parsed DSL program.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    /// `use float(m, e);`
    pub format: (u32, u32),
    /// `input x, y;` — scalar input ports (window filters instead use
    /// `sliding_window`, which implicitly reads the pixel stream `pix_i`).
    pub inputs: Vec<String>,
    /// `output z;`
    pub outputs: Vec<String>,
    /// `var float a, b;` and `var float w[3][3];`
    pub vars: Vec<VarDecl>,
    /// `image_resolution(1920, 1080);` if present.
    pub resolution: Option<(u32, u32)>,
    pub stmts: Vec<Stmt>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct VarDecl {
    pub name: String,
    /// `None` for scalars, `Some((rows, cols))` for 2-D arrays.
    pub dims: Option<(usize, usize)>,
    pub line: usize,
}

/// A variable reference: scalar `x` or element `w[1][2]`.
#[derive(Debug, Clone, PartialEq)]
pub struct VarRef {
    pub name: String,
    pub index: Option<(usize, usize)>,
}

impl VarRef {
    pub fn scalar(name: &str) -> Self {
        Self { name: name.to_string(), index: None }
    }

    pub fn display(&self) -> String {
        match self.index {
            Some((i, j)) => format!("{}[{i}][{j}]", self.name),
            None => self.name.clone(),
        }
    }
}

/// Right-hand sides.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// `x` or `w[0][1]`
    Var(VarRef),
    /// numeric literal
    Lit(f64),
    /// `f(arg, ...)` — operator or macro call
    Call { func: String, args: Vec<Expr> },
    /// `FP_RSH(x) >> n` / `FP_LSH(x) << n`
    Shift { left: bool, arg: Box<Expr>, amount: u32 },
    /// `[[1,2],[3,4]]` — kernel literal (array init)
    Matrix(Vec<Vec<f64>>),
}

/// One statement.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `lhs = expr;` — single assignment
    Assign { lhs: VarRef, rhs: Expr, line: usize },
    /// `[a, b] = cmp_and_swap(x, y);`
    AssignPair { lhs: (VarRef, VarRef), rhs: Expr, line: usize },
}

impl Stmt {
    pub fn line(&self) -> usize {
        match self {
            Stmt::Assign { line, .. } | Stmt::AssignPair { line, .. } => *line,
        }
    }
}
