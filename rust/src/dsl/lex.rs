//! Tokenizer for the DSL.  `#` starts a line comment (fig. 12 line 1).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Num(f64),
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Assign,
    Shr, // >>
    Shl, // <<
}

#[derive(Debug, Clone, PartialEq)]
pub struct SpannedTok {
    pub tok: Tok,
    pub line: usize,
}

/// Tokenize a whole source file.
pub fn lex(src: &str) -> Result<Vec<SpannedTok>> {
    let mut out = Vec::new();
    for (lineno, line) in src.lines().enumerate() {
        let line_no = lineno + 1;
        let b = line.as_bytes();
        let mut i = 0;
        while i < b.len() {
            let c = b[i];
            match c {
                b'#' => break, // comment to end of line
                c if c.is_ascii_whitespace() => i += 1,
                b'(' => {
                    out.push(SpannedTok { tok: Tok::LParen, line: line_no });
                    i += 1;
                }
                b')' => {
                    out.push(SpannedTok { tok: Tok::RParen, line: line_no });
                    i += 1;
                }
                b'[' => {
                    out.push(SpannedTok { tok: Tok::LBracket, line: line_no });
                    i += 1;
                }
                b']' => {
                    out.push(SpannedTok { tok: Tok::RBracket, line: line_no });
                    i += 1;
                }
                b',' => {
                    out.push(SpannedTok { tok: Tok::Comma, line: line_no });
                    i += 1;
                }
                b';' => {
                    out.push(SpannedTok { tok: Tok::Semi, line: line_no });
                    i += 1;
                }
                b'=' => {
                    out.push(SpannedTok { tok: Tok::Assign, line: line_no });
                    i += 1;
                }
                b'>' if b.get(i + 1) == Some(&b'>') => {
                    out.push(SpannedTok { tok: Tok::Shr, line: line_no });
                    i += 2;
                }
                b'<' if b.get(i + 1) == Some(&b'<') => {
                    out.push(SpannedTok { tok: Tok::Shl, line: line_no });
                    i += 2;
                }
                c if c.is_ascii_alphabetic() || c == b'_' => {
                    let start = i;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                    out.push(SpannedTok {
                        tok: Tok::Ident(line[start..i].to_string()),
                        line: line_no,
                    });
                }
                c if c.is_ascii_digit() || c == b'-' || c == b'.' => {
                    let start = i;
                    i += 1;
                    while i < b.len()
                        && (b[i].is_ascii_digit()
                            || b[i] == b'.'
                            || b[i] == b'e'
                            || b[i] == b'E'
                            || ((b[i] == b'-' || b[i] == b'+')
                                && matches!(b[i - 1], b'e' | b'E')))
                    {
                        i += 1;
                    }
                    let txt = &line[start..i];
                    match txt.parse::<f64>() {
                        Ok(v) => out.push(SpannedTok { tok: Tok::Num(v), line: line_no }),
                        Err(_) => bail!("line {line_no}: bad number {txt:?}"),
                    }
                }
                other => bail!("line {line_no}: unexpected character {:?}", other as char),
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lex_fig12_line() {
        let toks = lex("m = mult(x, y);").unwrap();
        let kinds: Vec<&Tok> = toks.iter().map(|t| &t.tok).collect();
        assert_eq!(
            kinds,
            vec![
                &Tok::Ident("m".into()),
                &Tok::Assign,
                &Tok::Ident("mult".into()),
                &Tok::LParen,
                &Tok::Ident("x".into()),
                &Tok::Comma,
                &Tok::Ident("y".into()),
                &Tok::RParen,
                &Tok::Semi
            ]
        );
    }

    #[test]
    fn comments_ignored() {
        let toks = lex("# DSL code to compute z\nuse float(10, 5);").unwrap();
        assert_eq!(toks[0].tok, Tok::Ident("use".into()));
        assert_eq!(toks[0].line, 2);
    }

    #[test]
    fn numbers_including_negative_and_exponent() {
        let toks = lex("K = [-1.0, 2e-3, 0.0313];").unwrap();
        let nums: Vec<f64> = toks
            .iter()
            .filter_map(|t| match t.tok {
                Tok::Num(v) => Some(v),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec![-1.0, 2e-3, 0.0313]);
    }

    #[test]
    fn shifts() {
        let toks = lex("f0 = FP_RSH(a0) >> 1;").unwrap();
        assert!(toks.iter().any(|t| t.tok == Tok::Shr));
        let toks = lex("f1 = FP_LSH(a1) << 3;").unwrap();
        assert!(toks.iter().any(|t| t.tok == Tok::Shl));
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("a = $;").is_err());
    }
}
