//! The custom floating-point DSL compiler (§V).
//!
//! Pipeline: [`lex`] → [`parse`] → [`lower`] (type check + macro expansion
//! + the §III-D latency-balancing schedule) → [`sverilog::generate`]
//! (pipelined SystemVerilog) / [`crate::sim::Engine`] (simulation) /
//! [`crate::resources`] (FPGA cost estimate).
//!
//! ## Language summary (figs. 12/14/16)
//!
//! ```text
//! # comment
//! use float(10, 5);                 # format: m mantissa, e exponent bits
//! input x, y;                       # scalar ports (non-window programs)
//! output z;
//! var float x, y, m, z;             # every variable is a custom float
//! var float w[3][3], K[3][3];       # 2-D arrays
//! image_resolution(1920, 1080);     # frame geometry for the window
//! w = sliding_window(pix_i, 3, 3);  # H×W stream window (line buffers)
//! K = [[1.0, 2.0, 1.0], ...];       # kernel literal → hex constants
//! m = mult(x, y);                   # operators: mult adder sub div sqrt
//! z = sqrt(m);                      #   log2 exp2 max min
//! f0 = FP_RSH(a0) >> 1;             # exponent shifts (×/÷ powers of two)
//! [g1, g2] = cmp_and_swap(f1, f2);  # two-output CAS
//! pix_o = conv3x3(w, K);            # filter macros: conv3x3 conv5x5
//! pix_o = median3x3(w);             #   median3x3 (library extension)
//! ```
//!
//! The program is untimed and single-assignment; the compiler computes
//! every signal latency and inserts the Δ delay registers automatically.

pub mod ast;
pub mod interp;
pub mod lex;
pub mod lower;
pub mod parse;
pub mod sverilog;
pub mod svlib;

use anyhow::Result;

use crate::fpcore::FloatFormat;

pub use ast::Program;
pub use interp::Interp;
pub use lower::{Compiled, WindowSpec};

/// Compile DSL source to a scheduled netlist (+ window metadata).
pub fn compile(src: &str, name: &str) -> Result<Compiled> {
    compile_with_format(src, name, None)
}

/// Compile like [`compile`], optionally overriding the program's own
/// `use float(m, e);` directive — the CLI's `--format` flag, and the way
/// one DSL source is swept across format widths without editing it.
pub fn compile_with_format(src: &str, name: &str, fmt: Option<FloatFormat>) -> Result<Compiled> {
    let mut prog = parse::parse(src)?;
    if let Some(f) = fmt {
        prog.format = (f.mantissa, f.exponent);
    }
    lower::lower(&prog, name)
}

/// Compile DSL source all the way to SystemVerilog.
pub fn compile_to_sv(src: &str, name: &str) -> Result<String> {
    Ok(sverilog::generate(&compile(src, name)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::OpMode;
    use crate::sim::Engine;
    use crate::video::Frame;

    const NLFILTER_DSL: &str = include_str!("../../../examples/dsl/nlfilter.dsl");
    const MEDIAN_DSL: &str = include_str!("../../../examples/dsl/median.dsl");
    const CONV_DSL: &str = include_str!("../../../examples/dsl/conv3x3.dsl");
    const CONV5_DSL: &str = include_str!("../../../examples/dsl/conv5x5.dsl");
    const SOBEL_DSL: &str = include_str!("../../../examples/dsl/sobel.dsl");
    const FIG12_DSL: &str = include_str!("../../../examples/dsl/fig12.dsl");

    #[test]
    fn nlfilter_dsl_matches_builtin_netlist() {
        // The DSL transcription of fig. 16 must lower to a datapath with
        // the same schedule and numerics as the hand-built nlfilter.
        let c = compile(NLFILTER_DSL, "nlfilter").unwrap();
        assert_eq!(c.netlist.total_latency(), 26);
        let builtin = crate::filters::nlfilter::nlfilter_netlist(c.fmt);
        let mut a = Engine::new(&c.netlist, OpMode::Exact);
        let mut b = Engine::new(&builtin, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..300 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            assert_eq!(a.eval(&w), b.eval(&w), "window {w:?}");
        }
    }

    #[test]
    fn nlfilter_dsl_paper_deltas() {
        let c = compile(NLFILTER_DSL, "nlfilter").unwrap();
        // f1 (f^β) latency 15, f2 (f^δ) latency 9, CAS Δ = 6
        let f1 = c.netlist.signal_by_name("f1").unwrap();
        let f2 = c.netlist.signal_by_name("f2").unwrap();
        assert_eq!(c.netlist.signals[f1].latency, 15);
        assert_eq!(c.netlist.signals[f2].latency, 9);
        let cas = c
            .netlist
            .nodes
            .iter()
            .find(|n| n.op.name() == "cmp_and_swap")
            .unwrap();
        assert_eq!(cas.in_delays, vec![0, 6]);
    }

    #[test]
    fn median_dsl_matches_builtin() {
        let c = compile(MEDIAN_DSL, "median").unwrap();
        let builtin = crate::filters::median::median_netlist(c.fmt);
        assert_eq!(c.netlist.total_latency(), builtin.total_latency());
        let mut a = Engine::new(&c.netlist, OpMode::Exact);
        let mut b = Engine::new(&builtin, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(29);
        for _ in 0..200 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            assert_eq!(a.eval(&w), b.eval(&w));
        }
    }

    #[test]
    fn conv_dsl_runs_on_frames() {
        let c = compile(CONV_DSL, "conv").unwrap();
        let f = Frame::test_card(20, 14);
        let mut eng = Engine::new(&c.netlist, OpMode::Exact);
        let out = crate::video::map_windows(&f, 3, |w| eng.eval(w)[0]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn conv5x5_dsl_matches_builtin() {
        let c = compile(CONV5_DSL, "conv5").unwrap();
        assert_eq!(c.netlist.total_latency(), 32);
        let k = crate::filters::conv::gaussian5x5();
        let builtin = crate::filters::conv::conv_netlist(c.fmt, 5, &k);
        let mut a = Engine::new(&c.netlist, OpMode::Exact);
        let mut b = Engine::new(&builtin, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(41);
        for _ in 0..100 {
            let w: Vec<f64> = (0..25).map(|_| rng.uniform(0.0, 255.0)).collect();
            assert_eq!(a.eval(&w), b.eval(&w));
        }
    }

    #[test]
    fn sobel_dsl_matches_builtin() {
        let c = compile(SOBEL_DSL, "sobel").unwrap();
        assert_eq!(c.netlist.total_latency(), 39);
        let builtin = crate::filters::sobel::sobel_netlist(c.fmt);
        let mut a = Engine::new(&c.netlist, OpMode::Exact);
        let mut b = Engine::new(&builtin, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(53);
        for _ in 0..200 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            assert_eq!(a.eval(&w), b.eval(&w));
        }
    }

    #[test]
    fn format_override_rewidths_the_datapath() {
        use crate::fpcore::FloatFormat;
        // same source, swept to float32(23,8): constants re-quantize and
        // the schedule stays structurally identical
        let f16 = compile(CONV_DSL, "c").unwrap();
        let f32v = compile_with_format(CONV_DSL, "c", Some(FloatFormat::new(23, 8))).unwrap();
        assert_eq!(f16.fmt, FloatFormat::new(10, 5));
        assert_eq!(f32v.fmt, FloatFormat::new(23, 8));
        assert_eq!(f16.netlist.nodes.len(), f32v.netlist.nodes.len());
        assert_eq!(f16.netlist.total_latency(), f32v.netlist.total_latency());
        // wider format preserves more of the 6.75/16-style coefficients
        let mut eng = Engine::new(&f32v.netlist, OpMode::Exact);
        let out = eng.eval(&[16.0; 9])[0];
        assert!((out - 16.0).abs() < 1e-2, "{out}");
    }

    #[test]
    fn fig12_full_pipeline_to_sv() {
        let sv = compile_to_sv(FIG12_DSL, "fp_func").unwrap();
        assert!(sv.contains("module fp_func"));
    }

    #[test]
    fn rtl_sim_validates_dsl_schedules() {
        // every example program passes the RTL-vs-functional alignment check
        for (src, name) in [
            (FIG12_DSL, "fig12"),
            (NLFILTER_DSL, "nl"),
            (MEDIAN_DSL, "med"),
            (CONV_DSL, "conv"),
            (CONV5_DSL, "conv5"),
            (SOBEL_DSL, "sobel"),
        ] {
            let c = compile(src, name).unwrap();
            let nl = &c.netlist;
            let lat = nl.total_latency() as usize;
            let n_in = nl.inputs.len();
            let mut rtl = crate::sim::RtlSim::new(nl, OpMode::Exact);
            let mut func = Engine::new(nl, OpMode::Exact);
            let mut rng = crate::util::rng::Rng::new(31);
            let stream: Vec<Vec<f64>> = (0..lat + 40)
                .map(|_| (0..n_in).map(|_| rng.uniform(1.0, 255.0)).collect())
                .collect();
            let outs: Vec<f64> = stream.iter().map(|s| rtl.step(s)[0]).collect();
            for (t, s) in stream.iter().enumerate() {
                if t + lat < outs.len() {
                    assert_eq!(outs[t + lat], func.eval(s)[0], "{name} pixel {t}");
                }
            }
        }
    }
}
