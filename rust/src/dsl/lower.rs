//! Elaboration: type-checked lowering of a DSL [`Program`] into a
//! scheduled [`Netlist`] (§V).
//!
//! The compiler walks the untimed statements in order, binding each
//! single-assignment variable to a netlist signal (or compile-time
//! constant), expanding the window/filter macros (`sliding_window`,
//! `conv3x3`, `conv5x5`, `median3x3`), and selecting constant-folded
//! operator variants (`mult` by a literal → `mult_const`, a DSP with a
//! static coefficient; `max(x, 1)` → the 1-cycle compare/select guard).
//! The returned netlist is already scheduled — latency propagation and the
//! Δ delay-register insertion of §III-D happen in `Builder::build`.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Context, Result};

use super::ast::{Expr, Program, Stmt, VarRef};
use crate::fpcore::FloatFormat;
use crate::sim::netlist::{Builder, Netlist};
use crate::sim::SignalId;

/// Window-filter metadata (present when the program uses `sliding_window`).
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSpec {
    pub height: usize,
    pub width: usize,
    /// `image_resolution(W, H)` if given.
    pub resolution: Option<(u32, u32)>,
}

/// A compiled DSL program.
#[derive(Debug)]
pub struct Compiled {
    pub fmt: FloatFormat,
    pub netlist: Netlist,
    pub window: Option<WindowSpec>,
    /// Module name for the generated SystemVerilog.
    pub name: String,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Value {
    Sig(SignalId),
    Const(f64),
}

struct Lowerer {
    b: Builder,
    /// Variable bindings: scalar name or "name[i][j]" → value.
    env: HashMap<String, Value>,
    /// Declared scalars and arrays.
    scalars: HashSet<String>,
    arrays: HashMap<String, (usize, usize)>,
    assigned: HashSet<String>,
    window: Option<WindowSpec>,
    resolution: Option<(u32, u32)>,
}

fn key(v: &VarRef) -> String {
    match v.index {
        Some((i, j)) => format!("{}[{i}][{j}]", v.name),
        None => v.name.clone(),
    }
}

/// Lower a parsed program to a scheduled netlist.
pub fn lower(prog: &Program, name: &str) -> Result<Compiled> {
    let (m, e) = prog.format;
    if m == 0 || e < 2 || e > 11 || m > 53 {
        bail!("unsupported float({m}, {e})");
    }
    let fmt = FloatFormat::new(m, e);
    let mut lw = Lowerer {
        b: Builder::new(fmt),
        env: HashMap::new(),
        scalars: HashSet::new(),
        arrays: HashMap::new(),
        assigned: HashSet::new(),
        window: None,
        resolution: prog.resolution,
    };

    // Declarations.
    for d in &prog.vars {
        let dup = match d.dims {
            Some(dims) => lw.arrays.insert(d.name.clone(), dims).is_some(),
            None => !lw.scalars.insert(d.name.clone()),
        };
        if dup {
            bail!("line {}: duplicate declaration of `{}`", d.line, d.name);
        }
    }
    for inp in &prog.inputs {
        if !lw.scalars.contains(inp) {
            bail!("input `{inp}` must be declared with `var float`");
        }
        let sig = lw.b.input(inp);
        lw.env.insert(inp.clone(), Value::Sig(sig));
        lw.assigned.insert(inp.clone());
    }
    for out in &prog.outputs {
        if !lw.scalars.contains(out) {
            bail!("output `{out}` must be declared with `var float`");
        }
    }

    // Statements.
    for stmt in &prog.stmts {
        lw.stmt(stmt)?;
    }

    // Outputs: explicit list, or the implicit `pix_o` of window programs.
    let outs: Vec<String> = if prog.outputs.is_empty() {
        if lw.assigned.contains("pix_o") {
            vec!["pix_o".to_string()]
        } else {
            bail!("no `output` declared and no `pix_o` assigned");
        }
    } else {
        prog.outputs.clone()
    };
    for out in &outs {
        match lw.env.get(out.as_str()) {
            Some(Value::Sig(s)) => {
                let sig = *s;
                lw.b.rename(sig, out);
                lw.b.output(out, sig);
            }
            Some(Value::Const(_)) => bail!("output `{out}` is a constant"),
            None => bail!("output `{out}` is never assigned"),
        }
    }

    Ok(Compiled {
        fmt,
        netlist: lw.b.build(),
        window: lw.window,
        name: name.to_string(),
    })
}

impl Lowerer {
    fn stmt(&mut self, stmt: &Stmt) -> Result<()> {
        match stmt {
            Stmt::Assign { lhs, rhs, line } => self
                .assign(lhs, rhs, *line)
                .with_context(|| format!("line {line}: in `{} = ...`", lhs.display())),
            Stmt::AssignPair { lhs, rhs, line } => self
                .assign_pair(lhs, rhs, *line)
                .with_context(|| format!("line {line}: in pair assignment")),
        }
    }

    fn check_lhs(&mut self, lhs: &VarRef, line: usize) -> Result<()> {
        match lhs.index {
            None => {
                if !self.scalars.contains(&lhs.name) {
                    bail!("line {line}: `{}` is not a declared scalar", lhs.name);
                }
            }
            Some((i, j)) => {
                let &(r, c) = self
                    .arrays
                    .get(&lhs.name)
                    .with_context(|| format!("line {line}: `{}` is not a declared array", lhs.name))?;
                if i >= r || j >= c {
                    bail!("line {line}: index [{i}][{j}] out of bounds for `{}[{r}][{c}]`", lhs.name);
                }
            }
        }
        let k = key(lhs);
        if !self.assigned.insert(k.clone()) {
            bail!("line {line}: `{k}` assigned twice (hardware wires are single-assignment)");
        }
        Ok(())
    }

    fn assign(&mut self, lhs: &VarRef, rhs: &Expr, line: usize) -> Result<()> {
        // Whole-array macro targets first.
        if lhs.index.is_none() && self.arrays.contains_key(&lhs.name) {
            return self.assign_array(lhs, rhs, line);
        }
        self.check_lhs(lhs, line)?;
        let v = self.expr(rhs, line)?;
        if let Value::Sig(s) = v {
            if lhs.index.is_none() {
                self.b.rename(s, &lhs.name);
            }
        }
        self.env.insert(key(lhs), v);
        Ok(())
    }

    fn assign_array(&mut self, lhs: &VarRef, rhs: &Expr, line: usize) -> Result<()> {
        let (rows, cols) = self.arrays[&lhs.name];
        match rhs {
            Expr::Matrix(mat) => {
                if mat.len() != rows || mat[0].len() != cols {
                    bail!(
                        "line {line}: matrix literal is {}x{} but `{}` is {rows}x{cols}",
                        mat.len(),
                        mat[0].len(),
                        lhs.name
                    );
                }
                for (i, row) in mat.iter().enumerate() {
                    for (j, &v) in row.iter().enumerate() {
                        let k = format!("{}[{i}][{j}]", lhs.name);
                        if !self.assigned.insert(k.clone()) {
                            bail!("line {line}: `{k}` assigned twice");
                        }
                        self.env.insert(k, Value::Const(crate::fpcore::quantize(v, self.b.fmt())));
                    }
                }
                Ok(())
            }
            Expr::Call { func, args } if func == "sliding_window" => {
                // sliding_window(pix_i, H, W)
                if args.len() != 3 {
                    bail!("line {line}: sliding_window(pix_i, H, W) takes 3 arguments");
                }
                let h = lit_usize(&args[1], line)?;
                let w = lit_usize(&args[2], line)?;
                if (h, w) != (rows, cols) {
                    bail!("line {line}: sliding_window is {h}x{w} but `{}` is {rows}x{cols}", lhs.name);
                }
                if h % 2 == 0 || w % 2 == 0 {
                    bail!("line {line}: window dimensions must be odd");
                }
                if self.window.is_some() {
                    bail!("line {line}: only one sliding_window per program");
                }
                self.window = Some(WindowSpec {
                    height: h,
                    width: w,
                    resolution: self.resolution,
                });
                for i in 0..h {
                    for j in 0..w {
                        let sig = self.b.input(&format!("w{i}{j}"));
                        let k = format!("{}[{i}][{j}]", lhs.name);
                        self.assigned.insert(k.clone());
                        self.env.insert(k, Value::Sig(sig));
                    }
                }
                Ok(())
            }
            other => bail!("line {line}: cannot assign {other:?} to array `{}`", lhs.name),
        }
    }

    fn assign_pair(&mut self, lhs: &(VarRef, VarRef), rhs: &Expr, line: usize) -> Result<()> {
        let (func, args) = match rhs {
            Expr::Call { func, args } if func == "cmp_and_swap" => (func, args),
            other => bail!("line {line}: pair assignment requires cmp_and_swap, got {other:?}"),
        };
        let _ = func;
        if args.len() != 2 {
            bail!("line {line}: cmp_and_swap takes 2 arguments");
        }
        let a = self.expr_sig(&args[0], line)?;
        let bsig = self.expr_sig(&args[1], line)?;
        self.check_lhs(&lhs.0, line)?;
        self.check_lhs(&lhs.1, line)?;
        let (lo, hi) = self.b.cas(a, bsig);
        if lhs.0.index.is_none() {
            self.b.rename(lo, &lhs.0.name);
        }
        if lhs.1.index.is_none() {
            self.b.rename(hi, &lhs.1.name);
        }
        self.env.insert(key(&lhs.0), Value::Sig(lo));
        self.env.insert(key(&lhs.1), Value::Sig(hi));
        Ok(())
    }

    /// Evaluate an expression to a value.
    fn expr(&mut self, e: &Expr, line: usize) -> Result<Value> {
        match e {
            Expr::Lit(v) => Ok(Value::Const(crate::fpcore::quantize(*v, self.b.fmt()))),
            Expr::Var(vr) => {
                let k = key(vr);
                self.env
                    .get(&k)
                    .copied()
                    .with_context(|| format!("line {line}: `{k}` used before assignment"))
            }
            Expr::Shift { left, arg, amount } => {
                let inner = match arg.as_ref() {
                    Expr::Call { func, args }
                        if (func == "FP_RSH" || func == "FP_LSH" || func == "fp_rsh" || func == "fp_lsh")
                            && args.len() == 1 =>
                    {
                        &args[0]
                    }
                    other => other,
                };
                let s = self.expr_sig(inner, line)?;
                let out = if *left {
                    self.b.lsh(s, *amount)
                } else {
                    self.b.rsh(s, *amount)
                };
                Ok(Value::Sig(out))
            }
            Expr::Matrix(_) => bail!("line {line}: matrix literal outside array assignment"),
            Expr::Call { func, args } => self.call(func, args, line),
        }
    }

    /// Evaluate to a signal, materializing constants as constant ports.
    fn expr_sig(&mut self, e: &Expr, line: usize) -> Result<SignalId> {
        match self.expr(e, line)? {
            Value::Sig(s) => Ok(s),
            Value::Const(c) => Ok(self.b.constant(c)),
        }
    }

    fn call(&mut self, func: &str, args: &[Expr], line: usize) -> Result<Value> {
        let need = |n: usize| -> Result<()> {
            if args.len() != n {
                bail!("line {line}: `{func}` takes {n} argument(s), got {}", args.len());
            }
            Ok(())
        };
        match func {
            "mult" | "mul" => {
                need(2)?;
                let a = self.expr(&args[0], line)?;
                let b = self.expr(&args[1], line)?;
                Ok(Value::Sig(match (a, b) {
                    (Value::Sig(x), Value::Const(c)) | (Value::Const(c), Value::Sig(x)) => {
                        self.b.mul_const(x, c)
                    }
                    (Value::Sig(x), Value::Sig(y)) => self.b.mul(x, y),
                    (Value::Const(x), Value::Const(y)) => {
                        return Ok(Value::Const(crate::fpcore::quantize(x * y, self.b.fmt())))
                    }
                }))
            }
            "adder" | "add" => {
                need(2)?;
                let a = self.expr_sig(&args[0], line)?;
                let b = self.expr_sig(&args[1], line)?;
                Ok(Value::Sig(self.b.add(a, b)))
            }
            "sub" => {
                need(2)?;
                let a = self.expr_sig(&args[0], line)?;
                let b = self.expr_sig(&args[1], line)?;
                Ok(Value::Sig(self.b.op2(crate::fpcore::OpKind::Sub, a, b)))
            }
            "div" => {
                need(2)?;
                let a = self.expr_sig(&args[0], line)?;
                let b = self.expr_sig(&args[1], line)?;
                Ok(Value::Sig(self.b.div(a, b)))
            }
            "sqrt" => {
                need(1)?;
                let a = self.expr_sig(&args[0], line)?;
                Ok(Value::Sig(self.b.sqrt(a)))
            }
            "log2" => {
                need(1)?;
                let a = self.expr_sig(&args[0], line)?;
                Ok(Value::Sig(self.b.log2(a)))
            }
            "exp2" => {
                need(1)?;
                let a = self.expr_sig(&args[0], line)?;
                Ok(Value::Sig(self.b.exp2(a)))
            }
            "max" | "min" => {
                need(2)?;
                let a = self.expr(&args[0], line)?;
                let b = self.expr(&args[1], line)?;
                Ok(Value::Sig(match (a, b) {
                    (Value::Sig(x), Value::Const(c)) | (Value::Const(c), Value::Sig(x)) => {
                        if func == "max" {
                            self.b.max_const(x, c)
                        } else {
                            let cs = self.b.constant(c);
                            self.b.op2(crate::fpcore::OpKind::Min, x, cs)
                        }
                    }
                    (Value::Sig(x), Value::Sig(y)) => {
                        let op = if func == "max" {
                            crate::fpcore::OpKind::Max
                        } else {
                            crate::fpcore::OpKind::Min
                        };
                        self.b.op2(op, x, y)
                    }
                    (Value::Const(x), Value::Const(y)) => {
                        return Ok(Value::Const(if func == "max" { x.max(y) } else { x.min(y) }))
                    }
                }))
            }
            "cmp_and_swap" => {
                bail!("line {line}: cmp_and_swap needs a pair target: [lo, hi] = cmp_and_swap(a, b)")
            }
            "conv3x3" | "conv5x5" => {
                need(2)?;
                let k = if func == "conv3x3" { 3 } else { 5 };
                let wins = self.array_values(&args[0], k, line)?;
                let kern = self.array_values(&args[1], k, line)?;
                let mut prods = Vec::with_capacity(k * k);
                for (w, c) in wins.iter().zip(&kern) {
                    let p = match (*w, *c) {
                        (Value::Sig(x), Value::Const(cc)) => self.b.mul_const(x, cc),
                        (Value::Sig(x), Value::Sig(y)) => self.b.mul(x, y),
                        (Value::Const(cc), Value::Sig(y)) => self.b.mul_const(y, cc),
                        (Value::Const(x), Value::Const(y)) => {
                            let q = crate::fpcore::quantize(x * y, self.b.fmt());
                            self.b.constant(q)
                        }
                    };
                    prods.push(p);
                }
                Ok(Value::Sig(self.b.adder_tree(&prods)))
            }
            "median3x3" => {
                // library extension: the fig. 8 median as a macro
                need(1)?;
                let wins = self.array_values(&args[0], 3, line)?;
                let sig = |lw: &mut Self, v: Value| match v {
                    Value::Sig(s) => s,
                    Value::Const(c) => lw.b.constant(c),
                };
                let pick = |lw: &mut Self, idx: [usize; 5], wins: &[Value]| {
                    idx.map(|i| sig(lw, wins[i]))
                };
                let fa = pick(self, crate::filters::median::FOOTPRINT_A, &wins);
                let fb = pick(self, crate::filters::median::FOOTPRINT_B, &wins);
                let sa = self.b.sort5(fa);
                let sb = self.b.sort5(fb);
                let sum = self.b.add(sa[2], sb[2]);
                Ok(Value::Sig(self.b.rsh(sum, 1)))
            }
            "FP_RSH" | "fp_rsh" | "FP_LSH" | "fp_lsh" => {
                bail!("line {line}: `{func}` must be followed by a shift amount: `{func}(x) >> n`")
            }
            "sliding_window" => {
                bail!("line {line}: sliding_window must be assigned to a declared array")
            }
            other => bail!("line {line}: unknown function `{other}`"),
        }
    }

    /// Flatten an array argument (by name) to its k*k element values.
    fn array_values(&mut self, e: &Expr, k: usize, line: usize) -> Result<Vec<Value>> {
        let name = match e {
            Expr::Var(vr) if vr.index.is_none() => &vr.name,
            other => bail!("line {line}: expected an array variable, got {other:?}"),
        };
        let &(r, c) = self
            .arrays
            .get(name)
            .with_context(|| format!("line {line}: `{name}` is not a declared array"))?;
        if (r, c) != (k, k) {
            bail!("line {line}: `{name}` is {r}x{c}, expected {k}x{k}");
        }
        let mut vals = Vec::with_capacity(k * k);
        for i in 0..k {
            for j in 0..k {
                let key = format!("{name}[{i}][{j}]");
                vals.push(
                    self.env
                        .get(&key)
                        .copied()
                        .with_context(|| format!("line {line}: `{key}` used before assignment"))?,
                );
            }
        }
        Ok(vals)
    }
}

fn lit_usize(e: &Expr, line: usize) -> Result<usize> {
    match e {
        Expr::Lit(v) if *v >= 0.0 && v.fract() == 0.0 => Ok(*v as usize),
        other => bail!("line {line}: expected an integer literal, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parse::parse;
    use crate::fpcore::OpMode;
    use crate::sim::Engine;

    const FIG12: &str = r#"
use float(10, 5);
input x, y;
output z;
var float x, y, m, s, d, z;
m = mult(x, y);
s = adder(x, y);
d = div(m, s);
z = sqrt(d);
"#;

    #[test]
    fn fig12_lowering_and_schedule() {
        let c = lower(&parse(FIG12).unwrap(), "fp_func").unwrap();
        // §V: λ(m)=2, λ(s)=6, Δ(m)=4, total = 6+7+5 = 18
        let m = c.netlist.signal_by_name("m").unwrap();
        let s = c.netlist.signal_by_name("s").unwrap();
        assert_eq!(c.netlist.signals[m].latency, 2);
        assert_eq!(c.netlist.signals[s].latency, 6);
        let div = c.netlist.nodes.iter().find(|n| n.op.name() == "div").unwrap();
        assert_eq!(div.in_delays, vec![4, 0]);
        assert_eq!(c.netlist.total_latency(), 18);
        assert!(c.window.is_none());
    }

    #[test]
    fn fig12_numerics() {
        let c = lower(&parse(FIG12).unwrap(), "fp_func").unwrap();
        let mut eng = Engine::new(&c.netlist, OpMode::Exact);
        let out = eng.eval(&[3.0, 6.0])[0];
        assert_eq!(out, crate::fpcore::quantize(2.0_f64.sqrt(), c.fmt));
    }

    const FIG14: &str = r#"
# conv3x3 in float16(10,5)
use float(10, 5);
var float w[3][3], K[3][3], pix_i, pix_o;
image_resolution(1920, 1080);
w = sliding_window(pix_i, 3, 3);
K = [[1.0, 2.0, 1.0], [2.0, 6.75, 2.0], [1.0, 2.0, 1.0]];
pix_o = conv3x3(w, K);
"#;

    #[test]
    fn fig14_window_program() {
        let c = lower(&parse(FIG14).unwrap(), "conv").unwrap();
        let w = c.window.as_ref().unwrap();
        assert_eq!((w.height, w.width), (3, 3));
        assert_eq!(w.resolution, Some((1920, 1080)));
        assert_eq!(c.netlist.inputs.len(), 9);
        assert_eq!(c.netlist.op_count("mult_const"), 9);
        assert_eq!(c.netlist.op_count("adder"), 8);
        assert_eq!(c.netlist.total_latency(), 26);
    }

    #[test]
    fn fig14_matches_builtin_conv() {
        let c = lower(&parse(FIG14).unwrap(), "conv").unwrap();
        let k = [1.0, 2.0, 1.0, 2.0, 6.75, 2.0, 1.0, 2.0, 1.0];
        let builtin = crate::filters::conv::conv_netlist(c.fmt, 3, &k);
        let mut a = Engine::new(&c.netlist, OpMode::Exact);
        let mut b = Engine::new(&builtin, OpMode::Exact);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..100 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            assert_eq!(a.eval(&w), b.eval(&w));
        }
    }

    #[test]
    fn error_double_assignment() {
        let src = "use float(10,5);\ninput x;\nvar float x, y;\ny = sqrt(x);\ny = sqrt(x);\noutput y;\n";
        let err = lower(&parse(src).unwrap(), "t").unwrap_err();
        assert!(format!("{err:#}").contains("assigned twice"), "{err:#}");
    }

    #[test]
    fn error_undeclared() {
        let src = "use float(10,5);\ninput x;\nvar float x, y;\noutput y;\ny = sqrt(q);\n";
        let err = lower(&parse(src).unwrap(), "t").unwrap_err();
        assert!(format!("{err:#}").contains("used before assignment"), "{err:#}");
    }

    #[test]
    fn error_unknown_function() {
        let src = "use float(10,5);\ninput x;\nvar float x, y;\noutput y;\ny = sin(x);\n";
        let err = lower(&parse(src).unwrap(), "t").unwrap_err();
        assert!(format!("{err:#}").contains("unknown function"), "{err:#}");
    }

    #[test]
    fn error_output_never_assigned() {
        let src = "use float(10,5);\ninput x;\nvar float x, y;\noutput y;\n";
        let err = lower(&parse(src).unwrap(), "t").unwrap_err();
        assert!(format!("{err:#}").contains("never assigned"), "{err:#}");
    }

    #[test]
    fn mult_by_literal_becomes_const_multiplier() {
        let src = "use float(10,5);\ninput x;\nvar float x, y;\noutput y;\ny = mult(x, 0.0313);\n";
        let c = lower(&parse(src).unwrap(), "t").unwrap();
        assert_eq!(c.netlist.op_count("mult_const"), 1);
        assert_eq!(c.netlist.op_count("mult"), 0);
    }
}
