//! Tree-walking interpreter for DSL programs — the *software* execution
//! model of Table I.
//!
//! MATLAB's `nlfilter` (and scipy's `generic_filter`) evaluate a dynamic
//! user function per window: every pixel pays dynamic dispatch, an
//! environment lookup per variable, and allocation.  This interpreter
//! reproduces that execution model over the same DSL AST the hardware
//! compiler consumes, so the software/hardware comparison of Table I is
//! apples-to-apples: identical semantics, different execution strategy.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::ast::{Expr, Program, Stmt, VarRef};
use crate::video::Frame;

/// Per-pixel interpreter state.
pub struct Interp<'p> {
    prog: &'p Program,
    ksize: usize,
}

impl<'p> Interp<'p> {
    /// Prepare an interpreter for a window program (`sliding_window` based).
    pub fn new_window(prog: &'p Program) -> Result<Self> {
        let ksize = prog
            .stmts
            .iter()
            .find_map(|s| match s {
                Stmt::Assign { rhs: Expr::Call { func, args }, .. }
                    if func == "sliding_window" =>
                {
                    match &args[1] {
                        Expr::Lit(v) => Some(*v as usize),
                        _ => None,
                    }
                }
                _ => None,
            })
            .with_context(|| "program has no sliding_window")?;
        Ok(Self { prog, ksize })
    }

    pub fn ksize(&self) -> usize {
        self.ksize
    }

    /// Evaluate the program for one window (raster order, ksize²).
    /// Every call builds a fresh environment — deliberately: this is the
    /// MATLAB-nlfilter cost model.
    pub fn eval_window(&self, window: &[f64]) -> Result<f64> {
        let mut env: HashMap<String, f64> = HashMap::new();
        let mut out_name: Option<String> = None;
        for stmt in &self.prog.stmts {
            match stmt {
                Stmt::Assign { lhs, rhs, line } => {
                    if let Expr::Call { func, .. } = rhs {
                        if func == "sliding_window" {
                            // bind w[i][j] from the window
                            let k = self.ksize;
                            for i in 0..k {
                                for j in 0..k {
                                    env.insert(
                                        format!("{}[{i}][{j}]", lhs.name),
                                        window[i * k + j],
                                    );
                                }
                            }
                            continue;
                        }
                    }
                    if let Expr::Matrix(mat) = rhs {
                        for (i, row) in mat.iter().enumerate() {
                            for (j, &v) in row.iter().enumerate() {
                                env.insert(format!("{}[{i}][{j}]", lhs.name), v);
                            }
                        }
                        continue;
                    }
                    let v = eval_expr(rhs, &env, *line)?;
                    env.insert(vkey(lhs), v);
                    if lhs.name == "pix_o" || self.prog.outputs.contains(&lhs.name) {
                        out_name = Some(vkey(lhs));
                    }
                }
                Stmt::AssignPair { lhs, rhs, line } => {
                    let (a, b) = match rhs {
                        Expr::Call { func, args } if func == "cmp_and_swap" => {
                            let x = eval_expr(&args[0], &env, *line)?;
                            let y = eval_expr(&args[1], &env, *line)?;
                            if x > y {
                                (y, x)
                            } else {
                                (x, y)
                            }
                        }
                        other => bail!("line {line}: bad pair rhs {other:?}"),
                    };
                    env.insert(vkey(&lhs.0), a);
                    env.insert(vkey(&lhs.1), b);
                }
            }
        }
        let out = out_name.with_context(|| "program never assigns its output")?;
        Ok(env[&out])
    }

    /// Run the program over a whole frame, MATLAB-`nlfilter` style
    /// (replicate borders).
    pub fn run_frame(&self, frame: &Frame) -> Result<Frame> {
        let k = self.ksize;
        let p = (k / 2) as isize;
        let mut out = Frame::new(frame.width, frame.height);
        let mut window = vec![0.0f64; k * k];
        for y in 0..frame.height as isize {
            for x in 0..frame.width as isize {
                let mut idx = 0;
                for dy in -p..=p {
                    for dx in -p..=p {
                        window[idx] = frame.get_clamped(x + dx, y + dy);
                        idx += 1;
                    }
                }
                out.set(x as usize, y as usize, self.eval_window(&window)?);
            }
        }
        Ok(out)
    }
}

fn vkey(v: &VarRef) -> String {
    match v.index {
        Some((i, j)) => format!("{}[{i}][{j}]", v.name),
        None => v.name.clone(),
    }
}

fn eval_expr(e: &Expr, env: &HashMap<String, f64>, line: usize) -> Result<f64> {
    match e {
        Expr::Lit(v) => Ok(*v),
        Expr::Var(vr) => env
            .get(&vkey(vr))
            .copied()
            .with_context(|| format!("line {line}: `{}` unbound", vkey(vr))),
        Expr::Shift { left, arg, amount } => {
            let inner = match arg.as_ref() {
                Expr::Call { func, args }
                    if matches!(func.as_str(), "FP_RSH" | "FP_LSH" | "fp_rsh" | "fp_lsh") =>
                {
                    &args[0]
                }
                other => other,
            };
            let v = eval_expr(inner, env, line)?;
            Ok(if *left {
                v * 2.0_f64.powi(*amount as i32)
            } else {
                v * 2.0_f64.powi(-(*amount as i32))
            })
        }
        Expr::Matrix(_) => bail!("line {line}: matrix in expression"),
        Expr::Call { func, args } => {
            let a = |i: usize| eval_expr(&args[i], env, line);
            match func.as_str() {
                "mult" | "mul" => Ok(a(0)? * a(1)?),
                "adder" | "add" => Ok(a(0)? + a(1)?),
                "sub" => Ok(a(0)? - a(1)?),
                "div" => Ok(a(0)? / a(1)?),
                "sqrt" => Ok(a(0)?.sqrt()),
                "log2" => Ok(a(0)?.log2()),
                "exp2" => Ok(a(0)?.exp2()),
                "max" => Ok(a(0)?.max(a(1)?)),
                "min" => Ok(a(0)?.min(a(1)?)),
                "conv3x3" | "conv5x5" => {
                    let k = if func == "conv3x3" { 3 } else { 5 };
                    let (wname, kname) = match (&args[0], &args[1]) {
                        (Expr::Var(wv), Expr::Var(kv)) => (&wv.name, &kv.name),
                        _ => bail!("line {line}: conv expects array variables"),
                    };
                    let mut acc = 0.0;
                    for i in 0..k {
                        for j in 0..k {
                            let w = env
                                .get(&format!("{wname}[{i}][{j}]"))
                                .with_context(|| format!("line {line}: {wname}[{i}][{j}]"))?;
                            let kk = env
                                .get(&format!("{kname}[{i}][{j}]"))
                                .with_context(|| format!("line {line}: {kname}[{i}][{j}]"))?;
                            acc += w * kk;
                        }
                    }
                    Ok(acc)
                }
                "median3x3" => {
                    let wname = match &args[0] {
                        Expr::Var(wv) => &wv.name,
                        _ => bail!("line {line}: median3x3 expects an array variable"),
                    };
                    let mut vals = Vec::with_capacity(9);
                    for i in 0..3 {
                        for j in 0..3 {
                            vals.push(*env.get(&format!("{wname}[{i}][{j}]")).unwrap());
                        }
                    }
                    let med5 = |idx: [usize; 5]| {
                        let mut v = idx.map(|i| vals[i]);
                        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                        v[2]
                    };
                    Ok((med5(crate::filters::median::FOOTPRINT_A)
                        + med5(crate::filters::median::FOOTPRINT_B))
                        / 2.0)
                }
                other => bail!("line {line}: unknown function `{other}`"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parse::parse;

    const NLFILTER_DSL: &str = include_str!("../../../examples/dsl/nlfilter.dsl");

    #[test]
    fn interp_matches_native_eq2() {
        let prog = parse(NLFILTER_DSL).unwrap();
        let it = Interp::new_window(&prog).unwrap();
        let mut rng = crate::util::rng::Rng::new(21);
        for _ in 0..100 {
            let w: Vec<f64> = (0..9).map(|_| rng.uniform(0.0, 255.0)).collect();
            let got = it.eval_window(&w).unwrap();
            let want = crate::filters::software::eq2_native(&w);
            assert!((got - want).abs() <= want.abs() * 1e-12, "{got} vs {want}");
        }
    }

    #[test]
    fn interp_frame_runs() {
        let prog = parse(NLFILTER_DSL).unwrap();
        let it = Interp::new_window(&prog).unwrap();
        let f = crate::video::Frame::test_card(16, 12);
        let out = it.run_frame(&f).unwrap();
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}
