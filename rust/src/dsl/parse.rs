//! Recursive-descent parser: tokens → [`Program`].
//!
//! Grammar (one construct per statement, semicolon-terminated):
//!
//! ```text
//! program   := item*
//! item      := "use" "float" "(" NUM "," NUM ")" ";"
//!            | "input" ident ("," ident)* ";"
//!            | "output" ident ("," ident)* ";"
//!            | "var" "float" decl ("," decl)* ";"
//!            | "image_resolution" "(" NUM "," NUM ")" ";"
//!            | assign
//! decl      := ident ("[" NUM "]" "[" NUM "]")?
//! assign    := varref "=" expr ";"
//!            | "[" varref "," varref "]" "=" expr ";"
//! varref    := ident ("[" NUM "]" "[" NUM "]")?
//! expr      := NUM
//!            | matrix
//!            | ident "(" expr ("," expr)* ")" (">>" NUM | "<<" NUM)?
//!            | varref
//! matrix    := "[" row ("," row)* "]"   where row := "[" NUM ("," NUM)* "]"
//! ```

use anyhow::{bail, Context, Result};

use super::ast::{Expr, Program, Stmt, VarDecl, VarRef};
use super::lex::{lex, SpannedTok, Tok};

pub fn parse(src: &str) -> Result<Program> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.program()
}

struct Parser {
    toks: Vec<SpannedTok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|t| &t.tok)
    }

    fn line(&self) -> usize {
        self.toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|t| t.line)
            .unwrap_or(0)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.pos)
            .map(|t| t.tok.clone())
            .with_context(|| "unexpected end of input")?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, want: &Tok) -> Result<()> {
        let line = self.line();
        let got = self.next()?;
        if &got != want {
            bail!("line {line}: expected {want:?}, got {got:?}");
        }
        Ok(())
    }

    fn ident(&mut self) -> Result<String> {
        let line = self.line();
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            other => bail!("line {line}: expected identifier, got {other:?}"),
        }
    }

    fn number(&mut self) -> Result<f64> {
        let line = self.line();
        match self.next()? {
            Tok::Num(v) => Ok(v),
            other => bail!("line {line}: expected number, got {other:?}"),
        }
    }

    fn usize_lit(&mut self) -> Result<usize> {
        let line = self.line();
        let v = self.number()?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("line {line}: expected a non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    fn program(&mut self) -> Result<Program> {
        let mut format = None;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        let mut vars = Vec::new();
        let mut resolution = None;
        let mut stmts = Vec::new();

        while self.peek().is_some() {
            let line = self.line();
            match self.peek() {
                Some(Tok::Ident(kw)) if kw == "use" => {
                    self.next()?;
                    let f = self.ident()?;
                    if f != "float" {
                        bail!("line {line}: only `use float(m, e)` is supported");
                    }
                    self.expect(&Tok::LParen)?;
                    let m = self.usize_lit()? as u32;
                    self.expect(&Tok::Comma)?;
                    let e = self.usize_lit()? as u32;
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::Semi)?;
                    if format.replace((m, e)).is_some() {
                        bail!("line {line}: duplicate `use float` directive");
                    }
                }
                Some(Tok::Ident(kw)) if kw == "input" => {
                    self.next()?;
                    inputs.extend(self.ident_list()?);
                }
                Some(Tok::Ident(kw)) if kw == "output" => {
                    self.next()?;
                    outputs.extend(self.ident_list()?);
                }
                Some(Tok::Ident(kw)) if kw == "var" => {
                    self.next()?;
                    let ty = self.ident()?;
                    if ty != "float" {
                        bail!("line {line}: only `var float ...` is supported");
                    }
                    loop {
                        let name = self.ident()?;
                        let dims = if self.peek() == Some(&Tok::LBracket) {
                            self.next()?;
                            let r = self.usize_lit()?;
                            self.expect(&Tok::RBracket)?;
                            self.expect(&Tok::LBracket)?;
                            let c = self.usize_lit()?;
                            self.expect(&Tok::RBracket)?;
                            Some((r, c))
                        } else {
                            None
                        };
                        vars.push(VarDecl { name, dims, line });
                        match self.next()? {
                            Tok::Comma => continue,
                            Tok::Semi => break,
                            other => bail!("line {line}: expected , or ; got {other:?}"),
                        }
                    }
                }
                Some(Tok::Ident(kw)) if kw == "image_resolution" => {
                    self.next()?;
                    self.expect(&Tok::LParen)?;
                    let w = self.usize_lit()? as u32;
                    self.expect(&Tok::Comma)?;
                    let h = self.usize_lit()? as u32;
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::Semi)?;
                    resolution = Some((w, h));
                }
                Some(Tok::LBracket) => {
                    // [a, b] = cmp_and_swap(x, y);
                    self.next()?;
                    let a = self.varref()?;
                    self.expect(&Tok::Comma)?;
                    let b = self.varref()?;
                    self.expect(&Tok::RBracket)?;
                    self.expect(&Tok::Assign)?;
                    let rhs = self.expr()?;
                    self.expect(&Tok::Semi)?;
                    stmts.push(Stmt::AssignPair { lhs: (a, b), rhs, line });
                }
                Some(Tok::Ident(_)) => {
                    let lhs = self.varref()?;
                    self.expect(&Tok::Assign)?;
                    let rhs = self.expr()?;
                    self.expect(&Tok::Semi)?;
                    stmts.push(Stmt::Assign { lhs, rhs, line });
                }
                other => bail!("line {line}: unexpected {other:?}"),
            }
        }

        Ok(Program {
            format: format.with_context(|| "missing `use float(m, e);` directive")?,
            inputs,
            outputs,
            vars,
            resolution,
            stmts,
        })
    }

    fn ident_list(&mut self) -> Result<Vec<String>> {
        let mut names = vec![self.ident()?];
        loop {
            match self.next()? {
                Tok::Comma => names.push(self.ident()?),
                Tok::Semi => break,
                other => bail!("expected , or ; got {other:?}"),
            }
        }
        Ok(names)
    }

    fn varref(&mut self) -> Result<VarRef> {
        let name = self.ident()?;
        let index = if self.peek() == Some(&Tok::LBracket) {
            self.next()?;
            let i = self.usize_lit()?;
            self.expect(&Tok::RBracket)?;
            self.expect(&Tok::LBracket)?;
            let j = self.usize_lit()?;
            self.expect(&Tok::RBracket)?;
            Some((i, j))
        } else {
            None
        };
        Ok(VarRef { name, index })
    }

    fn expr(&mut self) -> Result<Expr> {
        let line = self.line();
        match self.peek() {
            Some(Tok::Num(_)) => Ok(Expr::Lit(self.number()?)),
            Some(Tok::LBracket) => self.matrix(),
            Some(Tok::Ident(_)) => {
                let name = self.ident()?;
                if self.peek() == Some(&Tok::LParen) {
                    self.next()?;
                    let mut args = Vec::new();
                    if self.peek() != Some(&Tok::RParen) {
                        loop {
                            args.push(self.expr()?);
                            match self.next()? {
                                Tok::Comma => continue,
                                Tok::RParen => break,
                                other => {
                                    bail!("line {line}: expected , or ) got {other:?}")
                                }
                            }
                        }
                    } else {
                        self.next()?;
                    }
                    let call = Expr::Call { func: name, args };
                    // optional shift suffix: FP_RSH(x) >> n
                    match self.peek() {
                        Some(Tok::Shr) => {
                            self.next()?;
                            let n = self.usize_lit()? as u32;
                            Ok(Expr::Shift { left: false, arg: Box::new(call), amount: n })
                        }
                        Some(Tok::Shl) => {
                            self.next()?;
                            let n = self.usize_lit()? as u32;
                            Ok(Expr::Shift { left: true, arg: Box::new(call), amount: n })
                        }
                        _ => Ok(call),
                    }
                } else {
                    // plain var (possibly indexed)
                    let index = if self.peek() == Some(&Tok::LBracket) {
                        self.next()?;
                        let i = self.usize_lit()?;
                        self.expect(&Tok::RBracket)?;
                        self.expect(&Tok::LBracket)?;
                        let j = self.usize_lit()?;
                        self.expect(&Tok::RBracket)?;
                        Some((i, j))
                    } else {
                        None
                    };
                    Ok(Expr::Var(VarRef { name, index }))
                }
            }
            other => bail!("line {line}: unexpected {other:?} in expression"),
        }
    }

    fn matrix(&mut self) -> Result<Expr> {
        let line = self.line();
        self.expect(&Tok::LBracket)?;
        let mut rows = Vec::new();
        loop {
            self.expect(&Tok::LBracket)?;
            let mut row = Vec::new();
            loop {
                row.push(self.number()?);
                match self.next()? {
                    Tok::Comma => continue,
                    Tok::RBracket => break,
                    other => bail!("line {line}: expected , or ] got {other:?}"),
                }
            }
            rows.push(row);
            match self.next()? {
                Tok::Comma => continue,
                Tok::RBracket => break,
                other => bail!("line {line}: expected , or ] got {other:?}"),
            }
        }
        let w = rows[0].len();
        if !rows.iter().all(|r| r.len() == w) {
            bail!("line {line}: ragged matrix literal");
        }
        Ok(Expr::Matrix(rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 12: z = sqrt((x*y)/(x+y)) in float16(10,5).
    pub const FIG12: &str = r#"
# DSL code to compute z = sqrt((x*y)/(x+y))

use float(10, 5);
input x, y;
output z;

var float x, y, m, s, d, z;

m = mult(x, y);
s = adder(x, y);
d = div(m, s);
z = sqrt(d);
"#;

    #[test]
    fn parse_fig12() {
        let p = parse(FIG12).unwrap();
        assert_eq!(p.format, (10, 5));
        assert_eq!(p.inputs, vec!["x", "y"]);
        assert_eq!(p.outputs, vec!["z"]);
        assert_eq!(p.vars.len(), 6);
        assert_eq!(p.stmts.len(), 4);
        match &p.stmts[0] {
            Stmt::Assign { lhs, rhs, .. } => {
                assert_eq!(lhs.name, "m");
                assert!(matches!(rhs, Expr::Call { func, .. } if func == "mult"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_conv_program() {
        let src = r#"
use float(10, 5);
var float w[3][3], K[3][3], pix_i, pix_o;
image_resolution(1920, 1080);
w = sliding_window(pix_i, 3, 3);
K = [[1.0, 2.0, 1.0], [2.0, 6.75, 2.0], [1.0, 2.0, 1.0]];
pix_o = conv3x3(w, K);
"#;
        let p = parse(src).unwrap();
        assert_eq!(p.resolution, Some((1920, 1080)));
        assert_eq!(p.stmts.len(), 3);
        match &p.stmts[1] {
            Stmt::Assign { rhs: Expr::Matrix(m), .. } => {
                assert_eq!(m[1][1], 6.75);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_pair_assign_and_shift() {
        let src = r#"
use float(10, 5);
var float f1, f2, g1, g2, a0, f0;
[g1, g2] = cmp_and_swap(f1, f2);
f0 = FP_RSH(a0) >> 1;
"#;
        let p = parse(src).unwrap();
        assert!(matches!(&p.stmts[0], Stmt::AssignPair { .. }));
        match &p.stmts[1] {
            Stmt::Assign { rhs: Expr::Shift { left, amount, .. }, .. } => {
                assert!(!left);
                assert_eq!(*amount, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_indexed_assign() {
        let src = "use float(10,5);\nvar float w[3][3], w2[3][3];\nw2[0][0] = max(w[0][0], 1);\n";
        let p = parse(src).unwrap();
        match &p.stmts[0] {
            Stmt::Assign { lhs, .. } => assert_eq!(lhs.index, Some((0, 0))),
            _ => panic!(),
        }
    }

    #[test]
    fn missing_use_is_error() {
        assert!(parse("input x;\n").is_err());
    }

    #[test]
    fn error_reports_line() {
        let err = parse("use float(10,5);\n\nm = mult(x;\n").unwrap_err();
        assert!(err.to_string().contains("line 3"), "{err}");
    }
}
