//! fpspatial — CLI entry point.
//!
//! All parsing and dispatch lives in [`fpspatial::cli`] (a library module,
//! so `tests/cli_e2e.rs` drives the same code in-process); this binary
//! only collects `argv` and maps errors to a non-zero exit status.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = fpspatial::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
