//! [`FrameServer`]: N independent filter streams scheduled over **one**
//! shared supervised worker pool (the `fpspatial serve` layer).
//!
//! A [`Session`](super::Session) serves exactly one stream; a frame
//! server multiplexes many — the ROADMAP's "many cameras, one box"
//! shape.  Each registered stream keeps the full per-stream contract of
//! the session runtime:
//!
//! * **in-order delivery** — outputs come back per stream strictly in
//!   submission order, bit-identical to a solo session / the sequential
//!   oracle;
//! * **bounded queue + backpressure** — every stream has its own
//!   in-flight budget and [`OverloadPolicy`]; one slow stream cannot
//!   starve the pool (jobs are dispatched round-robin across streams);
//! * **typed fault isolation** — a worker panic while serving stream A
//!   surfaces as a buffered [`ServerEvent::Fault`] on stream A (and the
//!   worker is respawned); stream B never observes it;
//! * **exact accounting** — per-stream drop / deadline-miss / restart
//!   counters, plus aggregate [`Metrics`] over all streams.
//!
//! Frame buffers are recycled through one spare pool shared by every
//! stream, so a warm server allocates nothing in steady state (hand
//! outputs back via [`FrameServer::recycle`]).
//!
//! Two driving styles:
//!
//! * **deterministic** — [`FrameServer::submit`] / [`FrameServer::pump`]
//!   / [`FrameServer::drain`] from one thread (tests, benches);
//! * **channel ingest** — clone [`StreamSender`]s off the server, feed
//!   frames from producer threads, and let [`FrameServer::run`] schedule
//!   until every sender hangs up.
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use fpspatial::filters::FilterKind;
//! use fpspatial::fpcore::OpMode;
//! use fpspatial::pipeline::{FrameServer, Pipeline, ServerEvent, SessionConfig};
//! use fpspatial::video::Frame;
//!
//! let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
//! let mut server = FrameServer::builder(2)
//!     .stream(&plan, SessionConfig::new())
//!     .stream(&plan, SessionConfig::new())
//!     .build()?;
//! for i in 0..4u64 {
//!     server.submit(0, &Frame::noise(32, 24, i))?;
//!     server.submit(1, &Frame::noise(32, 24, 100 + i))?;
//! }
//! let mut delivered = [0u64; 2];
//! for ev in server.drain()? {
//!     if let ServerEvent::Frame { stream, .. } = ev {
//!         delivered[stream] += 1;
//!     }
//! }
//! assert_eq!(delivered, [4, 4]);
//! assert_eq!(server.aggregate().delivered, 8);
//! # Ok(())
//! # }
//! ```

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::pool::{reshape, MultiPool, Polled, Wait};
use super::{CompiledPipeline, ExecError, ExecPlan, Metrics, OverloadPolicy, SessionConfig};
use crate::video::Frame;

/// Outcome of a [`FrameServer::submit`]: the frame's per-stream sequence
/// number, and whether it entered the pipeline or was shed by the
/// stream's [`OverloadPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submitted {
    /// The frame was handed to the worker pool.
    Queued(u64),
    /// The stream's budget was full and its policy shed a frame (the
    /// incoming one, or — under DropOldest — an older queued one whose
    /// slot the incoming frame took).  The drop is counted either way.
    Dropped(u64),
}

/// One observation delivered by the server: an in-order output frame, or
/// a stream-scoped fault (worker panic, stage failure, missed deadline).
/// Faults never abort the server — the offending stream skips the frame
/// and every stream keeps being served.
#[derive(Debug)]
pub enum ServerEvent {
    /// Stream `stream`'s next in-order output.  Hand `frame` back via
    /// [`FrameServer::recycle`] to keep the steady state allocation-free.
    Frame { stream: usize, seq: u64, latency: Duration, frame: Frame },
    /// A typed fault attributed to one stream's frame; the stream's
    /// counters have already been updated.
    Fault { stream: usize, error: ExecError },
}

/// Handle for feeding one stream of a running [`FrameServer`] from a
/// producer thread (see [`FrameServer::sender`] / [`FrameServer::run`]).
#[derive(Clone)]
pub struct StreamSender {
    stream: usize,
    tx: SyncSender<(usize, Frame)>,
}

impl StreamSender {
    /// The stream this handle feeds.
    pub fn stream(&self) -> usize {
        self.stream
    }

    /// Send one frame (blocking while the shared ingest channel is
    /// full).  Returns `false` once the server is gone.
    pub fn send(&self, frame: Frame) -> bool {
        self.tx.send((self.stream, frame)).is_ok()
    }
}

/// Registration-order builder for a [`FrameServer`] (stream ids are
/// assigned 0, 1, … in [`ServerBuilder::stream`] call order — workers
/// compile one evaluator per stream at spawn, so the full roster is
/// declared up front).
pub struct ServerBuilder<'p> {
    workers: usize,
    specs: Vec<(&'p CompiledPipeline, usize, SessionConfig)>,
}

impl<'p> ServerBuilder<'p> {
    /// Register a stream executing `plan` under `config`, with the
    /// default in-flight budget (`workers +`
    /// [`ExecPlan::DEFAULT_REORDER`]).  Returns the builder; the new
    /// stream's id is the number of streams registered before it.
    pub fn stream(self, plan: &'p CompiledPipeline, config: SessionConfig) -> Self {
        let queue = self.workers + ExecPlan::DEFAULT_REORDER;
        self.stream_with_queue(plan, config, queue)
    }

    /// [`ServerBuilder::stream`] with an explicit per-stream in-flight
    /// budget (bounded queue depth).
    pub fn stream_with_queue(
        mut self,
        plan: &'p CompiledPipeline,
        config: SessionConfig,
        queue: usize,
    ) -> Self {
        self.specs.push((plan, queue, config));
        self
    }

    /// Spawn the shared worker pool and return the server.
    pub fn build(self) -> Result<FrameServer<'p>> {
        if self.workers == 0 {
            bail!("a frame server needs at least one worker");
        }
        if self.specs.is_empty() {
            bail!("a frame server needs at least one registered stream");
        }
        if let Some(s) = self.specs.iter().position(|(_, queue, _)| *queue == 0) {
            bail!("stream {s} needs an in-flight budget of at least 1");
        }
        let plans: Vec<&'p CompiledPipeline> = self.specs.iter().map(|(p, _, _)| *p).collect();
        let configs: Vec<SessionConfig> = self.specs.iter().map(|(_, _, c)| c.clone()).collect();
        let pool_specs: Vec<(&CompiledPipeline, usize, &SessionConfig)> = self
            .specs
            .iter()
            .map(|(plan, queue, config)| (*plan, *queue, config))
            .collect();
        let pool = MultiPool::spawn(&pool_specs, self.workers);
        let ingest_cap: usize = self.specs.iter().map(|(_, queue, _)| *queue).sum();
        let (ingest_tx, ingest_rx) = sync_channel::<(usize, Frame)>(ingest_cap.max(4));
        let n = plans.len();
        Ok(FrameServer {
            plans,
            configs,
            pool,
            dims: vec![None; n],
            lats: vec![Vec::new(); n],
            events: VecDeque::new(),
            started: Instant::now(),
            ingest_rx,
            ingest_tx: Some(ingest_tx),
            idle_wakeups: 0,
        })
    }
}

/// N independent streams over ONE shared supervised worker pool.  See
/// the [module docs](self) for the contract, [`FrameServer::builder`]
/// to construct one.
pub struct FrameServer<'p> {
    plans: Vec<&'p CompiledPipeline>,
    configs: Vec<SessionConfig>,
    pool: MultiPool,
    /// Per-stream pinned geometry (latched by each stream's first frame).
    dims: Vec<Option<(usize, usize)>>,
    /// Per-stream delivered latencies (for [`FrameServer::metrics`]).
    lats: Vec<Vec<Duration>>,
    /// Buffered observations awaiting [`FrameServer::take_events`].
    events: VecDeque<ServerEvent>,
    started: Instant,
    ingest_rx: Receiver<(usize, Frame)>,
    /// Master ingest sender; cloned by [`FrameServer::sender`], dropped
    /// when [`FrameServer::run`] starts so the loop can observe hang-up.
    ingest_tx: Option<SyncSender<(usize, Frame)>>,
    /// Scheduler iterations of [`FrameServer::run`] that made no
    /// progress (no ingest, no completion, no delivery, no expiry) —
    /// the regression counter for the old 1 ms poll loop, asserted zero
    /// by `tests/server.rs`.
    idle_wakeups: u64,
}

impl<'p> FrameServer<'p> {
    /// Start building a server whose shared pool has `workers` threads.
    pub fn builder(workers: usize) -> ServerBuilder<'p> {
        ServerBuilder { workers, specs: Vec::new() }
    }

    /// Number of registered streams.
    pub fn streams(&self) -> usize {
        self.plans.len()
    }

    /// Number of worker threads in the shared pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Submit one frame to `stream` by reference (copied into a recycled
    /// buffer).  Applies the stream's geometry pin, input validation and
    /// overload policy; see [`Submitted`].
    pub fn submit(&mut self, stream: usize, frame: &Frame) -> Result<Submitted> {
        let mut owned = self.pool.take_spare();
        reshape(&mut owned, frame.width, frame.height);
        owned.data.copy_from_slice(&frame.data);
        self.submit_owned(stream, owned)
    }

    /// Submit one owned frame to `stream` (zero-copy ingest path; the
    /// buffer joins the shared recycling pool afterwards).
    ///
    /// Errors are submission-scoped and leave every other stream — and
    /// this stream's already-queued frames — untouched:
    /// [`ExecError::PoisonFrame`] for rejected input,
    /// [`ExecError::QueueOverflow`] when a Block-policy wait exceeds the
    /// stream's deadline, or a geometry-pin error.  Worker-side faults
    /// are *not* returned here; they surface as
    /// [`ServerEvent::Fault`]s.
    pub fn submit_owned(&mut self, stream: usize, frame: Frame) -> Result<Submitted> {
        if stream >= self.plans.len() {
            bail!("unknown stream id {stream} (server has {} streams)", self.plans.len());
        }
        if let Err(e) = self.admit(stream, &frame) {
            self.pool.recycle(frame);
            return Err(e);
        }
        let seq = self.pool.next_submit(stream);
        if let Err(e) = self.screen(stream, &frame, seq) {
            self.pool.recycle(frame);
            return Err(e);
        }
        if self.pool.live_frames(stream) >= self.pool.cap(stream) {
            // fold in whatever has already completed, without blocking
            self.pump_completions()?;
            self.expire_overdue();
        }
        if self.pool.live_frames(stream) >= self.pool.cap(stream) {
            match self.configs[stream].overload {
                OverloadPolicy::Block => {
                    if let Err(e) = self.block_for_room(stream) {
                        self.pool.recycle(frame);
                        return Err(e);
                    }
                }
                OverloadPolicy::DropNewest => {
                    self.pool.drop_newest(stream, frame);
                    return Ok(Submitted::Dropped(seq));
                }
                OverloadPolicy::DropOldest => {
                    if !self.pool.retract_oldest(stream) {
                        self.pool.drop_newest(stream, frame);
                        return Ok(Submitted::Dropped(seq));
                    }
                }
            }
        }
        let seq = self.pool.submit(stream, frame);
        self.sweep_ready();
        Ok(Submitted::Queued(seq))
    }

    /// Backpressure wait for `stream` (Block policy), bounded by the
    /// stream's deadline and measured from when the stall began — an
    /// already-expired budget fails fast as a typed overflow.
    fn block_for_room(&mut self, stream: usize) -> Result<()> {
        let deadline = self.configs[stream].deadline;
        let stalled = Instant::now();
        while self.pool.live_frames(stream) >= self.pool.cap(stream) {
            let wait = match deadline {
                Some(d) => match d.checked_sub(stalled.elapsed()) {
                    Some(left) if !left.is_zero() => Wait::Timeout(left),
                    _ => {
                        return Err(ExecError::QueueOverflow {
                            frame_seq: self.pool.next_submit(stream),
                            capacity: self.pool.cap(stream),
                            waited: stalled.elapsed(),
                        }
                        .into());
                    }
                },
                None => Wait::Block,
            };
            match self.pool.poll_completion(&self.plans, wait)? {
                Polled::Progress => {}
                Polled::Faulted { stream: s, error } => {
                    self.events.push_back(ServerEvent::Fault { stream: s, error });
                }
                Polled::TimedOut => {
                    return Err(ExecError::QueueOverflow {
                        frame_seq: self.pool.next_submit(stream),
                        capacity: self.pool.cap(stream),
                        waited: stalled.elapsed(),
                    }
                    .into());
                }
            }
            self.sweep_ready();
            self.expire_overdue();
        }
        Ok(())
    }

    /// Nonblocking scheduler tick: fold every already-arrived completion,
    /// deliver in-order-ready frames, give up on overdue ones.  Returns
    /// the buffered events (outputs and faults, oldest first).
    pub fn pump(&mut self) -> Result<Vec<ServerEvent>> {
        self.pump_completions()?;
        self.expire_overdue();
        Ok(self.take_events())
    }

    /// Block until every stream's in-flight work is delivered, abandoned
    /// (per-stream deadlines) or faulted; returns the buffered events.
    pub fn drain(&mut self) -> Result<Vec<ServerEvent>> {
        loop {
            self.pump_completions()?;
            self.expire_overdue();
            if (0..self.plans.len()).all(|s| self.pool.unemitted(s) == 0) {
                break;
            }
            // deadline-bounded watchdog wait when any stream has one (an
            // overdue frame must be expired even if no completion lands)
            let wait = match self.configs.iter().filter_map(|c| c.deadline).min() {
                Some(d) => Wait::Timeout(d),
                None => Wait::Block,
            };
            match self.pool.poll_completion(&self.plans, wait)? {
                Polled::Progress | Polled::TimedOut => {}
                Polled::Faulted { stream, error } => {
                    self.events.push_back(ServerEvent::Fault { stream, error });
                }
            }
            self.sweep_ready();
        }
        Ok(self.take_events())
    }

    /// A producer-side handle feeding `stream` through the shared ingest
    /// channel.  Create every sender **before** calling
    /// [`FrameServer::run`] (run hangs up the master sender so it can
    /// observe the producers finishing).
    pub fn sender(&self, stream: usize) -> Result<StreamSender> {
        if stream >= self.plans.len() {
            bail!("unknown stream id {stream} (server has {} streams)", self.plans.len());
        }
        match &self.ingest_tx {
            Some(tx) => Ok(StreamSender { stream, tx: tx.clone() }),
            None => bail!("the server is already running; create senders before run()"),
        }
    }

    /// Serve the ingest channel until every [`StreamSender`] is dropped,
    /// then drain.  Each event is handed to `on_event`; return the
    /// output frame from the callback to recycle its buffer (return
    /// `None` to keep it).  Submission-side faults (poison frames,
    /// Block-policy overflow) are converted to [`ServerEvent::Fault`]s
    /// on their stream, keeping every other stream live; only
    /// non-stream errors (e.g. [`ExecError::Shutdown`]) abort the loop.
    ///
    /// The loop is event-driven, not polled: with work in flight it
    /// blocks on the pool's completion channel (bounded by the nearest
    /// pending deadline so overdue frames still expire on time); idle
    /// and connected it blocks indefinitely on the ingest channel.  An
    /// idle server therefore makes **no** progress-free wakeups
    /// ([`FrameServer::idle_wakeups`]).
    pub fn run(&mut self, mut on_event: impl FnMut(ServerEvent) -> Option<Frame>) -> Result<()> {
        self.ingest_tx.take();
        let mut connected = true;
        loop {
            let mut progress = false;
            // fold everything already queued, without blocking
            while connected {
                match self.ingest_rx.try_recv() {
                    Ok((stream, frame)) => {
                        progress = true;
                        self.ingest(stream, frame)?;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => connected = false,
                }
            }
            progress |= self.pump_completions()?;
            progress |= self.expire_overdue() > 0;
            while let Some(ev) = self.events.pop_front() {
                progress = true;
                if let Some(frame) = on_event(ev) {
                    self.pool.recycle(frame);
                }
            }
            let in_flight = (0..self.plans.len()).any(|s| self.pool.unemitted(s) > 0);
            if !connected && !in_flight {
                break;
            }
            if in_flight {
                // sleep on the completion channel; cap the wait at the
                // nearest pending deadline so expiry never slips
                let wait = match self.nearest_deadline_wait() {
                    Some(t) => Wait::Timeout(t),
                    None => Wait::Block,
                };
                match self.pool.poll_completion(&self.plans, wait)? {
                    Polled::Progress => progress = true,
                    Polled::Faulted { stream, error } => {
                        progress = true;
                        self.events.push_back(ServerEvent::Fault { stream, error });
                    }
                    // a timeout is progress only if something expires —
                    // the next iteration's expire_overdue decides
                    Polled::TimedOut => {}
                }
                self.sweep_ready();
            } else {
                // idle: nothing can happen until a producer acts, so
                // block for free (a send or hang-up is the only wake)
                match self.ingest_rx.recv() {
                    Ok((stream, frame)) => self.ingest(stream, frame)?,
                    Err(_) => connected = false,
                }
                progress = true;
            }
            if !progress {
                self.idle_wakeups += 1;
            }
        }
        for ev in self.drain()? {
            if let Some(frame) = on_event(ev) {
                self.pool.recycle(frame);
            }
        }
        Ok(())
    }

    /// Submit one ingested frame, converting stream-scoped failures into
    /// buffered [`ServerEvent::Fault`]s (only non-stream errors
    /// propagate and abort [`FrameServer::run`]).
    fn ingest(&mut self, stream: usize, frame: Frame) -> Result<()> {
        if let Err(e) = self.submit_owned(stream, frame) {
            match e.downcast::<ExecError>() {
                Ok(error) => self.events.push_back(ServerEvent::Fault { stream, error }),
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Time until the earliest pending per-stream deadline fires, over
    /// streams with work in flight (`None`: no deadline can fire).
    fn nearest_deadline_wait(&self) -> Option<Duration> {
        let mut min: Option<Duration> = None;
        for s in 0..self.plans.len() {
            let Some(d) = self.configs[s].deadline else { continue };
            let Some(stamp) = self.pool.oldest_unemitted_stamp(s) else { continue };
            let left = d.saturating_sub(stamp.elapsed());
            min = Some(match min {
                Some(m) if m < left => m,
                _ => left,
            });
        }
        min
    }

    /// Progress-free scheduler wakeups observed by [`FrameServer::run`]
    /// so far — zero for an idle or purely event-driven run.
    pub fn idle_wakeups(&self) -> u64 {
        self.idle_wakeups
    }

    /// Hand an output frame buffer back to the shared recycling pool.
    pub fn recycle(&mut self, frame: Frame) {
        self.pool.recycle(frame);
    }

    /// One stream's report: submitted/delivered counts, latency
    /// statistics over its delivered frames, and its exact fault
    /// counters.  `elapsed` spans the server's lifetime.
    pub fn metrics(&self, stream: usize) -> Metrics {
        let c = self.pool.counters(stream);
        let submitted = self.pool.next_submit(stream);
        Metrics::from_latencies(submitted, self.started.elapsed(), self.lats[stream].clone())
            .with_fault_counts(c.dropped, c.deadline_misses, c.worker_restarts)
    }

    /// The whole server's report: counts and counters summed over every
    /// stream, latency statistics over all delivered frames.  (A worker
    /// that died *between* jobs — possible only under fault injection —
    /// books its restart on stream 0, so the aggregate stays exact.)
    pub fn aggregate(&self) -> Metrics {
        let mut all: Vec<Duration> = Vec::new();
        let mut submitted = 0u64;
        let (mut dropped, mut misses, mut restarts) = (0u64, 0u64, 0u64);
        for s in 0..self.plans.len() {
            all.extend_from_slice(&self.lats[s]);
            submitted += self.pool.next_submit(s);
            let c = self.pool.counters(s);
            dropped += c.dropped;
            misses += c.deadline_misses;
            restarts += c.worker_restarts;
        }
        Metrics::from_latencies(submitted, self.started.elapsed(), all)
            .with_fault_counts(dropped, misses, restarts)
    }

    /// Validate `frame` against stream `s`'s plan and pinned geometry.
    fn admit(&mut self, s: usize, frame: &Frame) -> Result<()> {
        match self.dims[s] {
            None => {
                self.plans[s].check_frame(frame)?;
                self.dims[s] = Some((frame.width, frame.height));
            }
            Some((w, h)) if (w, h) == (frame.width, frame.height) => {}
            Some((w, h)) => bail!(
                "stream {s} is pinned to {w}x{h} frames but received {}x{}: streams keep \
                 line buffers sized to one geometry — register a second stream for the new size",
                frame.width,
                frame.height
            ),
        }
        Ok(())
    }

    /// Input screening at submission (injected corruption under chaos
    /// builds, non-finite pixel validation) — same contract as
    /// [`Session`](super::Session).
    fn screen(&self, s: usize, frame: &Frame, seq: u64) -> Result<()> {
        #[cfg(feature = "fault-injection")]
        if let Some(faults) = &self.configs[s].faults {
            if let Some(value) = faults.corruption(seq) {
                return Err(ExecError::PoisonFrame { frame_seq: seq, index: 0, value }.into());
            }
        }
        if self.configs[s].validate {
            if let Some(index) = frame.data.iter().position(|v| !v.is_finite()) {
                return Err(ExecError::PoisonFrame {
                    frame_seq: seq,
                    index,
                    value: frame.data[index],
                }
                .into());
            }
        }
        Ok(())
    }

    /// Fold every already-arrived completion (any stream) without
    /// blocking, buffering faults and delivering ready outputs.
    /// Returns whether anything was folded.
    fn pump_completions(&mut self) -> Result<bool> {
        let mut any = false;
        loop {
            match self.pool.poll_completion(&self.plans, Wait::NoWait)? {
                Polled::Progress => any = true,
                Polled::Faulted { stream, error } => {
                    any = true;
                    self.events.push_back(ServerEvent::Fault { stream, error });
                }
                Polled::TimedOut => break,
            }
        }
        self.sweep_ready();
        Ok(any)
    }

    /// Move every stream's in-order-ready outputs into the event buffer.
    fn sweep_ready(&mut self) {
        for s in 0..self.plans.len() {
            let deadline = self.configs[s].deadline;
            while let Some((seq, latency, frame)) = self.pool.take_ready(s, deadline) {
                self.lats[s].push(latency);
                self.events.push_back(ServerEvent::Frame { stream: s, seq, latency, frame });
            }
        }
    }

    /// Give up on frames overdue against their stream's deadline: count
    /// the miss and the drop, surrender the slot (a late completion is
    /// recycled as stale) and buffer the typed fault.  Ready-but-late
    /// frames were already delivered (as counted misses) by
    /// [`FrameServer::sweep_ready`].  Returns how many frames expired.
    fn expire_overdue(&mut self) -> usize {
        let mut expired = 0usize;
        for s in 0..self.plans.len() {
            let Some(d) = self.configs[s].deadline else { continue };
            while let Some(stamp) = self.pool.oldest_unemitted_stamp(s) {
                let elapsed = stamp.elapsed();
                if elapsed <= d {
                    break;
                }
                let seq = self.pool.oldest_unemitted(s);
                let c = self.pool.counters_mut(s);
                c.deadline_misses += 1;
                c.dropped += 1;
                self.pool.abandon_seq(s, seq);
                expired += 1;
                self.events.push_back(ServerEvent::Fault {
                    stream: s,
                    error: ExecError::DeadlineExceeded { frame_seq: seq, deadline: d, elapsed },
                });
            }
        }
        expired
    }

    /// Drain the buffered events, oldest first.
    fn take_events(&mut self) -> Vec<ServerEvent> {
        self.events.drain(..).collect()
    }
}
