//! [`Pipeline`]: the ordered-stage builder in front of
//! [`CompiledPipeline`].

use anyhow::{bail, Context, Result};

use super::CompiledPipeline;
use crate::filters::{FilterChain, FilterKind, HwFilter};
use crate::fpcore::{FloatFormat, OpMode};

/// Per-stage modifiers bound after the stage was added ([`Pipeline::fmt`]
/// / [`Pipeline::stride`] style: each binds to the stage added
/// immediately before it).
#[derive(Default, Clone, Copy)]
struct Mods {
    fmt: Option<FloatFormat>,
    stride: Option<usize>,
}

/// One stage spec, recorded in builder order.
enum StageSpec {
    /// A built-in datapath; `fmt` falls back to the builder default.
    Builtin { kind: FilterKind, mods: Mods },
    /// DSL source; `fmt` overrides the program's `use float(m, e);`.
    Dsl { src: String, name: String, mods: Mods },
    /// ReLU (`max(x, 0)` over a 1×1 window).
    Relu { mods: Mods },
    /// Max-pool over a `k×k` window with its own explicit stride.
    Pool { k: usize, stride: usize, mods: Mods },
    /// A caller-compiled filter (custom kernels, pre-validated DSL).
    Prebuilt(Box<HwFilter>, Mods),
}

impl StageSpec {
    fn mods_mut(&mut self) -> &mut Mods {
        match self {
            StageSpec::Builtin { mods, .. }
            | StageSpec::Dsl { mods, .. }
            | StageSpec::Relu { mods }
            | StageSpec::Pool { mods, .. }
            | StageSpec::Prebuilt(_, mods) => mods,
        }
    }
}

/// Builder for an ordered filter pipeline — a single filter is just a
/// chain of one.  Stages are added in flow order with
/// [`Pipeline::builtin`] / [`Pipeline::dsl`] / [`Pipeline::stage`]; a
/// [`Pipeline::fmt`] call binds a custom float format to the stage added
/// immediately before it (mirroring the CLI's per-stage `--fmt`).
///
/// Nothing is validated until [`Pipeline::compile`], which returns the
/// immutable [`CompiledPipeline`] plan (or the first recorded error).
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use fpspatial::filters::FilterKind;
/// use fpspatial::fpcore::OpMode;
/// use fpspatial::pipeline::Pipeline;
///
/// let plan = Pipeline::new()
///     .builtin(FilterKind::Conv3x3) // default format: float16(10,5)
///     .builtin(FilterKind::Median)
///     .fmt(16, 7)                   // this stage runs in float24(16,7)
///     .compile(OpMode::Exact)?;
/// assert_eq!(plan.stages().len(), 2);
/// assert!(plan.is_mixed_format()); // a converter sits at the boundary
/// # Ok(())
/// # }
/// ```
pub struct Pipeline {
    stages: Vec<StageSpec>,
    /// Applied to `Builtin` stages with no explicit format.
    default_fmt: FloatFormat,
    /// Channel planes every stage runs over (chains require a uniform
    /// plane count, so this is a pipeline-wide setting).
    channels: Option<usize>,
    /// First builder misuse (e.g. `fmt` with no stage), surfaced by
    /// `compile` so the chained builder calls stay infallible.
    err: Option<String>,
}

impl Default for Pipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Pipeline {
    /// An empty pipeline with the paper's default float16(10,5) format.
    pub fn new() -> Self {
        Self {
            stages: Vec::new(),
            default_fmt: FloatFormat::new(10, 5),
            channels: None,
            err: None,
        }
    }

    /// Build a pipeline directly from compiled stages (flow order).
    pub fn from_stages(stages: impl IntoIterator<Item = HwFilter>) -> Self {
        let mut p = Self::new();
        for hw in stages {
            p = p.stage(hw);
        }
        p
    }

    /// Default format for built-in stages that get no explicit
    /// [`Pipeline::fmt`] (DSL stages default to their own
    /// `use float(m, e);` directive instead).
    pub fn default_format(mut self, fmt: FloatFormat) -> Self {
        self.default_fmt = fmt;
        self
    }

    /// Append a built-in filter stage.
    pub fn builtin(mut self, kind: FilterKind) -> Self {
        self.stages.push(StageSpec::Builtin { kind, mods: Mods::default() });
        self
    }

    /// Append a ReLU stage (`max(x, 0)`, 1×1 window).  Format defaults
    /// to the builder default; override with [`Pipeline::fmt`].
    pub fn relu(mut self) -> Self {
        self.stages.push(StageSpec::Relu { mods: Mods::default() });
        self
    }

    /// Append a `k×k` max-pool stage with the given stride (`stride = k`
    /// is the classic non-overlapping pool).  Format defaults to the
    /// builder default; override with [`Pipeline::fmt`].
    pub fn max_pool(mut self, k: usize, stride: usize) -> Self {
        self.stages.push(StageSpec::Pool { k, stride, mods: Mods::default() });
        self
    }

    /// Append a DSL window-program stage (module name auto-derived as
    /// `dsl_stage<i>`; use [`Pipeline::dsl_named`] to control it).
    pub fn dsl(self, src: impl Into<String>) -> Self {
        let name = format!("dsl_stage{}", self.stages.len());
        self.dsl_named(src, name)
    }

    /// Append a DSL window-program stage with an explicit module/display
    /// name.
    pub fn dsl_named(mut self, src: impl Into<String>, name: impl Into<String>) -> Self {
        self.stages
            .push(StageSpec::Dsl { src: src.into(), name: name.into(), mods: Mods::default() });
        self
    }

    /// Append an already-compiled filter (e.g. [`HwFilter::with_kernel`]
    /// convolutions with custom coefficients).
    pub fn stage(mut self, hw: HwFilter) -> Self {
        self.stages.push(StageSpec::Prebuilt(Box::new(hw), Mods::default()));
        self
    }

    /// Set the custom float format of the stage added immediately before
    /// — shorthand for [`Pipeline::format`] with `FloatFormat::new(m, e)`.
    pub fn fmt(self, mantissa: u32, exponent: u32) -> Self {
        self.format(FloatFormat::new(mantissa, exponent))
    }

    /// Set the custom float format of the stage added immediately before
    /// this call.  Misuse (no stage yet, a second format for the same
    /// stage, or a prebuilt stage that already carries its format) is
    /// reported by [`Pipeline::compile`].
    pub fn format(mut self, fmt: FloatFormat) -> Self {
        let misuse = match self.stages.last_mut() {
            None => Some(
                "Pipeline::fmt binds to the stage added before it; add a stage first \
                 (or use Pipeline::default_format)"
                    .to_string(),
            ),
            Some(StageSpec::Prebuilt(hw, _)) => Some(format!(
                "stage `{}` was added pre-compiled and already carries its format ({})",
                hw.name(),
                hw.fmt
            )),
            Some(spec) => {
                let slot = &mut spec.mods_mut().fmt;
                if slot.is_some() {
                    Some("stage already has a format; give one Pipeline::fmt per stage".to_string())
                } else {
                    *slot = Some(fmt);
                    None
                }
            }
        };
        if self.err.is_none() {
            self.err = misuse;
        }
        self
    }

    /// Set the vertical/horizontal stride of the stage added immediately
    /// before this call (same binding rule as [`Pipeline::fmt`]).  A
    /// strided stage emits every `stride`-th window in both axes, so it
    /// shrinks the output frame to `ceil(dim / stride)`.  Misuse (no
    /// stage yet, a second stride for the same stage, or a pool stage,
    /// whose stride is an explicit [`Pipeline::max_pool`] argument) is
    /// reported by [`Pipeline::compile`].
    pub fn stride(mut self, stride: usize) -> Self {
        let misuse = match self.stages.last_mut() {
            None => Some(
                "Pipeline::stride binds to the stage added before it; add a stage first"
                    .to_string(),
            ),
            Some(StageSpec::Pool { .. }) => Some(
                "a pool stage takes its stride as the explicit Pipeline::max_pool(k, stride) \
                 argument"
                    .to_string(),
            ),
            Some(spec) => {
                let slot = &mut spec.mods_mut().stride;
                if slot.is_some() {
                    Some(
                        "stage already has a stride; give one Pipeline::stride per stage"
                            .to_string(),
                    )
                } else {
                    *slot = Some(stride);
                    None
                }
            }
        };
        if self.err.is_none() {
            self.err = misuse;
        }
        self
    }

    /// Run every stage over `channels` independent planes stacked
    /// vertically in the frame (`frame.height = channels · plane_height`).
    /// Chains require a uniform plane count across stages, so this is a
    /// pipeline-wide setting, not a per-stage binding.
    pub fn channels(mut self, channels: usize) -> Self {
        self.channels = Some(channels);
        self
    }

    /// Validate and compile the spec into an immutable
    /// [`CompiledPipeline`] plan: every stage's netlist is built (DSL
    /// sources are compiled), formats are resolved, and the inter-stage
    /// converters / accumulated halo are derived.  `mode` fixes the
    /// numeric operator model ([`OpMode::Exact`] bit-level rounding or
    /// [`OpMode::Poly`] piecewise-polynomial approximations) for every
    /// session created from the plan and for the sequential oracle.
    pub fn compile(self, mode: OpMode) -> Result<CompiledPipeline> {
        if let Some(err) = self.err {
            bail!("invalid pipeline spec: {err}");
        }
        if self.stages.is_empty() {
            bail!("a pipeline needs at least one stage (Pipeline::builtin / dsl / stage)");
        }
        let mut stages = Vec::with_capacity(self.stages.len());
        for (i, spec) in self.stages.into_iter().enumerate() {
            let (mut hw, mods) = match spec {
                StageSpec::Builtin { kind, mods } => (
                    HwFilter::new(kind, mods.fmt.unwrap_or(self.default_fmt))
                        .with_context(|| format!("pipeline stage {i}"))?,
                    mods,
                ),
                StageSpec::Dsl { src, name, mods } => (
                    HwFilter::from_dsl(&src, &name, mods.fmt)
                        .with_context(|| format!("pipeline stage {i} (`{name}`)"))?,
                    mods,
                ),
                StageSpec::Relu { mods } => {
                    (HwFilter::relu(mods.fmt.unwrap_or(self.default_fmt)), mods)
                }
                StageSpec::Pool { k, stride, mods } => (
                    HwFilter::max_pool(mods.fmt.unwrap_or(self.default_fmt), k, stride)
                        .with_context(|| format!("pipeline stage {i}"))?,
                    mods,
                ),
                StageSpec::Prebuilt(hw, mods) => (*hw, mods),
            };
            if let Some(s) = mods.stride {
                hw = hw.with_stride(s);
            }
            if let Some(c) = self.channels {
                hw = hw.with_channels(c);
            }
            stages.push(hw);
        }
        let chain = FilterChain::new(stages)?;
        Ok(CompiledPipeline::from_chain(chain, mode))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::conv;

    const MEDIAN_DSL: &str = include_str!("../../../examples/dsl/median.dsl");
    const FIG12_DSL: &str = include_str!("../../../examples/dsl/fig12.dsl");

    #[test]
    fn empty_pipeline_is_an_error() {
        let err = Pipeline::new().compile(OpMode::Exact).unwrap_err();
        assert!(err.to_string().contains("at least one stage"), "{err}");
    }

    #[test]
    fn fmt_before_any_stage_is_a_compile_error() {
        let err = Pipeline::new().fmt(10, 5).builtin(FilterKind::Median).compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("add a stage first"), "{msg}");
    }

    #[test]
    fn double_fmt_for_one_stage_is_a_compile_error() {
        let err = Pipeline::new()
            .builtin(FilterKind::Median)
            .fmt(10, 5)
            .fmt(16, 7)
            .compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("one Pipeline::fmt per stage"), "{msg}");
    }

    #[test]
    fn fmt_on_a_prebuilt_stage_is_a_compile_error() {
        let hw = HwFilter::new(FilterKind::Median, FloatFormat::new(10, 5)).unwrap();
        let err = Pipeline::new().stage(hw).fmt(16, 7).compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("already carries its format"), "{msg}");
    }

    #[test]
    fn hls_sobel_is_rejected_with_the_stage_index() {
        let err = Pipeline::new()
            .builtin(FilterKind::Median)
            .builtin(FilterKind::HlsSobel)
            .compile(OpMode::Exact)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stage 1"), "{msg}");
        assert!(msg.contains("hls_sobel"), "{msg}");
    }

    #[test]
    fn scalar_dsl_programs_are_rejected() {
        let err =
            Pipeline::new().dsl(FIG12_DSL).compile(OpMode::Exact).unwrap_err();
        assert!(format!("{err:#}").contains("sliding_window"), "{err:#}");
    }

    #[test]
    fn default_format_applies_to_unannotated_builtins_only() {
        let plan = Pipeline::new()
            .default_format(FloatFormat::new(16, 7))
            .builtin(FilterKind::Median)
            .builtin(FilterKind::Conv3x3)
            .fmt(10, 5)
            .dsl_named(MEDIAN_DSL, "median_dsl") // keeps its own float16(10,5)
            .compile(OpMode::Exact)
            .unwrap();
        let fmts: Vec<FloatFormat> = plan.stages().iter().map(|hw| hw.fmt).collect();
        assert_eq!(
            fmts,
            vec![FloatFormat::new(16, 7), FloatFormat::new(10, 5), FloatFormat::new(10, 5)]
        );
        assert_eq!(plan.name(), "median->conv3x3->median_dsl");
    }

    #[test]
    fn auto_dsl_names_index_by_position() {
        let plan = Pipeline::new()
            .builtin(FilterKind::Median)
            .dsl(MEDIAN_DSL)
            .compile(OpMode::Exact)
            .unwrap();
        assert_eq!(plan.name(), "median->dsl_stage1");
    }

    #[test]
    fn prebuilt_stages_keep_their_kernel() {
        let k = [0.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0];
        let hw = HwFilter::with_kernel(FilterKind::Conv3x3, FloatFormat::new(10, 5), &k);
        let plan = Pipeline::new().stage(hw).compile(OpMode::Exact).unwrap();
        // noise pixels are integers in [0, 255]: exactly representable in
        // float16(10,5), so the doubling kernel's output is exactly 2x
        let f = crate::video::Frame::noise(16, 9, 42);
        let out = plan.run_frame_sequential(&f);
        assert_eq!(out.get(8, 4), 2.0 * f.get(8, 4));
    }

    #[test]
    fn from_stages_preserves_order() {
        let plan = Pipeline::from_stages(vec![
            HwFilter::new(FilterKind::Median, FloatFormat::new(10, 5)).unwrap(),
            HwFilter::new(FilterKind::FpSobel, FloatFormat::new(10, 5)).unwrap(),
        ])
        .compile(OpMode::Exact)
        .unwrap();
        assert_eq!(plan.name(), "median->fp_sobel");
    }

    #[test]
    fn builtin_conv_matches_the_gaussian_prebuilt_stage() {
        // Pipeline::builtin(Conv3x3) defaults to the same Gaussian kernel
        // as HwFilter::new / with_kernel(gaussian3x3)
        let plan = Pipeline::new().builtin(FilterKind::Conv3x3).compile(OpMode::Exact).unwrap();
        let hand = HwFilter::with_kernel(
            FilterKind::Conv3x3,
            FloatFormat::new(10, 5),
            &conv::gaussian3x3(),
        );
        let want = Pipeline::new().stage(hand).compile(OpMode::Exact).unwrap();
        let f = crate::video::Frame::test_card(20, 12);
        assert_eq!(plan.run_frame_sequential(&f).data, want.run_frame_sequential(&f).data);
        assert_eq!(plan.stages()[0].geom, crate::video::StageGeometry::square(3));
    }

    #[test]
    fn stride_before_any_stage_is_a_compile_error() {
        let err = Pipeline::new().stride(2).builtin(FilterKind::Median).compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("add a stage first"), "{msg}");
    }

    #[test]
    fn double_stride_for_one_stage_is_a_compile_error() {
        let err = Pipeline::new()
            .builtin(FilterKind::Median)
            .stride(2)
            .stride(3)
            .compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("one Pipeline::stride per stage"), "{msg}");
    }

    #[test]
    fn stride_on_a_pool_stage_is_a_compile_error() {
        let err = Pipeline::new().max_pool(2, 2).stride(2).compile(OpMode::Exact);
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("max_pool"), "{msg}");
    }

    #[test]
    fn cnn_stages_compile_with_per_stage_formats() {
        let plan = Pipeline::new()
            .builtin(FilterKind::Conv3x3)
            .fmt(16, 7)
            .stride(2)
            .relu()
            .fmt(10, 5)
            .max_pool(2, 2)
            .compile(OpMode::Exact)
            .unwrap();
        assert_eq!(plan.name(), "conv3x3->relu->maxpool2x2");
        assert!(plan.is_mixed_format());
        let geoms: Vec<_> = plan.stages().iter().map(|hw| hw.geom).collect();
        assert_eq!(geoms[0].stride, 2);
        assert_eq!((geoms[1].win_h, geoms[1].win_w, geoms[1].stride), (1, 1, 1));
        assert_eq!((geoms[2].win_h, geoms[2].stride), (2, 2));
    }

    #[test]
    fn channels_apply_to_every_stage() {
        let plan = Pipeline::new()
            .builtin(FilterKind::Median)
            .builtin(FilterKind::Conv3x3)
            .channels(3)
            .compile(OpMode::Exact)
            .unwrap();
        assert!(plan.stages().iter().all(|hw| hw.geom.channels == 3));
        assert_eq!(plan.channels(), 3);
        // a 3-plane frame: each 20x8 plane filtered independently
        let f = crate::video::Frame::test_card(20, 24);
        let out = plan.run_frame_sequential(&f);
        assert_eq!((out.width, out.height), (20, 24));
    }

    #[test]
    fn zero_stride_is_rejected_at_compile() {
        let err = Pipeline::new()
            .builtin(FilterKind::Median)
            .stride(0)
            .compile(OpMode::Exact)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stride"), "{msg}");
    }
}
