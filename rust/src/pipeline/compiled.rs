//! [`CompiledPipeline`]: the immutable validated plan between the
//! [`Pipeline`](super::Pipeline) builder and the [`Session`] executor.

use anyhow::Result;

use super::{ExecPlan, Session, SessionConfig};
use crate::filters::{eval_band, FilterChain, HwFilter};
use crate::fpcore::{FmtConvert, OpMode};
use crate::resources::Usage;
use crate::sim::{Engine, KernelCache};
use crate::util::json::Json;
use crate::video::{Frame, WindowGenerator};

/// An immutable, validated execution plan: every stage's scheduled
/// netlist, the explicit inter-stage format converters, the accumulated
/// vertical halo, and the latency / line-buffer / resource reporting of
/// the whole cascade.  Produced by
/// [`Pipeline::compile`](super::Pipeline::compile); executed by
/// [`Session`]s created with [`CompiledPipeline::session`].
///
/// The plan is the *identity* of the computation — the numeric mode is
/// fixed here, so every session (and the sequential oracle
/// [`CompiledPipeline::run_frame_sequential`]) evaluates the same
/// function.  Plans are freely shared across threads (`&CompiledPipeline`
/// is all a session borrows).
pub struct CompiledPipeline {
    chain: FilterChain,
    mode: OpMode,
    /// Stride-aware accumulated halo: source context rows a band
    /// evaluation reads above/below its output band (backward fold of
    /// `h·strideᵢ + max(p_topᵢ, p_botᵢ)` over the stages).
    total_halo: usize,
}

impl CompiledPipeline {
    pub(crate) fn from_chain(chain: FilterChain, mode: OpMode) -> Self {
        let total_halo = chain.total_halo();
        let plan = Self { chain, mode, total_halo };
        // Warm the process-wide kernel cache at plan-compile time so no
        // session / pool worker / server stream pays the (cold, locked)
        // first compile on its hot path — and so N executors of this
        // plan provably share one kernel per stage.  Warm the *execution*
        // netlists (boundary converters folded into the producing stage),
        // which is what ChainRunner compiles.
        for i in 0..plan.len() {
            KernelCache::global().get_or_compile(plan.chain.exec_netlist(i).as_ref(), mode);
        }
        plan
    }

    /// The fixed numeric operator model of this plan.
    pub fn mode(&self) -> OpMode {
        self.mode
    }

    /// The compiled stages, in flow order.
    pub fn stages(&self) -> &[HwFilter] {
        self.chain.stages()
    }

    /// Number of stages (a single filter is a pipeline of one).
    pub fn len(&self) -> usize {
        self.chain.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chain.is_empty()
    }

    /// Display name: stage names joined in flow order (cached — safe to
    /// call in per-frame metrics/logging paths).
    pub fn name(&self) -> &str {
        self.chain.name()
    }

    /// The explicit converter at each of the `len() − 1` stage
    /// boundaries — `None` where the formats match (plain wire).
    pub fn converters(&self) -> Vec<Option<FmtConvert>> {
        self.chain.converters()
    }

    /// Does any boundary convert between formats?
    pub fn is_mixed_format(&self) -> bool {
        self.chain.is_mixed_format()
    }

    /// Largest stage window (max of height/width over the stages).
    pub fn max_ksize(&self) -> usize {
        self.chain.max_ksize()
    }

    /// Channel planes every stage of the plan runs over.
    pub fn channels(&self) -> usize {
        self.chain.channels()
    }

    /// Output frame dimensions for a `width × height` input — strided
    /// stages shrink the frame, so this is NOT the input shape.
    pub fn output_dims(&self, width: usize, height: usize) -> (usize, usize) {
        self.chain.output_dims(width, height)
    }

    /// Σ per-stage halo radii: how many source context rows a band
    /// evaluation needs above/below the output band (tiled execution).
    pub fn total_halo(&self) -> usize {
        self.total_halo
    }

    /// Combined datapath latency in cycles (stage netlists plus the
    /// inter-stage converters).
    pub fn datapath_latency(&self) -> u32 {
        self.chain.datapath_latency()
    }

    /// End-to-end latency in cycles for `width`-pixel lines (window
    /// generators' structural latency + datapaths + converters).
    pub fn pipeline_latency_cycles(&self, width: usize) -> u64 {
        self.chain.pipeline_latency_cycles(width)
    }

    /// Total line-buffer storage across stages for `width`-pixel lines.
    pub fn line_buffer_bits(&self, width: usize) -> u64 {
        self.chain.line_buffer_bits(width)
    }

    /// Chain-wide FPGA resource estimate for `line_width`-pixel lines.
    pub fn resource_usage(&self, line_width: usize) -> Usage {
        self.chain.resource_usage(line_width)
    }

    /// Can this plan stream `frame`?  (Usable error naming the offending
    /// stage, instead of the panic an unchecked evaluation would raise.)
    pub fn check_frame(&self, frame: &Frame) -> Result<()> {
        self.chain.check_frame(frame)
    }

    /// Emit ONE SystemVerilog top module for the whole plan: every
    /// stage's compiled module, `fmt_converter` blocks at mixed-format
    /// boundaries, and per-stage `generateWindow` line buffers.
    pub fn emit_sv(&self, top: &str, resolution: (u32, u32)) -> String {
        self.chain.emit_sv(top, resolution)
    }

    /// JSON dump of the plan (stage netlists + converters + latency).
    pub fn netlist_json(&self, top: &str) -> Json {
        self.chain.netlist_json(top)
    }

    /// Human-readable dump of every stage's compiled fused kernel
    /// (`fpspatial compile --emit kernel`): pass counters + one line per
    /// direct-threaded instruction.
    pub fn kernel_dump(&self) -> String {
        let mut out = String::new();
        for (i, hw) in self.stages().iter().enumerate() {
            out.push_str(&format!("stage {}\n", hw.name()));
            out.push_str(
                &KernelCache::global()
                    .get_or_compile(self.chain.exec_netlist(i).as_ref(), self.mode)
                    .dump(),
            );
        }
        out
    }

    /// The underlying stage container (crate-internal: sessions compile
    /// their engines from it).
    pub(crate) fn chain(&self) -> &FilterChain {
        &self.chain
    }

    /// Rewrite the plan by composing every adjacent stride-1 same-format
    /// linear-convolution pair into one wider convolution (3×3∘3×3 →
    /// 5×5), measuring the numeric drift on the default deterministic
    /// reference frames.  Refuses — with per-boundary reasons — when no
    /// boundary is fusible (non-linear, strided, or mixed-format).  See
    /// [`crate::opt::fuse`].
    pub fn fused(&self) -> Result<(CompiledPipeline, crate::opt::FusionReport)> {
        crate::opt::fuse::fuse_plan(self)
    }

    /// [`CompiledPipeline::fused`] with explicit reference frames and
    /// pricing line width.
    pub fn fused_with(
        &self,
        frames: &[Frame],
        line_width: usize,
    ) -> Result<(CompiledPipeline, crate::opt::FusionReport)> {
        crate::opt::fuse::fuse_plan_with(self, frames, line_width)
    }

    /// Create a mutable executor for this plan.  Each session owns its
    /// engines, window generators and scratch (plus a persistent worker
    /// pool for [`ExecPlan::Streaming`]), so concurrent sessions on one
    /// plan never contend.
    pub fn session(&self, exec: ExecPlan) -> Result<Session<'_>> {
        Session::new(self, exec)
    }

    /// [`CompiledPipeline::session`] with an explicit supervision
    /// contract: per-frame deadline, overload policy, input validation
    /// (and, under `--features fault-injection`, a chaos script).
    pub fn session_with(&self, exec: ExecPlan, config: SessionConfig) -> Result<Session<'_>> {
        Session::new_with(self, exec, config)
    }

    /// The plan's **self-check oracle**: apply each stage to a fully
    /// materialised frame, sequentially, with a fresh scalar engine and
    /// window generator per call, converting the frame into the next
    /// stage's format at every mixed-format boundary.  This is the
    /// reference semantics every [`ExecPlan`] must reproduce
    /// bit-identically (`tests/batch_parity.rs`, `tests/chain_parity.rs`,
    /// `tests/session_reuse.rs`).
    ///
    /// Deliberately shares no execution machinery with [`Session`]: no
    /// cached engines, no fused row streaming, no lane batching.
    ///
    /// Panics on frames [`CompiledPipeline::check_frame`] rejects.
    pub fn run_frame_sequential(&self, frame: &Frame) -> Frame {
        if frame.height == 0 {
            let (ow, _) = self.output_dims(frame.width, 0);
            return Frame::new(ow, 0);
        }
        let converters = self.converters();
        let mut cur: Option<Frame> = None;
        for (i, hw) in self.stages().iter().enumerate() {
            let src = cur.as_ref().unwrap_or(frame);
            let (ow, oh) = hw.output_dims(src.width, src.height);
            let mut out = Frame::new(ow, oh);
            let mut eng = Engine::new(&hw.netlist, self.mode);
            let mut gen = WindowGenerator::with_geometry(hw.geom, src.width).unwrap_or_else(|e| {
                panic!("stage `{}`: {e} (see CompiledPipeline::check_frame)", hw.name())
            });
            eval_band(&mut eng, &mut gen, src, 0, oh, &mut out.data);
            if let Some(Some(cvt)) = converters.get(i) {
                cvt.apply_row(&mut out.data);
            }
            cur = Some(out);
        }
        cur.expect("plans have at least one stage")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::FilterKind;
    use crate::fpcore::FloatFormat;
    use crate::pipeline::Pipeline;

    const F16: FloatFormat = FloatFormat::new(10, 5);
    const F24: FloatFormat = FloatFormat::new(16, 7);

    fn mixed_plan() -> CompiledPipeline {
        Pipeline::new()
            .builtin(FilterKind::Median)
            .format(F24)
            .builtin(FilterKind::FpSobel)
            .format(F16)
            .compile(OpMode::Exact)
            .unwrap()
    }

    #[test]
    fn plan_reports_the_cascade_shape() {
        let plan = mixed_plan();
        assert_eq!(plan.len(), 2);
        assert!(!plan.is_empty());
        assert_eq!(plan.name(), "median->fp_sobel");
        assert_eq!(plan.mode(), OpMode::Exact);
        assert_eq!(plan.max_ksize(), 3);
        assert_eq!(plan.total_halo(), 2);
        assert!(plan.is_mixed_format());
        assert_eq!(plan.converters(), vec![Some(FmtConvert::new(F24, F16))]);
        // stage latencies + the 2-cycle converter
        assert_eq!(plan.datapath_latency(), 19 + 39 + 2);
        assert_eq!(plan.pipeline_latency_cycles(100), (100 + 1 + 19) + 2 + (100 + 1 + 39));
        assert_eq!(plan.line_buffer_bits(100), 2 * 100 * 24 + 2 * 100 * 16);
    }

    #[test]
    fn oracle_matches_manual_per_stage_quantized_application() {
        // independent reference: run each stage as its own plan, quantize
        // the materialised frame at the boundary by hand
        let plan = mixed_plan();
        let f = Frame::test_card(29, 14);
        let s0 = Pipeline::new().builtin(FilterKind::Median).format(F24).compile(OpMode::Exact);
        let s1 = Pipeline::new().builtin(FilterKind::FpSobel).format(F16).compile(OpMode::Exact);
        let mut mid = s0.unwrap().run_frame_sequential(&f);
        for v in &mut mid.data {
            *v = crate::fpcore::quantize(*v, F16);
        }
        let want = s1.unwrap().run_frame_sequential(&mid);
        let got = plan.run_frame_sequential(&f);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn oracle_handles_empty_frames() {
        let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact).unwrap();
        let out = plan.run_frame_sequential(&Frame::new(24, 0));
        assert_eq!((out.width, out.height), (24, 0));
    }

    #[test]
    fn strided_plan_reports_and_produces_shrunk_output() {
        let plan = Pipeline::new()
            .builtin(FilterKind::Conv3x3)
            .stride(2)
            .relu()
            .max_pool(2, 2)
            .compile(OpMode::Exact)
            .unwrap();
        // 23×13 → conv3x3/s2 → 12×7 → relu → 12×7 → pool2x2/s2 → 6×4
        assert_eq!(plan.output_dims(23, 13), (6, 4));
        // halo fold: pool(1) → relu(1) → conv/s2 (1·2+1 = 3)
        assert_eq!(plan.total_halo(), 3);
        let out = plan.run_frame_sequential(&Frame::test_card(23, 13));
        assert_eq!((out.width, out.height), (6, 4));
    }

    #[test]
    fn check_frame_names_the_offending_stage() {
        let plan = Pipeline::new()
            .builtin(FilterKind::Median)
            .builtin(FilterKind::Conv5x5)
            .compile(OpMode::Exact)
            .unwrap();
        let err = plan.check_frame(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("conv5x5"), "{err}");
        assert!(plan.check_frame(&Frame::test_card(24, 16)).is_ok());
    }

    #[test]
    fn emission_and_json_delegate_to_the_cascade() {
        let plan = mixed_plan();
        let sv = plan.emit_sv("cascade", (1920, 1080));
        assert_eq!(sv.matches("endmodule").count(), 3);
        assert_eq!(sv.matches("fmt_converter #(").count(), 1);
        let v = crate::util::json::Json::parse(&plan.netlist_json("cascade").to_string()).unwrap();
        assert_eq!(v.get("stages").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("converters").unwrap().as_arr().unwrap().len(), 1);
    }
}
