//! The one execution API: **`Pipeline` → `CompiledPipeline` → `Session`**.
//!
//! The paper frames the generator as a single parameterized pipeline spec
//! compiled into an executable artifact; this module gives the software
//! runtime the same shape, collapsing the historical fork between
//! single-filter and chain execution and between the
//! scalar/batched/tiled/streaming entry points:
//!
//! 1. [`Pipeline`] — a builder over ordered stages
//!    ([`Pipeline::builtin`] / [`Pipeline::dsl`] / [`Pipeline::relu`] /
//!    [`Pipeline::max_pool`] / [`Pipeline::stage`], with per-stage
//!    [`Pipeline::fmt`] / [`Pipeline::stride`] overrides and
//!    pipeline-wide [`Pipeline::channels`]).  A single filter is simply
//!    a chain of one; CNN-shaped stacks can also be loaded from a `.net`
//!    descriptor file ([`load_net`] / [`parse_net`]).
//! 2. [`CompiledPipeline`] — the immutable validated plan produced by
//!    [`Pipeline::compile`]: compiled netlists, inter-stage format
//!    converters, accumulated halo, latency / line-buffer / resource
//!    reporting, SystemVerilog emission ([`CompiledPipeline::emit_sv`])
//!    and the sequential self-check oracle
//!    ([`CompiledPipeline::run_frame_sequential`]).
//! 3. [`Session`] — the mutable per-thread executor created from a plan
//!    plus an [`ExecPlan`].  A session owns reusable engines, window
//!    generators and lane scratch (and, for
//!    [`ExecPlan::Streaming`], a persistent worker pool), so
//!    [`Session::process`] across a whole video stream performs no
//!    steady-state reallocation of the execution machinery.
//! 4. [`FrameServer`] — N independent streams scheduled over ONE shared
//!    supervised worker pool (fair round-robin dispatch, bounded
//!    per-stream queues with backpressure, frame buffers recycled across
//!    streams, per-stream + aggregate [`Metrics`]) — the engine behind
//!    `fpspatial serve`.
//!
//! Every execution strategy is one [`ExecPlan`] value, and every plan is
//! bit-identical to the others and to the sequential oracle — enforced by
//! `tests/session_reuse.rs`, `tests/batch_parity.rs` and
//! `tests/chain_parity.rs`.
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use fpspatial::filters::FilterKind;
//! use fpspatial::fpcore::OpMode;
//! use fpspatial::pipeline::{ExecPlan, Pipeline};
//! use fpspatial::video::Frame;
//!
//! // denoise -> edge-detect, mixed precision, one fused streaming pass
//! let plan = Pipeline::new()
//!     .builtin(FilterKind::Median)
//!     .fmt(10, 5)
//!     .builtin(FilterKind::FpSobel)
//!     .fmt(7, 6)
//!     .compile(OpMode::Exact)?;
//! assert_eq!(plan.name(), "median->fp_sobel");
//!
//! let mut session = plan.session(ExecPlan::Batched)?;
//! for i in 0..3 {
//!     let frame = Frame::noise(64, 48, i);
//!     let out = session.process(&frame)?; // engines & line buffers stay warm
//!     assert_eq!((out.width, out.height), (64, 48));
//! }
//! # Ok(())
//! # }
//! ```

mod builder;
mod compiled;
mod error;
mod net;
mod pool;
mod server;
mod session;

use std::time::Duration;

use anyhow::{bail, Result};

pub use builder::Pipeline;
pub use compiled::CompiledPipeline;
pub use error::ExecError;
pub use net::{load_net, parse_net};
pub use server::{FrameServer, ServerBuilder, ServerEvent, StreamSender, Submitted};
pub use session::{OverloadPolicy, Session, SessionConfig};

/// How a [`Session`] executes its plan.  Every variant is bit-identical
/// to the others; they differ only in throughput and parallelism:
///
/// * [`ExecPlan::Scalar`] — serial, scalar netlist engine (one window per
///   tape dispatch).  The reference-shaped path.
/// * [`ExecPlan::Batched`] — serial, lane-batched engine
///   ([`crate::sim::LANES`] windows per tape dispatch).  The single-thread
///   fast path.
/// * [`ExecPlan::Tiled`] — one frame sharded into horizontal row bands,
///   one persistent lane-batched evaluator per worker (scoped threads per
///   frame; engines and generators are reused across frames).
/// * [`ExecPlan::Streaming`] — a persistent worker-thread pool: frames
///   fan out whole, results are re-ordered through a bounded reorder
///   window and delivered strictly in submission order.  `reorder` bounds
///   how far completions may run ahead (the in-flight budget is
///   `workers + reorder`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPlan {
    Scalar,
    Batched,
    Tiled { workers: usize },
    Streaming { workers: usize, reorder: usize },
}

impl ExecPlan {
    /// Default reorder-window depth for [`ExecPlan::Streaming`] (the old
    /// coordinator queue depth).
    pub const DEFAULT_REORDER: usize = 4;

    /// Streaming plan with the default reorder window.
    pub const fn streaming(workers: usize) -> Self {
        ExecPlan::Streaming { workers, reorder: Self::DEFAULT_REORDER }
    }

    /// Parse the CLI spelling: `scalar | batched | tiled:N | streaming:N`.
    ///
    /// ```
    /// use fpspatial::pipeline::ExecPlan;
    /// assert_eq!(ExecPlan::parse("tiled:4").unwrap(), ExecPlan::Tiled { workers: 4 });
    /// assert!(ExecPlan::parse("tiled:0").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ExecPlan> {
        let (head, workers) = match s.split_once(':') {
            None => (s, None),
            Some((head, n)) => {
                let workers: usize = n.parse().map_err(|_| {
                    anyhow::anyhow!("--exec {head}:N needs an integer worker count, got {n:?}")
                })?;
                if workers == 0 {
                    bail!("--exec {head}:N needs at least one worker, got 0");
                }
                (head, Some(workers))
            }
        };
        match (head, workers) {
            ("scalar", None) => Ok(ExecPlan::Scalar),
            ("batched", None) => Ok(ExecPlan::Batched),
            ("scalar" | "batched", Some(_)) => {
                bail!("--exec {head} takes no worker count (tiled:N / streaming:N do)")
            }
            ("tiled", Some(workers)) => Ok(ExecPlan::Tiled { workers }),
            ("streaming", Some(workers)) => Ok(ExecPlan::streaming(workers)),
            ("tiled" | "streaming", None) => {
                bail!("--exec {head} needs a worker count (e.g. {head}:4)")
            }
            _ => bail!("unknown --exec plan {s:?} (scalar|batched|tiled:N|streaming:N)"),
        }
    }
}

impl std::fmt::Display for ExecPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecPlan::Scalar => write!(f, "scalar"),
            ExecPlan::Batched => write!(f, "batched"),
            ExecPlan::Tiled { workers } => write!(f, "tiled:{workers}"),
            ExecPlan::Streaming { workers, .. } => write!(f, "streaming:{workers}"),
        }
    }
}

/// Throughput/latency report of a [`Session::process_sequence`] run (and
/// of the deprecated coordinator entry points, which now delegate here).
///
/// The fault counters cover the run being reported (not the session's
/// lifetime): frames `dropped` by an overload policy or an abandoned
/// deadline, `deadline_misses` (frames delivered — or given up on — past
/// the configured deadline), and `worker_restarts` (panicked workers the
/// supervisor respawned).  All three are zero on a healthy run.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Frames *submitted* this run (see [`Metrics::submitted`]); the
    /// delivered count is [`Metrics::delivered`].
    pub frames: u64,
    /// Frames actually delivered in order this run (submitted minus
    /// dropped/abandoned).  Rate reporting is based on this count.
    pub delivered: u64,
    pub elapsed: Duration,
    pub mean_latency: Duration,
    /// 99th-percentile submit→sink latency.
    pub p99_latency: Duration,
    pub max_latency: Duration,
    /// Frames dropped (overload policy) or abandoned (deadline) this run.
    pub dropped: u64,
    /// Frames late against [`SessionConfig::deadline`] this run.
    pub deadline_misses: u64,
    /// Panicked workers respawned by the supervisor this run.
    pub worker_restarts: u64,
}

impl Metrics {
    /// Delivered-frame rate.  Frames dropped by an overload policy or an
    /// abandoned deadline were never processed to completion, so they do
    /// not inflate the throughput report.
    pub fn fps(&self) -> f64 {
        self.delivered as f64 / self.elapsed.as_secs_f64()
    }

    /// Frames submitted this run (delivered + dropped).
    pub fn submitted(&self) -> u64 {
        self.frames
    }

    /// Effective pixel rate (active pixels/s) over *delivered* frames.
    pub fn pixel_rate(&self, w: usize, h: usize) -> f64 {
        self.fps() * (w * h) as f64
    }

    /// Aggregate per-frame latencies (stamped at in-order delivery) into
    /// the report.  `frames` counts submissions; `lats` has one entry per
    /// *delivered* frame, so latency statistics — and the delivered count
    /// behind [`Metrics::fps`] — ignore dropped frames.
    pub(crate) fn from_latencies(frames: u64, elapsed: Duration, mut lats: Vec<Duration>) -> Self {
        let total: Duration = lats.iter().sum();
        let max_latency = lats.iter().max().copied().unwrap_or(Duration::ZERO);
        let delivered = lats.len() as u32;
        lats.sort_unstable();
        Metrics {
            frames,
            delivered: delivered as u64,
            elapsed,
            mean_latency: if delivered > 0 { total / delivered } else { Duration::ZERO },
            p99_latency: percentile(&lats, 0.99),
            max_latency,
            dropped: 0,
            deadline_misses: 0,
            worker_restarts: 0,
        }
    }

    /// Attach the run's fault counters (see the struct docs).
    pub(crate) fn with_fault_counts(
        mut self,
        dropped: u64,
        deadline_misses: u64,
        worker_restarts: u64,
    ) -> Self {
        self.dropped = dropped;
        self.deadline_misses = deadline_misses;
        self.worker_restarts = worker_restarts;
        self
    }
}

/// `q`-th percentile (0..=1) of an ascending-sorted latency list.
fn percentile(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_plan_parse_accepts_the_four_spellings() {
        assert_eq!(ExecPlan::parse("scalar").unwrap(), ExecPlan::Scalar);
        assert_eq!(ExecPlan::parse("batched").unwrap(), ExecPlan::Batched);
        assert_eq!(ExecPlan::parse("tiled:3").unwrap(), ExecPlan::Tiled { workers: 3 });
        assert_eq!(
            ExecPlan::parse("streaming:2").unwrap(),
            ExecPlan::Streaming { workers: 2, reorder: ExecPlan::DEFAULT_REORDER }
        );
    }

    #[test]
    fn exec_plan_parse_rejects_malformed_specs() {
        for bad in ["", "warp", "tiled", "streaming", "tiled:0", "streaming:0", "tiled:abc",
            "scalar:2", "batched:4", "tiled:-1"]
        {
            let err = ExecPlan::parse(bad).unwrap_err();
            assert!(!err.to_string().is_empty(), "{bad:?}");
        }
        // the errors name what was wrong
        assert!(ExecPlan::parse("tiled").unwrap_err().to_string().contains("worker count"));
        assert!(ExecPlan::parse("tiled:0").unwrap_err().to_string().contains("at least one"));
        assert!(ExecPlan::parse("warp").unwrap_err().to_string().contains("warp"));
        assert!(ExecPlan::parse("scalar:2").unwrap_err().to_string().contains("no worker"));
    }

    #[test]
    fn exec_plan_display_round_trips() {
        for plan in [
            ExecPlan::Scalar,
            ExecPlan::Batched,
            ExecPlan::Tiled { workers: 4 },
            ExecPlan::streaming(2),
        ] {
            assert_eq!(ExecPlan::parse(&plan.to_string()).unwrap(), plan);
        }
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
        let one = [Duration::from_millis(5)];
        assert_eq!(percentile(&one, 0.99), one[0]);
        let many: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&many, 0.99), Duration::from_millis(99));
        assert_eq!(percentile(&many, 0.5), Duration::from_millis(50));
    }

    #[test]
    fn metrics_from_latencies() {
        let lats = vec![Duration::from_millis(4), Duration::from_millis(2)];
        let m = Metrics::from_latencies(2, Duration::from_millis(10), lats);
        assert_eq!(m.frames, 2);
        assert_eq!(m.delivered, 2);
        assert_eq!(m.mean_latency, Duration::from_millis(3));
        assert_eq!(m.max_latency, Duration::from_millis(4));
        assert_eq!(m.p99_latency, Duration::from_millis(4));
        assert!((m.fps() - 200.0).abs() < 1e-9);
        assert_eq!((m.dropped, m.deadline_misses, m.worker_restarts), (0, 0, 0));
        let empty = Metrics::from_latencies(0, Duration::from_millis(1), vec![]);
        assert_eq!(empty.mean_latency, Duration::ZERO);
    }

    #[test]
    fn metrics_mean_ignores_dropped_frames() {
        // 4 submitted, 2 delivered: mean is over the 2 delivered latencies
        let lats = vec![Duration::from_millis(4), Duration::from_millis(2)];
        let m = Metrics::from_latencies(4, Duration::from_millis(10), lats)
            .with_fault_counts(2, 1, 0);
        assert_eq!(m.frames, 4);
        assert_eq!(m.mean_latency, Duration::from_millis(3));
        assert_eq!(m.dropped, 2);
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.worker_restarts, 0);
    }

    #[test]
    fn metrics_rates_count_delivered_frames_only() {
        // 4 submitted, 2 delivered over 10ms: the honest rate is 200/s,
        // not 400/s — never-processed frames must not inflate throughput
        let lats = vec![Duration::from_millis(4), Duration::from_millis(2)];
        let m = Metrics::from_latencies(4, Duration::from_millis(10), lats)
            .with_fault_counts(2, 0, 0);
        assert_eq!(m.submitted(), 4);
        assert_eq!(m.delivered, 2);
        assert!((m.fps() - 200.0).abs() < 1e-9);
        assert!((m.pixel_rate(10, 10) - 20_000.0).abs() < 1e-6);
    }
}
