//! The shared supervised worker pool behind [`Session`](super::Session)
//! streaming plans and the many-stream [`FrameServer`](super::FrameServer).
//!
//! PR 6 built the job-queue/completion-channel seam for exactly one
//! stream; this module generalizes it: ONE pool of worker threads
//! multiplexes N independent streams, each with its own compiled
//! evaluator, bounded in-flight budget, reorder window and fault
//! accounting ([`Lane`]).  Jobs are tagged with their stream id, the
//! queue hands them out **fairly** (round-robin across streams with
//! queued work), and frame buffers are recycled through one shared spare
//! pool across every stream.
//!
//! Supervision invariants (unchanged from the single-stream pool):
//!
//! * a worker panic mid-job is captured at the worker boundary and comes
//!   back as a typed fault *attributed to the job's stream* — other
//!   streams never observe it;
//! * a worker that dies **between** jobs (e.g. a panic inside the
//!   dequeue itself) reports [`Report::Died`]: no frame is lost (the job
//!   was not yet claimed) and the worker is respawned;
//! * the queue mutex is **poison-tolerant**: a panic while holding the
//!   lock must not take the pool down with it, so every lock/wait
//!   recovers the guard from [`PoisonError`] — the queue state is plain
//!   `VecDeque`s + flags mutated in place, consistent at every step, so
//!   the recovered guard is always safe to use.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::session::SessionConfig;
use super::{CompiledPipeline, ExecError};
use crate::filters::{eval_band, eval_band_kernel, ChainRunner};
#[cfg(feature = "fault-injection")]
use crate::runtime::fault::FaultScript;
use crate::sim::{Engine, KernelExec};
use crate::video::{Frame, StageGeometry, WindowGenerator};

/// Recover a possibly-poisoned mutex guard.  The pool's shared state is
/// always internally consistent (plain queues and flags, mutated in
/// place), so a panic that unwound through a critical section leaves
/// nothing half-written — the guard is safe to keep using, and refusing
/// it would defeat the whole respawn story.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_recover`] for the condvar wait side.
pub(crate) fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Render a caught panic payload for [`ExecError::WorkerPanicked`].
pub(crate) fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Resize `f` to `w`×`h` without reallocating when capacity suffices —
/// and without touching the payload when the length already matches
/// (every caller overwrites the full buffer, so the zero-fill is only
/// needed when the length actually changes).
pub(crate) fn reshape(f: &mut Frame, w: usize, h: usize) {
    f.width = w;
    f.height = h;
    if f.data.len() != w * h {
        f.data.clear();
        f.data.resize(w * h, 0.0);
    }
}

/// One worker's compiled evaluator for one stream's plan.  Single-stage
/// plans keep the direct engine + window-generator hot path (no
/// fused-chain row indirection); multi-stage plans run the fused
/// [`ChainRunner`].
pub(crate) enum WorkerExec {
    Single { geom: StageGeometry, eng: EngineKind, gen: Option<WindowGenerator> },
    Fused(ChainRunner),
}

pub(crate) enum EngineKind {
    Scalar(Engine),
    /// Compiled fused kernel (via the process-wide `KernelCache`, so N
    /// workers / sessions / server streams of one filter compile once).
    Kernel(KernelExec),
}

impl WorkerExec {
    pub(crate) fn new(plan: &CompiledPipeline, batched: bool) -> Self {
        if plan.len() == 1 {
            let hw = &plan.stages()[0];
            let eng = if batched {
                EngineKind::Kernel(KernelExec::for_netlist(&hw.netlist, plan.mode()))
            } else {
                EngineKind::Scalar(Engine::new(&hw.netlist, plan.mode()))
            };
            WorkerExec::Single { geom: hw.geom, eng, gen: None }
        } else {
            WorkerExec::Fused(ChainRunner::new(plan.chain(), plan.mode(), batched))
        }
    }

    /// Output frame dimensions for a `w × h` input (strided stages
    /// shrink the frame).
    pub(crate) fn output_dims(&self, w: usize, h: usize) -> (usize, usize) {
        match self {
            WorkerExec::Single { geom, .. } => geom.out_dims(w, h),
            WorkerExec::Fused(runner) => runner.output_dims(w, h),
        }
    }

    /// Evaluate **output** rows `[y0, y1)` of `frame` into `out_rows`,
    /// bit-identical to the same rows of a sequential whole-frame pass.
    /// Structured failures (e.g. a window generator refusing the frame
    /// geometry) come back as `Err` instead of unwinding the worker.
    pub(crate) fn run_band(
        &mut self,
        frame: &Frame,
        y0: usize,
        y1: usize,
        out_rows: &mut [f64],
    ) -> std::result::Result<(), String> {
        match self {
            WorkerExec::Single { geom, eng, gen } => {
                let g = WindowGenerator::reuse(gen, *geom, frame.width)
                    .map_err(|e| format!("{e} (see CompiledPipeline::check_frame)"))?;
                match eng {
                    EngineKind::Scalar(e) => eval_band(e, g, frame, y0, y1, out_rows),
                    EngineKind::Kernel(e) => eval_band_kernel(e, g, frame, y0, y1, out_rows),
                }
            }
            WorkerExec::Fused(runner) => runner.run_band(frame, y0, y1, out_rows),
        }
        Ok(())
    }
}

/// Per-stream fault accounting (mirrored into
/// [`Metrics`](super::Metrics)).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct FaultCounters {
    pub(crate) dropped: u64,
    pub(crate) deadline_misses: u64,
    pub(crate) worker_restarts: u64,
}

/// `(stream, seq, input frame, output frame)` travelling to the workers.
/// Both frames are recycled through the pool's shared spare list.
pub(crate) struct Job {
    pub(crate) stream: usize,
    pub(crate) seq: u64,
    pub(crate) frame: Frame,
    pub(crate) out: Frame,
}

/// What a worker hands back for one claimed job.  The buffers always
/// come back — even from a panicked evaluation — so the frame pool never
/// leaks.
pub(crate) struct Completion {
    pub(crate) worker: usize,
    pub(crate) stream: usize,
    pub(crate) seq: u64,
    pub(crate) input: Frame,
    pub(crate) output: Frame,
    pub(crate) outcome: Outcome,
}

pub(crate) enum Outcome {
    /// `output` holds the frame's result.
    Ok,
    /// The stage reported a structured failure; the worker survives.
    Failed(String),
    /// The evaluation unwound; the worker thread exits after sending
    /// this and the supervisor respawns it.
    Panicked(String),
}

/// Everything a worker sends back to the supervisor.
pub(crate) enum Report {
    Done(Completion),
    /// The worker unwound *between* jobs (a panic inside the dequeue
    /// itself, e.g. a poisoned queue hook).  No job was claimed, so no
    /// frame is lost — the supervisor just respawns the thread.
    Died { worker: usize, payload: String },
}

/// Everything a worker thread carries besides its evaluators: the
/// per-stream fault scripts (chaos builds only).
#[derive(Clone, Default)]
pub(crate) struct WorkerCtx {
    #[cfg(feature = "fault-injection")]
    faults: Vec<Option<Arc<FaultScript>>>,
}

impl WorkerCtx {
    pub(crate) fn new(_configs: &[&SessionConfig]) -> Self {
        Self {
            #[cfg(feature = "fault-injection")]
            faults: _configs.iter().map(|c| c.faults.clone()).collect(),
        }
    }

    /// Mid-evaluation hook (inside the worker's `catch_unwind`).
    pub(crate) fn fire(&self, _stream: usize, _seq: u64) {
        #[cfg(feature = "fault-injection")]
        if let Some(f) = self.faults.get(_stream).and_then(Option::as_ref) {
            f.fire(_seq);
        }
    }

    /// Mid-dequeue hook: fires **while the queue lock is held**, before
    /// the job is claimed — an armed panic here poisons the mutex and a
    /// worker dies between jobs, exercising both recovery paths at once.
    fn fire_dequeue(&self, _stream: usize, _seq: u64) {
        #[cfg(feature = "fault-injection")]
        if let Some(f) = self.faults.get(_stream).and_then(Option::as_ref) {
            f.fire_dequeue(_seq);
        }
    }
}

/// The unclaimed-job queue between the submitting thread and the
/// workers: one `VecDeque` per stream behind one mutex, handed out
/// round-robin so a busy stream cannot starve the others.  A hand-rolled
/// `Mutex` + `Condvar` (not a channel) so the submitter can *retract*
/// unclaimed jobs ([`OverloadPolicy::DropOldest`](super::OverloadPolicy))
/// — and poison-tolerant throughout (see [`lock_recover`]).
pub(crate) struct JobQueue {
    inner: Mutex<JobsInner>,
    ready: Condvar,
}

struct JobsInner {
    queues: Vec<VecDeque<Job>>,
    /// Round-robin cursor: the stream the next pop starts scanning at.
    rr: usize,
    /// Total queued jobs across all streams.
    queued: usize,
    closed: bool,
}

impl JobQueue {
    pub(crate) fn new(streams: usize) -> Self {
        Self {
            inner: Mutex::new(JobsInner {
                queues: (0..streams).map(|_| VecDeque::new()).collect(),
                rr: 0,
                queued: 0,
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    pub(crate) fn push(&self, job: Job) {
        {
            let mut g = lock_recover(&self.inner);
            g.queued += 1;
            let stream = job.stream;
            g.queues[stream].push_back(job);
        }
        self.ready.notify_one();
    }

    /// Worker side: block for the next job, scanning streams round-robin
    /// from the shared cursor; `None` once closed and empty.  The
    /// dequeue fault hook fires under the lock *before* the job is
    /// claimed, so an unwinding worker leaves the job queued for a
    /// healthy peer.
    pub(crate) fn pop(&self, ctx: &WorkerCtx) -> Option<Job> {
        let mut g = lock_recover(&self.inner);
        loop {
            if g.queued > 0 {
                let n = g.queues.len();
                for k in 0..n {
                    let s = (g.rr + k) % n;
                    let (stream, seq) = match g.queues[s].front() {
                        None => continue,
                        Some(job) => (job.stream, job.seq),
                    };
                    ctx.fire_dequeue(stream, seq);
                    let job = g.queues[s].pop_front().expect("front was just observed");
                    g.rr = (s + 1) % n;
                    g.queued -= 1;
                    return Some(job);
                }
            }
            if g.closed {
                return None;
            }
            g = wait_recover(&self.ready, g);
        }
    }

    /// Submitter side: retract stream `s`'s oldest *unclaimed* job.
    pub(crate) fn steal_oldest(&self, s: usize) -> Option<Job> {
        let mut g = lock_recover(&self.inner);
        let job = g.queues[s].pop_front();
        if job.is_some() {
            g.queued -= 1;
        }
        job
    }

    /// Submitter side: retract every unclaimed job of stream `s`.
    pub(crate) fn drain(&self, s: usize) -> Vec<Job> {
        let mut g = lock_recover(&self.inner);
        let jobs: Vec<Job> = g.queues[s].drain(..).collect();
        g.queued -= jobs.len();
        jobs
    }

    pub(crate) fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.ready.notify_all();
    }
}

/// How long [`MultiPool::poll_completion`] may wait.
pub(crate) enum Wait {
    Block,
    Timeout(Duration),
    NoWait,
}

/// One observation from [`MultiPool::poll_completion`].
pub(crate) enum Polled {
    /// A completion was folded into the pool state (parked in a reorder
    /// window, recycled if stale, or a between-jobs death repaired).
    Progress,
    /// A worker fault on a live frame was captured (and, for a panic,
    /// the worker already respawned).  The frame is lost; every stream
    /// keeps being served.
    Faulted { stream: usize, error: ExecError },
    TimedOut,
}

/// The body of one pool worker thread: claim jobs (any stream), evaluate
/// inside a `catch_unwind` boundary with the claiming stream's
/// evaluator, hand the buffers back whatever happens.  The dequeue
/// itself is also guarded: an unwind there reports [`Report::Died`].
fn worker_loop(
    mut execs: Vec<WorkerExec>,
    id: usize,
    jobs: Arc<JobQueue>,
    results: SyncSender<Report>,
    ctx: WorkerCtx,
) {
    loop {
        let Job { stream, seq, frame, mut out } =
            match catch_unwind(AssertUnwindSafe(|| jobs.pop(&ctx))) {
                Ok(Some(job)) => job,
                Ok(None) => return,
                Err(p) => {
                    // died between jobs: nothing claimed, nothing lost
                    let _ = results.send(Report::Died { worker: id, payload: panic_text(p) });
                    return;
                }
            };
        let exec = &mut execs[stream];
        let (ow, oh) = exec.output_dims(frame.width, frame.height);
        reshape(&mut out, ow, oh);
        let r = catch_unwind(AssertUnwindSafe(|| {
            ctx.fire(stream, seq);
            exec.run_band(&frame, 0, oh, &mut out.data)
        }));
        let (outcome, dead) = match r {
            Ok(Ok(())) => (Outcome::Ok, false),
            Ok(Err(message)) => (Outcome::Failed(message), false),
            Err(p) => (Outcome::Panicked(panic_text(p)), true),
        };
        let done = Report::Done(Completion {
            worker: id,
            stream,
            seq,
            input: frame,
            output: out,
            outcome,
        });
        let sent = results.send(done).is_ok();
        // a panicked worker exits after reporting (its evaluator state is
        // suspect); the supervisor respawns a fresh one
        if dead || !sent {
            return;
        }
    }
}

/// Compile fresh evaluators (one per stream) on the supervisor thread
/// and hand them to a new worker thread (the thread borrows nothing).
fn spawn_worker(
    plans: &[&CompiledPipeline],
    id: usize,
    jobs: &Arc<JobQueue>,
    results_tx: &SyncSender<Report>,
    ctx: &WorkerCtx,
) -> JoinHandle<()> {
    let execs: Vec<WorkerExec> = plans.iter().map(|p| WorkerExec::new(p, true)).collect();
    let jobs = Arc::clone(jobs);
    let results = results_tx.clone();
    let ctx = ctx.clone();
    thread::spawn(move || worker_loop(execs, id, jobs, results, ctx))
}

/// One stream's scheduling state inside the shared pool: reorder window,
/// skip set, submit stamps, cursors, in-flight budget and fault
/// accounting — exactly the per-session state of the PR 6 pool, now one
/// lane of many.
pub(crate) struct Lane {
    /// Completed outputs waiting for their turn (reorder window).
    pending: BTreeMap<u64, Frame>,
    /// Sequence numbers that will never be delivered (dropped, retracted,
    /// or faulted); the emit cursor steps over them.
    skipped: BTreeSet<u64>,
    /// Submit stamps, by sequence number.
    times: BTreeMap<u64, Instant>,
    next_submit: u64,
    next_emit: u64,
    /// Frames handed to workers and not yet emitted or recycled.
    live: usize,
    /// Per-stream in-flight budget.
    cap: usize,
    pub(crate) counters: FaultCounters,
}

impl Lane {
    fn new(cap: usize) -> Self {
        Self {
            pending: BTreeMap::new(),
            skipped: BTreeSet::new(),
            times: BTreeMap::new(),
            next_submit: 0,
            next_emit: 0,
            live: 0,
            cap,
            counters: FaultCounters::default(),
        }
    }
}

/// ONE supervised worker pool multiplexing N independent streams: jobs
/// fan out through the fair [`JobQueue`], completions come back tagged
/// with their stream and are re-ordered per [`Lane`].  Worker panics are
/// captured, attributed to the offending stream, and the dead worker is
/// respawned with fresh evaluators for *every* stream.
pub(crate) struct MultiPool {
    jobs: Arc<JobQueue>,
    results: Receiver<Report>,
    /// Kept for respawning workers; taken (→ hang-up) on pool drop.
    results_tx: Option<SyncSender<Report>>,
    /// One slot per worker id, stable across respawns.
    handles: Vec<Option<JoinHandle<()>>>,
    ctx: WorkerCtx,
    lanes: Vec<Lane>,
    /// Recycled frame buffers, shared across every stream.
    spare: Vec<Frame>,
    workers: usize,
}

impl MultiPool {
    /// Spawn `workers` threads serving one lane per `(plan, cap,
    /// config)` spec.  `cap` is the lane's in-flight budget.
    pub(crate) fn spawn(
        specs: &[(&CompiledPipeline, usize, &SessionConfig)],
        workers: usize,
    ) -> Self {
        let plans: Vec<&CompiledPipeline> = specs.iter().map(|(p, _, _)| *p).collect();
        let configs: Vec<&SessionConfig> = specs.iter().map(|(_, _, c)| *c).collect();
        let total_cap: usize = specs.iter().map(|(_, cap, _)| *cap).sum();
        let jobs = Arc::new(JobQueue::new(specs.len()));
        let (results_tx, results) = sync_channel::<Report>(total_cap.max(4) + workers);
        let ctx = WorkerCtx::new(&configs);
        let handles = (0..workers)
            .map(|id| Some(spawn_worker(&plans, id, &jobs, &results_tx, &ctx)))
            .collect();
        Self {
            jobs,
            results,
            results_tx: Some(results_tx),
            handles,
            ctx,
            lanes: specs.iter().map(|(_, cap, _)| Lane::new(*cap)).collect(),
            spare: Vec::new(),
            workers,
        }
    }

    pub(crate) fn workers(&self) -> usize {
        self.workers
    }

    /// Stream `s`'s in-flight budget.
    pub(crate) fn cap(&self, s: usize) -> usize {
        self.lanes[s].cap
    }

    /// Frames of stream `s` currently owned by the pool machinery
    /// (claimed, queued, or parked in the reorder window).
    pub(crate) fn live_frames(&self, s: usize) -> usize {
        self.lanes[s].live
    }

    /// Stream `s`'s sequence numbers not yet delivered in order
    /// (including skipped ones the cursor has not stepped over yet).
    pub(crate) fn unemitted(&self, s: usize) -> u64 {
        self.lanes[s].next_submit - self.lanes[s].next_emit
    }

    /// The oldest sequence number still owed to stream `s`'s caller.
    pub(crate) fn oldest_unemitted(&self, s: usize) -> u64 {
        self.lanes[s].next_emit
    }

    /// The sequence number stream `s`'s next submission will get.
    pub(crate) fn next_submit(&self, s: usize) -> u64 {
        self.lanes[s].next_submit
    }

    /// When stream `s`'s oldest owed frame was submitted (None when
    /// nothing is owed or its slot was already surrendered).
    pub(crate) fn oldest_unemitted_stamp(&self, s: usize) -> Option<Instant> {
        let lane = &self.lanes[s];
        lane.times.get(&lane.next_emit).copied()
    }

    pub(crate) fn counters(&self, s: usize) -> FaultCounters {
        self.lanes[s].counters
    }

    pub(crate) fn counters_mut(&mut self, s: usize) -> &mut FaultCounters {
        &mut self.lanes[s].counters
    }

    pub(crate) fn take_spare(&mut self) -> Frame {
        self.spare.pop().unwrap_or_else(|| Frame::new(0, 0))
    }

    pub(crate) fn recycle(&mut self, frame: Frame) {
        self.spare.push(frame);
    }

    /// Hand one owned frame of stream `s` to the workers (caller
    /// enforces the lane budget).
    pub(crate) fn submit(&mut self, s: usize, frame: Frame) -> u64 {
        let out = self.take_spare();
        let lane = &mut self.lanes[s];
        let seq = lane.next_submit;
        lane.next_submit += 1;
        lane.times.insert(seq, Instant::now());
        lane.live += 1;
        self.jobs.push(Job { stream: s, seq, frame, out });
        seq
    }

    /// Drop an incoming frame of stream `s` instead of submitting it:
    /// its sequence slot is consumed (so in-order delivery simply skips
    /// it) and the drop is counted.
    pub(crate) fn drop_newest(&mut self, s: usize, frame: Frame) {
        let lane = &mut self.lanes[s];
        let seq = lane.next_submit;
        lane.next_submit += 1;
        lane.skipped.insert(seq);
        lane.counters.dropped += 1;
        self.recycle(frame);
    }

    /// Retract stream `s`'s oldest unclaimed job to make room
    /// (DropOldest).  Returns false when every queued job of the stream
    /// is already claimed by a worker.
    pub(crate) fn retract_oldest(&mut self, s: usize) -> bool {
        match self.jobs.steal_oldest(s) {
            None => false,
            Some(Job { seq, frame, out, .. }) => {
                let lane = &mut self.lanes[s];
                lane.times.remove(&seq);
                lane.live -= 1;
                // a stale job (already abandoned past its deadline) was
                // counted as dropped when it was surrendered — retracting
                // it now just reclaims the slot
                if seq >= lane.next_emit {
                    lane.skipped.insert(seq);
                    lane.counters.dropped += 1;
                }
                self.recycle(frame);
                self.recycle(out);
                true
            }
        }
    }

    /// Receive one report (bounded by `wait`) and fold it into the pool
    /// state.  Worker panics are captured here: the buffers are
    /// recovered, the worker is respawned with fresh evaluators, and the
    /// typed error comes back as [`Polled::Faulted`] attributed to the
    /// stream whose frame was lost.
    pub(crate) fn poll_completion(
        &mut self,
        plans: &[&CompiledPipeline],
        wait: Wait,
    ) -> Result<Polled> {
        let report = match wait {
            Wait::Block => match self.results.recv() {
                Ok(r) => r,
                Err(_) => return Err(ExecError::Shutdown.into()),
            },
            Wait::Timeout(d) => match self.results.recv_timeout(d) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => return Ok(Polled::TimedOut),
                Err(RecvTimeoutError::Disconnected) => return Err(ExecError::Shutdown.into()),
            },
            Wait::NoWait => match self.results.try_recv() {
                Ok(r) => r,
                Err(TryRecvError::Empty) => return Ok(Polled::TimedOut),
                Err(TryRecvError::Disconnected) => return Err(ExecError::Shutdown.into()),
            },
        };
        let c = match report {
            Report::Done(c) => c,
            Report::Died { worker, .. } => {
                // no job was claimed: repair the pool and move on.  A
                // single-lane pool attributes the restart to its one
                // stream; a multi-lane pool books it on lane 0 of the
                // aggregate view (no stream's frame was affected).
                self.respawn(plans, worker);
                self.lanes[0].counters.worker_restarts += 1;
                return Ok(Polled::Progress);
            }
        };
        let Completion { worker, stream, seq, input, output, outcome } = c;
        self.spare.push(input);
        let lane = &mut self.lanes[stream];
        // a frame abandoned past its deadline completes "stale": its slot
        // was already surrendered, so the buffers are simply recycled
        let stale = seq < lane.next_emit;
        match outcome {
            Outcome::Ok => {
                if stale {
                    self.spare.push(output);
                    lane.live -= 1;
                } else {
                    lane.pending.insert(seq, output);
                }
                Ok(Polled::Progress)
            }
            Outcome::Failed(message) => {
                lane.live -= 1;
                self.spare.push(output);
                if stale {
                    return Ok(Polled::Progress);
                }
                self.lanes[stream].skipped.insert(seq);
                Ok(Polled::Faulted {
                    stream,
                    error: ExecError::StageFailed { worker, frame_seq: seq, message },
                })
            }
            Outcome::Panicked(payload) => {
                lane.live -= 1;
                self.spare.push(output);
                self.respawn(plans, worker);
                self.lanes[stream].counters.worker_restarts += 1;
                if stale {
                    return Ok(Polled::Progress);
                }
                self.lanes[stream].skipped.insert(seq);
                Ok(Polled::Faulted {
                    stream,
                    error: ExecError::WorkerPanicked { worker, frame_seq: seq, payload },
                })
            }
        }
    }

    /// Replace a dead worker with a fresh one on the same id.
    fn respawn(&mut self, plans: &[&CompiledPipeline], worker: usize) {
        if let Some(h) = self.handles[worker].take() {
            let _ = h.join();
        }
        let tx = self.results_tx.clone().expect("pool is live");
        self.handles[worker] = Some(spawn_worker(plans, worker, &self.jobs, &tx, &self.ctx));
    }

    /// Pop stream `s`'s next in-order completion if it has arrived,
    /// stepping over skipped (dropped/faulted) sequence numbers.  Counts
    /// a deadline miss for frames delivered later than `deadline`.
    pub(crate) fn take_ready(
        &mut self,
        s: usize,
        deadline: Option<Duration>,
    ) -> Option<(u64, Duration, Frame)> {
        let lane = &mut self.lanes[s];
        loop {
            if lane.skipped.remove(&lane.next_emit) {
                lane.times.remove(&lane.next_emit);
                lane.next_emit += 1;
                continue;
            }
            let out = lane.pending.remove(&lane.next_emit)?;
            let seq = lane.next_emit;
            lane.next_emit += 1;
            lane.live -= 1;
            let lat = lane.times.remove(&seq).expect("one stamp per submission").elapsed();
            if let Some(d) = deadline {
                if lat > d {
                    lane.counters.deadline_misses += 1;
                }
            }
            return Some((seq, lat, out));
        }
    }

    /// Surrender one timed-out frame's slot on stream `s`: the emit
    /// cursor moves past it and its late completion will be recycled as
    /// stale.
    pub(crate) fn abandon_seq(&mut self, s: usize, seq: u64) {
        let lane = &mut self.lanes[s];
        lane.times.remove(&seq);
        lane.next_emit = lane.next_emit.max(seq + 1);
    }

    /// Abandon all of stream `s`'s in-flight work **without blocking**
    /// (error paths / [`Session::reset`](super::Session::reset)):
    /// retract its unclaimed jobs, fold in every already-arrived
    /// completion, recycle its reorder window, and fast-forward its emit
    /// cursor.  Frames still being evaluated by a worker come back later
    /// as stale completions and are recycled then.  Other streams are
    /// untouched.
    pub(crate) fn abandon_stream(&mut self, s: usize, plans: &[&CompiledPipeline]) {
        for Job { frame, out, .. } in self.jobs.drain(s) {
            self.spare.push(frame);
            self.spare.push(out);
            self.lanes[s].live -= 1;
        }
        loop {
            match self.poll_completion(plans, Wait::NoWait) {
                Ok(Polled::TimedOut) | Err(_) => break,
                Ok(_) => {}
            }
        }
        let lane = &mut self.lanes[s];
        let pending = std::mem::take(&mut lane.pending);
        lane.live -= pending.len();
        for (_, frame) in pending {
            self.spare.push(frame);
        }
        lane.times.clear();
        lane.skipped.clear();
        lane.next_emit = lane.next_submit;
    }
}

impl Drop for MultiPool {
    fn drop(&mut self) {
        // hang up the job queue so idle workers exit ...
        self.jobs.close();
        // ... drop our own completion sender so the channel can die ...
        self.results_tx.take();
        // ... unblock any worker parked on a full result channel ...
        while self.results.recv().is_ok() {}
        // ... and reap the threads.
        for h in self.handles.iter_mut().filter_map(Option::take) {
            let _ = h.join();
        }
    }
}
