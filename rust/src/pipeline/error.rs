//! [`ExecError`]: the structured error taxonomy of the supervised
//! session runtime.
//!
//! Every failure on an execution path is one of these variants, carried
//! inside the crate's `anyhow::Result` so existing callers keep working
//! while programmatic callers can recover the structure:
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use fpspatial::filters::FilterKind;
//! use fpspatial::fpcore::OpMode;
//! use fpspatial::pipeline::{ExecError, ExecPlan, Pipeline};
//! use fpspatial::video::Frame;
//!
//! let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
//! let mut session = plan.session(ExecPlan::Scalar)?;
//! let mut bad = Frame::test_card(24, 16);
//! bad.data[7] = f64::NAN;
//! let err = session.process(&bad).unwrap_err();
//! match err.downcast_ref::<ExecError>() {
//!     Some(ExecError::PoisonFrame { index: 7, .. }) => {}
//!     other => panic!("expected PoisonFrame, got {other:?}"),
//! }
//! # Ok(())
//! # }
//! ```
//!
//! The variants a caller can observe, and what each one means for the
//! session, are documented per variant below; the summary contract is:
//! **no variant poisons the session** — after any `ExecError` the session
//! keeps serving subsequent frames (workers are respawned behind
//! [`ExecError::WorkerPanicked`]; timed-out frames are abandoned behind
//! [`ExecError::DeadlineExceeded`]; a geometry change still needs
//! [`Session::reset`](super::Session::reset), exactly as before).

use std::time::Duration;

/// A structured execution failure from a [`Session`](super::Session).
///
/// Frame sequence numbers are 0-based per session (the order frames were
/// submitted to this session since creation).
#[derive(Debug, Clone)]
#[non_exhaustive]
pub enum ExecError {
    /// A worker thread panicked while evaluating a frame.  The panic was
    /// contained by the supervisor: the payload is captured here, the
    /// worker has already been **respawned**, and the session keeps
    /// serving subsequent frames — only the offending frame is lost.
    WorkerPanicked {
        /// Index of the worker that died (0-based; stable across respawns).
        worker: usize,
        /// The frame whose evaluation unwound.
        frame_seq: u64,
        /// The panic payload, rendered to a string.
        payload: String,
    },

    /// A frame's result did not arrive within the configured per-frame
    /// deadline ([`SessionConfig::deadline`](super::SessionConfig)).  The
    /// frame is abandoned (its late completion is recycled silently) and
    /// counted in [`Metrics::deadline_misses`](super::Metrics) and
    /// [`Metrics::dropped`](super::Metrics).
    DeadlineExceeded {
        frame_seq: u64,
        /// The configured deadline.
        deadline: Duration,
        /// How long the session actually waited before giving up.
        elapsed: Duration,
    },

    /// Submission could not proceed: the in-flight budget stayed full for
    /// a whole deadline with no completion arriving (a stalled or hung
    /// pipeline under [`OverloadPolicy::Block`](super::OverloadPolicy)
    /// with a deadline configured).
    QueueOverflow {
        /// The frame that could not be submitted.
        frame_seq: u64,
        /// The in-flight budget (`workers + reorder`).
        capacity: usize,
        /// How long submission waited for space.
        waited: Duration,
    },

    /// The input frame contains a non-finite pixel (NaN or ±Inf).  The
    /// custom-float datapaths define no semantics for non-finite inputs,
    /// so validation rejects the frame before it reaches any worker
    /// (disable with [`SessionConfig::validate`](super::SessionConfig)).
    PoisonFrame {
        /// The submission slot the frame would have occupied.
        frame_seq: u64,
        /// Index (row-major) of the first offending pixel.
        index: usize,
        /// The offending value.
        value: f64,
    },

    /// A stage reported a structured failure while evaluating a frame
    /// (e.g. a window generator refused the frame geometry mid-band).
    /// The worker survives; only this frame is lost.
    StageFailed {
        worker: usize,
        frame_seq: u64,
        message: String,
    },

    /// The worker pool is gone (its result channel disconnected without a
    /// hand-over).  Should not occur under supervision; kept as the
    /// honest terminal error.
    Shutdown,
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::WorkerPanicked { worker, frame_seq, payload } => write!(
                f,
                "worker {worker} panicked while processing frame {frame_seq}: {payload} \
                 (worker respawned; subsequent frames are unaffected)"
            ),
            ExecError::DeadlineExceeded { frame_seq, deadline, elapsed } => write!(
                f,
                "frame {frame_seq} missed its {deadline:?} deadline (waited {elapsed:?}); \
                 the frame was abandoned and the session keeps serving"
            ),
            ExecError::QueueOverflow { frame_seq, capacity, waited } => write!(
                f,
                "frame {frame_seq} could not be submitted: the in-flight budget of \
                 {capacity} frames stayed full for {waited:?} with no completion \
                 (pipeline stalled?)"
            ),
            ExecError::PoisonFrame { frame_seq, index, value } => write!(
                f,
                "frame {frame_seq} contains a non-finite pixel at index {index} \
                 ({value}): the custom-float datapaths define no semantics for \
                 non-finite inputs (sanitize the frame, or disable validation with \
                 SessionConfig::validate(false))"
            ),
            ExecError::StageFailed { worker, frame_seq, message } => write!(
                f,
                "worker {worker} could not evaluate frame {frame_seq}: {message}"
            ),
            ExecError::Shutdown => write!(
                f,
                "streaming session workers shut down unexpectedly (worker thread panicked?)"
            ),
        }
    }
}

impl std::error::Error for ExecError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_frame_and_the_recovery() {
        let e = ExecError::WorkerPanicked {
            worker: 2,
            frame_seq: 7,
            payload: "boom".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("worker 2"), "{msg}");
        assert!(msg.contains("frame 7"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("respawned"), "{msg}");
    }

    #[test]
    fn poison_frame_points_at_the_pixel() {
        let e = ExecError::PoisonFrame { frame_seq: 0, index: 42, value: f64::NAN };
        let msg = e.to_string();
        assert!(msg.contains("index 42"), "{msg}");
        assert!(msg.contains("NaN"), "{msg}");
    }

    #[test]
    fn errors_downcast_through_anyhow() {
        let e: anyhow::Error = ExecError::Shutdown.into();
        assert!(matches!(e.downcast_ref::<ExecError>(), Some(ExecError::Shutdown)));
        assert!(e.to_string().contains("shut down unexpectedly"));
    }

    #[test]
    fn deadline_and_overflow_render_their_numbers() {
        let e = ExecError::DeadlineExceeded {
            frame_seq: 3,
            deadline: Duration::from_millis(5),
            elapsed: Duration::from_millis(9),
        };
        assert!(e.to_string().contains("frame 3"), "{e}");
        let e = ExecError::QueueOverflow {
            frame_seq: 9,
            capacity: 6,
            waited: Duration::from_millis(5),
        };
        assert!(e.to_string().contains("6 frames"), "{e}");
    }
}
