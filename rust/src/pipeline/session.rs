//! [`Session`]: the mutable, **supervised** executor of a
//! [`CompiledPipeline`].
//!
//! A session owns every piece of mutable execution state the plan needs —
//! compiled engines (netlist→tape), window generators (line buffers),
//! per-stage row buffers and lane scratch, and for
//! [`ExecPlan::Streaming`] a persistent worker-thread pool with a frame
//! recycling pool — so processing a whole video stream reuses the same
//! machinery frame after frame instead of reallocating it per call.
//! Sessions pin their frame geometry on first use (a size change is a
//! usable error, not a silent rebuild) because the warm line buffers and
//! scratch are sized to it.
//!
//! The runtime is supervised: a panic while evaluating a frame is caught
//! at the worker boundary, reported as a typed
//! [`ExecError::WorkerPanicked`] naming the offending frame, and the
//! worker is respawned — the poison frame is isolated, not fatal, and the
//! session keeps serving subsequent frames.  A [`SessionConfig`] adds
//! per-frame deadlines and an [`OverloadPolicy`] so a slow consumer
//! degrades gracefully (counted drops) instead of deadlocking.  Input
//! frames are validated once at entry: non-finite pixels are rejected
//! with [`ExecError::PoisonFrame`] before they reach any datapath.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{CompiledPipeline, ExecError, ExecPlan, Metrics};
use crate::filters::{eval_band, eval_band_batched, ChainRunner};
#[cfg(feature = "fault-injection")]
use crate::runtime::fault::FaultScript;
use crate::sim::{BatchEngine, Engine};
use crate::video::{Frame, StageGeometry, WindowGenerator};

/// What a session does when a frame arrives while the in-flight budget
/// is full (streaming plans; other plans never overload).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadPolicy {
    /// Wait for capacity (the classic backpressure behaviour).  With a
    /// deadline configured the wait is bounded: a budget that stays full
    /// for a whole deadline is reported as [`ExecError::QueueOverflow`].
    #[default]
    Block,
    /// Drop the *incoming* frame: the submitter never blocks, the oldest
    /// in-flight work is preserved, and the drop is counted in
    /// [`Metrics::dropped`].
    DropNewest,
    /// Drop the oldest frame still waiting *unclaimed* in the job queue
    /// to make room for the incoming one (freshest-data-wins, e.g. live
    /// camera feeds).  If every queued frame is already claimed by a
    /// worker there is nothing to retract, and the incoming frame is
    /// dropped instead — the submitter still never blocks.
    DropOldest,
}

impl OverloadPolicy {
    /// Parse the CLI spelling: `block | drop-newest | drop-oldest`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "block" => Ok(Self::Block),
            "drop-newest" => Ok(Self::DropNewest),
            "drop-oldest" => Ok(Self::DropOldest),
            _ => bail!("unknown overload policy {s:?} (block|drop-newest|drop-oldest)"),
        }
    }
}

impl std::fmt::Display for OverloadPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OverloadPolicy::Block => "block",
            OverloadPolicy::DropNewest => "drop-newest",
            OverloadPolicy::DropOldest => "drop-oldest",
        })
    }
}

/// Runtime policy of a [`Session`]: deadline, overload behaviour, input
/// validation, and (under `--features fault-injection`) a chaos script.
/// Built fluently and passed to [`CompiledPipeline::session_with`]:
///
/// ```
/// use std::time::Duration;
/// use fpspatial::pipeline::{OverloadPolicy, SessionConfig};
///
/// let cfg = SessionConfig::new()
///     .deadline(Duration::from_millis(100))
///     .overload(OverloadPolicy::DropNewest);
/// assert_eq!(cfg.overload, OverloadPolicy::DropNewest);
/// assert!(cfg.validate);
/// ```
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Per-frame deadline, measured submit → in-order delivery.  `None`
    /// (the default) waits indefinitely, exactly like before.
    pub deadline: Option<Duration>,
    /// What to do when the streaming in-flight budget is full.
    pub overload: OverloadPolicy,
    /// Reject frames containing non-finite pixels at submission
    /// ([`ExecError::PoisonFrame`]).  Default **on** — the custom-float
    /// datapaths define no semantics for NaN/Inf inputs.
    pub validate: bool,
    /// Deterministic chaos plan (see [`crate::runtime::fault`]).
    #[cfg(feature = "fault-injection")]
    pub faults: Option<Arc<FaultScript>>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            deadline: None,
            overload: OverloadPolicy::Block,
            validate: true,
            #[cfg(feature = "fault-injection")]
            faults: None,
        }
    }
}

impl SessionConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bound every frame's submit→delivery latency.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Choose the overload policy (default [`OverloadPolicy::Block`]).
    pub fn overload(mut self, p: OverloadPolicy) -> Self {
        self.overload = p;
        self
    }

    /// Enable/disable non-finite input validation (default on).
    pub fn validate(mut self, on: bool) -> Self {
        self.validate = on;
        self
    }

    /// Attach a fault-injection script (chaos testing only).
    #[cfg(feature = "fault-injection")]
    pub fn with_faults(mut self, faults: Arc<FaultScript>) -> Self {
        self.faults = Some(faults);
        self
    }
}

/// Fire any armed fault hooks for `seq` (no-op without the
/// `fault-injection` feature).
fn fire_faults(_config: &SessionConfig, _seq: u64) {
    #[cfg(feature = "fault-injection")]
    if let Some(f) = &_config.faults {
        f.fire(_seq);
    }
}

/// Render a caught panic payload for [`ExecError::WorkerPanicked`].
fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One worker's compiled evaluator.  Single-stage plans keep the direct
/// engine + window-generator hot path (no fused-chain row indirection);
/// multi-stage plans run the fused [`ChainRunner`].
enum WorkerExec {
    Single { geom: StageGeometry, eng: EngineKind, gen: Option<WindowGenerator> },
    Fused(ChainRunner),
}

enum EngineKind {
    Scalar(Engine),
    Batched(BatchEngine),
}

impl WorkerExec {
    fn new(plan: &CompiledPipeline, batched: bool) -> Self {
        if plan.len() == 1 {
            let hw = &plan.stages()[0];
            let eng = if batched {
                EngineKind::Batched(BatchEngine::new(&hw.netlist, plan.mode()))
            } else {
                EngineKind::Scalar(Engine::new(&hw.netlist, plan.mode()))
            };
            WorkerExec::Single { geom: hw.geom, eng, gen: None }
        } else {
            WorkerExec::Fused(ChainRunner::new(plan.chain(), plan.mode(), batched))
        }
    }

    /// Output frame dimensions for a `w × h` input (strided stages
    /// shrink the frame).
    fn output_dims(&self, w: usize, h: usize) -> (usize, usize) {
        match self {
            WorkerExec::Single { geom, .. } => geom.out_dims(w, h),
            WorkerExec::Fused(runner) => runner.output_dims(w, h),
        }
    }

    /// Evaluate **output** rows `[y0, y1)` of `frame` into `out_rows`,
    /// bit-identical to the same rows of a sequential whole-frame pass.
    /// Structured failures (e.g. a window generator refusing the frame
    /// geometry) come back as `Err` instead of unwinding the worker.
    fn run_band(
        &mut self,
        frame: &Frame,
        y0: usize,
        y1: usize,
        out_rows: &mut [f64],
    ) -> std::result::Result<(), String> {
        match self {
            WorkerExec::Single { geom, eng, gen } => {
                let g = WindowGenerator::reuse(gen, *geom, frame.width)
                    .map_err(|e| format!("{e} (see CompiledPipeline::check_frame)"))?;
                match eng {
                    EngineKind::Scalar(e) => eval_band(e, g, frame, y0, y1, out_rows),
                    EngineKind::Batched(e) => eval_band_batched(e, g, frame, y0, y1, out_rows),
                }
            }
            WorkerExec::Fused(runner) => runner.run_band(frame, y0, y1, out_rows),
        }
        Ok(())
    }
}

/// Session-side fault accounting (mirrored into [`Metrics`]).
#[derive(Debug, Default, Clone, Copy)]
struct FaultCounters {
    dropped: u64,
    deadline_misses: u64,
    worker_restarts: u64,
}

/// Mutable session state, by [`ExecPlan`] shape.
enum State {
    /// [`ExecPlan::Scalar`] / [`ExecPlan::Batched`]: one serial evaluator
    /// (rebuilt on a contained panic).
    Direct { exec: WorkerExec, batched: bool },
    /// [`ExecPlan::Tiled`]: one persistent evaluator per worker; each
    /// frame is sharded into row bands on scoped threads.
    Tiled(Vec<WorkerExec>),
    /// [`ExecPlan::Streaming`]: a supervised persistent worker pool.
    Streaming(StreamPool),
}

/// A reusable executor created from a [`CompiledPipeline`] and an
/// [`ExecPlan`].  See [`CompiledPipeline::session`] /
/// [`CompiledPipeline::session_with`].
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use fpspatial::filters::FilterKind;
/// use fpspatial::fpcore::OpMode;
/// use fpspatial::pipeline::{ExecPlan, Pipeline};
/// use fpspatial::video::Frame;
///
/// let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
/// let mut session = plan.session(ExecPlan::streaming(2))?;
/// let frames: Vec<Frame> = (0..4u64).map(|i| Frame::noise(32, 24, i)).collect();
/// let mut outs = Vec::new();
/// let metrics = session.process_sequence(frames, |_seq, f| outs.push(f))?;
/// assert_eq!(metrics.frames, 4);
/// assert_eq!(metrics.dropped, 0);
/// assert_eq!(outs.len(), 4); // delivered strictly in order
/// # Ok(())
/// # }
/// ```
pub struct Session<'p> {
    plan: &'p CompiledPipeline,
    exec: ExecPlan,
    config: SessionConfig,
    state: State,
    /// Frame geometry, latched by the first processed frame.
    dims: Option<(usize, usize)>,
    /// Next frame sequence number for the non-streaming plans (streaming
    /// seqs are tracked by the pool).
    submitted: u64,
    /// Direct/Tiled-side fault accounting (the pool keeps its own).
    counters: FaultCounters,
}

impl<'p> Session<'p> {
    pub(crate) fn new(plan: &'p CompiledPipeline, exec: ExecPlan) -> Result<Self> {
        Self::new_with(plan, exec, SessionConfig::default())
    }

    pub(crate) fn new_with(
        plan: &'p CompiledPipeline,
        exec: ExecPlan,
        config: SessionConfig,
    ) -> Result<Self> {
        let state = match exec {
            ExecPlan::Scalar => {
                State::Direct { exec: WorkerExec::new(plan, false), batched: false }
            }
            ExecPlan::Batched => State::Direct { exec: WorkerExec::new(plan, true), batched: true },
            ExecPlan::Tiled { workers } => {
                if workers == 0 {
                    bail!("a tiled session needs at least one worker");
                }
                State::Tiled((0..workers).map(|_| WorkerExec::new(plan, true)).collect())
            }
            ExecPlan::Streaming { workers, reorder } => {
                if workers == 0 {
                    bail!("a streaming session needs at least one worker");
                }
                if reorder == 0 {
                    bail!("a streaming session needs a reorder window of at least 1");
                }
                State::Streaming(StreamPool::spawn(plan, workers, reorder, &config))
            }
        };
        Ok(Self {
            plan,
            exec,
            config,
            state,
            dims: None,
            submitted: 0,
            counters: FaultCounters::default(),
        })
    }

    /// The plan this session executes.
    pub fn plan(&self) -> &'p CompiledPipeline {
        self.plan
    }

    /// The execution strategy this session was created with.
    pub fn exec(&self) -> ExecPlan {
        self.exec
    }

    /// The runtime policy this session was created with.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Frame geometry this session is pinned to (None until the first
    /// frame is processed, or after [`Session::reset`]).
    pub fn dims(&self) -> Option<(usize, usize)> {
        self.dims
    }

    fn totals(&self) -> FaultCounters {
        let mut c = self.counters;
        if let State::Streaming(pool) = &self.state {
            c.dropped += pool.counters.dropped;
            c.deadline_misses += pool.counters.deadline_misses;
            c.worker_restarts += pool.counters.worker_restarts;
        }
        c
    }

    /// Frames dropped so far (overload policy or deadline abandonment).
    pub fn dropped(&self) -> u64 {
        self.totals().dropped
    }

    /// Frames that missed the configured deadline so far.
    pub fn deadline_misses(&self) -> u64 {
        self.totals().deadline_misses
    }

    /// Workers respawned after a contained panic so far.
    pub fn worker_restarts(&self) -> u64 {
        self.totals().worker_restarts
    }

    /// Unpin the frame geometry so the next frame may have a new size
    /// (engines survive; line buffers rebuild on the next frame).  Any
    /// in-flight streaming work left over from an aborted or faulted run
    /// is abandoned without blocking.
    pub fn reset(&mut self) {
        self.dims = None;
        let plan = self.plan;
        if let State::Streaming(pool) = &mut self.state {
            pool.abandon_all(plan);
        }
    }

    /// Validate `frame` against the plan and the pinned geometry.
    fn admit(&mut self, frame: &Frame) -> Result<()> {
        match self.dims {
            None => {
                self.plan.check_frame(frame)?;
                self.dims = Some((frame.width, frame.height));
            }
            Some((w, h)) if (w, h) == (frame.width, frame.height) => {}
            Some((w, h)) => bail!(
                "this session is pinned to {w}x{h} frames but received {}x{}: sessions keep \
                 line buffers and scratch sized to one geometry — call Session::reset() or \
                 open a new session for the new size",
                frame.width,
                frame.height
            ),
        }
        Ok(())
    }

    /// The sequence number the next submitted frame will get.
    fn next_seq(&self) -> u64 {
        match &self.state {
            State::Streaming(pool) => pool.next_submit,
            _ => self.submitted,
        }
    }

    /// Input screening at submission: injected corruption (chaos builds)
    /// and non-finite pixel validation, both reported as
    /// [`ExecError::PoisonFrame`] before the frame reaches any worker.
    fn screen(&self, frame: &Frame, seq: u64) -> Result<()> {
        #[cfg(feature = "fault-injection")]
        if let Some(faults) = &self.config.faults {
            if let Some(value) = faults.corruption(seq) {
                return Err(ExecError::PoisonFrame { frame_seq: seq, index: 0, value }.into());
            }
        }
        if self.config.validate {
            if let Some(index) = frame.data.iter().position(|v| !v.is_finite()) {
                return Err(ExecError::PoisonFrame {
                    frame_seq: seq,
                    index,
                    value: frame.data[index],
                }
                .into());
            }
        }
        Ok(())
    }

    /// Process one frame, returning the filtered output (at the plan's
    /// **output** dimensions — strided stages shrink the frame).
    /// Bit-identical to [`CompiledPipeline::run_frame_sequential`] under
    /// every [`ExecPlan`] (`tests/session_reuse.rs`).
    pub fn process(&mut self, frame: &Frame) -> Result<Frame> {
        let (ow, oh) = self.plan.output_dims(frame.width, frame.height);
        let mut out = Frame::new(ow, oh);
        self.process_into(frame, &mut out)?;
        Ok(out)
    }

    /// [`Session::process`] into a caller-owned frame: with a warm
    /// session and a reused `out`, the steady state performs no
    /// allocation at all (engines, generators, scratch and — for
    /// streaming — the in-flight frame pool are all recycled).  On `Err`
    /// the contents of `out` are unspecified.
    pub fn process_into(&mut self, frame: &Frame, out: &mut Frame) -> Result<()> {
        self.admit(frame)?;
        let seq = self.next_seq();
        self.screen(frame, seq)?;
        let Session { plan, config, state, submitted, counters, .. } = self;
        let plan = *plan;
        let (ow, oh) = plan.output_dims(frame.width, frame.height);
        match state {
            State::Direct { exec, batched } => {
                *submitted = seq + 1;
                let started = Instant::now();
                reshape(out, ow, oh);
                run_direct(exec, *batched, plan, config, seq, frame, out, counters)?;
                if let Some(d) = config.deadline {
                    // serial evaluation cannot be preempted; a late frame
                    // is still delivered but counted as a miss
                    if started.elapsed() > d {
                        counters.deadline_misses += 1;
                    }
                }
            }
            State::Tiled(workers) => {
                *submitted = seq + 1;
                let started = Instant::now();
                reshape(out, ow, oh);
                run_tiled(workers, plan, config, seq, frame, out, counters)?;
                if let Some(d) = config.deadline {
                    if started.elapsed() > d {
                        counters.deadline_misses += 1;
                    }
                }
            }
            State::Streaming(pool) => {
                // leftovers from an aborted sequence (e.g. a panic that
                // unwound out of its on_frame callback) must never be
                // served as this frame's result
                if pool.unemitted() > 0 {
                    pool.abandon_all(plan);
                }
                let mut input = pool.take_spare();
                reshape(&mut input, frame.width, frame.height);
                input.data.copy_from_slice(&frame.data);
                let seq = pool.submit(input);
                let started = Instant::now();
                loop {
                    if let Some((got_seq, _lat, mut got)) = pool.take_ready(config.deadline) {
                        debug_assert_eq!(got_seq, seq);
                        std::mem::swap(out, &mut got);
                        pool.recycle(got);
                        return Ok(());
                    }
                    let wait = match config.deadline {
                        None => Wait::Block,
                        Some(d) => Wait::Timeout(d.saturating_sub(started.elapsed())),
                    };
                    match pool.poll_completion(plan, wait)? {
                        Polled::Progress => {}
                        Polled::Faulted(e) => {
                            pool.abandon_all(plan);
                            return Err(e.into());
                        }
                        Polled::TimedOut => {
                            let deadline = config.deadline.expect("timeouts need a deadline");
                            let elapsed = started.elapsed();
                            pool.counters.deadline_misses += 1;
                            pool.counters.dropped += 1;
                            pool.abandon_seq(seq);
                            return Err(ExecError::DeadlineExceeded {
                                frame_seq: seq,
                                deadline,
                                elapsed,
                            }
                            .into());
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Process an owned frame sequence, delivering outputs **in order**
    /// to `on_frame` and returning throughput/latency [`Metrics`].
    ///
    /// Under [`ExecPlan::Streaming`] the sequence is pipelined: up to
    /// `workers + reorder` frames are in flight at once and completions
    /// are re-ordered through the bounded reorder window, exactly like
    /// the camera→FPGA→display stream of §IV.  Other plans process
    /// frames one at a time.  Latency is stamped submit→in-order
    /// delivery.  `on_frame` receives each frame's index within *this*
    /// sequence; indices of dropped frames (overload policy) are simply
    /// absent, and the surviving outputs stay strictly ascending.
    pub fn process_sequence(
        &mut self,
        frames: Vec<Frame>,
        mut on_frame: impl FnMut(u64, Frame),
    ) -> Result<Metrics> {
        let n = frames.len() as u64;
        let before = self.totals();
        let t0 = Instant::now();
        let mut lats: Vec<Duration> = Vec::with_capacity(frames.len());
        if matches!(self.exec, ExecPlan::Streaming { .. }) {
            // On any error the pool must not be left holding in-flight
            // frames — a later process() would pop a stale completion.
            if let Err(e) = self.stream_sequence(frames, &mut lats, &mut on_frame) {
                let plan = self.plan;
                let State::Streaming(pool) = &mut self.state else { unreachable!() };
                pool.abandon_all(plan);
                return Err(e);
            }
        } else {
            for (seq, frame) in frames.into_iter().enumerate() {
                let t = Instant::now();
                let out = self.process(&frame)?;
                lats.push(t.elapsed());
                on_frame(seq as u64, out);
            }
        }
        let after = self.totals();
        Ok(Metrics::from_latencies(n, t0.elapsed(), lats).with_fault_counts(
            after.dropped - before.dropped,
            after.deadline_misses - before.deadline_misses,
            after.worker_restarts - before.worker_restarts,
        ))
    }

    /// The pipelined body of [`Session::process_sequence`] under
    /// [`ExecPlan::Streaming`] — separated so the caller can abandon
    /// in-flight work on any error.
    fn stream_sequence(
        &mut self,
        frames: Vec<Frame>,
        lats: &mut Vec<Duration>,
        on_frame: &mut impl FnMut(u64, Frame),
    ) -> Result<()> {
        let plan = self.plan;
        let deadline = self.config.deadline;
        let overload = self.config.overload;
        let base = {
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            // leftovers from a run aborted by a panic in its callback
            if pool.unemitted() > 0 {
                pool.abandon_all(plan);
            }
            pool.next_submit
        };
        for frame in frames {
            self.admit(&frame)?;
            let seq = self.next_seq();
            self.screen(&frame, seq)?;
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            if pool.live_frames() >= pool.cap() {
                // fold in whatever has already completed, without blocking
                loop {
                    match pool.poll_completion(plan, Wait::NoWait)? {
                        Polled::Progress => {}
                        Polled::Faulted(e) => return Err(e.into()),
                        Polled::TimedOut => break,
                    }
                }
                drain_ready(pool, deadline, base, lats, on_frame);
            }
            if pool.live_frames() >= pool.cap() {
                match overload {
                    OverloadPolicy::Block => {
                        // classic backpressure; bounded by the deadline
                        // when one is configured
                        while pool.live_frames() >= pool.cap() {
                            let wait = match deadline {
                                Some(d) => Wait::Timeout(d),
                                None => Wait::Block,
                            };
                            match pool.poll_completion(plan, wait)? {
                                Polled::Progress => {}
                                Polled::Faulted(e) => return Err(e.into()),
                                Polled::TimedOut => {
                                    return Err(ExecError::QueueOverflow {
                                        frame_seq: seq,
                                        capacity: pool.cap(),
                                        waited: deadline.unwrap_or_default(),
                                    }
                                    .into());
                                }
                            }
                            drain_ready(pool, deadline, base, lats, on_frame);
                        }
                    }
                    OverloadPolicy::DropNewest => {
                        pool.drop_newest(frame);
                        continue;
                    }
                    OverloadPolicy::DropOldest => {
                        if !pool.retract_oldest() {
                            // every queued frame is already claimed by a
                            // worker — nothing to retract; drop the
                            // incoming frame so the submitter never blocks
                            pool.drop_newest(frame);
                            continue;
                        }
                    }
                }
            }
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            pool.submit(frame);
            drain_ready(pool, deadline, base, lats, on_frame);
        }
        // drain the tail in order
        let State::Streaming(pool) = &mut self.state else { unreachable!() };
        while pool.unemitted() > 0 {
            drain_ready(pool, deadline, base, lats, on_frame);
            if pool.unemitted() == 0 {
                break;
            }
            let wait = match deadline {
                Some(d) => Wait::Timeout(d),
                None => Wait::Block,
            };
            match pool.poll_completion(plan, wait)? {
                Polled::Progress => {}
                Polled::Faulted(e) => return Err(e.into()),
                Polled::TimedOut => {
                    let d = deadline.unwrap_or_default();
                    return Err(ExecError::DeadlineExceeded {
                        frame_seq: pool.oldest_unemitted(),
                        deadline: d,
                        elapsed: d,
                    }
                    .into());
                }
            }
        }
        Ok(())
    }
}

/// Deliver every in-order-ready completion to `on_frame`, re-based to
/// sequence-local indices.
fn drain_ready(
    pool: &mut StreamPool,
    deadline: Option<Duration>,
    base: u64,
    lats: &mut Vec<Duration>,
    on_frame: &mut impl FnMut(u64, Frame),
) {
    while let Some((seq, lat, out)) = pool.take_ready(deadline) {
        lats.push(lat);
        on_frame(seq - base, out);
    }
}

/// Evaluate a whole frame on one supervised serial evaluator: a panic is
/// contained, the evaluator rebuilt, and the typed error returned.
#[allow(clippy::too_many_arguments)]
fn run_direct(
    exec: &mut WorkerExec,
    batched: bool,
    plan: &CompiledPipeline,
    config: &SessionConfig,
    seq: u64,
    frame: &Frame,
    out: &mut Frame,
    counters: &mut FaultCounters,
) -> Result<()> {
    let oh = out.height;
    let r = catch_unwind(AssertUnwindSafe(|| {
        fire_faults(config, seq);
        exec.run_band(frame, 0, oh, &mut out.data)
    }));
    match r {
        Ok(Ok(())) => Ok(()),
        Ok(Err(message)) => {
            Err(ExecError::StageFailed { worker: 0, frame_seq: seq, message }.into())
        }
        Err(payload) => {
            // the evaluator's internal state is suspect after an unwind:
            // rebuild it so the next frame runs on a fresh one
            *exec = WorkerExec::new(plan, batched);
            counters.worker_restarts += 1;
            Err(ExecError::WorkerPanicked {
                worker: 0,
                frame_seq: seq,
                payload: panic_text(payload),
            }
            .into())
        }
    }
}

/// One band's failure, carried back from a scoped tile worker.
struct BandFault {
    worker: usize,
    panicked: bool,
    message: String,
}

/// Shard the **output** frame into horizontal row bands, one per
/// (persistent) worker evaluator, on scoped threads.  Band traversal
/// reads the real context rows from the source frame (each band's
/// backward plan reaches up through every stride), so the stitched
/// output is bit-identical to a serial pass.  Panicking bands are
/// contained: their evaluator is rebuilt and the first fault is
/// reported; the frame fails as a unit.
fn run_tiled(
    workers: &mut [WorkerExec],
    plan: &CompiledPipeline,
    config: &SessionConfig,
    seq: u64,
    frame: &Frame,
    out: &mut Frame,
    counters: &mut FaultCounters,
) -> Result<()> {
    let (ow, oh) = (out.width, out.height);
    let n = workers.len().min(oh);
    let band_h = oh.div_ceil(n);
    let faults: Vec<BandFault> = thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter_mut()
            .zip(out.data.chunks_mut(band_h * ow))
            .enumerate()
            .map(|(i, (exec, chunk))| {
                let y0 = i * band_h;
                let y1 = (y0 + band_h).min(oh);
                s.spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        // one-shot hooks: with several bands racing, the
                        // fault strikes exactly one of them
                        fire_faults(config, seq);
                        exec.run_band(frame, y0, y1, chunk)
                    }));
                    match r {
                        Ok(Ok(())) => None,
                        Ok(Err(message)) => {
                            Some(BandFault { worker: i, panicked: false, message })
                        }
                        Err(p) => {
                            Some(BandFault { worker: i, panicked: true, message: panic_text(p) })
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("band supervisors do not panic"))
            .collect()
    });
    let mut first: Option<ExecError> = None;
    for f in faults {
        let err = if f.panicked {
            workers[f.worker] = WorkerExec::new(plan, true);
            counters.worker_restarts += 1;
            ExecError::WorkerPanicked { worker: f.worker, frame_seq: seq, payload: f.message }
        } else {
            ExecError::StageFailed { worker: f.worker, frame_seq: seq, message: f.message }
        };
        first.get_or_insert(err);
    }
    match first {
        None => Ok(()),
        Some(e) => Err(e.into()),
    }
}

/// Resize `f` to `w`×`h` without reallocating when capacity suffices —
/// and without touching the payload when the length already matches
/// (every caller overwrites the full buffer, so the zero-fill is only
/// needed when the length actually changes).
fn reshape(f: &mut Frame, w: usize, h: usize) {
    f.width = w;
    f.height = h;
    if f.data.len() != w * h {
        f.data.clear();
        f.data.resize(w * h, 0.0);
    }
}

/// `(seq, input frame, output frame)` travelling to the workers.  Both
/// frames are recycled through [`StreamPool::spare`].
struct Job {
    seq: u64,
    frame: Frame,
    out: Frame,
}

/// What a worker hands back for one claimed job.  The buffers always
/// come back — even from a panicked evaluation — so the frame pool never
/// leaks.
struct Completion {
    worker: usize,
    seq: u64,
    input: Frame,
    output: Frame,
    outcome: Outcome,
}

enum Outcome {
    /// `output` holds the frame's result.
    Ok,
    /// The stage reported a structured failure; the worker survives.
    Failed(String),
    /// The evaluation unwound; the worker thread exits after sending
    /// this and the supervisor respawns it.
    Panicked(String),
}

/// Everything a worker thread carries besides its evaluator.
#[derive(Clone, Default)]
struct WorkerCtx {
    #[cfg(feature = "fault-injection")]
    faults: Option<Arc<FaultScript>>,
}

impl WorkerCtx {
    fn from_config(_config: &SessionConfig) -> Self {
        Self {
            #[cfg(feature = "fault-injection")]
            faults: _config.faults.clone(),
        }
    }

    fn fire(&self, _seq: u64) {
        #[cfg(feature = "fault-injection")]
        if let Some(f) = &self.faults {
            f.fire(_seq);
        }
    }
}

/// The unclaimed-job queue between the session thread and the workers.
/// A hand-rolled `Mutex<VecDeque>` (not a channel) so the session can
/// *retract* the oldest unclaimed job under [`OverloadPolicy::DropOldest`].
/// Capacity is enforced by the session's in-flight budget, not here.
struct JobQueue {
    inner: Mutex<JobsInner>,
    ready: Condvar,
}

struct JobsInner {
    queue: VecDeque<Job>,
    closed: bool,
}

impl JobQueue {
    fn new() -> Self {
        Self {
            inner: Mutex::new(JobsInner { queue: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    fn push(&self, job: Job) {
        self.inner.lock().unwrap().queue.push_back(job);
        self.ready.notify_one();
    }

    /// Worker side: block for the next job; `None` once closed and empty.
    fn pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(job) = inner.queue.pop_front() {
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    /// Session side: retract the oldest *unclaimed* job, if any.
    fn steal_oldest(&self) -> Option<Job> {
        self.inner.lock().unwrap().queue.pop_front()
    }

    /// Session side: retract every unclaimed job.
    fn drain(&self) -> Vec<Job> {
        self.inner.lock().unwrap().queue.drain(..).collect()
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }
}

/// How long [`StreamPool::poll_completion`] may wait.
enum Wait {
    Block,
    Timeout(Duration),
    NoWait,
}

/// One observation from [`StreamPool::poll_completion`].
enum Polled {
    /// A completion was folded into the pool state (parked in the
    /// reorder window, or recycled if stale).
    Progress,
    /// A worker fault on a live frame was captured (and, for a panic,
    /// the worker already respawned).  The frame is lost; the session
    /// keeps serving.
    Faulted(ExecError),
    TimedOut,
}

/// The body of one streaming worker thread: claim jobs, evaluate inside
/// a `catch_unwind` boundary, hand the buffers back whatever happens.
fn worker_loop(
    mut exec: WorkerExec,
    id: usize,
    jobs: Arc<JobQueue>,
    results: SyncSender<Completion>,
    ctx: WorkerCtx,
) {
    while let Some(Job { seq, frame, mut out }) = jobs.pop() {
        let (ow, oh) = exec.output_dims(frame.width, frame.height);
        reshape(&mut out, ow, oh);
        let r = catch_unwind(AssertUnwindSafe(|| {
            ctx.fire(seq);
            exec.run_band(&frame, 0, oh, &mut out.data)
        }));
        let (outcome, dead) = match r {
            Ok(Ok(())) => (Outcome::Ok, false),
            Ok(Err(message)) => (Outcome::Failed(message), false),
            Err(p) => (Outcome::Panicked(panic_text(p)), true),
        };
        let sent = results
            .send(Completion { worker: id, seq, input: frame, output: out, outcome })
            .is_ok();
        // a panicked worker exits after reporting (its evaluator state is
        // suspect); the supervisor respawns a fresh one
        if dead || !sent {
            break;
        }
    }
}

/// Supervised persistent worker pool of a streaming session: jobs fan
/// out through [`JobQueue`], completions come back tagged and are
/// re-ordered in [`StreamPool::pending`] (never larger than the
/// in-flight budget).  The pool supervises its workers — panics are
/// captured as [`Outcome::Panicked`] completions and the dead worker is
/// respawned — and keeps drop/deadline/restart accounting.
struct StreamPool {
    jobs: Arc<JobQueue>,
    results: Receiver<Completion>,
    /// Kept for respawning workers; taken (→ hang-up) on pool drop.
    results_tx: Option<SyncSender<Completion>>,
    /// One slot per worker id, stable across respawns.
    handles: Vec<Option<JoinHandle<()>>>,
    ctx: WorkerCtx,
    /// Completed outputs waiting for their turn (reorder window).
    pending: BTreeMap<u64, Frame>,
    /// Sequence numbers that will never be delivered (dropped, retracted,
    /// or faulted); the emit cursor steps over them.
    skipped: BTreeSet<u64>,
    /// Submit stamps, by sequence number.
    times: BTreeMap<u64, Instant>,
    /// Recycled frame buffers (inputs come back from workers; outputs
    /// come back through `Session::process_into`'s swap).
    spare: Vec<Frame>,
    next_submit: u64,
    next_emit: u64,
    /// Frames handed to workers and not yet emitted or recycled.
    live: usize,
    counters: FaultCounters,
    workers: usize,
    reorder: usize,
}

impl StreamPool {
    fn spawn(
        plan: &CompiledPipeline,
        workers: usize,
        reorder: usize,
        config: &SessionConfig,
    ) -> Self {
        let cap = workers + reorder;
        let jobs = Arc::new(JobQueue::new());
        let (results_tx, results) = sync_channel::<Completion>(cap.max(4));
        let ctx = WorkerCtx::from_config(config);
        let handles = (0..workers)
            .map(|id| Some(spawn_worker(plan, id, &jobs, &results_tx, &ctx)))
            .collect();
        Self {
            jobs,
            results,
            results_tx: Some(results_tx),
            handles,
            ctx,
            pending: BTreeMap::new(),
            skipped: BTreeSet::new(),
            times: BTreeMap::new(),
            spare: Vec::new(),
            next_submit: 0,
            next_emit: 0,
            live: 0,
            counters: FaultCounters::default(),
            workers,
            reorder,
        }
    }

    /// In-flight budget: how many frames may be outstanding at once.
    fn cap(&self) -> usize {
        self.workers + self.reorder
    }

    /// Frames currently owned by the pool machinery (claimed, queued, or
    /// parked in the reorder window).
    fn live_frames(&self) -> usize {
        self.live
    }

    /// Sequence numbers not yet delivered in order (including skipped
    /// ones the cursor has not stepped over yet).
    fn unemitted(&self) -> u64 {
        self.next_submit - self.next_emit
    }

    /// The oldest sequence number still owed to the caller.
    fn oldest_unemitted(&self) -> u64 {
        self.next_emit
    }

    fn take_spare(&mut self) -> Frame {
        self.spare.pop().unwrap_or_else(|| Frame::new(0, 0))
    }

    fn recycle(&mut self, frame: Frame) {
        self.spare.push(frame);
    }

    /// Hand one owned frame to the workers (caller enforces the budget).
    fn submit(&mut self, frame: Frame) -> u64 {
        let out = self.take_spare();
        let seq = self.next_submit;
        self.next_submit += 1;
        self.times.insert(seq, Instant::now());
        self.live += 1;
        self.jobs.push(Job { seq, frame, out });
        seq
    }

    /// Drop an incoming frame instead of submitting it: its sequence
    /// slot is consumed (so in-order delivery simply skips it) and the
    /// drop is counted.
    fn drop_newest(&mut self, frame: Frame) {
        let seq = self.next_submit;
        self.next_submit += 1;
        self.skipped.insert(seq);
        self.counters.dropped += 1;
        self.recycle(frame);
    }

    /// Retract the oldest unclaimed job to make room (DropOldest).
    /// Returns false when every job is already claimed by a worker.
    fn retract_oldest(&mut self) -> bool {
        match self.jobs.steal_oldest() {
            None => false,
            Some(Job { seq, frame, out }) => {
                self.times.remove(&seq);
                self.live -= 1;
                self.recycle(frame);
                self.recycle(out);
                // a stale job (already abandoned past its deadline) was
                // counted as dropped when it was surrendered — retracting
                // it now just reclaims the slot
                if seq >= self.next_emit {
                    self.skipped.insert(seq);
                    self.counters.dropped += 1;
                }
                true
            }
        }
    }

    /// Receive one completion (bounded by `wait`) and fold it into the
    /// pool state.  Worker panics are captured here: the buffers are
    /// recovered, the worker is respawned, and the typed error comes
    /// back as [`Polled::Faulted`] when the frame was still live.
    fn poll_completion(&mut self, plan: &CompiledPipeline, wait: Wait) -> Result<Polled> {
        let c = match wait {
            Wait::Block => match self.results.recv() {
                Ok(c) => c,
                Err(_) => return Err(ExecError::Shutdown.into()),
            },
            Wait::Timeout(d) => match self.results.recv_timeout(d) {
                Ok(c) => c,
                Err(RecvTimeoutError::Timeout) => return Ok(Polled::TimedOut),
                Err(RecvTimeoutError::Disconnected) => return Err(ExecError::Shutdown.into()),
            },
            Wait::NoWait => match self.results.try_recv() {
                Ok(c) => c,
                Err(TryRecvError::Empty) => return Ok(Polled::TimedOut),
                Err(TryRecvError::Disconnected) => return Err(ExecError::Shutdown.into()),
            },
        };
        let Completion { worker, seq, input, output, outcome } = c;
        self.spare.push(input);
        // a frame abandoned past its deadline completes "stale": its slot
        // was already surrendered, so the buffers are simply recycled
        let stale = seq < self.next_emit;
        match outcome {
            Outcome::Ok => {
                if stale {
                    self.spare.push(output);
                    self.live -= 1;
                } else {
                    self.pending.insert(seq, output);
                }
                Ok(Polled::Progress)
            }
            Outcome::Failed(message) => {
                self.spare.push(output);
                self.live -= 1;
                if stale {
                    return Ok(Polled::Progress);
                }
                self.skipped.insert(seq);
                Ok(Polled::Faulted(ExecError::StageFailed { worker, frame_seq: seq, message }))
            }
            Outcome::Panicked(payload) => {
                self.spare.push(output);
                self.live -= 1;
                self.respawn(plan, worker);
                if stale {
                    return Ok(Polled::Progress);
                }
                self.skipped.insert(seq);
                Ok(Polled::Faulted(ExecError::WorkerPanicked { worker, frame_seq: seq, payload }))
            }
        }
    }

    /// Replace a dead worker with a fresh one on the same id.
    fn respawn(&mut self, plan: &CompiledPipeline, worker: usize) {
        if let Some(h) = self.handles[worker].take() {
            let _ = h.join();
        }
        let tx = self.results_tx.clone().expect("pool is live");
        self.handles[worker] = Some(spawn_worker(plan, worker, &self.jobs, &tx, &self.ctx));
        self.counters.worker_restarts += 1;
    }

    /// Pop the next in-order completion if it has arrived, stepping over
    /// skipped (dropped/faulted) sequence numbers.  Counts a deadline
    /// miss for frames delivered later than `deadline`.
    fn take_ready(&mut self, deadline: Option<Duration>) -> Option<(u64, Duration, Frame)> {
        loop {
            if self.skipped.remove(&self.next_emit) {
                self.times.remove(&self.next_emit);
                self.next_emit += 1;
                continue;
            }
            let out = self.pending.remove(&self.next_emit)?;
            let seq = self.next_emit;
            self.next_emit += 1;
            self.live -= 1;
            let lat = self.times.remove(&seq).expect("one stamp per submission").elapsed();
            if let Some(d) = deadline {
                if lat > d {
                    self.counters.deadline_misses += 1;
                }
            }
            return Some((seq, lat, out));
        }
    }

    /// Surrender one timed-out frame's slot: the emit cursor moves past
    /// it and its late completion will be recycled as stale.
    fn abandon_seq(&mut self, seq: u64) {
        self.times.remove(&seq);
        self.next_emit = self.next_emit.max(seq + 1);
    }

    /// Abandon all in-flight work **without blocking** (error paths /
    /// [`Session::reset`]): retract every unclaimed job, fold in every
    /// already-arrived completion, recycle the reorder window, and
    /// fast-forward the emit cursor.  Frames still being evaluated by a
    /// worker come back later as stale completions and are recycled then.
    fn abandon_all(&mut self, plan: &CompiledPipeline) {
        for Job { frame, out, .. } in self.jobs.drain() {
            self.spare.push(frame);
            self.spare.push(out);
            self.live -= 1;
        }
        loop {
            match self.poll_completion(plan, Wait::NoWait) {
                Ok(Polled::TimedOut) | Err(_) => break,
                Ok(_) => {}
            }
        }
        let pending = std::mem::take(&mut self.pending);
        self.live -= pending.len();
        for (_, frame) in pending {
            self.spare.push(frame);
        }
        self.times.clear();
        self.skipped.clear();
        self.next_emit = self.next_submit;
    }
}

/// Compile a fresh evaluator on the session thread and hand it to a new
/// worker thread (the thread borrows nothing from the plan).
fn spawn_worker(
    plan: &CompiledPipeline,
    id: usize,
    jobs: &Arc<JobQueue>,
    results_tx: &SyncSender<Completion>,
    ctx: &WorkerCtx,
) -> JoinHandle<()> {
    let exec = WorkerExec::new(plan, true);
    let jobs = Arc::clone(jobs);
    let results = results_tx.clone();
    let ctx = ctx.clone();
    thread::spawn(move || worker_loop(exec, id, jobs, results, ctx))
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        // hang up the job queue so idle workers exit ...
        self.jobs.close();
        // ... drop our own completion sender so the channel can die ...
        self.results_tx.take();
        // ... unblock any worker parked on a full result channel ...
        while self.results.recv().is_ok() {}
        // ... and reap the threads.
        for h in self.handles.iter_mut().filter_map(Option::take) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::FilterKind;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::pipeline::Pipeline;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn median_plan() -> CompiledPipeline {
        Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact).unwrap()
    }

    const ALL_EXECS: [ExecPlan; 4] = [
        ExecPlan::Scalar,
        ExecPlan::Batched,
        ExecPlan::Tiled { workers: 3 },
        ExecPlan::Streaming { workers: 2, reorder: 2 },
    ];

    #[test]
    fn every_exec_plan_matches_the_oracle_on_one_frame() {
        let plan = median_plan();
        let f = Frame::test_card(37, 19);
        let want = plan.run_frame_sequential(&f);
        for exec in ALL_EXECS {
            let mut s = plan.session(exec).unwrap();
            let got = s.process(&f).unwrap();
            assert_eq!(got.data, want.data, "{exec}");
        }
    }

    #[test]
    fn zero_workers_is_a_usable_error() {
        let plan = median_plan();
        for exec in [ExecPlan::Tiled { workers: 0 }, ExecPlan::Streaming { workers: 0, reorder: 4 }]
        {
            let err = plan.session(exec).unwrap_err();
            assert!(err.to_string().contains("at least one worker"), "{err}");
        }
        let err =
            plan.session(ExecPlan::Streaming { workers: 2, reorder: 0 }).unwrap_err();
        assert!(err.to_string().contains("reorder"), "{err}");
    }

    #[test]
    fn size_change_is_a_usable_error_and_reset_clears_it() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        s.process(&Frame::test_card(24, 16)).unwrap();
        assert_eq!(s.dims(), Some((24, 16)));
        let err = s.process(&Frame::test_card(32, 16)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("24x16"), "{msg}");
        assert!(msg.contains("32x16"), "{msg}");
        assert!(msg.contains("reset"), "{msg}");
        s.reset();
        let out = s.process(&Frame::test_card(32, 16)).unwrap();
        assert_eq!((out.width, out.height), (32, 16));
    }

    #[test]
    fn bad_first_frame_reports_the_plan_error() {
        let plan = Pipeline::new().builtin(FilterKind::Conv5x5).compile(OpMode::Exact).unwrap();
        let mut s = plan.session(ExecPlan::Scalar).unwrap();
        let err = s.process(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        // empty frames are usable errors too (the old run paths panicked)
        let err = s.process(&Frame::new(24, 0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn non_finite_pixels_are_rejected_under_every_plan() {
        let plan = median_plan();
        for exec in ALL_EXECS {
            let mut s = plan.session(exec).unwrap();
            let mut bad = Frame::test_card(24, 16);
            bad.data[37] = f64::INFINITY;
            let err = s.process(&bad).unwrap_err();
            match err.downcast_ref::<ExecError>() {
                Some(ExecError::PoisonFrame { index: 37, value, .. }) => {
                    assert!(value.is_infinite(), "{exec}");
                }
                other => panic!("{exec}: expected PoisonFrame, got {other:?}"),
            }
            // the rejection pinned the geometry but poisoned nothing: the
            // sanitized frame still processes
            let good = Frame::test_card(24, 16);
            let got = s.process(&good).unwrap();
            assert_eq!(got.data, plan.run_frame_sequential(&good).data, "{exec}");
        }
    }

    #[test]
    fn validation_can_be_disabled() {
        let plan = median_plan();
        let cfg = SessionConfig::new().validate(false);
        let mut s = plan.session_with(ExecPlan::Batched, cfg).unwrap();
        let mut bad = Frame::test_card(24, 16);
        bad.data[0] = f64::NAN;
        // undefined numerically, but must not error or hang
        let out = s.process(&bad).unwrap();
        assert_eq!((out.width, out.height), (24, 16));
    }

    #[test]
    fn overload_policy_parses_and_displays() {
        for (s, want) in [
            ("block", OverloadPolicy::Block),
            ("drop-newest", OverloadPolicy::DropNewest),
            ("drop-oldest", OverloadPolicy::DropOldest),
        ] {
            assert_eq!(OverloadPolicy::parse(s).unwrap(), want);
            assert_eq!(want.to_string(), s);
        }
        let err = OverloadPolicy::parse("shed").unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
    }

    #[test]
    fn process_into_reuses_the_output_buffer() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        let f = Frame::test_card(33, 21);
        let want = plan.run_frame_sequential(&f);
        let mut out = Frame::new(0, 0);
        for _ in 0..3 {
            s.process_into(&f, &mut out).unwrap();
            assert_eq!(out.data, want.data);
        }
    }

    #[test]
    fn streaming_sequence_is_ordered_and_metered() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::streaming(3)).unwrap();
        let frames: Vec<Frame> = (0..10u64).map(|i| Frame::noise(24, 18, i)).collect();
        let mut seqs = Vec::new();
        let m = s
            .process_sequence(frames.clone(), |seq, out| {
                let want = plan.run_frame_sequential(&frames[seq as usize]);
                assert_eq!(out.data, want.data, "frame {seq}");
                seqs.push(seq);
            })
            .unwrap();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
        assert_eq!(m.frames, 10);
        assert_eq!((m.dropped, m.deadline_misses, m.worker_restarts), (0, 0, 0));
        assert!(m.p99_latency <= m.max_latency);
        assert!(m.mean_latency <= m.max_latency);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn empty_sequence_yields_zero_metrics() {
        let plan = median_plan();
        for exec in [ExecPlan::Scalar, ExecPlan::streaming(2)] {
            let mut s = plan.session(exec).unwrap();
            let m = s.process_sequence(vec![], |_, _| panic!("no frames")).unwrap();
            assert_eq!(m.frames, 0);
            assert_eq!(m.p99_latency, Duration::ZERO);
        }
    }

    #[test]
    fn more_tiled_workers_than_rows() {
        let plan = median_plan();
        let f = Frame::gradient(20, 5);
        let want = plan.run_frame_sequential(&f);
        let mut s = plan.session(ExecPlan::Tiled { workers: 32 }).unwrap();
        assert_eq!(s.process(&f).unwrap().data, want.data);
    }

    #[test]
    fn sessions_share_a_plan_concurrently() {
        let plan = median_plan();
        let f = Frame::test_card(31, 17);
        let want = plan.run_frame_sequential(&f);
        thread::scope(|sc| {
            for _ in 0..3 {
                sc.spawn(|| {
                    let mut s = plan.session(ExecPlan::Batched).unwrap();
                    assert_eq!(s.process(&f).unwrap().data, want.data);
                });
            }
        });
    }

    #[test]
    fn a_generous_deadline_changes_nothing() {
        let plan = median_plan();
        let cfg = SessionConfig::new().deadline(Duration::from_secs(60));
        for exec in ALL_EXECS {
            let mut s = plan.session_with(exec, cfg.clone()).unwrap();
            let frames: Vec<Frame> = (0..6u64).map(|i| Frame::noise(24, 18, i)).collect();
            let m = s.process_sequence(frames.clone(), |_, _| {}).unwrap();
            assert_eq!(m.frames, 6, "{exec}");
            assert_eq!(m.dropped, 0, "{exec}");
            assert_eq!(m.deadline_misses, 0, "{exec}");
            assert_eq!(s.worker_restarts(), 0, "{exec}");
        }
    }
}
