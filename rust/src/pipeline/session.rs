//! [`Session`]: the mutable executor of a [`CompiledPipeline`].
//!
//! A session owns every piece of mutable execution state the plan needs —
//! compiled engines (netlist→tape), window generators (line buffers),
//! per-stage row buffers and lane scratch, and for
//! [`ExecPlan::Streaming`] a persistent worker-thread pool with a frame
//! recycling pool — so processing a whole video stream reuses the same
//! machinery frame after frame instead of reallocating it per call.
//! Sessions pin their frame geometry on first use (a size change is a
//! usable error, not a silent rebuild) because the warm line buffers and
//! scratch are sized to it.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::{CompiledPipeline, ExecPlan, Metrics};
use crate::filters::{eval_band, eval_band_batched, ChainRunner};
use crate::sim::{BatchEngine, Engine};
use crate::video::{Frame, WindowGenerator};

/// One worker's compiled evaluator.  Single-stage plans keep the direct
/// engine + window-generator hot path (no fused-chain row indirection);
/// multi-stage plans run the fused [`ChainRunner`].
enum WorkerExec {
    Single { ksize: usize, eng: EngineKind, gen: Option<WindowGenerator> },
    Fused(ChainRunner),
}

enum EngineKind {
    Scalar(Engine),
    Batched(BatchEngine),
}

impl WorkerExec {
    fn new(plan: &CompiledPipeline, batched: bool) -> Self {
        if plan.len() == 1 {
            let hw = &plan.stages()[0];
            let eng = if batched {
                EngineKind::Batched(BatchEngine::new(&hw.netlist, plan.mode()))
            } else {
                EngineKind::Scalar(Engine::new(&hw.netlist, plan.mode()))
            };
            WorkerExec::Single { ksize: hw.ksize, eng, gen: None }
        } else {
            WorkerExec::Fused(ChainRunner::new(plan.chain(), plan.mode(), batched))
        }
    }

    /// Evaluate output rows `[y0, y1)` of `frame` into `out_rows`,
    /// bit-identical to the same rows of a sequential whole-frame pass.
    fn run_band(&mut self, frame: &Frame, y0: usize, y1: usize, out_rows: &mut [f64]) {
        match self {
            WorkerExec::Single { ksize, eng, gen } => {
                let g = WindowGenerator::reuse(gen, *ksize, frame.width).unwrap_or_else(|e| {
                    panic!("session worker: {e} (see CompiledPipeline::check_frame)")
                });
                match eng {
                    EngineKind::Scalar(e) => eval_band(e, g, frame, y0, y1, out_rows),
                    EngineKind::Batched(e) => eval_band_batched(e, g, frame, y0, y1, out_rows),
                }
            }
            WorkerExec::Fused(runner) => runner.run_band(frame, y0, y1, out_rows),
        }
    }
}

/// Mutable session state, by [`ExecPlan`] shape.
enum State {
    /// [`ExecPlan::Scalar`] / [`ExecPlan::Batched`]: one serial evaluator.
    Direct(WorkerExec),
    /// [`ExecPlan::Tiled`]: one persistent evaluator per worker; each
    /// frame is sharded into row bands on scoped threads.
    Tiled(Vec<WorkerExec>),
    /// [`ExecPlan::Streaming`]: a persistent worker-thread pool.
    Streaming(StreamPool),
}

/// A reusable executor created from a [`CompiledPipeline`] and an
/// [`ExecPlan`].  See [`CompiledPipeline::session`].
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use fpspatial::filters::FilterKind;
/// use fpspatial::fpcore::OpMode;
/// use fpspatial::pipeline::{ExecPlan, Pipeline};
/// use fpspatial::video::Frame;
///
/// let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
/// let mut session = plan.session(ExecPlan::streaming(2))?;
/// let frames: Vec<Frame> = (0..4u64).map(|i| Frame::noise(32, 24, i)).collect();
/// let mut outs = Vec::new();
/// let metrics = session.process_sequence(frames, |_seq, f| outs.push(f))?;
/// assert_eq!(metrics.frames, 4);
/// assert_eq!(outs.len(), 4); // delivered strictly in order
/// # Ok(())
/// # }
/// ```
pub struct Session<'p> {
    plan: &'p CompiledPipeline,
    exec: ExecPlan,
    state: State,
    /// Frame geometry, latched by the first processed frame.
    dims: Option<(usize, usize)>,
}

impl<'p> Session<'p> {
    pub(crate) fn new(plan: &'p CompiledPipeline, exec: ExecPlan) -> Result<Self> {
        let state = match exec {
            ExecPlan::Scalar => State::Direct(WorkerExec::new(plan, false)),
            ExecPlan::Batched => State::Direct(WorkerExec::new(plan, true)),
            ExecPlan::Tiled { workers } => {
                if workers == 0 {
                    bail!("a tiled session needs at least one worker");
                }
                State::Tiled((0..workers).map(|_| WorkerExec::new(plan, true)).collect())
            }
            ExecPlan::Streaming { workers, reorder } => {
                if workers == 0 {
                    bail!("a streaming session needs at least one worker");
                }
                if reorder == 0 {
                    bail!("a streaming session needs a reorder window of at least 1");
                }
                State::Streaming(StreamPool::spawn(plan, workers, reorder))
            }
        };
        Ok(Self { plan, exec, state, dims: None })
    }

    /// The plan this session executes.
    pub fn plan(&self) -> &'p CompiledPipeline {
        self.plan
    }

    /// The execution strategy this session was created with.
    pub fn exec(&self) -> ExecPlan {
        self.exec
    }

    /// Frame geometry this session is pinned to (None until the first
    /// frame is processed, or after [`Session::reset`]).
    pub fn dims(&self) -> Option<(usize, usize)> {
        self.dims
    }

    /// Unpin the frame geometry so the next frame may have a new size
    /// (engines survive; line buffers rebuild on the next frame).  Any
    /// in-flight streaming work left over from an aborted
    /// [`Session::process_sequence`] is discarded.
    pub fn reset(&mut self) {
        self.dims = None;
        if let State::Streaming(pool) = &mut self.state {
            pool.discard_in_flight();
        }
    }

    /// Validate `frame` against the plan and the pinned geometry.
    fn admit(&mut self, frame: &Frame) -> Result<()> {
        match self.dims {
            None => {
                self.plan.check_frame(frame)?;
                self.dims = Some((frame.width, frame.height));
            }
            Some((w, h)) if (w, h) == (frame.width, frame.height) => {}
            Some((w, h)) => bail!(
                "this session is pinned to {w}x{h} frames but received {}x{}: sessions keep \
                 line buffers and scratch sized to one geometry — call Session::reset() or \
                 open a new session for the new size",
                frame.width,
                frame.height
            ),
        }
        Ok(())
    }

    /// Process one frame, returning the filtered output.  Bit-identical
    /// to [`CompiledPipeline::run_frame_sequential`] under every
    /// [`ExecPlan`] (`tests/session_reuse.rs`).
    pub fn process(&mut self, frame: &Frame) -> Result<Frame> {
        let mut out = Frame::new(frame.width, frame.height);
        self.process_into(frame, &mut out)?;
        Ok(out)
    }

    /// [`Session::process`] into a caller-owned frame: with a warm
    /// session and a reused `out`, the steady state performs no
    /// allocation at all (engines, generators, scratch and — for
    /// streaming — the in-flight frame pool are all recycled).
    pub fn process_into(&mut self, frame: &Frame, out: &mut Frame) -> Result<()> {
        self.admit(frame)?;
        match &mut self.state {
            State::Direct(exec) => {
                reshape(out, frame.width, frame.height);
                exec.run_band(frame, 0, frame.height, &mut out.data);
            }
            State::Tiled(workers) => {
                reshape(out, frame.width, frame.height);
                run_tiled(workers, frame, out);
            }
            State::Streaming(pool) => {
                // a panic that unwound out of a previous process_sequence
                // (e.g. in its on_frame callback) can leave completed
                // frames behind; never serve those as this frame's result
                if pool.outstanding() > 0 {
                    pool.discard_in_flight();
                }
                let mut input = pool.take_spare();
                reshape(&mut input, frame.width, frame.height);
                input.data.copy_from_slice(&frame.data);
                pool.submit(input)?;
                let (_seq, _lat, mut got) = pool.next_result()?;
                std::mem::swap(out, &mut got);
                pool.recycle(got);
            }
        }
        Ok(())
    }

    /// Process an owned frame sequence, delivering outputs **in order**
    /// to `on_frame` and returning throughput/latency [`Metrics`].
    ///
    /// Under [`ExecPlan::Streaming`] the sequence is pipelined: up to
    /// `workers + reorder` frames are in flight at once and completions
    /// are re-ordered through the bounded reorder window, exactly like
    /// the camera→FPGA→display stream of §IV.  Other plans process
    /// frames one at a time.  Latency is stamped submit→in-order
    /// delivery.
    pub fn process_sequence(
        &mut self,
        frames: Vec<Frame>,
        mut on_frame: impl FnMut(u64, Frame),
    ) -> Result<Metrics> {
        let n = frames.len() as u64;
        let t0 = Instant::now();
        let mut lats: Vec<Duration> = Vec::with_capacity(frames.len());
        if matches!(self.exec, ExecPlan::Streaming { .. }) {
            // On any error the pool must not be left holding in-flight
            // frames — a later process() would pop a stale completion.
            if let Err(e) = self.stream_sequence(frames, &mut lats, &mut on_frame) {
                let State::Streaming(pool) = &mut self.state else { unreachable!() };
                pool.discard_in_flight();
                return Err(e);
            }
        } else {
            for (seq, frame) in frames.into_iter().enumerate() {
                let t = Instant::now();
                let out = self.process(&frame)?;
                lats.push(t.elapsed());
                on_frame(seq as u64, out);
            }
        }
        Ok(Metrics::from_latencies(n, t0.elapsed(), lats))
    }

    /// The pipelined body of [`Session::process_sequence`] under
    /// [`ExecPlan::Streaming`] — separated so the caller can discard
    /// in-flight work on any error.
    fn stream_sequence(
        &mut self,
        frames: Vec<Frame>,
        lats: &mut Vec<Duration>,
        on_frame: &mut impl FnMut(u64, Frame),
    ) -> Result<()> {
        if let State::Streaming(pool) = &mut self.state {
            // leftovers from a run aborted by a panic in its callback
            if pool.outstanding() > 0 {
                pool.discard_in_flight();
            }
        }
        for frame in frames {
            self.admit(&frame)?;
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            // backpressure: hold the in-flight budget, draining
            // completions (in order) while we wait
            while pool.outstanding() >= pool.cap() {
                pool.recv_one()?;
                while let Some((seq, lat, out)) = pool.take_ready() {
                    lats.push(lat);
                    on_frame(seq, out);
                }
            }
            pool.submit(frame)?;
            while let Some((seq, lat, out)) = pool.take_ready() {
                lats.push(lat);
                on_frame(seq, out);
            }
        }
        let State::Streaming(pool) = &mut self.state else { unreachable!() };
        while pool.outstanding() > 0 {
            let (seq, lat, out) = pool.next_result()?;
            lats.push(lat);
            on_frame(seq, out);
        }
        Ok(())
    }
}

/// Resize `f` to `w`×`h` without reallocating when capacity suffices —
/// and without touching the payload when the length already matches
/// (every caller overwrites the full buffer, so the zero-fill is only
/// needed when the length actually changes).
fn reshape(f: &mut Frame, w: usize, h: usize) {
    f.width = w;
    f.height = h;
    if f.data.len() != w * h {
        f.data.clear();
        f.data.resize(w * h, 0.0);
    }
}

/// Shard `frame` into horizontal row bands, one per (persistent) worker
/// evaluator, on scoped threads.  Band traversal reads the real context
/// rows from the source frame, so the stitched output is bit-identical
/// to a serial pass.
fn run_tiled(workers: &mut [WorkerExec], frame: &Frame, out: &mut Frame) {
    let (w, h) = (frame.width, frame.height);
    let n = workers.len().min(h);
    let band_h = h.div_ceil(n);
    thread::scope(|s| {
        for (i, (exec, chunk)) in
            workers.iter_mut().zip(out.data.chunks_mut(band_h * w)).enumerate()
        {
            let y0 = i * band_h;
            let y1 = (y0 + band_h).min(h);
            s.spawn(move || exec.run_band(frame, y0, y1, chunk));
        }
    });
}

/// `(seq, input frame, output frame)` travelling to/from the workers.
/// Both frames are recycled through [`StreamPool::spare`].
type Job = (u64, Frame, Frame);

/// Persistent worker pool of a streaming session: jobs fan out through a
/// bounded channel, completions come back tagged and are re-ordered in
/// [`StreamPool::pending`] (never larger than the in-flight budget).
struct StreamPool {
    /// `None` once the pool is shutting down (hang-up signal).
    jobs: Option<SyncSender<Job>>,
    results: Receiver<Job>,
    handles: Vec<JoinHandle<()>>,
    /// Completed outputs waiting for their turn (reorder window).
    pending: BTreeMap<u64, Frame>,
    /// Submit stamps; front belongs to `next_emit`.
    times: VecDeque<Instant>,
    /// Recycled frame buffers (inputs come back from workers; outputs
    /// come back through `Session::process_into`'s swap).
    spare: Vec<Frame>,
    next_submit: u64,
    next_emit: u64,
    workers: usize,
    reorder: usize,
}

impl StreamPool {
    fn spawn(plan: &CompiledPipeline, workers: usize, reorder: usize) -> Self {
        let cap = workers + reorder;
        let (jobs_tx, jobs_rx) = sync_channel::<Job>(reorder);
        let (results_tx, results_rx) = sync_channel::<Job>(cap);
        let jobs_rx = Arc::new(Mutex::new(jobs_rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            // compiled on the session thread, owned by the worker — the
            // thread borrows nothing from the plan
            let mut exec = WorkerExec::new(plan, true);
            let jobs_rx = Arc::clone(&jobs_rx);
            let results_tx = results_tx.clone();
            handles.push(thread::spawn(move || {
                loop {
                    // guard dropped before evaluating (one-statement scope)
                    let msg = { jobs_rx.lock().unwrap().recv() };
                    let Ok((seq, frame, mut out)) = msg else { break };
                    reshape(&mut out, frame.width, frame.height);
                    exec.run_band(&frame, 0, frame.height, &mut out.data);
                    if results_tx.send((seq, frame, out)).is_err() {
                        break;
                    }
                }
            }));
        }
        Self {
            jobs: Some(jobs_tx),
            results: results_rx,
            handles,
            pending: BTreeMap::new(),
            times: VecDeque::new(),
            spare: Vec::new(),
            next_submit: 0,
            next_emit: 0,
            workers,
            reorder,
        }
    }

    /// In-flight budget: how many frames may be outstanding at once.
    fn cap(&self) -> usize {
        self.workers + self.reorder
    }

    /// Submitted but not yet delivered in order.
    fn outstanding(&self) -> usize {
        (self.next_submit - self.next_emit) as usize
    }

    fn take_spare(&mut self) -> Frame {
        self.spare.pop().unwrap_or_else(|| Frame::new(0, 0))
    }

    fn recycle(&mut self, frame: Frame) {
        self.spare.push(frame);
    }

    /// Send one owned frame to the workers (caller enforces the cap).
    fn submit(&mut self, frame: Frame) -> Result<u64> {
        debug_assert!(self.outstanding() < self.cap(), "in-flight budget exceeded");
        let out = self.take_spare();
        let seq = self.next_submit;
        self.times.push_back(Instant::now());
        self.jobs
            .as_ref()
            .expect("pool is live")
            .send((seq, frame, out))
            .map_err(|_| worker_death())?;
        self.next_submit += 1;
        Ok(seq)
    }

    /// Block for one completion (any order) and park it in the reorder
    /// window; the input buffer goes back to the spare pool.
    fn recv_one(&mut self) -> Result<()> {
        let (seq, input, out) = self.results.recv().map_err(|_| worker_death())?;
        self.spare.push(input);
        self.pending.insert(seq, out);
        Ok(())
    }

    /// Pop the next in-order completion, if it has arrived.
    fn take_ready(&mut self) -> Option<(u64, Duration, Frame)> {
        let out = self.pending.remove(&self.next_emit)?;
        let seq = self.next_emit;
        self.next_emit += 1;
        let lat = self.times.pop_front().expect("one stamp per submission").elapsed();
        Some((seq, lat, out))
    }

    /// Block until the next in-order completion is available.
    fn next_result(&mut self) -> Result<(u64, Duration, Frame)> {
        loop {
            if let Some(r) = self.take_ready() {
                return Ok(r);
            }
            self.recv_one()?;
        }
    }

    /// Discard all in-flight work (error paths / [`Session::reset`]):
    /// receive whatever the workers still owe, recycle every buffer, and
    /// fast-forward the emit cursor so the next submission starts clean.
    fn discard_in_flight(&mut self) {
        while (self.next_submit - self.next_emit) as usize > self.pending.len() {
            match self.results.recv() {
                Ok((seq, input, out)) => {
                    self.spare.push(input);
                    self.pending.insert(seq, out);
                }
                Err(_) => break, // workers died; nothing more is owed
            }
        }
        let pending = std::mem::take(&mut self.pending);
        for (_, frame) in pending {
            self.spare.push(frame);
        }
        self.times.clear();
        self.next_emit = self.next_submit;
    }
}

fn worker_death() -> anyhow::Error {
    anyhow!("streaming session workers shut down unexpectedly (worker thread panicked?)")
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        // hang up the job channel so workers drain and exit ...
        self.jobs.take();
        // ... unblock any worker parked on a full result channel ...
        while self.results.recv().is_ok() {}
        // ... and reap the threads.
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::FilterKind;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::pipeline::Pipeline;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn median_plan() -> CompiledPipeline {
        Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact).unwrap()
    }

    #[test]
    fn every_exec_plan_matches_the_oracle_on_one_frame() {
        let plan = median_plan();
        let f = Frame::test_card(37, 19);
        let want = plan.run_frame_sequential(&f);
        for exec in [
            ExecPlan::Scalar,
            ExecPlan::Batched,
            ExecPlan::Tiled { workers: 3 },
            ExecPlan::streaming(2),
        ] {
            let mut s = plan.session(exec).unwrap();
            let got = s.process(&f).unwrap();
            assert_eq!(got.data, want.data, "{exec}");
        }
    }

    #[test]
    fn zero_workers_is_a_usable_error() {
        let plan = median_plan();
        for exec in [ExecPlan::Tiled { workers: 0 }, ExecPlan::Streaming { workers: 0, reorder: 4 }]
        {
            let err = plan.session(exec).unwrap_err();
            assert!(err.to_string().contains("at least one worker"), "{err}");
        }
        let err =
            plan.session(ExecPlan::Streaming { workers: 2, reorder: 0 }).unwrap_err();
        assert!(err.to_string().contains("reorder"), "{err}");
    }

    #[test]
    fn size_change_is_a_usable_error_and_reset_clears_it() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        s.process(&Frame::test_card(24, 16)).unwrap();
        assert_eq!(s.dims(), Some((24, 16)));
        let err = s.process(&Frame::test_card(32, 16)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("24x16"), "{msg}");
        assert!(msg.contains("32x16"), "{msg}");
        assert!(msg.contains("reset"), "{msg}");
        s.reset();
        let out = s.process(&Frame::test_card(32, 16)).unwrap();
        assert_eq!((out.width, out.height), (32, 16));
    }

    #[test]
    fn bad_first_frame_reports_the_plan_error() {
        let plan = Pipeline::new().builtin(FilterKind::Conv5x5).compile(OpMode::Exact).unwrap();
        let mut s = plan.session(ExecPlan::Scalar).unwrap();
        let err = s.process(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        // empty frames are usable errors too (the old run paths panicked)
        let err = s.process(&Frame::new(24, 0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn process_into_reuses_the_output_buffer() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        let f = Frame::test_card(33, 21);
        let want = plan.run_frame_sequential(&f);
        let mut out = Frame::new(0, 0);
        for _ in 0..3 {
            s.process_into(&f, &mut out).unwrap();
            assert_eq!(out.data, want.data);
        }
    }

    #[test]
    fn streaming_sequence_is_ordered_and_metered() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::streaming(3)).unwrap();
        let frames: Vec<Frame> = (0..10u64).map(|i| Frame::noise(24, 18, i)).collect();
        let mut seqs = Vec::new();
        let m = s
            .process_sequence(frames.clone(), |seq, out| {
                let want = plan.run_frame_sequential(&frames[seq as usize]);
                assert_eq!(out.data, want.data, "frame {seq}");
                seqs.push(seq);
            })
            .unwrap();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
        assert_eq!(m.frames, 10);
        assert!(m.p99_latency <= m.max_latency);
        assert!(m.mean_latency <= m.max_latency);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn empty_sequence_yields_zero_metrics() {
        let plan = median_plan();
        for exec in [ExecPlan::Scalar, ExecPlan::streaming(2)] {
            let mut s = plan.session(exec).unwrap();
            let m = s.process_sequence(vec![], |_, _| panic!("no frames")).unwrap();
            assert_eq!(m.frames, 0);
            assert_eq!(m.p99_latency, Duration::ZERO);
        }
    }

    #[test]
    fn more_tiled_workers_than_rows() {
        let plan = median_plan();
        let f = Frame::gradient(20, 5);
        let want = plan.run_frame_sequential(&f);
        let mut s = plan.session(ExecPlan::Tiled { workers: 32 }).unwrap();
        assert_eq!(s.process(&f).unwrap().data, want.data);
    }

    #[test]
    fn sessions_share_a_plan_concurrently() {
        let plan = median_plan();
        let f = Frame::test_card(31, 17);
        let want = plan.run_frame_sequential(&f);
        thread::scope(|sc| {
            for _ in 0..3 {
                sc.spawn(|| {
                    let mut s = plan.session(ExecPlan::Batched).unwrap();
                    assert_eq!(s.process(&f).unwrap().data, want.data);
                });
            }
        });
    }
}
