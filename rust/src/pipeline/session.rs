//! [`Session`]: the mutable, **supervised** executor of a
//! [`CompiledPipeline`].
//!
//! A session owns every piece of mutable execution state the plan needs —
//! compiled engines (netlist→tape), window generators (line buffers),
//! per-stage row buffers and lane scratch, and for
//! [`ExecPlan::Streaming`] a persistent worker-thread pool with a frame
//! recycling pool — so processing a whole video stream reuses the same
//! machinery frame after frame instead of reallocating it per call.
//! Sessions pin their frame geometry on first use (a size change is a
//! usable error, not a silent rebuild) because the warm line buffers and
//! scratch are sized to it.
//!
//! The runtime is supervised: a panic while evaluating a frame is caught
//! at the worker boundary, reported as a typed
//! [`ExecError::WorkerPanicked`] naming the offending frame, and the
//! worker is respawned — the poison frame is isolated, not fatal, and the
//! session keeps serving subsequent frames.  A [`SessionConfig`] adds
//! per-frame deadlines and an [`OverloadPolicy`] so a slow consumer
//! degrades gracefully (counted drops) instead of deadlocking.  Input
//! frames are validated once at entry: non-finite pixels are rejected
//! with [`ExecError::PoisonFrame`] before they reach any datapath.
//!
//! The streaming worker pool itself lives in [`super::pool`]: a session's
//! pool is exactly one lane of the shared multi-stream
//! [`MultiPool`] that [`FrameServer`](super::FrameServer) schedules N
//! streams over — so every session test exercises the shared supervision
//! machinery.

use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(feature = "fault-injection")]
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::pool::{panic_text, reshape, FaultCounters, MultiPool, Polled, Wait, WorkerExec};
use super::{CompiledPipeline, ExecError, ExecPlan, Metrics};
#[cfg(feature = "fault-injection")]
use crate::runtime::fault::FaultScript;
use crate::video::Frame;

/// What a session does when a frame arrives while the in-flight budget
/// is full (streaming plans; other plans never overload).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadPolicy {
    /// Wait for capacity (the classic backpressure behaviour).  With a
    /// deadline configured the wait is bounded: a budget that stays full
    /// for a whole deadline is reported as [`ExecError::QueueOverflow`].
    #[default]
    Block,
    /// Drop the *incoming* frame: the submitter never blocks, the oldest
    /// in-flight work is preserved, and the drop is counted in
    /// [`Metrics::dropped`].
    DropNewest,
    /// Drop the oldest frame still waiting *unclaimed* in the job queue
    /// to make room for the incoming one (freshest-data-wins, e.g. live
    /// camera feeds).  If every queued frame is already claimed by a
    /// worker there is nothing to retract, and the incoming frame is
    /// dropped instead — the submitter still never blocks.
    DropOldest,
}

impl OverloadPolicy {
    /// Parse the CLI spelling: `block | drop-newest | drop-oldest`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "block" => Ok(Self::Block),
            "drop-newest" => Ok(Self::DropNewest),
            "drop-oldest" => Ok(Self::DropOldest),
            _ => bail!("unknown overload policy {s:?} (block|drop-newest|drop-oldest)"),
        }
    }
}

impl std::fmt::Display for OverloadPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OverloadPolicy::Block => "block",
            OverloadPolicy::DropNewest => "drop-newest",
            OverloadPolicy::DropOldest => "drop-oldest",
        })
    }
}

/// Runtime policy of a [`Session`]: deadline, overload behaviour, input
/// validation, and (under `--features fault-injection`) a chaos script.
/// Built fluently and passed to [`CompiledPipeline::session_with`]:
///
/// ```
/// use std::time::Duration;
/// use fpspatial::pipeline::{OverloadPolicy, SessionConfig};
///
/// let cfg = SessionConfig::new()
///     .deadline(Duration::from_millis(100))
///     .overload(OverloadPolicy::DropNewest);
/// assert_eq!(cfg.overload, OverloadPolicy::DropNewest);
/// assert!(cfg.validate);
/// ```
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Per-frame deadline, measured submit → in-order delivery.  `None`
    /// (the default) waits indefinitely, exactly like before.
    pub deadline: Option<Duration>,
    /// What to do when the streaming in-flight budget is full.
    pub overload: OverloadPolicy,
    /// Reject frames containing non-finite pixels at submission
    /// ([`ExecError::PoisonFrame`]).  Default **on** — the custom-float
    /// datapaths define no semantics for NaN/Inf inputs.
    pub validate: bool,
    /// Deterministic chaos plan (see [`crate::runtime::fault`]).
    #[cfg(feature = "fault-injection")]
    pub faults: Option<Arc<FaultScript>>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            deadline: None,
            overload: OverloadPolicy::Block,
            validate: true,
            #[cfg(feature = "fault-injection")]
            faults: None,
        }
    }
}

impl SessionConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bound every frame's submit→delivery latency.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Choose the overload policy (default [`OverloadPolicy::Block`]).
    pub fn overload(mut self, p: OverloadPolicy) -> Self {
        self.overload = p;
        self
    }

    /// Enable/disable non-finite input validation (default on).
    pub fn validate(mut self, on: bool) -> Self {
        self.validate = on;
        self
    }

    /// Attach a fault-injection script (chaos testing only).
    #[cfg(feature = "fault-injection")]
    pub fn with_faults(mut self, faults: Arc<FaultScript>) -> Self {
        self.faults = Some(faults);
        self
    }
}

/// Fire any armed fault hooks for `seq` (no-op without the
/// `fault-injection` feature).
fn fire_faults(_config: &SessionConfig, _seq: u64) {
    #[cfg(feature = "fault-injection")]
    if let Some(f) = &_config.faults {
        f.fire(_seq);
    }
}

/// Mutable session state, by [`ExecPlan`] shape.
enum State {
    /// [`ExecPlan::Scalar`] / [`ExecPlan::Batched`]: one serial evaluator
    /// (rebuilt on a contained panic).
    Direct { exec: WorkerExec, batched: bool },
    /// [`ExecPlan::Tiled`]: one persistent evaluator per worker; each
    /// frame is sharded into row bands on scoped threads.
    Tiled(Vec<WorkerExec>),
    /// [`ExecPlan::Streaming`]: a supervised persistent worker pool.
    Streaming(StreamPool),
}

/// A reusable executor created from a [`CompiledPipeline`] and an
/// [`ExecPlan`].  See [`CompiledPipeline::session`] /
/// [`CompiledPipeline::session_with`].
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use fpspatial::filters::FilterKind;
/// use fpspatial::fpcore::OpMode;
/// use fpspatial::pipeline::{ExecPlan, Pipeline};
/// use fpspatial::video::Frame;
///
/// let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
/// let mut session = plan.session(ExecPlan::streaming(2))?;
/// let frames: Vec<Frame> = (0..4u64).map(|i| Frame::noise(32, 24, i)).collect();
/// let mut outs = Vec::new();
/// let metrics = session.process_sequence(frames, |_seq, f| outs.push(f))?;
/// assert_eq!(metrics.frames, 4);
/// assert_eq!(metrics.dropped, 0);
/// assert_eq!(outs.len(), 4); // delivered strictly in order
/// # Ok(())
/// # }
/// ```
pub struct Session<'p> {
    plan: &'p CompiledPipeline,
    exec: ExecPlan,
    config: SessionConfig,
    state: State,
    /// Frame geometry, latched by the first processed frame.
    dims: Option<(usize, usize)>,
    /// Next frame sequence number for the non-streaming plans (streaming
    /// seqs are tracked by the pool).
    submitted: u64,
    /// Direct/Tiled-side fault accounting (the pool keeps its own).
    counters: FaultCounters,
}

impl<'p> Session<'p> {
    pub(crate) fn new(plan: &'p CompiledPipeline, exec: ExecPlan) -> Result<Self> {
        Self::new_with(plan, exec, SessionConfig::default())
    }

    pub(crate) fn new_with(
        plan: &'p CompiledPipeline,
        exec: ExecPlan,
        config: SessionConfig,
    ) -> Result<Self> {
        let state = match exec {
            ExecPlan::Scalar => {
                State::Direct { exec: WorkerExec::new(plan, false), batched: false }
            }
            ExecPlan::Batched => State::Direct { exec: WorkerExec::new(plan, true), batched: true },
            ExecPlan::Tiled { workers } => {
                if workers == 0 {
                    bail!("a tiled session needs at least one worker");
                }
                State::Tiled((0..workers).map(|_| WorkerExec::new(plan, true)).collect())
            }
            ExecPlan::Streaming { workers, reorder } => {
                if workers == 0 {
                    bail!("a streaming session needs at least one worker");
                }
                if reorder == 0 {
                    bail!("a streaming session needs a reorder window of at least 1");
                }
                State::Streaming(StreamPool::spawn(plan, workers, reorder, &config))
            }
        };
        Ok(Self {
            plan,
            exec,
            config,
            state,
            dims: None,
            submitted: 0,
            counters: FaultCounters::default(),
        })
    }

    /// The plan this session executes.
    pub fn plan(&self) -> &'p CompiledPipeline {
        self.plan
    }

    /// The execution strategy this session was created with.
    pub fn exec(&self) -> ExecPlan {
        self.exec
    }

    /// The runtime policy this session was created with.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Frame geometry this session is pinned to (None until the first
    /// frame is processed, or after [`Session::reset`]).
    pub fn dims(&self) -> Option<(usize, usize)> {
        self.dims
    }

    fn totals(&self) -> FaultCounters {
        let mut c = self.counters;
        if let State::Streaming(pool) = &self.state {
            let p = pool.counters();
            c.dropped += p.dropped;
            c.deadline_misses += p.deadline_misses;
            c.worker_restarts += p.worker_restarts;
        }
        c
    }

    /// Frames dropped so far (overload policy or deadline abandonment).
    pub fn dropped(&self) -> u64 {
        self.totals().dropped
    }

    /// Frames that missed the configured deadline so far.
    pub fn deadline_misses(&self) -> u64 {
        self.totals().deadline_misses
    }

    /// Workers respawned after a contained panic so far.
    pub fn worker_restarts(&self) -> u64 {
        self.totals().worker_restarts
    }

    /// Unpin the frame geometry so the next frame may have a new size
    /// (engines survive; line buffers rebuild on the next frame).  Any
    /// in-flight streaming work left over from an aborted or faulted run
    /// is abandoned without blocking.
    pub fn reset(&mut self) {
        self.dims = None;
        let plan = self.plan;
        if let State::Streaming(pool) = &mut self.state {
            pool.abandon_all(plan);
        }
    }

    /// Validate `frame` against the plan and the pinned geometry.
    fn admit(&mut self, frame: &Frame) -> Result<()> {
        match self.dims {
            None => {
                self.plan.check_frame(frame)?;
                self.dims = Some((frame.width, frame.height));
            }
            Some((w, h)) if (w, h) == (frame.width, frame.height) => {}
            Some((w, h)) => bail!(
                "this session is pinned to {w}x{h} frames but received {}x{}: sessions keep \
                 line buffers and scratch sized to one geometry — call Session::reset() or \
                 open a new session for the new size",
                frame.width,
                frame.height
            ),
        }
        Ok(())
    }

    /// The sequence number the next submitted frame will get.
    fn next_seq(&self) -> u64 {
        match &self.state {
            State::Streaming(pool) => pool.next_submit(),
            _ => self.submitted,
        }
    }

    /// Input screening at submission: injected corruption (chaos builds)
    /// and non-finite pixel validation, both reported as
    /// [`ExecError::PoisonFrame`] before the frame reaches any worker.
    fn screen(&self, frame: &Frame, seq: u64) -> Result<()> {
        #[cfg(feature = "fault-injection")]
        if let Some(faults) = &self.config.faults {
            if let Some(value) = faults.corruption(seq) {
                return Err(ExecError::PoisonFrame { frame_seq: seq, index: 0, value }.into());
            }
        }
        if self.config.validate {
            if let Some(index) = frame.data.iter().position(|v| !v.is_finite()) {
                return Err(ExecError::PoisonFrame {
                    frame_seq: seq,
                    index,
                    value: frame.data[index],
                }
                .into());
            }
        }
        Ok(())
    }

    /// Process one frame, returning the filtered output (at the plan's
    /// **output** dimensions — strided stages shrink the frame).
    /// Bit-identical to [`CompiledPipeline::run_frame_sequential`] under
    /// every [`ExecPlan`] (`tests/session_reuse.rs`).
    pub fn process(&mut self, frame: &Frame) -> Result<Frame> {
        let (ow, oh) = self.plan.output_dims(frame.width, frame.height);
        let mut out = Frame::new(ow, oh);
        self.process_into(frame, &mut out)?;
        Ok(out)
    }

    /// [`Session::process`] into a caller-owned frame: with a warm
    /// session and a reused `out`, the steady state performs no
    /// allocation at all (engines, generators, scratch and — for
    /// streaming — the in-flight frame pool are all recycled).  On `Err`
    /// the contents of `out` are unspecified.
    pub fn process_into(&mut self, frame: &Frame, out: &mut Frame) -> Result<()> {
        self.admit(frame)?;
        let seq = self.next_seq();
        self.screen(frame, seq)?;
        let Session { plan, config, state, submitted, counters, .. } = self;
        let plan = *plan;
        let (ow, oh) = plan.output_dims(frame.width, frame.height);
        match state {
            State::Direct { exec, batched } => {
                *submitted = seq + 1;
                let started = Instant::now();
                reshape(out, ow, oh);
                run_direct(exec, *batched, plan, config, seq, frame, out, counters)?;
                if let Some(d) = config.deadline {
                    // serial evaluation cannot be preempted; a late frame
                    // is still delivered but counted as a miss
                    if started.elapsed() > d {
                        counters.deadline_misses += 1;
                    }
                }
            }
            State::Tiled(workers) => {
                *submitted = seq + 1;
                let started = Instant::now();
                reshape(out, ow, oh);
                run_tiled(workers, plan, config, seq, frame, out, counters)?;
                if let Some(d) = config.deadline {
                    if started.elapsed() > d {
                        counters.deadline_misses += 1;
                    }
                }
            }
            State::Streaming(pool) => {
                // leftovers from an aborted sequence (e.g. a panic that
                // unwound out of its on_frame callback) must never be
                // served as this frame's result
                if pool.unemitted() > 0 {
                    pool.abandon_all(plan);
                }
                let mut input = pool.take_spare();
                reshape(&mut input, frame.width, frame.height);
                input.data.copy_from_slice(&frame.data);
                let seq = pool.submit(input);
                let started = Instant::now();
                loop {
                    if let Some((got_seq, _lat, mut got)) = pool.take_ready(config.deadline) {
                        debug_assert_eq!(got_seq, seq);
                        std::mem::swap(out, &mut got);
                        pool.recycle(got);
                        return Ok(());
                    }
                    let wait = match config.deadline {
                        None => Wait::Block,
                        // an already-expired deadline fails fast instead
                        // of spinning on zero-length timeouts against the
                        // completion channel
                        Some(d) => match d.checked_sub(started.elapsed()) {
                            Some(left) if !left.is_zero() => Wait::Timeout(left),
                            _ => return Err(deadline_exceeded(pool, seq, d, started.elapsed())),
                        },
                    };
                    match pool.poll_completion(plan, wait)? {
                        Polled::Progress => {}
                        Polled::Faulted { error, .. } => {
                            pool.abandon_all(plan);
                            return Err(error.into());
                        }
                        Polled::TimedOut => {
                            let d = config.deadline.expect("timeouts need a deadline");
                            return Err(deadline_exceeded(pool, seq, d, started.elapsed()));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Process an owned frame sequence, delivering outputs **in order**
    /// to `on_frame` and returning throughput/latency [`Metrics`].
    ///
    /// Under [`ExecPlan::Streaming`] the sequence is pipelined: up to
    /// `workers + reorder` frames are in flight at once and completions
    /// are re-ordered through the bounded reorder window, exactly like
    /// the camera→FPGA→display stream of §IV.  Other plans process
    /// frames one at a time.  Latency is stamped submit→in-order
    /// delivery.  `on_frame` receives each frame's index within *this*
    /// sequence; indices of dropped frames (overload policy) are simply
    /// absent, and the surviving outputs stay strictly ascending.
    pub fn process_sequence(
        &mut self,
        frames: Vec<Frame>,
        mut on_frame: impl FnMut(u64, Frame),
    ) -> Result<Metrics> {
        let n = frames.len() as u64;
        let before = self.totals();
        let t0 = Instant::now();
        let mut lats: Vec<Duration> = Vec::with_capacity(frames.len());
        if matches!(self.exec, ExecPlan::Streaming { .. }) {
            // On any error the pool must not be left holding in-flight
            // frames — a later process() would pop a stale completion.
            if let Err(e) = self.stream_sequence(frames, &mut lats, &mut on_frame) {
                let plan = self.plan;
                let State::Streaming(pool) = &mut self.state else { unreachable!() };
                pool.abandon_all(plan);
                return Err(e);
            }
        } else {
            for (seq, frame) in frames.into_iter().enumerate() {
                let t = Instant::now();
                let out = self.process(&frame)?;
                lats.push(t.elapsed());
                on_frame(seq as u64, out);
            }
        }
        let after = self.totals();
        Ok(Metrics::from_latencies(n, t0.elapsed(), lats).with_fault_counts(
            after.dropped - before.dropped,
            after.deadline_misses - before.deadline_misses,
            after.worker_restarts - before.worker_restarts,
        ))
    }

    /// The pipelined body of [`Session::process_sequence`] under
    /// [`ExecPlan::Streaming`] — separated so the caller can abandon
    /// in-flight work on any error.
    fn stream_sequence(
        &mut self,
        frames: Vec<Frame>,
        lats: &mut Vec<Duration>,
        on_frame: &mut impl FnMut(u64, Frame),
    ) -> Result<()> {
        let plan = self.plan;
        let deadline = self.config.deadline;
        let overload = self.config.overload;
        let base = {
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            // leftovers from a run aborted by a panic in its callback
            if pool.unemitted() > 0 {
                pool.abandon_all(plan);
            }
            pool.next_submit()
        };
        for frame in frames {
            self.admit(&frame)?;
            let seq = self.next_seq();
            self.screen(&frame, seq)?;
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            if pool.live_frames() >= pool.cap() {
                // fold in whatever has already completed, without blocking
                loop {
                    match pool.poll_completion(plan, Wait::NoWait)? {
                        Polled::Progress => {}
                        Polled::Faulted { error, .. } => return Err(error.into()),
                        Polled::TimedOut => break,
                    }
                }
                drain_ready(pool, deadline, base, lats, on_frame);
            }
            if pool.live_frames() >= pool.cap() {
                match overload {
                    OverloadPolicy::Block => {
                        // classic backpressure; bounded by the deadline
                        // when one is configured, measured from when the
                        // stall began — a budget still full once it
                        // expires fails fast as a typed overflow (never a
                        // zero-length wait on the completion channel)
                        let stalled = Instant::now();
                        while pool.live_frames() >= pool.cap() {
                            let wait = match deadline {
                                Some(d) => match d.checked_sub(stalled.elapsed()) {
                                    Some(left) if !left.is_zero() => Wait::Timeout(left),
                                    _ => {
                                        return Err(queue_overflow(pool, seq, stalled.elapsed()))
                                    }
                                },
                                None => Wait::Block,
                            };
                            match pool.poll_completion(plan, wait)? {
                                Polled::Progress => {}
                                Polled::Faulted { error, .. } => return Err(error.into()),
                                Polled::TimedOut => {
                                    return Err(queue_overflow(pool, seq, stalled.elapsed()));
                                }
                            }
                            drain_ready(pool, deadline, base, lats, on_frame);
                        }
                    }
                    OverloadPolicy::DropNewest => {
                        pool.drop_newest(frame);
                        continue;
                    }
                    OverloadPolicy::DropOldest => {
                        if !pool.retract_oldest() {
                            // every queued frame is already claimed by a
                            // worker — nothing to retract; drop the
                            // incoming frame so the submitter never blocks
                            pool.drop_newest(frame);
                            continue;
                        }
                    }
                }
            }
            let State::Streaming(pool) = &mut self.state else { unreachable!() };
            pool.submit(frame);
            drain_ready(pool, deadline, base, lats, on_frame);
        }
        // drain the tail in order
        let State::Streaming(pool) = &mut self.state else { unreachable!() };
        while pool.unemitted() > 0 {
            drain_ready(pool, deadline, base, lats, on_frame);
            if pool.unemitted() == 0 {
                break;
            }
            let wait = match deadline {
                Some(d) => Wait::Timeout(d),
                None => Wait::Block,
            };
            match pool.poll_completion(plan, wait)? {
                Polled::Progress => {}
                Polled::Faulted { error, .. } => return Err(error.into()),
                Polled::TimedOut => {
                    let d = deadline.unwrap_or_default();
                    return Err(ExecError::DeadlineExceeded {
                        frame_seq: pool.oldest_unemitted(),
                        deadline: d,
                        elapsed: d,
                    }
                    .into());
                }
            }
        }
        Ok(())
    }
}

/// Give up on a timed-out frame: count the miss and the drop, surrender
/// its slot, and build the typed error.  Shared by the bounded wait and
/// the fail-fast path an already-expired deadline takes.
fn deadline_exceeded(
    pool: &mut StreamPool,
    seq: u64,
    deadline: Duration,
    elapsed: Duration,
) -> anyhow::Error {
    let c = pool.counters_mut();
    c.deadline_misses += 1;
    c.dropped += 1;
    pool.abandon_seq(seq);
    ExecError::DeadlineExceeded { frame_seq: seq, deadline, elapsed }.into()
}

/// Typed overflow for a submission stalled past its deadline, reporting
/// how long the in-flight budget actually stayed full.
fn queue_overflow(pool: &StreamPool, seq: u64, waited: Duration) -> anyhow::Error {
    ExecError::QueueOverflow { frame_seq: seq, capacity: pool.cap(), waited }.into()
}

/// Deliver every in-order-ready completion to `on_frame`, re-based to
/// sequence-local indices.
fn drain_ready(
    pool: &mut StreamPool,
    deadline: Option<Duration>,
    base: u64,
    lats: &mut Vec<Duration>,
    on_frame: &mut impl FnMut(u64, Frame),
) {
    while let Some((seq, lat, out)) = pool.take_ready(deadline) {
        lats.push(lat);
        on_frame(seq - base, out);
    }
}

/// Evaluate a whole frame on one supervised serial evaluator: a panic is
/// contained, the evaluator rebuilt, and the typed error returned.
#[allow(clippy::too_many_arguments)]
fn run_direct(
    exec: &mut WorkerExec,
    batched: bool,
    plan: &CompiledPipeline,
    config: &SessionConfig,
    seq: u64,
    frame: &Frame,
    out: &mut Frame,
    counters: &mut FaultCounters,
) -> Result<()> {
    let oh = out.height;
    let r = catch_unwind(AssertUnwindSafe(|| {
        fire_faults(config, seq);
        exec.run_band(frame, 0, oh, &mut out.data)
    }));
    match r {
        Ok(Ok(())) => Ok(()),
        Ok(Err(message)) => {
            Err(ExecError::StageFailed { worker: 0, frame_seq: seq, message }.into())
        }
        Err(payload) => {
            // the evaluator's internal state is suspect after an unwind:
            // rebuild it so the next frame runs on a fresh one
            *exec = WorkerExec::new(plan, batched);
            counters.worker_restarts += 1;
            Err(ExecError::WorkerPanicked {
                worker: 0,
                frame_seq: seq,
                payload: panic_text(payload),
            }
            .into())
        }
    }
}

/// One band's failure, carried back from a scoped tile worker.
struct BandFault {
    worker: usize,
    panicked: bool,
    message: String,
}

/// Shard the **output** frame into horizontal row bands, one per
/// (persistent) worker evaluator, on scoped threads.  Band traversal
/// reads the real context rows from the source frame (each band's
/// backward plan reaches up through every stride), so the stitched
/// output is bit-identical to a serial pass.  Panicking bands are
/// contained: their evaluator is rebuilt and the first fault is
/// reported; the frame fails as a unit.
fn run_tiled(
    workers: &mut [WorkerExec],
    plan: &CompiledPipeline,
    config: &SessionConfig,
    seq: u64,
    frame: &Frame,
    out: &mut Frame,
    counters: &mut FaultCounters,
) -> Result<()> {
    let (ow, oh) = (out.width, out.height);
    let n = workers.len().min(oh);
    let band_h = oh.div_ceil(n);
    let faults: Vec<BandFault> = thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter_mut()
            .zip(out.data.chunks_mut(band_h * ow))
            .enumerate()
            .map(|(i, (exec, chunk))| {
                let y0 = i * band_h;
                let y1 = (y0 + band_h).min(oh);
                s.spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        // one-shot hooks: with several bands racing, the
                        // fault strikes exactly one of them
                        fire_faults(config, seq);
                        exec.run_band(frame, y0, y1, chunk)
                    }));
                    match r {
                        Ok(Ok(())) => None,
                        Ok(Err(message)) => {
                            Some(BandFault { worker: i, panicked: false, message })
                        }
                        Err(p) => {
                            Some(BandFault { worker: i, panicked: true, message: panic_text(p) })
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().expect("band supervisors do not panic"))
            .collect()
    });
    let mut first: Option<ExecError> = None;
    for f in faults {
        let err = if f.panicked {
            workers[f.worker] = WorkerExec::new(plan, true);
            counters.worker_restarts += 1;
            ExecError::WorkerPanicked { worker: f.worker, frame_seq: seq, payload: f.message }
        } else {
            ExecError::StageFailed { worker: f.worker, frame_seq: seq, message: f.message }
        };
        first.get_or_insert(err);
    }
    match first {
        None => Ok(()),
        Some(e) => Err(e.into()),
    }
}

/// The single-stream view of the shared multi-stream pool: a streaming
/// session is exactly lane 0 of a one-lane [`MultiPool`] — the same
/// machinery [`FrameServer`](super::FrameServer) schedules N lanes over,
/// so every session test exercises the shared supervision paths (fair
/// queue, poison-tolerant locking, respawn, recycling).
struct StreamPool {
    pool: MultiPool,
}

impl StreamPool {
    fn spawn(
        plan: &CompiledPipeline,
        workers: usize,
        reorder: usize,
        config: &SessionConfig,
    ) -> Self {
        Self { pool: MultiPool::spawn(&[(plan, workers + reorder, config)], workers) }
    }

    /// In-flight budget: how many frames may be outstanding at once.
    fn cap(&self) -> usize {
        self.pool.cap(0)
    }

    /// Frames currently owned by the pool machinery (claimed, queued, or
    /// parked in the reorder window).
    fn live_frames(&self) -> usize {
        self.pool.live_frames(0)
    }

    /// Sequence numbers not yet delivered in order (including skipped
    /// ones the cursor has not stepped over yet).
    fn unemitted(&self) -> u64 {
        self.pool.unemitted(0)
    }

    /// The oldest sequence number still owed to the caller.
    fn oldest_unemitted(&self) -> u64 {
        self.pool.oldest_unemitted(0)
    }

    /// The sequence number the next submission will get.
    fn next_submit(&self) -> u64 {
        self.pool.next_submit(0)
    }

    fn counters(&self) -> FaultCounters {
        self.pool.counters(0)
    }

    fn counters_mut(&mut self) -> &mut FaultCounters {
        self.pool.counters_mut(0)
    }

    fn take_spare(&mut self) -> Frame {
        self.pool.take_spare()
    }

    fn recycle(&mut self, frame: Frame) {
        self.pool.recycle(frame)
    }

    /// Hand one owned frame to the workers (caller enforces the budget).
    fn submit(&mut self, frame: Frame) -> u64 {
        self.pool.submit(0, frame)
    }

    /// Drop an incoming frame instead of submitting it (DropNewest).
    fn drop_newest(&mut self, frame: Frame) {
        self.pool.drop_newest(0, frame)
    }

    /// Retract the oldest unclaimed job to make room (DropOldest).
    fn retract_oldest(&mut self) -> bool {
        self.pool.retract_oldest(0)
    }

    /// Receive one completion (bounded by `wait`) and fold it into the
    /// pool state (see [`MultiPool::poll_completion`]).
    fn poll_completion(&mut self, plan: &CompiledPipeline, wait: Wait) -> Result<Polled> {
        self.pool.poll_completion(std::slice::from_ref(&plan), wait)
    }

    /// Pop the next in-order completion if it has arrived (see
    /// [`MultiPool::take_ready`]).
    fn take_ready(&mut self, deadline: Option<Duration>) -> Option<(u64, Duration, Frame)> {
        self.pool.take_ready(0, deadline)
    }

    /// Surrender one timed-out frame's slot: the emit cursor moves past
    /// it and its late completion will be recycled as stale.
    fn abandon_seq(&mut self, seq: u64) {
        self.pool.abandon_seq(0, seq)
    }

    /// Abandon all in-flight work **without blocking** (error paths /
    /// [`Session::reset`]); see [`MultiPool::abandon_stream`].
    fn abandon_all(&mut self, plan: &CompiledPipeline) {
        self.pool.abandon_stream(0, std::slice::from_ref(&plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::FilterKind;
    use crate::fpcore::{FloatFormat, OpMode};
    use crate::pipeline::Pipeline;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn median_plan() -> CompiledPipeline {
        Pipeline::new().builtin(FilterKind::Median).format(F16).compile(OpMode::Exact).unwrap()
    }

    const ALL_EXECS: [ExecPlan; 4] = [
        ExecPlan::Scalar,
        ExecPlan::Batched,
        ExecPlan::Tiled { workers: 3 },
        ExecPlan::Streaming { workers: 2, reorder: 2 },
    ];

    #[test]
    fn every_exec_plan_matches_the_oracle_on_one_frame() {
        let plan = median_plan();
        let f = Frame::test_card(37, 19);
        let want = plan.run_frame_sequential(&f);
        for exec in ALL_EXECS {
            let mut s = plan.session(exec).unwrap();
            let got = s.process(&f).unwrap();
            assert_eq!(got.data, want.data, "{exec}");
        }
    }

    #[test]
    fn zero_workers_is_a_usable_error() {
        let plan = median_plan();
        for exec in [ExecPlan::Tiled { workers: 0 }, ExecPlan::Streaming { workers: 0, reorder: 4 }]
        {
            let err = plan.session(exec).unwrap_err();
            assert!(err.to_string().contains("at least one worker"), "{err}");
        }
        let err =
            plan.session(ExecPlan::Streaming { workers: 2, reorder: 0 }).unwrap_err();
        assert!(err.to_string().contains("reorder"), "{err}");
    }

    #[test]
    fn size_change_is_a_usable_error_and_reset_clears_it() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        s.process(&Frame::test_card(24, 16)).unwrap();
        assert_eq!(s.dims(), Some((24, 16)));
        let err = s.process(&Frame::test_card(32, 16)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("24x16"), "{msg}");
        assert!(msg.contains("32x16"), "{msg}");
        assert!(msg.contains("reset"), "{msg}");
        s.reset();
        let out = s.process(&Frame::test_card(32, 16)).unwrap();
        assert_eq!((out.width, out.height), (32, 16));
    }

    #[test]
    fn bad_first_frame_reports_the_plan_error() {
        let plan = Pipeline::new().builtin(FilterKind::Conv5x5).compile(OpMode::Exact).unwrap();
        let mut s = plan.session(ExecPlan::Scalar).unwrap();
        let err = s.process(&Frame::test_card(4, 8)).unwrap_err();
        assert!(err.to_string().contains("narrower"), "{err}");
        // empty frames are usable errors too (the old run paths panicked)
        let err = s.process(&Frame::new(24, 0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn non_finite_pixels_are_rejected_under_every_plan() {
        let plan = median_plan();
        for exec in ALL_EXECS {
            let mut s = plan.session(exec).unwrap();
            let mut bad = Frame::test_card(24, 16);
            bad.data[37] = f64::INFINITY;
            let err = s.process(&bad).unwrap_err();
            match err.downcast_ref::<ExecError>() {
                Some(ExecError::PoisonFrame { index: 37, value, .. }) => {
                    assert!(value.is_infinite(), "{exec}");
                }
                other => panic!("{exec}: expected PoisonFrame, got {other:?}"),
            }
            // the rejection pinned the geometry but poisoned nothing: the
            // sanitized frame still processes
            let good = Frame::test_card(24, 16);
            let got = s.process(&good).unwrap();
            assert_eq!(got.data, plan.run_frame_sequential(&good).data, "{exec}");
        }
    }

    #[test]
    fn validation_can_be_disabled() {
        let plan = median_plan();
        let cfg = SessionConfig::new().validate(false);
        let mut s = plan.session_with(ExecPlan::Batched, cfg).unwrap();
        let mut bad = Frame::test_card(24, 16);
        bad.data[0] = f64::NAN;
        // undefined numerically, but must not error or hang
        let out = s.process(&bad).unwrap();
        assert_eq!((out.width, out.height), (24, 16));
    }

    #[test]
    fn overload_policy_parses_and_displays() {
        for (s, want) in [
            ("block", OverloadPolicy::Block),
            ("drop-newest", OverloadPolicy::DropNewest),
            ("drop-oldest", OverloadPolicy::DropOldest),
        ] {
            assert_eq!(OverloadPolicy::parse(s).unwrap(), want);
            assert_eq!(want.to_string(), s);
        }
        let err = OverloadPolicy::parse("shed").unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
    }

    #[test]
    fn process_into_reuses_the_output_buffer() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::Batched).unwrap();
        let f = Frame::test_card(33, 21);
        let want = plan.run_frame_sequential(&f);
        let mut out = Frame::new(0, 0);
        for _ in 0..3 {
            s.process_into(&f, &mut out).unwrap();
            assert_eq!(out.data, want.data);
        }
    }

    #[test]
    fn streaming_sequence_is_ordered_and_metered() {
        let plan = median_plan();
        let mut s = plan.session(ExecPlan::streaming(3)).unwrap();
        let frames: Vec<Frame> = (0..10u64).map(|i| Frame::noise(24, 18, i)).collect();
        let mut seqs = Vec::new();
        let m = s
            .process_sequence(frames.clone(), |seq, out| {
                let want = plan.run_frame_sequential(&frames[seq as usize]);
                assert_eq!(out.data, want.data, "frame {seq}");
                seqs.push(seq);
            })
            .unwrap();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
        assert_eq!(m.frames, 10);
        assert_eq!((m.dropped, m.deadline_misses, m.worker_restarts), (0, 0, 0));
        assert!(m.p99_latency <= m.max_latency);
        assert!(m.mean_latency <= m.max_latency);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn empty_sequence_yields_zero_metrics() {
        let plan = median_plan();
        for exec in [ExecPlan::Scalar, ExecPlan::streaming(2)] {
            let mut s = plan.session(exec).unwrap();
            let m = s.process_sequence(vec![], |_, _| panic!("no frames")).unwrap();
            assert_eq!(m.frames, 0);
            assert_eq!(m.p99_latency, Duration::ZERO);
        }
    }

    #[test]
    fn more_tiled_workers_than_rows() {
        let plan = median_plan();
        let f = Frame::gradient(20, 5);
        let want = plan.run_frame_sequential(&f);
        let mut s = plan.session(ExecPlan::Tiled { workers: 32 }).unwrap();
        assert_eq!(s.process(&f).unwrap().data, want.data);
    }

    #[test]
    fn sessions_share_a_plan_concurrently() {
        let plan = median_plan();
        let f = Frame::test_card(31, 17);
        let want = plan.run_frame_sequential(&f);
        thread::scope(|sc| {
            for _ in 0..3 {
                sc.spawn(|| {
                    let mut s = plan.session(ExecPlan::Batched).unwrap();
                    assert_eq!(s.process(&f).unwrap().data, want.data);
                });
            }
        });
    }

    #[test]
    fn a_generous_deadline_changes_nothing() {
        let plan = median_plan();
        let cfg = SessionConfig::new().deadline(Duration::from_secs(60));
        for exec in ALL_EXECS {
            let mut s = plan.session_with(exec, cfg.clone()).unwrap();
            let frames: Vec<Frame> = (0..6u64).map(|i| Frame::noise(24, 18, i)).collect();
            let m = s.process_sequence(frames.clone(), |_, _| {}).unwrap();
            assert_eq!(m.frames, 6, "{exec}");
            assert_eq!(m.dropped, 0, "{exec}");
            assert_eq!(m.deadline_misses, 0, "{exec}");
            assert_eq!(s.worker_restarts(), 0, "{exec}");
        }
    }

    #[test]
    fn an_expired_deadline_fails_fast_without_spinning() {
        // deadline already expired at poll time: the typed error must
        // come back promptly (fail-fast path, no zero-timeout busy loop)
        let plan = median_plan();
        let cfg = SessionConfig::new().deadline(Duration::from_nanos(1));
        let mut s = plan.session_with(ExecPlan::streaming(2), cfg).unwrap();
        // a frame large enough that its evaluation cannot possibly finish
        // between submit and the first poll
        let f = Frame::test_card(96, 64);
        let t0 = Instant::now();
        let err = s.process(&f).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "took {:?}", t0.elapsed());
        match err.downcast_ref::<ExecError>() {
            Some(ExecError::DeadlineExceeded { frame_seq: 0, .. }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(s.deadline_misses(), 1);
        assert_eq!(s.dropped(), 1);
    }
}
