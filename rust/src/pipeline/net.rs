//! Layer-stack descriptor files (`*.net`) → [`Pipeline`].
//!
//! A `.net` file describes a CNN-shaped stack — conv/relu/pool layers
//! with per-layer custom float formats — one stage per line, in flow
//! order.  The grammar is deliberately tiny:
//!
//! ```text
//! # comments run to end of line; blank lines are skipped
//! input channels=3            # optional, before any stage
//! conv3x3 fmt=16,7 stride=2   # any built-in filter name
//! relu fmt=16,7
//! dsl my_filter.dsl fmt=10,5  # path relative to the .net file
//! maxpool k=2 stride=2 fmt=10,5
//! ```
//!
//! Stage lines are a head word plus `key=value` options: `fmt=m,e`
//! (custom float mantissa,exponent bits), `stride=s` (emit every s-th
//! window per axis), and for `maxpool` the window `k=K` (with `stride`
//! defaulting to `K`, the classic non-overlapping pool).  Everything is
//! validated by [`Pipeline::compile`]; this parser only reports
//! line-level grammar errors with their line number.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Pipeline;
use crate::filters::FilterKind;
use crate::fpcore::FloatFormat;

/// Parse `fmt=m,e` option values.
fn parse_fmt(v: &str) -> Result<FloatFormat> {
    let (m, e) = v
        .split_once(',')
        .with_context(|| format!("fmt takes mantissa,exponent bits (e.g. fmt=10,5), got {v:?}"))?;
    let m: u32 = m.trim().parse().with_context(|| format!("bad mantissa bits {m:?}"))?;
    let e: u32 = e.trim().parse().with_context(|| format!("bad exponent bits {e:?}"))?;
    Ok(FloatFormat::new(m, e))
}

/// One stage line's parsed `key=value` options.
#[derive(Default)]
struct Opts {
    fmt: Option<FloatFormat>,
    stride: Option<usize>,
    k: Option<usize>,
    channels: Option<usize>,
}

fn parse_opts<'a>(toks: impl Iterator<Item = &'a str>) -> Result<Opts> {
    let mut o = Opts::default();
    for tok in toks {
        let Some((key, val)) = tok.split_once('=') else {
            bail!("expected key=value option, got {tok:?}");
        };
        match key {
            "fmt" => o.fmt = Some(parse_fmt(val)?),
            "stride" => {
                o.stride =
                    Some(val.parse().with_context(|| format!("bad stride {val:?}"))?)
            }
            "k" => o.k = Some(val.parse().with_context(|| format!("bad window k {val:?}"))?),
            "channels" => {
                o.channels =
                    Some(val.parse().with_context(|| format!("bad channel count {val:?}"))?)
            }
            _ => bail!("unknown option {key:?} (fmt=m,e | stride=s | k=K | channels=C)"),
        }
    }
    Ok(o)
}

/// Parse a `.net` descriptor into a [`Pipeline`] builder.  `base` is the
/// directory `dsl <path>` lines resolve against (the descriptor's own
/// directory when loaded via [`load_net`]).
pub fn parse_net(src: &str, base: Option<&Path>) -> Result<Pipeline> {
    let mut p = Pipeline::new();
    let mut stages = 0usize;
    for (i, raw) in src.lines().enumerate() {
        let lno = i + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let head = toks.next().expect("non-empty line has a head token");
        let ctx = || format!("net descriptor line {lno}: `{line}`");
        match head {
            "input" => {
                if stages > 0 {
                    bail!("{}: `input` must come before the first stage", ctx());
                }
                let o = parse_opts(toks).with_context(ctx)?;
                if o.fmt.is_some() || o.stride.is_some() || o.k.is_some() {
                    bail!("{}: `input` takes only channels=C", ctx());
                }
                if let Some(c) = o.channels {
                    p = p.channels(c);
                }
            }
            "relu" => {
                let o = parse_opts(toks).with_context(ctx)?;
                stages += 1;
                p = p.relu();
                if let Some(f) = o.fmt {
                    p = p.format(f);
                }
                if let Some(s) = o.stride {
                    p = p.stride(s);
                }
            }
            "maxpool" => {
                let o = parse_opts(toks).with_context(ctx)?;
                let Some(k) = o.k else {
                    bail!("{}: maxpool needs its window (e.g. maxpool k=2 stride=2)", ctx());
                };
                stages += 1;
                p = p.max_pool(k, o.stride.unwrap_or(k));
                if let Some(f) = o.fmt {
                    p = p.format(f);
                }
            }
            "dsl" => {
                let Some(path) = toks.next() else {
                    bail!("{}: dsl needs a file path (e.g. dsl my_filter.dsl)", ctx());
                };
                let o = parse_opts(toks).with_context(ctx)?;
                let resolved = match base {
                    Some(dir) => dir.join(path),
                    None => Path::new(path).to_path_buf(),
                };
                let dsl_src = std::fs::read_to_string(&resolved).with_context(|| {
                    format!("{}: reading DSL stage {}", ctx(), resolved.display())
                })?;
                let name = Path::new(path)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("dsl_stage")
                    .to_string();
                stages += 1;
                p = p.dsl_named(dsl_src, name);
                if let Some(f) = o.fmt {
                    p = p.format(f);
                }
                if let Some(s) = o.stride {
                    p = p.stride(s);
                }
            }
            _ => {
                let Some(kind) = FilterKind::by_name(head) else {
                    bail!(
                        "{}: unknown stage `{head}` (built-ins: {}; or relu | maxpool k=K | \
                         dsl <path> | input channels=C)",
                        ctx(),
                        FilterKind::ALL
                            .iter()
                            .map(|k| k.name())
                            .collect::<Vec<_>>()
                            .join(" | ")
                    );
                };
                let o = parse_opts(toks).with_context(ctx)?;
                stages += 1;
                p = p.builtin(kind);
                if let Some(f) = o.fmt {
                    p = p.format(f);
                }
                if let Some(s) = o.stride {
                    p = p.stride(s);
                }
            }
        }
    }
    if stages == 0 {
        bail!("net descriptor has no stages");
    }
    Ok(p)
}

/// Load a `.net` descriptor file; `dsl` stage paths resolve relative to
/// the descriptor's directory.
pub fn load_net(path: impl AsRef<Path>) -> Result<Pipeline> {
    let path = path.as_ref();
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading net descriptor {}", path.display()))?;
    parse_net(&src, path.parent())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpcore::OpMode;

    const VGG_ISH: &str = "
# a small VGG-style block
input channels=1
conv3x3 fmt=16,7
relu fmt=16,7
conv3x3 fmt=10,5
relu fmt=10,5
maxpool k=2 stride=2 fmt=10,5
";

    #[test]
    fn vgg_style_stack_parses_and_compiles() {
        let plan = parse_net(VGG_ISH, None).unwrap().compile(OpMode::Exact).unwrap();
        assert_eq!(plan.len(), 5);
        assert_eq!(plan.name(), "conv3x3->relu->conv3x3->relu->maxpool2x2");
        assert!(plan.is_mixed_format());
        // 64x48 -> conv -> conv -> pool/2 => 32x24
        assert_eq!(plan.output_dims(64, 48), (32, 24));
    }

    #[test]
    fn pool_stride_defaults_to_its_window() {
        let plan =
            parse_net("maxpool k=3", None).unwrap().compile(OpMode::Exact).unwrap();
        let g = plan.stages()[0].geom;
        assert_eq!((g.win_h, g.win_w, g.stride), (3, 3, 3));
    }

    #[test]
    fn channels_reach_every_stage() {
        let plan = parse_net("input channels=3\nmedian\nrelu", None)
            .unwrap()
            .compile(OpMode::Exact)
            .unwrap();
        assert!(plan.stages().iter().all(|hw| hw.geom.channels == 3));
    }

    #[test]
    fn errors_carry_the_line_number() {
        let err = parse_net("median\nwarp9000", None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("warp9000"), "{msg}");

        let err = parse_net("conv3x3 fmt=banana", None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 1"), "{msg}");

        let err = parse_net("maxpool stride=2", None).unwrap_err();
        assert!(format!("{err:#}").contains("maxpool k=2"), "{err:#}");

        let err = parse_net("median\ninput channels=2", None).unwrap_err();
        assert!(format!("{err:#}").contains("before the first stage"), "{err:#}");
    }

    #[test]
    fn empty_descriptor_is_an_error() {
        let err = parse_net("# nothing but comments\n\n", None).unwrap_err();
        assert!(err.to_string().contains("no stages"), "{err}");
    }

    #[test]
    fn comments_and_unknown_options_behave() {
        let plan = parse_net("median # trailing comment\n", None)
            .unwrap()
            .compile(OpMode::Exact)
            .unwrap();
        assert_eq!(plan.name(), "median");
        let err = parse_net("median speed=11", None).unwrap_err();
        assert!(format!("{err:#}").contains("unknown option"), "{err:#}");
    }

    #[test]
    fn the_checked_in_example_compiles() {
        let plan = load_net(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/net/vgg_block.net"
        ))
        .unwrap()
        .compile(OpMode::Exact)
        .unwrap();
        assert!(plan.len() >= 3);
        // a strided stack: the output frame is smaller than the input
        let (ow, oh) = plan.output_dims(64, 48);
        assert!(ow < 64 && oh < 48, "{ow}x{oh}");
    }
}
