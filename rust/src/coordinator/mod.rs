//! Legacy streaming orchestrator — now a compatibility layer.
//!
//! The coordinator's six `run_*` entry points (single-filter and chain ×
//! whole-pipeline, streaming, tiled) predate the unified execution API
//! and are kept only as **thin deprecated shims**: each one compiles its
//! filter/chain into a [`crate::pipeline::CompiledPipeline`] and runs it
//! through a [`crate::pipeline::Session`] with the matching
//! [`crate::pipeline::ExecPlan`].  New code should build the plan
//! directly:
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use fpspatial::filters::FilterKind;
//! use fpspatial::fpcore::OpMode;
//! use fpspatial::pipeline::{ExecPlan, Pipeline};
//!
//! let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
//! let mut session = plan.session(ExecPlan::Tiled { workers: 4 })?;
//! # let _ = session;
//! # Ok(())
//! # }
//! ```
//!
//! Because every execution plan is bit-identical, the shims map the old
//! `batched` engine toggle onto the plans' canonical engines (tiled and
//! streaming sessions always run lane-batched); outputs are unchanged
//! bit for bit.  Behavioural notes: sessions pin their frame geometry,
//! so a shim call with a mixed-size frame sequence now reports a usable
//! error instead of silently rebuilding generators mid-stream; an empty
//! (height-0) frame in a streaming sequence is also a usable error now —
//! the old worker panicked on it inside the window generator's band
//! assert (`run_frame_tiled`'s defined h==0 behaviour, returning an
//! empty frame, is preserved); and a `queue_depth` of 0 (a rendezvous
//! channel before) is clamped to the sessions' minimum reorder window
//! of 1.
//!
//! The shims inherit the sessions' supervised runtime for free: worker
//! panics surface as typed `ExecError` values instead of tearing down
//! the channel, and callers who need frame deadlines or overload
//! shedding should migrate to [`crate::pipeline::SessionConfig`] — the
//! legacy entry points always run with the default (block, no deadline)
//! policy.
//!
//! [`synth_sequence`] (the deterministic workload generator used by
//! benches and examples) lives on here undeprecated.

use anyhow::Result;

use crate::filters::{FilterChain, HwFilter};
use crate::fpcore::OpMode;
use crate::pipeline::{CompiledPipeline, ExecPlan, Pipeline};
use crate::video::Frame;

pub use crate::pipeline::Metrics;

/// Configuration of a streaming run (legacy: maps onto
/// [`ExecPlan::Streaming`] with `reorder = queue_depth`).
pub struct PipelineConfig {
    pub workers: usize,
    /// Queue depth between stages (backpressure bound).
    pub queue_depth: usize,
    pub mode: OpMode,
    /// Historical engine toggle — streaming sessions always evaluate
    /// lane-batched; outputs are bit-identical either way.
    pub batched: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 1, queue_depth: 4, mode: OpMode::Exact, batched: false }
    }
}

/// Configuration of an intra-frame tiled run (legacy: maps onto
/// [`ExecPlan::Tiled`]).
#[derive(Debug, Clone)]
pub struct TileConfig {
    pub workers: usize,
    pub mode: OpMode,
    /// Historical engine toggle — tiled sessions always evaluate
    /// lane-batched; outputs are bit-identical either way.
    pub batched: bool,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self { workers: 4, mode: OpMode::Exact, batched: true }
    }
}

/// Single-stage plan for a legacy `&HwFilter` call.
fn filter_plan(filter: &HwFilter, mode: OpMode) -> Result<CompiledPipeline> {
    Pipeline::from_stages([filter.clone()]).compile(mode)
}

/// Plan for a legacy `&FilterChain` call (stages are cloned; engine
/// caches start cold per call — these shims are compatibility paths, not
/// hot paths).
fn chain_plan(chain: &FilterChain, mode: OpMode) -> Result<CompiledPipeline> {
    Pipeline::from_stages(chain.stages().iter().cloned()).compile(mode)
}

/// Run `frames` through `filter` on a worker pool, delivering output
/// frames **in order** to `on_frame`; returns metrics.
#[deprecated(note = "compile a pipeline::Pipeline and use Session::process_sequence \
                     with ExecPlan::Streaming")]
pub fn run_pipeline_streaming(
    filter: &HwFilter,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
    on_frame: impl FnMut(u64, Frame),
) -> Result<Metrics> {
    let plan = filter_plan(filter, cfg.mode)?;
    // queue_depth 0 was a valid rendezvous channel in the old coordinator;
    // sessions need a reorder window of >= 1, so clamp for compatibility
    plan.session(ExecPlan::Streaming { workers: cfg.workers, reorder: cfg.queue_depth.max(1) })?
        .process_sequence(frames, on_frame)
}

/// Run `frames` through `filter` on a worker pool; returns the output
/// frames (in order) and metrics.
#[deprecated(note = "compile a pipeline::Pipeline and use Session::process_sequence \
                     with ExecPlan::Streaming")]
#[allow(deprecated)]
pub fn run_pipeline(
    filter: &HwFilter,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
) -> Result<(Vec<Frame>, Metrics)> {
    let mut outputs = Vec::with_capacity(frames.len());
    let metrics = run_pipeline_streaming(filter, frames, cfg, |_, f| outputs.push(f))?;
    Ok((outputs, metrics))
}

/// Chained [`run_pipeline_streaming`].
#[deprecated(note = "compile the chain stages into a pipeline::Pipeline and use \
                     Session::process_sequence with ExecPlan::Streaming")]
pub fn run_pipeline_chain_streaming(
    chain: &FilterChain,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
    on_frame: impl FnMut(u64, Frame),
) -> Result<Metrics> {
    let plan = chain_plan(chain, cfg.mode)?;
    plan.session(ExecPlan::Streaming { workers: cfg.workers, reorder: cfg.queue_depth.max(1) })?
        .process_sequence(frames, on_frame)
}

/// Chained [`run_pipeline`].
#[deprecated(note = "compile the chain stages into a pipeline::Pipeline and use \
                     Session::process_sequence with ExecPlan::Streaming")]
#[allow(deprecated)]
pub fn run_pipeline_chain(
    chain: &FilterChain,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
) -> Result<(Vec<Frame>, Metrics)> {
    let mut outputs = Vec::with_capacity(frames.len());
    let metrics = run_pipeline_chain_streaming(chain, frames, cfg, |_, f| outputs.push(f))?;
    Ok((outputs, metrics))
}

/// Filter a single frame by sharding it into horizontal row bands, one
/// per worker.  Output is bit-identical to a serial pass.
#[deprecated(note = "compile a pipeline::Pipeline and use a Session with ExecPlan::Tiled")]
pub fn run_frame_tiled(filter: &HwFilter, frame: &Frame, cfg: &TileConfig) -> Frame {
    if frame.height == 0 {
        return Frame::new(frame.width, 0);
    }
    filter_plan(filter, cfg.mode)
        .and_then(|plan| plan.session(ExecPlan::Tiled { workers: cfg.workers })?.process(frame))
        .unwrap_or_else(|e| panic!("run_frame_tiled: {e:#}"))
}

/// Chained [`run_frame_tiled`]: each worker runs the fused chain over its
/// band with the accumulated inter-stage halo.
#[deprecated(note = "compile the chain stages into a pipeline::Pipeline and use a \
                     Session with ExecPlan::Tiled")]
pub fn run_frame_chain_tiled(chain: &FilterChain, frame: &Frame, cfg: &TileConfig) -> Frame {
    if frame.height == 0 {
        return Frame::new(frame.width, 0);
    }
    chain_plan(chain, cfg.mode)
        .and_then(|plan| plan.session(ExecPlan::Tiled { workers: cfg.workers })?.process(frame))
        .unwrap_or_else(|e| panic!("run_frame_chain_tiled: {e:#}"))
}

/// Helper used by examples/benches: synthesize a deterministic frame
/// sequence (a moving test card with noise bursts).
pub fn synth_sequence(width: usize, height: usize, n: usize) -> Vec<Frame> {
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                Frame::salt_pepper(width, height, 0.05, i as u64 + 1)
            } else {
                let base = Frame::test_card(width, height);
                // shift the card horizontally per frame (motion)
                Frame::from_fn(width, height, |x, y| base.get((x + i * 3) % width, y))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    // These tests pin the *shims*: same outputs, same ordering, same
    // metrics shape as before the migration.  The first-class coverage of
    // the execution paths lives in tests/session_reuse.rs and the parity
    // suites.
    #![allow(deprecated)]

    use super::*;
    use crate::filters::FilterKind;
    use crate::fpcore::FloatFormat;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    fn oracle(filter: &HwFilter, frame: &Frame, mode: OpMode) -> Frame {
        filter_plan(filter, mode).unwrap().run_frame_sequential(frame)
    }

    #[test]
    fn pipeline_shim_preserves_order_and_values() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let frames = synth_sequence(32, 24, 8);
        let cfg = PipelineConfig { workers: 3, ..Default::default() };
        let (outs, metrics) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
        assert_eq!(outs.len(), 8);
        assert_eq!(metrics.frames, 8);
        assert!(metrics.p99_latency <= metrics.max_latency);
        for (f, got) in frames.iter().zip(&outs) {
            assert_eq!(got.data, oracle(&hw, f, OpMode::Exact).data);
        }
    }

    #[test]
    fn empty_sequence() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let (outs, m) = run_pipeline(&hw, vec![], &PipelineConfig::default()).unwrap();
        assert!(outs.is_empty());
        assert_eq!(m.frames, 0);
    }

    #[test]
    fn queue_depth_zero_still_runs() {
        // the old coordinator accepted a depth-0 (rendezvous) channel;
        // the shim clamps it onto the sessions' minimum reorder window
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let frames = synth_sequence(24, 18, 4);
        let cfg = PipelineConfig { workers: 2, queue_depth: 0, ..Default::default() };
        let (outs, m) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
        assert_eq!(m.frames, 4);
        for (f, got) in frames.iter().zip(&outs) {
            assert_eq!(got.data, oracle(&hw, f, OpMode::Exact).data);
        }
    }

    #[test]
    fn tiled_shim_bit_identical_to_serial() {
        let f = Frame::test_card(37, 29); // ragged width, uneven bands
        for kind in [FilterKind::Median, FilterKind::Conv5x5] {
            let hw = HwFilter::new(kind, F16).unwrap();
            for mode in [OpMode::Exact, OpMode::Poly] {
                let want = oracle(&hw, &f, mode);
                for workers in [1usize, 3, 64] {
                    for batched in [false, true] {
                        let cfg = TileConfig { workers, mode, batched };
                        let got = run_frame_tiled(&hw, &f, &cfg);
                        assert_eq!(got.data, want.data, "{} {mode:?} {workers}", kind.name());
                    }
                }
            }
        }
    }

    #[test]
    fn tiled_shim_empty_frame() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let out = run_frame_tiled(&hw, &Frame::new(20, 0), &TileConfig::default());
        assert_eq!((out.width, out.height), (20, 0));
    }

    #[test]
    fn chain_shims_bit_identical() {
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::FpSobel, FloatFormat::new(7, 6)).unwrap(),
        ])
        .unwrap();
        let plan = chain_plan(&chain, OpMode::Exact).unwrap();
        let f = Frame::test_card(37, 23);
        let want = plan.run_frame_sequential(&f);
        let cfg = TileConfig { workers: 3, mode: OpMode::Exact, batched: true };
        assert_eq!(run_frame_chain_tiled(&chain, &f, &cfg).data, want.data);

        let frames = synth_sequence(33, 21, 5);
        let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
        let (outs, m) = run_pipeline_chain(&chain, frames.clone(), &cfg).unwrap();
        assert_eq!(m.frames, 5);
        for (f, got) in frames.iter().zip(&outs) {
            assert_eq!(got.data, plan.run_frame_sequential(f).data);
        }
    }

    #[test]
    fn streaming_shim_sink_sees_ordered_sequence() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let frames = synth_sequence(24, 18, 10);
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let mut seqs = Vec::new();
        let m = run_pipeline_streaming(&hw, frames, &cfg, |seq, _| seqs.push(seq)).unwrap();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
        assert_eq!(m.frames, 10);
    }
}
