//! Streaming orchestrator: the Layer-3 runtime that feeds video frames
//! through filter pipelines and reports throughput.
//!
//! Architecture (camera → FPGA → display, §IV mapped onto threads):
//!
//! ```text
//!  source thread ──bounded queue──▶ filter worker(s) ──bounded queue──▶ sink
//! ```
//!
//! Bounded `sync_channel`s model the stream's backpressure: a slow filter
//! stalls the source exactly like a stalled AXI-stream.  Workers are OS
//! threads (the offline crate set has no tokio — DESIGN.md
//! §Substitutions); each worker owns its compiled `Engine`, so scaling
//! workers shards frames round-robin like the paper's per-pixel-clock
//! replication.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::filters::HwFilter;
use crate::fpcore::OpMode;
use crate::sim::Engine;
use crate::video::{Frame, WindowGenerator};

/// A numbered frame travelling through the pipeline.
pub struct Tagged {
    pub seq: u64,
    pub frame: Frame,
    pub submitted: Instant,
}

/// Pipeline throughput/latency report.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub frames: u64,
    pub elapsed: Duration,
    pub mean_latency: Duration,
    pub max_latency: Duration,
}

impl Metrics {
    pub fn fps(&self) -> f64 {
        self.frames as f64 / self.elapsed.as_secs_f64()
    }

    /// Effective pixel rate (active pixels/s).
    pub fn pixel_rate(&self, w: usize, h: usize) -> f64 {
        self.fps() * (w * h) as f64
    }
}

/// Configuration of a streaming run.
pub struct PipelineConfig {
    pub workers: usize,
    /// Queue depth between stages (backpressure bound).
    pub queue_depth: usize,
    pub mode: OpMode,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 1, queue_depth: 4, mode: OpMode::Exact }
    }
}

/// Run `frames` through `filter` on a worker pool; returns the output
/// frames (in order) and metrics.
pub fn run_pipeline(
    filter: &HwFilter,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
) -> Result<(Vec<Frame>, Metrics)> {
    assert!(cfg.workers >= 1);
    let n = frames.len() as u64;
    let t0 = Instant::now();

    // source → workers
    let (src_tx, src_rx) = sync_channel::<Tagged>(cfg.queue_depth);
    // workers → sink
    let (out_tx, out_rx) = sync_channel::<(u64, Frame, Instant)>(cfg.queue_depth);

    let src_rx = SharedReceiver::new(src_rx);
    let mut handles = Vec::new();
    for _ in 0..cfg.workers {
        let rx = src_rx.clone();
        let tx = out_tx.clone();
        let netlist = filter.netlist.clone();
        let ksize = filter.ksize;
        let mode = cfg.mode;
        handles.push(thread::spawn(move || {
            let mut eng = Engine::new(&netlist, mode);
            let mut buf = [0.0f64; 1];
            while let Some(t) = rx.recv() {
                let mut out = Frame::new(t.frame.width, t.frame.height);
                let mut gen = WindowGenerator::new(ksize, t.frame.width);
                gen.process_frame(&t.frame, |x, y, w| {
                    eng.eval_into(w, &mut buf);
                    out.set(x, y, buf[0]);
                });
                if tx.send((t.seq, out, t.submitted)).is_err() {
                    break;
                }
            }
        }));
    }
    drop(out_tx);

    // source thread
    let feeder = thread::spawn(move || {
        for (seq, frame) in frames.into_iter().enumerate() {
            let tag = Tagged { seq: seq as u64, frame, submitted: Instant::now() };
            if src_tx.send(tag).is_err() {
                break;
            }
        }
    });

    // sink: collect in order
    let mut done: Vec<Option<Frame>> = (0..n).map(|_| None).collect();
    let mut total_lat = Duration::ZERO;
    let mut max_lat = Duration::ZERO;
    for (seq, frame, submitted) in out_rx {
        let lat = submitted.elapsed();
        total_lat += lat;
        max_lat = max_lat.max(lat);
        done[seq as usize] = Some(frame);
    }
    feeder.join().ok();
    for h in handles {
        h.join().ok();
    }

    let elapsed = t0.elapsed();
    let outputs: Vec<Frame> = done.into_iter().map(|f| f.expect("missing frame")).collect();
    Ok((
        outputs,
        Metrics {
            frames: n,
            elapsed,
            mean_latency: if n > 0 { total_lat / n as u32 } else { Duration::ZERO },
            max_latency: max_lat,
        },
    ))
}

/// mpsc::Receiver shared by multiple workers (mutex-guarded pop).
struct SharedReceiver<T> {
    inner: std::sync::Arc<std::sync::Mutex<Receiver<T>>>,
}

impl<T> Clone for SharedReceiver<T> {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl<T> SharedReceiver<T> {
    fn new(rx: Receiver<T>) -> Self {
        Self { inner: std::sync::Arc::new(std::sync::Mutex::new(rx)) }
    }

    fn recv(&self) -> Option<T> {
        self.inner.lock().unwrap().recv().ok()
    }
}

/// Helper used by examples/benches: synthesize a deterministic frame
/// sequence (a moving test card with noise bursts).
pub fn synth_sequence(width: usize, height: usize, n: usize) -> Vec<Frame> {
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                Frame::salt_pepper(width, height, 0.05, i as u64 + 1)
            } else {
                let base = Frame::test_card(width, height);
                // shift the card horizontally per frame (motion)
                Frame::from_fn(width, height, |x, y| base.get((x + i * 3) % width, y))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::{FilterKind, HwFilter};
    use crate::fpcore::FloatFormat;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn pipeline_preserves_order_and_values() {
        let hw = HwFilter::new(FilterKind::Median, F16);
        let frames = synth_sequence(32, 24, 8);
        let cfg = PipelineConfig { workers: 3, ..Default::default() };
        let (outs, metrics) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
        assert_eq!(outs.len(), 8);
        assert_eq!(metrics.frames, 8);
        // order + values must match a serial run
        for (f, got) in frames.iter().zip(&outs) {
            let want = hw.run_frame(f, OpMode::Exact);
            assert_eq!(got.data, want.data);
        }
    }

    #[test]
    fn multiworker_not_slower_than_nothing() {
        // smoke: metrics populated, fps positive
        let hw = HwFilter::new(FilterKind::Conv3x3, F16);
        let frames = synth_sequence(48, 32, 6);
        let (_, m) = run_pipeline(&hw, frames, &PipelineConfig::default()).unwrap();
        assert!(m.fps() > 0.0);
        assert!(m.mean_latency > Duration::ZERO);
    }

    #[test]
    fn empty_sequence() {
        let hw = HwFilter::new(FilterKind::Median, F16);
        let (outs, m) = run_pipeline(&hw, vec![], &PipelineConfig::default()).unwrap();
        assert!(outs.is_empty());
        assert_eq!(m.frames, 0);
    }
}
