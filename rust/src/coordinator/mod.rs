//! Streaming orchestrator: the Layer-3 runtime that feeds video frames
//! through filter pipelines and reports throughput.
//!
//! Architecture (camera → FPGA → display, §IV mapped onto threads):
//!
//! ```text
//!  source thread ──bounded queue──▶ filter worker(s) ──bounded queue──▶ sink
//! ```
//!
//! Bounded `sync_channel`s model the stream's backpressure: a slow filter
//! stalls the source exactly like a stalled AXI-stream.  Workers are OS
//! threads (the offline crate set has no tokio — DESIGN.md
//! §Substitutions); each worker owns its compiled engine (scalar
//! [`Engine`] or lane-batched [`BatchEngine`], per
//! [`PipelineConfig::batched`]), so scaling workers shards frames
//! round-robin like the paper's per-pixel-clock replication.
//!
//! Two parallelism axes:
//!
//! * **Inter-frame** ([`run_pipeline`] / [`run_pipeline_streaming`]) —
//!   whole frames fan out to the worker pool.  The sink re-orders
//!   completions through a bounded *reorder window* (completions can only
//!   race ahead by the in-flight budget `workers + queue depths`, so the
//!   window — a small `BTreeMap` — never grows with the sequence length)
//!   and hands frames downstream strictly in order.  Latency is tracked
//!   per frame; [`Metrics`] reports mean, p99 and max.
//! * **Intra-frame** ([`run_frame_tiled`]) — one frame is sharded into
//!   horizontal row bands, one per worker.  Each band is streamed through
//!   its own window generator (`WindowGenerator::process_band` reads the
//!   `p` context rows straight from the source frame, clamped only at
//!   real frame borders), so the stitched output is bit-identical to a
//!   serial pass while a single-frame 1080p workload scales with worker
//!   count instead of only whole-frame round-robin.
//!
//! Both axes also exist for **multi-filter chains**
//! ([`run_pipeline_chain_streaming`] / [`run_frame_chain_tiled`]): each
//! worker owns a fused [`ChainRunner`] (every stage's engine + window
//! generator), frames stream through all stages in one pass, and tiled
//! chain bands read `P = Σ ksizeᵢ/2` context rows — the accumulated
//! inter-stage halo — so the stitched chain output stays bit-identical to
//! sequential full-frame application.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::filters::{eval_band, eval_band_batched, ChainRunner, FilterChain, HwFilter};
use crate::fpcore::OpMode;
use crate::sim::{BatchEngine, Engine, Netlist};
use crate::video::{Frame, WindowGenerator};

/// A worker's compiled engine — scalar or lane-batched behind one
/// band-evaluation call, so the worker/tiling loop bodies exist once.
enum AnyEngine {
    Scalar(Engine),
    Batched(BatchEngine),
}

impl AnyEngine {
    fn new(nl: &Netlist, mode: OpMode, batched: bool) -> Self {
        if batched {
            AnyEngine::Batched(BatchEngine::new(nl, mode))
        } else {
            AnyEngine::Scalar(Engine::new(nl, mode))
        }
    }

    fn eval_band(
        &mut self,
        gen: &mut WindowGenerator,
        frame: &Frame,
        y0: usize,
        y1: usize,
        out_rows: &mut [f64],
    ) {
        match self {
            AnyEngine::Scalar(e) => eval_band(e, gen, frame, y0, y1, out_rows),
            AnyEngine::Batched(e) => eval_band_batched(e, gen, frame, y0, y1, out_rows),
        }
    }
}

/// A numbered frame travelling through the pipeline.
pub struct Tagged {
    pub seq: u64,
    pub frame: Frame,
    pub submitted: Instant,
}

/// Pipeline throughput/latency report.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub frames: u64,
    pub elapsed: Duration,
    pub mean_latency: Duration,
    /// 99th-percentile submit→sink latency.
    pub p99_latency: Duration,
    pub max_latency: Duration,
}

impl Metrics {
    pub fn fps(&self) -> f64 {
        self.frames as f64 / self.elapsed.as_secs_f64()
    }

    /// Effective pixel rate (active pixels/s).
    pub fn pixel_rate(&self, w: usize, h: usize) -> f64 {
        self.fps() * (w * h) as f64
    }
}

/// Configuration of a streaming run.
pub struct PipelineConfig {
    pub workers: usize,
    /// Queue depth between stages (backpressure bound).
    pub queue_depth: usize,
    pub mode: OpMode,
    /// Evaluate with the lane-batched engine (bit-identical, faster).
    pub batched: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 1, queue_depth: 4, mode: OpMode::Exact, batched: false }
    }
}

/// The shared pipeline skeleton: source thread → worker pool → in-order
/// sink with a bounded reorder window.  `make_worker` builds one
/// per-thread evaluator (engines + window generators live thread-local);
/// the single-filter and chained pipelines differ only in that closure.
fn run_pipeline_core<F>(
    make_worker: impl Fn() -> F,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
    mut on_frame: impl FnMut(u64, Frame),
) -> Result<Metrics>
where
    F: FnMut(&Frame) -> Frame + Send,
{
    assert!(cfg.workers >= 1);
    let n = frames.len() as u64;
    let t0 = Instant::now();

    // source → workers
    let (src_tx, src_rx) = sync_channel::<Tagged>(cfg.queue_depth);
    // workers → sink
    let (out_tx, out_rx) = sync_channel::<(u64, Frame, Instant)>(cfg.queue_depth);
    let src_rx = SharedReceiver::new(src_rx);

    let mut lats: Vec<Duration> = Vec::with_capacity(n as usize);
    thread::scope(|s| {
        for _ in 0..cfg.workers {
            let rx = src_rx.clone();
            let tx = out_tx.clone();
            let mut work = make_worker();
            s.spawn(move || {
                while let Some(t) = rx.recv() {
                    let out = work(&t.frame);
                    if tx.send((t.seq, out, t.submitted)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(out_tx);

        // source thread
        s.spawn(move || {
            for (seq, frame) in frames.into_iter().enumerate() {
                let tag = Tagged { seq: seq as u64, frame, submitted: Instant::now() };
                if src_tx.send(tag).is_err() {
                    break;
                }
            }
        });

        // sink (this thread): drain in order through a bounded reorder
        // window instead of buffering the whole sequence.  Latency is
        // stamped at in-order *delivery*, so a frame held in the reorder
        // window behind a slow predecessor is charged that wait.
        let mut pending: BTreeMap<u64, (Frame, Instant)> = BTreeMap::new();
        let mut next_emit = 0u64;
        for (seq, frame, submitted) in out_rx {
            pending.insert(seq, (frame, submitted));
            while let Some((frame, submitted)) = pending.remove(&next_emit) {
                lats.push(submitted.elapsed());
                on_frame(next_emit, frame);
                next_emit += 1;
            }
        }
        debug_assert!(pending.is_empty(), "pipeline dropped a frame");
    });

    let elapsed = t0.elapsed();
    let total_lat: Duration = lats.iter().sum();
    let max_lat = lats.iter().max().copied().unwrap_or(Duration::ZERO);
    lats.sort_unstable();
    Ok(Metrics {
        frames: n,
        elapsed,
        mean_latency: if n > 0 { total_lat / n as u32 } else { Duration::ZERO },
        p99_latency: percentile(&lats, 0.99),
        max_latency: max_lat,
    })
}

/// Run `frames` through `filter` on a worker pool, delivering output
/// frames **in order** to `on_frame` as soon as they clear the reorder
/// window; returns metrics.  Memory stays bounded by the in-flight
/// budget (`workers` + queue depths) — the sink never buffers the whole
/// sequence.
pub fn run_pipeline_streaming(
    filter: &HwFilter,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
    on_frame: impl FnMut(u64, Frame),
) -> Result<Metrics> {
    let netlist = &filter.netlist;
    let ksize = filter.ksize;
    let (mode, batched) = (cfg.mode, cfg.batched);
    run_pipeline_core(
        || {
            let mut gen: Option<WindowGenerator> = None;
            let mut eng = AnyEngine::new(netlist, mode, batched);
            move |frame: &Frame| {
                let mut out = Frame::new(frame.width, frame.height);
                let g = WindowGenerator::reuse(&mut gen, ksize, frame.width)
                    .unwrap_or_else(|e| panic!("pipeline worker: {e}"));
                eng.eval_band(g, frame, 0, frame.height, &mut out.data);
                out
            }
        },
        frames,
        cfg,
        on_frame,
    )
}

/// Run `frames` through `filter` on a worker pool; returns the output
/// frames (in order) and metrics.  Thin collector over
/// [`run_pipeline_streaming`].
pub fn run_pipeline(
    filter: &HwFilter,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
) -> Result<(Vec<Frame>, Metrics)> {
    let mut outputs = Vec::with_capacity(frames.len());
    let metrics = run_pipeline_streaming(filter, frames, cfg, |_, f| outputs.push(f))?;
    Ok((outputs, metrics))
}

/// Chained [`run_pipeline_streaming`]: every worker owns a fused
/// [`ChainRunner`], so each frame passes through all chain stages in one
/// streaming pass (no intermediate frames) and outputs are delivered in
/// order through the same bounded reorder window.
pub fn run_pipeline_chain_streaming(
    chain: &FilterChain,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
    on_frame: impl FnMut(u64, Frame),
) -> Result<Metrics> {
    let (mode, batched) = (cfg.mode, cfg.batched);
    run_pipeline_core(
        || {
            let mut runner = ChainRunner::new(chain, mode, batched);
            move |frame: &Frame| runner.run_frame(frame)
        },
        frames,
        cfg,
        on_frame,
    )
}

/// Chained [`run_pipeline`]: collect the in-order outputs of
/// [`run_pipeline_chain_streaming`].
pub fn run_pipeline_chain(
    chain: &FilterChain,
    frames: Vec<Frame>,
    cfg: &PipelineConfig,
) -> Result<(Vec<Frame>, Metrics)> {
    let mut outputs = Vec::with_capacity(frames.len());
    let metrics = run_pipeline_chain_streaming(chain, frames, cfg, |_, f| outputs.push(f))?;
    Ok((outputs, metrics))
}

/// `q`-th percentile (0..=1) of an ascending-sorted latency list.
fn percentile(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Configuration of an intra-frame tiled run.
#[derive(Debug, Clone)]
pub struct TileConfig {
    pub workers: usize,
    pub mode: OpMode,
    /// Evaluate bands with the lane-batched engine (bit-identical).
    pub batched: bool,
}

impl Default for TileConfig {
    fn default() -> Self {
        Self { workers: 4, mode: OpMode::Exact, batched: true }
    }
}

/// The shared intra-frame tiling skeleton: shard `frame` into horizontal
/// row bands (one per worker, clamped to the row count) and evaluate each
/// band on its own thread with a per-thread evaluator from `make_worker`.
/// The single-filter and chained tiled paths differ only in that closure.
fn run_frame_tiled_core<B>(frame: &Frame, workers: usize, make_worker: impl Fn() -> B) -> Frame
where
    B: FnMut(&Frame, usize, usize, &mut [f64]) + Send,
{
    assert!(workers >= 1);
    let (w, h) = (frame.width, frame.height);
    if h == 0 {
        return Frame::new(w, 0);
    }
    let workers = workers.min(h);
    let band_h = h.div_ceil(workers);
    let mut out = Frame::new(w, h);
    thread::scope(|s| {
        for (i, chunk) in out.data.chunks_mut(band_h * w).enumerate() {
            let y0 = i * band_h;
            let y1 = (y0 + band_h).min(h);
            let mut work = make_worker();
            s.spawn(move || work(frame, y0, y1, chunk));
        }
    });
    out
}

/// Filter a single frame by sharding it into horizontal row bands, one
/// per worker, each streamed through its own engine + window generator.
/// Output is bit-identical to `filter.run_frame` / `run_frame_batched`
/// (the band traversal reads real context rows, so no seams), but a
/// one-frame workload scales with worker count.
pub fn run_frame_tiled(filter: &HwFilter, frame: &Frame, cfg: &TileConfig) -> Frame {
    run_frame_tiled_core(frame, cfg.workers, || {
        let mut gen: Option<WindowGenerator> = None;
        let mut eng = AnyEngine::new(&filter.netlist, cfg.mode, cfg.batched);
        move |frame: &Frame, y0: usize, y1: usize, chunk: &mut [f64]| {
            let g = WindowGenerator::reuse(&mut gen, filter.ksize, frame.width)
                .unwrap_or_else(|e| panic!("tiled worker: {e}"));
            eng.eval_band(g, frame, y0, y1, chunk);
        }
    })
}

/// Chained [`run_frame_tiled`]: filter one frame through a whole
/// [`FilterChain`] by sharding it into horizontal row bands, one fused
/// [`ChainRunner`] per worker.  Each band streams `P = Σ ksizeᵢ/2` extra
/// source rows of context (the accumulated inter-stage halo, clamped at
/// the real frame borders), so the stitched output is bit-identical to
/// [`FilterChain::run_frame`] / sequential full-frame application.
pub fn run_frame_chain_tiled(chain: &FilterChain, frame: &Frame, cfg: &TileConfig) -> Frame {
    run_frame_tiled_core(frame, cfg.workers, || {
        let mut runner = ChainRunner::new(chain, cfg.mode, cfg.batched);
        move |frame: &Frame, y0: usize, y1: usize, chunk: &mut [f64]| {
            runner.run_band(frame, y0, y1, chunk);
        }
    })
}

/// mpsc::Receiver shared by multiple workers (mutex-guarded pop).
struct SharedReceiver<T> {
    inner: std::sync::Arc<std::sync::Mutex<Receiver<T>>>,
}

impl<T> Clone for SharedReceiver<T> {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl<T> SharedReceiver<T> {
    fn new(rx: Receiver<T>) -> Self {
        Self { inner: std::sync::Arc::new(std::sync::Mutex::new(rx)) }
    }

    fn recv(&self) -> Option<T> {
        self.inner.lock().unwrap().recv().ok()
    }
}

/// Helper used by examples/benches: synthesize a deterministic frame
/// sequence (a moving test card with noise bursts).
pub fn synth_sequence(width: usize, height: usize, n: usize) -> Vec<Frame> {
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                Frame::salt_pepper(width, height, 0.05, i as u64 + 1)
            } else {
                let base = Frame::test_card(width, height);
                // shift the card horizontally per frame (motion)
                Frame::from_fn(width, height, |x, y| base.get((x + i * 3) % width, y))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filters::{FilterKind, HwFilter};
    use crate::fpcore::FloatFormat;

    const F16: FloatFormat = FloatFormat::new(10, 5);

    #[test]
    fn pipeline_preserves_order_and_values() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let frames = synth_sequence(32, 24, 8);
        let cfg = PipelineConfig { workers: 3, ..Default::default() };
        let (outs, metrics) = run_pipeline(&hw, frames.clone(), &cfg).unwrap();
        assert_eq!(outs.len(), 8);
        assert_eq!(metrics.frames, 8);
        // order + values must match a serial run
        for (f, got) in frames.iter().zip(&outs) {
            let want = hw.run_frame(f, OpMode::Exact);
            assert_eq!(got.data, want.data);
        }
    }

    #[test]
    fn batched_pipeline_matches_scalar_pipeline() {
        let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap();
        let frames = synth_sequence(33, 21, 6); // ragged width
        let scalar_cfg = PipelineConfig { workers: 2, ..Default::default() };
        let batched_cfg = PipelineConfig { workers: 2, batched: true, ..Default::default() };
        let (a, _) = run_pipeline(&hw, frames.clone(), &scalar_cfg).unwrap();
        let (b, _) = run_pipeline(&hw, frames, &batched_cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn streaming_sink_sees_ordered_sequence() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let frames = synth_sequence(24, 18, 10);
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let mut seqs = Vec::new();
        let m = run_pipeline_streaming(&hw, frames, &cfg, |seq, _| seqs.push(seq)).unwrap();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
        assert_eq!(m.frames, 10);
        assert!(m.p99_latency <= m.max_latency);
        assert!(m.mean_latency <= m.max_latency);
    }

    #[test]
    fn multiworker_not_slower_than_nothing() {
        // smoke: metrics populated, fps positive
        let hw = HwFilter::new(FilterKind::Conv3x3, F16).unwrap();
        let frames = synth_sequence(48, 32, 6);
        let (_, m) = run_pipeline(&hw, frames, &PipelineConfig::default()).unwrap();
        assert!(m.fps() > 0.0);
        assert!(m.mean_latency > Duration::ZERO);
        assert!(m.p99_latency > Duration::ZERO);
    }

    #[test]
    fn empty_sequence() {
        let hw = HwFilter::new(FilterKind::Median, F16).unwrap();
        let (outs, m) = run_pipeline(&hw, vec![], &PipelineConfig::default()).unwrap();
        assert!(outs.is_empty());
        assert_eq!(m.frames, 0);
        assert_eq!(m.p99_latency, Duration::ZERO);
    }

    #[test]
    fn tiled_is_bit_identical_to_serial() {
        let f = Frame::test_card(37, 29); // ragged width, uneven bands
        for kind in [FilterKind::Median, FilterKind::Conv5x5] {
            let hw = HwFilter::new(kind, F16).unwrap();
            for mode in [OpMode::Exact, OpMode::Poly] {
                let want = hw.run_frame(&f, mode);
                for workers in [1usize, 2, 3, 4, 64] {
                    for batched in [false, true] {
                        let cfg = TileConfig { workers, mode, batched };
                        let got = run_frame_tiled(&hw, &f, &cfg);
                        assert_eq!(
                            got.data,
                            want.data,
                            "{} {mode:?} workers={workers} batched={batched}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    fn test_chain() -> FilterChain {
        FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, F16).unwrap(),
            HwFilter::new(FilterKind::FpSobel, F16).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn chain_tiled_bit_identical_to_sequential() {
        let chain = test_chain();
        let f = Frame::test_card(37, 23);
        for mode in [OpMode::Exact, OpMode::Poly] {
            let want = chain.run_frame_sequential(&f, mode);
            for workers in [1usize, 3, 4, 64] {
                for batched in [false, true] {
                    let cfg = TileConfig { workers, mode, batched };
                    let got = run_frame_chain_tiled(&chain, &f, &cfg);
                    assert_eq!(
                        got.data, want.data,
                        "{mode:?} workers={workers} batched={batched}"
                    );
                }
            }
        }
    }

    #[test]
    fn chain_pipeline_ordered_and_bit_identical() {
        let chain = test_chain();
        let frames = synth_sequence(33, 21, 6); // ragged width
        let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
        let (outs, m) = run_pipeline_chain(&chain, frames.clone(), &cfg).unwrap();
        assert_eq!(m.frames, 6);
        for (f, got) in frames.iter().zip(&outs) {
            let want = chain.run_frame_sequential(f, OpMode::Exact);
            assert_eq!(got.data, want.data);
        }
    }

    #[test]
    fn mixed_format_chain_tiled_and_pipelined_bit_identical() {
        // wide denoiser -> narrow edge detector: the boundary converter
        // must survive band tiling (halo rows re-convert identically) and
        // the worker pipeline
        let chain = FilterChain::new(vec![
            HwFilter::new(FilterKind::Median, FloatFormat::new(16, 7)).unwrap(),
            HwFilter::new(FilterKind::FpSobel, FloatFormat::new(10, 5)).unwrap(),
        ])
        .unwrap();
        let f = Frame::test_card(37, 23);
        let want = chain.run_frame_sequential(&f, OpMode::Exact);
        for workers in [1usize, 3, 64] {
            for batched in [false, true] {
                let cfg = TileConfig { workers, mode: OpMode::Exact, batched };
                let got = run_frame_chain_tiled(&chain, &f, &cfg);
                assert_eq!(got.data, want.data, "workers={workers} batched={batched}");
            }
        }
        let frames = synth_sequence(33, 21, 5);
        let cfg = PipelineConfig { workers: 3, batched: true, ..Default::default() };
        let (outs, _) = run_pipeline_chain(&chain, frames.clone(), &cfg).unwrap();
        for (f, got) in frames.iter().zip(&outs) {
            assert_eq!(got.data, chain.run_frame_sequential(f, OpMode::Exact).data);
        }
    }

    #[test]
    fn chain_streaming_sink_in_order() {
        let chain = test_chain();
        let frames = synth_sequence(24, 18, 8);
        let cfg = PipelineConfig { workers: 4, ..Default::default() };
        let mut seqs = Vec::new();
        let m =
            run_pipeline_chain_streaming(&chain, frames, &cfg, |seq, _| seqs.push(seq)).unwrap();
        assert_eq!(seqs, (0..8).collect::<Vec<u64>>());
        assert_eq!(m.frames, 8);
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.99), Duration::ZERO);
        let one = [Duration::from_millis(5)];
        assert_eq!(percentile(&one, 0.99), one[0]);
        let many: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&many, 0.99), Duration::from_millis(99));
        assert_eq!(percentile(&many, 0.5), Duration::from_millis(50));
    }
}
