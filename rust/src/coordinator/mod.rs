//! Workload helpers shared by examples and benches.
//!
//! The coordinator's legacy `run_*` entry points (single-filter and
//! chain × whole-pipeline, streaming, tiled) are gone: the unified
//! execution API replaced them.  Build a
//! [`crate::pipeline::Pipeline`], compile it into a
//! [`crate::pipeline::CompiledPipeline`], and run it through a
//! [`crate::pipeline::Session`] with the matching
//! [`crate::pipeline::ExecPlan`]:
//!
//! ```
//! # fn main() -> anyhow::Result<()> {
//! use fpspatial::filters::FilterKind;
//! use fpspatial::fpcore::OpMode;
//! use fpspatial::pipeline::{ExecPlan, Pipeline};
//!
//! let plan = Pipeline::new().builtin(FilterKind::Median).compile(OpMode::Exact)?;
//! let mut session = plan.session(ExecPlan::Tiled { workers: 4 })?;
//! # let _ = session;
//! # Ok(())
//! # }
//! ```
//!
//! What lives on here: [`synth_sequence`], the deterministic workload
//! generator used by benches and examples, and the [`Metrics`] re-export
//! for callers that imported it from this module.

use crate::video::Frame;

pub use crate::pipeline::Metrics;

/// Helper used by examples/benches: synthesize a deterministic frame
/// sequence (a moving test card with noise bursts).
pub fn synth_sequence(width: usize, height: usize, n: usize) -> Vec<Frame> {
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                Frame::salt_pepper(width, height, 0.05, i as u64 + 1)
            } else {
                let base = Frame::test_card(width, height);
                // shift the card horizontally per frame (motion)
                Frame::from_fn(width, height, |x, y| base.get((x + i * 3) % width, y))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_sequence_is_deterministic_and_sized() {
        let a = synth_sequence(32, 24, 8);
        let b = synth_sequence(32, 24, 8);
        assert_eq!(a.len(), 8);
        for (fa, fb) in a.iter().zip(&b) {
            assert_eq!((fa.width, fa.height), (32, 24));
            assert_eq!(fa.data, fb.data);
        }
        // noise bursts land every 4th frame, so consecutive frames differ
        assert_ne!(a[2].data, a[3].data);
    }
}
